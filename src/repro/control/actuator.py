"""Actuators — where controller decisions touch the (simulated) world.

An :class:`Actuator` applies the :class:`~repro.control.controller.Action`
dataclasses it understands and ignores the rest:

- :class:`FleetActuator` — the rail/VID programmer of a simulated pod.  It
  holds the *applied* per-chip ``(v_core, v_sram)`` (plus straggler boost
  overrides that survive subsequent LUT writes), and after each control
  tick re-evaluates chip power and the steady-state thermal field at the
  applied rails (``settle``), producing the :class:`FleetReadout` the
  telemetry loop feeds back — on real hardware this is the PMBus write plus
  the next TSD readout.
- :class:`EngineActuator` — admission control on the serve engine
  (:class:`Throttle` -> ``engine.admit_cap``).

On CPU there are no rails to program; the state/bookkeeping here is the
deployable part, exactly as ``core.runtime`` frames it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import thermal
from repro.core import tpu_fleet as TF
from repro.control.controller import (Action, BoostRail, Preempt,
                                      RailBackoff, Rebalance, SafeState,
                                      SetRails, Throttle)
from repro.control.telemetry import (ChipTempSample, SafeStateSample,
                                     Sample, Snapshot)


@runtime_checkable
class Actuator(Protocol):
    def apply(self, action: Action) -> bool:
        """Apply one action; return True when handled."""
        ...


@dataclass
class FleetReadout:
    """Power/thermal state of the pod at the applied rails."""
    pod_power_w: float
    nominal_power_w: float
    saving: float
    t_mean: float
    t_max: float


class FleetActuator:
    """Applied-rail state + thermal feedback for a ``TpuFleetSubstrate``.

    Doubles as a :class:`TelemetrySource`: ``poll`` reports the chip
    temperature field of the last ``settle``, closing the loop.
    """

    def __init__(self, substrate, prof: TF.StepProfile, lib: TF.TpuLibrary,
                 t_amb: float = 25.0, planner=None, field=None,
                 write_faults=None, max_retries: int = 3,
                 backoff_us: float = 50.0):
        self.substrate = substrate
        self.prof = prof
        self.lib = lib
        self.planner = planner  # shares the cached nominal-baseline solve
        self.field = field  # RailField with a baseline grid: interpolated
        # nominal reference (used before the exact planner solve when set)
        chips = substrate.n_domains
        self.v_core = np.full(chips, TF.V_CORE_NOM, np.float32)
        self.v_sram = np.full(chips, TF.V_SRAM_NOM, np.float32)
        self.boosted = set()  # chips pinned to boost rails (stragglers)
        self._boost_rails = {}  # chip -> (v_core, v_sram) boost override
        self.rebalance_log: List[Rebalance] = []
        self.backoff_log: List[RailBackoff] = []  # §V SDC rail retreats
        self.util_applied = np.ones(chips, np.float32)  # last settled util
        self.T = np.asarray(substrate.T0({"t_amb": t_amb}))
        self.p_chip = np.zeros(chips, np.float64)  # last settled chip power
        self.readout: Optional[FleetReadout] = None
        self._nominal_cache = {}
        # §9 verify-after-write rail channel: a ControlFaultModel NACKs
        # individual chip writes; bounded exponential-backoff retry, then
        # the chip pins to nominal safe-state rails until cleared
        self.write_faults = write_faults
        self.max_retries = max(int(max_retries), 0)
        self.backoff_us = float(backoff_us)
        self.safe_state: set = set()  # chips pinned at nominal rails
        self.safe_log: List[SafeState] = []
        self.write_retries = 0     # chip-writes retried after a NACK
        self.write_nacks = 0       # NACKed chip-write attempts (cumulative)
        self.backoff_wait_us = 0.0  # total modeled backoff wait
        self._now = 0.0            # control-tick clock for the fault model

    @classmethod
    def from_runtime(cls, rt, t_amb: Optional[float] = None, field=None):
        """Build over an ``EnergyAwareRuntime``'s substrate/profile/lib."""
        return cls(rt.substrate, rt.prof, rt.lib,
                   t_amb=rt.t_amb if t_amb is None else t_amb,
                   planner=rt.planner, field=field)

    # ------------------------------------------------------------------
    def apply(self, action: Action) -> bool:
        if isinstance(action, SetRails):
            # scalar (legacy pod-uniform LUT) or per-chip (RailField /
            # solver plan) rail vectors land the same way
            vc = np.broadcast_to(np.asarray(action.v_core, np.float32),
                                 self.v_core.shape).copy()
            vs = np.broadcast_to(np.asarray(action.v_sram, np.float32),
                                 self.v_sram.shape).copy()
            for c in self.boosted:  # boosts survive field/plan rewrites
                bc, bs = self._boost_rails.get(c,
                                               (TF.V_CORE_NOM, TF.V_SRAM_NOM))
                vc[c] = bc  # each chip keeps ITS boost rails, not
                vs[c] = bs  # a pod-wide nominal pin
            self._program(vc, vs)
            return True
        if isinstance(action, SafeState):
            self._pin_safe(action.chip)
            return True
        if isinstance(action, BoostRail):
            self.boosted.add(action.chip)
            self._boost_rails[action.chip] = (action.v_core, action.v_sram)
            self.v_core[action.chip] = action.v_core
            self.v_sram[action.chip] = action.v_sram
            return True
        if isinstance(action, Rebalance):
            self.rebalance_log.append(action)
            self.boosted.discard(action.chip)
            self._boost_rails.pop(action.chip, None)
            return True
        if isinstance(action, RailBackoff):
            # the raised rails arrive in the same tick's SetRails; log the
            # event (a real PMBus driver would also latch a fault counter)
            self.backoff_log.append(action)
            return True
        return False

    def release_boost(self, chip: int) -> None:
        self.boosted.discard(chip)
        self._boost_rails.pop(chip, None)

    # -- §9 verify-after-write rail channel -----------------------------
    def begin_tick(self, now: float) -> None:
        """Clock the write channel (the fault model windows are in ticks);
        called by the loop before actions land."""
        self._now = float(now)

    def _program(self, vc: np.ndarray, vs: np.ndarray,
                 chips: Optional[np.ndarray] = None) -> None:
        """Land the target rails chip by chip.  Without a fault model this
        is one atomic write (the legacy path, bitwise identical).  With
        one, each chip write is verify-after-write: a NACKed chip retries
        with exponential backoff up to ``max_retries``, then pins to
        nominal safe-state rails until :meth:`clear_safe_state`.

        ``chips`` (global indices) addresses a *slice* of the fleet — a
        per-pod rail channel (``control.fleet``) programs only its own
        chips; ``vc``/``vs`` then align with ``chips``.  ``None`` keeps
        the full-width legacy path untouched."""
        if chips is None:
            n = vc.shape[0]
            for c in self.safe_state:  # pinned chips ignore new targets
                vc[c] = TF.V_CORE_NOM
                vs[c] = TF.V_SRAM_NOM
            if self.write_faults is None:
                self.v_core, self.v_sram = vc, vs
                return
            pending = np.array(
                [c for c in range(n) if c not in self.safe_state], np.int64)
            for c in self.safe_state:
                self.v_core[c] = TF.V_CORE_NOM
                self.v_sram[c] = TF.V_SRAM_NOM
            self._retry_writes(pending, vc, vs, pending.copy())
            return
        chips = np.asarray(chips, np.int64)
        vc = np.asarray(vc, np.float32).copy()
        vs = np.asarray(vs, np.float32).copy()
        safe = np.array([int(c) in self.safe_state for c in chips], bool)
        vc[safe] = TF.V_CORE_NOM
        vs[safe] = TF.V_SRAM_NOM
        if self.write_faults is None:
            self.v_core[chips] = vc
            self.v_sram[chips] = vs
            return
        self.v_core[chips[safe]] = TF.V_CORE_NOM
        self.v_sram[chips[safe]] = TF.V_SRAM_NOM
        # targets indexed per-slice: write through the global chip ids
        pend_local = np.nonzero(~safe)[0].astype(np.int64)
        full_vc = self.v_core.copy()
        full_vs = self.v_sram.copy()
        full_vc[chips] = vc
        full_vs[chips] = vs
        self._retry_writes(chips[pend_local], full_vc, full_vs,
                           chips[pend_local].copy())
        return

    def _retry_writes(self, pending: np.ndarray, vc: np.ndarray,
                      vs: np.ndarray, _orig) -> None:
        """Verify-after-write retry ladder over ``pending`` global chips,
        targets taken from full-width ``vc``/``vs``."""
        delay = self.backoff_us
        for attempt in range(self.max_retries + 1):
            nack = np.asarray(self.write_faults.nack(
                int(pending.size), self._now, attempt), bool)
            acked = pending[~nack]
            self.v_core[acked] = vc[acked]
            self.v_sram[acked] = vs[acked]
            pending = pending[nack]
            if pending.size == 0:
                return
            self.write_nacks += int(pending.size)
            if attempt < self.max_retries:
                self.write_retries += int(pending.size)
                self.backoff_wait_us += delay
                delay *= 2.0
        for c in pending:  # retries exhausted: nominal is the safe state
            self._pin_safe(int(c))

    def _pin_safe(self, chip: int) -> None:
        self.v_core[chip] = TF.V_CORE_NOM
        self.v_sram[chip] = TF.V_SRAM_NOM
        if chip not in self.safe_state:
            self.safe_state.add(chip)
            self.safe_log.append(SafeState(chip=chip, v_core=TF.V_CORE_NOM,
                                           v_sram=TF.V_SRAM_NOM))

    def clear_safe_state(self, chip: int) -> None:
        """Operator/repair path: the chip accepts writes again from the
        next SetRails on."""
        self.safe_state.discard(chip)

    # ------------------------------------------------------------------
    def settle(self, snap: Snapshot,
               util: Optional[np.ndarray] = None) -> FleetReadout:
        """Evaluate power and the steady-state thermal field at the applied
        rails under the sensed ambient (two power<->thermal sweeps from the
        previous field — the quasi-static readout between control ticks).

        ``util`` defaults to the snapshot's own estimate (engine load x
        elastic shares) so the readout reflects the load the rails were
        chosen for; a snapshot without either signal settles at ones."""
        t_amb = snap.t_amb if snap.t_amb is not None else 25.0
        chips = self.substrate.n_domains
        if util is None:
            util = snap.util(chips)
        us = np.asarray(util if util is not None else np.ones(chips),
                        np.float32)
        self.util_applied = us  # SDC telemetry reads the settled load
        m, n = self.substrate.grid
        T = self.T
        for _ in range(2):
            p = np.asarray(TF.chip_power(self.lib, self.prof, self.v_core,
                                         self.v_sram, 1.0, T)) * us
            # warm-start from the applied-rail field: between control ticks
            # the steady state drifts by well under a degree
            T = np.asarray(thermal.solve(p * 1e3, m, n, t_amb,
                                         self.substrate.thermal_cfg, T))
        self.T = T
        self.p_chip = np.asarray(p)  # per-chip power at the applied rails
        pod = float(p.sum())
        p_nom = self._nominal_power(float(t_amb), us)
        self.readout = FleetReadout(
            pod_power_w=pod, nominal_power_w=p_nom,
            saving=1.0 - pod / p_nom if p_nom > 0 else 0.0,
            t_mean=float(T.mean()), t_max=float(T.max()))
        return self.readout

    def _nominal_power(self, t_amb: float, us: np.ndarray) -> float:
        if (self.field is not None
                and float(np.min(us)) >= self.field.u_min
                and self.field.covers_util(us)):
            # interpolated per-chip nominal baseline from the RailField's
            # solved grid — no per-tick nominal fixed point.  Only inside
            # the solved utilization axis: clamping would misreport the
            # reference (e.g. a 0.1-load tick read against the 0.25 slice
            # inflates the saving ~2.5x), so out-of-axis loads fall back
            # to the exact solve below
            p = self.field.nominal_power(t_amb, us)
            if p is not None:
                return float(np.sum(p))
        if self.planner is not None:
            # one definition of "nominal" per environment across the plane:
            # the planner's cached nominal-only fixed point (PlanOut's
            # baseline_power_w reference)
            pb = self.planner.baseline_power(self.planner.env(t_amb, us))
            return float(pb.sum())
        # standalone fallback: relaxation sweeps at nominal rails
        key = (round(t_amb, 3), us.tobytes())
        if key not in self._nominal_cache:
            m, n = self.substrate.grid
            T = np.asarray(self.substrate.T0({"t_amb": t_amb}))
            for _ in range(3):
                p = np.asarray(TF.chip_power(
                    self.lib, self.prof, TF.V_CORE_NOM, TF.V_SRAM_NOM,
                    1.0, T)) * us
                T = np.asarray(thermal.solve(p * 1e3, m, n, t_amb,
                                             self.substrate.thermal_cfg, T))
            self._nominal_cache[key] = float(p.sum())
            if len(self._nominal_cache) > 64:
                self._nominal_cache.pop(next(iter(self._nominal_cache)))
        return self._nominal_cache[key]

    # -- TelemetrySource -------------------------------------------------
    def poll(self, now: float) -> List[Sample]:
        out: List[Sample] = [ChipTempSample(self.T)]
        if self.safe_state:  # planner sees safe-state chips via telemetry
            out.append(SafeStateSample(frozenset(self.safe_state)))
        return out


class EngineActuator:
    """Admission control on a ``serve.Engine`` (Throttle -> admit_cap,
    Preempt -> evict active low-priority slots to the host page pool)."""

    def __init__(self, engine):
        self.engine = engine
        self.log: List[Throttle] = []
        self.preempt_log: List[Preempt] = []

    def apply(self, action: Action) -> bool:
        if isinstance(action, Throttle):
            self.engine.admit_cap = action.admit_cap
            self.log.append(action)
            return True
        if isinstance(action, Preempt):
            self.engine.preempt_to(action.keep_active)
            self.preempt_log.append(action)
            return True
        return False
