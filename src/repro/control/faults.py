"""Control-plane fault injection — the chaos the loop must contain.

"Exceeding Conservative Limits" and the reduced-voltage FPGA studies
(PAPERS.md) document how hardware run past worst-case guard bands actually
fails: thermal sensors go noisy, stuck, or silent in *bursts* (voltage- and
temperature-correlated, not i.i.d.), and rail writes NACK under the same
stress.  :class:`ControlFaultModel` is the seeded generator for exactly
those fault classes; :class:`ChaosTelemetry` applies the sensor-side ones
to any :class:`~repro.control.telemetry.TelemetrySource`.

Design contract (pinned by ``tests/test_control_faults.py``):

- **deterministic** — one seed, per-concern ``numpy`` Generators (sensor
  draws and rail-write NACKs never share a stream, so wrapping an extra
  source cannot shift the write channel's draws); ``reset()`` replays the
  identical fault sequence, which is what keeps ``scenarios.chaos_day``
  fingerprint-pinned.
- **zero at rate 0** — ``ControlFaultModel(rate=0)`` is bitwise identity
  end to end: no sample is touched, no write NACKs, no watchdog events.
  Every golden pin must hold with a rate-0 model attached.
- **windowed** — faults can be confined to tick windows (the sensor storm
  and the NACK burst of ``chaos_day``); outside a window the channel is
  clean.

Fault classes
-------------
Sensor side (drawn per corruptible sample, at most one class fires):

- ``dropout`` — the sample is lost; the bus carries the last-good value
  forward and its age grows (the controller's stale fallback trigger).
- ``spike`` — value off by ``spike_c`` degC: far outside the plausibility
  range, so the bus quarantines it (validity catches it).
- ``stale`` — the previous sample is re-emitted with its *original*
  timestamp: the bus quarantines it by age (freshness catches it).
- ``stuck`` — the value freezes for ``stuck_ticks`` with fresh timestamps:
  undetectable by validity or freshness, absorbed by the controller's
  guard band / watchdog — the honest worst case.

Actuator side: ``nack(n, now, attempt)`` — per-chip rail-write NACKs for
the :class:`~repro.control.actuator.FleetActuator` verify-after-write
retry channel.

Watchdog side (scripted, not drawn — a missed deadline is a property of
the host, not of a sensor): ``deadline_misses`` / ``solver_faults`` are
tick sets the controller's watchdog consumes.
"""
from __future__ import annotations

from typing import FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.control.telemetry import (AmbientSample, ChipTempSample, Sample)

_CLASSES = ("dropout", "spike", "stale", "stuck")


class ControlFaultModel:
    """Seeded generator for sensor, rail-write, and watchdog faults.

    Parameters
    ----------
    rate:
        Master fault probability.  Each sensor class defaults to
        ``rate / 4`` (so ~``rate`` of samples are faulted overall) and the
        rail-write NACK probability defaults to ``rate``; all are
        individually overridable.  ``rate=0`` with no overrides is the
        identity model.
    seed:
        Base seed; per-concern streams derive from it.
    dropout, spike, stale, stuck:
        Per-class sensor fault probabilities (override ``rate / 4``).
    nack:
        Per-chip, per-attempt rail-write NACK probability (override
        ``rate``).
    sensor_window, nack_window:
        Optional ``(start, end)`` tick windows (half-open) outside of which
        the respective channel is clean.
    spike_c:
        Spike magnitude [degC] — large enough that the bus plausibility
        range always rejects it.
    stuck_ticks:
        How many polls a stuck sensor keeps repeating the frozen value.
    deadline_misses, solver_faults:
        Scripted tick sets for the controller watchdog: control ticks whose
        deadline was missed / whose solver fallback diverges.
    """

    def __init__(self, rate: float = 0.0, seed: int = 0, *,
                 dropout: Optional[float] = None,
                 spike: Optional[float] = None,
                 stale: Optional[float] = None,
                 stuck: Optional[float] = None,
                 nack: Optional[float] = None,
                 sensor_window: Optional[Tuple[int, int]] = None,
                 nack_window: Optional[Tuple[int, int]] = None,
                 spike_c: float = 500.0,
                 stuck_ticks: int = 4,
                 deadline_misses: Sequence[int] = (),
                 solver_faults: Sequence[int] = ()):
        self.rate = float(rate)
        self.seed = int(seed)
        self.p = {
            "dropout": self.rate / 4 if dropout is None else float(dropout),
            "spike": self.rate / 4 if spike is None else float(spike),
            "stale": self.rate / 4 if stale is None else float(stale),
            "stuck": self.rate / 4 if stuck is None else float(stuck),
        }
        self.nack_p = self.rate if nack is None else float(nack)
        self.sensor_window = sensor_window
        self.nack_window = nack_window
        self.spike_c = float(spike_c)
        self.stuck_ticks = max(int(stuck_ticks), 1)
        self.deadline_misses: FrozenSet[int] = frozenset(
            int(t) for t in deadline_misses)
        self.solver_faults: FrozenSet[int] = frozenset(
            int(t) for t in solver_faults)
        self.reset()

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Rewind every stream: the next replay sees the identical fault
        sequence (the chaos-day determinism pin)."""
        self._r_sensor = np.random.default_rng((self.seed, 0xC1A05))
        self._r_nack = np.random.default_rng((self.seed, 0x9ACC))

    def for_pod(self, pod: int) -> "ControlFaultModel":
        """A pod-decorrelated clone for ``control.fleet``: identical fault
        classes, rates, windows and scripted ticks, but pod > 0 derives
        its streams from a seed threaded with the pod index, so sibling
        pods do not replay the same fault sequence.  ``for_pod(0)`` keeps
        the base seed — a single-pod fleet draws bitwise the same chaos
        as the flat loop."""
        seed = (self.seed if pod == 0
                else (self.seed + 0x9E3779B97F4A7C15 * int(pod)) % (1 << 63))
        return ControlFaultModel(
            rate=self.rate, seed=seed,
            dropout=self.p["dropout"], spike=self.p["spike"],
            stale=self.p["stale"], stuck=self.p["stuck"],
            nack=self.nack_p,
            sensor_window=self.sensor_window,
            nack_window=self.nack_window,
            spike_c=self.spike_c, stuck_ticks=self.stuck_ticks,
            deadline_misses=self.deadline_misses,
            solver_faults=self.solver_faults)

    @staticmethod
    def _in(window: Optional[Tuple[int, int]], now: float) -> bool:
        return window is None or window[0] <= now < window[1]

    # -- sensor channel -------------------------------------------------
    def sensor_fault(self, now: float) -> Optional[str]:
        """Draw at most one fault class for one corruptible sample (one
        uniform per call — the draw happens even outside the window so the
        stream stays aligned across window edges)."""
        u = float(self._r_sensor.random())
        if not self._in(self.sensor_window, now):
            return None
        lo = 0.0
        for cls in _CLASSES:
            hi = lo + self.p[cls]
            if lo <= u < hi:
                return cls
            lo = hi
        return None

    # -- rail-write channel ---------------------------------------------
    def nack(self, n: int, now: float, attempt: int) -> np.ndarray:
        """Per-chip NACK mask for one write attempt over ``n`` pending
        chips (True = the verify-after-write readback mismatched)."""
        if n <= 0:
            return np.zeros(0, bool)
        draw = self._r_nack.random(n)
        if self.nack_p <= 0.0 or not self._in(self.nack_window, now):
            return np.zeros(n, bool)
        return draw < self.nack_p

    # -- watchdog channel ------------------------------------------------
    def deadline_miss(self, now: float) -> bool:
        return int(now) in self.deadline_misses

    def solver_fault(self, now: float) -> bool:
        return int(now) in self.solver_faults

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"ControlFaultModel(rate={self.rate}, seed={self.seed}, "
                f"nack={self.nack_p}, windows={self.sensor_window}/"
                f"{self.nack_window})")


class ChaosTelemetry:
    """Wrap any :class:`TelemetrySource` and corrupt its temperature
    samples per the fault model.  Non-temperature samples pass through
    untouched; with ``ControlFaultModel(rate=0)`` the wrapper is bitwise
    identity (same objects, same order)."""

    def __init__(self, source, faults: ControlFaultModel):
        self.source = source
        self.faults = faults
        # per-stream (sample class) memory for stale-repeat and stuck-at
        self._last = {}   # class key -> (sample, poll time it arrived)
        self._stuck = {}  # class key -> {"sample": ..., "left": int}

    def poll(self, now: float) -> List[Sample]:
        out: List[Sample] = []
        for smp in self.source.poll(now):
            if isinstance(smp, (AmbientSample, ChipTempSample)):
                out.extend(self._corrupt(smp, now))
            else:
                out.append(smp)
        return out

    # ------------------------------------------------------------------
    @staticmethod
    def _with(smp: Sample, value, stamp) -> Sample:
        if isinstance(smp, AmbientSample):
            return AmbientSample(t_amb=value, stamp=stamp)
        return ChipTempSample(t_chip=value, stamp=stamp)

    @staticmethod
    def _value(smp: Sample):
        return smp.t_amb if isinstance(smp, AmbientSample) else smp.t_chip

    def _corrupt(self, smp: Sample, now: float) -> List[Sample]:
        key = type(smp).__name__
        stuck = self._stuck.get(key)
        if stuck is not None and stuck["left"] > 0:
            # frozen value, fresh timestamp: passes validity AND freshness
            stuck["left"] -= 1
            return [self._with(smp, self._value(stuck["sample"]), None)]
        mode = self.faults.sensor_fault(now)
        if mode == "dropout":
            return []
        if mode == "spike":
            return [self._with(smp, self._value(smp) + self.faults.spike_c,
                               None)]
        if mode == "stale":
            prev = self._last.get(key)
            if prev is not None:
                old, t_old = prev
                return [self._with(old, self._value(old), t_old)]
            # nothing to repeat yet: fall through as a clean sample
        elif mode == "stuck":
            self._stuck[key] = {"sample": smp,
                                "left": self.faults.stuck_ticks - 1}
        self._last[key] = (smp, now)
        return [smp]
