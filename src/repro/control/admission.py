"""Thermal-aware admission control — co-scheduling admissions with rails.

The serve engine admits queued requests whenever a cache slot is free; the
rail plan prices the *power* of the utilization those admissions create.
This module closes the loop between the two (DESIGN.md §8): admission is
itself a thermal actuation, so the admission budget and the rail plan are
decided **jointly**, every control tick, from one snapshot.

Why instantaneous tokens/joule is the wrong objective: pod power has a
large load-independent intercept (leakage, clocks, fabric keepalive), so
the *instantaneous* tokens/joule always improves with more admissions —
a myopic optimizer degenerates to "admit everything", which is exactly the
throughput-only baseline.  The gain the paper's thermal margin buys is
**intertemporal**: a token served at a cool ambient runs on lower rails
(V² power) than the same token at a hot ambient.  When traffic has slack,
deferring marginal admissions from hot ticks to cool ticks serves the same
tokens for fewer joules.

:class:`AdmissionController` prices that arbitrage from the
:class:`~repro.control.lut.RailField`'s per-chip nominal-power grid
(``p_nom``, solved on the same ``ambient x utilization`` knots as the
rails — no extra fixed points at decision time):

- the **marginal power** of the k-th admission at ambient ``t`` is
  ``P(t, u_k) - P(t, u_{k-1})`` with ``u_k = (active + k) / slots``;
- the **reference price** is the same marginal taken at the *cheapest*
  ambient knot the field knows — the best the day will offer;
- the k-th admission is taken while its price is within
  ``defer_premium`` of the reference; past that it is deferred to a
  cooler tick.

Deferral is starvation-bounded by **SLO forcing**: once the queue head has
waited ``max_wait`` engine ticks, the full backlog is admitted regardless
of price — on a day that never cools, every request still runs within its
deadline.  An optional ``min_active`` floor additionally keeps that many
slots busy whenever the queue is non-empty (trading arbitrage for
latency); it defaults to 0 because trickling work through the expensive
window erodes exactly the hot->cool shift the pricing buys.

The chosen budget ``k*`` is emitted as a :class:`~repro.control.controller.
Throttle` (the knob :class:`~repro.control.actuator.EngineActuator`
programs into ``Engine.admit_cap``), and the wrapped
:class:`~repro.control.controller.LutController` is asked for rails at the
**planned** utilization ``u_{k*}`` — the load the pod is about to run, not
the load it sensed — so ``SetRails`` and ``Throttle`` land as one decision.
The inner controller's thermal-emergency throttle remains authoritative:
its cap, when armed, floors ours.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.control.controller import (Action, LutController, Preempt,
                                      Throttle)
from repro.control.telemetry import Snapshot

_EPS = 1e-9


@dataclass
class AdmissionStats:
    priced: int = 0        # control ticks that ran the pricing loop
    granted: int = 0       # cumulative admission budget granted
    deferred: int = 0      # admissions priced out to a cooler tick
    forced: int = 0        # SLO-forced full-backlog admissions
    passthrough: int = 0   # ticks with no pricing signal (no field/p_nom)
    preempts: int = 0      # thermal-emergency Preempt actions emitted


class AdmissionController:
    """Joint admission + rail decisions over a wrapped :class:`LutController`.

    Parameters
    ----------
    inner:
        The rail controller to wrap.  Its ``field.p_nom`` grid is the
        pricing signal; without one (legacy scalar-LUT mode) admission
        degrades gracefully to the throughput-only behavior (no cap).
    defer_premium:
        Admit the k-th request while its marginal power is within this
        factor of the same marginal at the field's cheapest ambient knot.
        ``1.0`` defers anything pricier than the day's best; large values
        never defer (throughput-only).
    max_wait:
        Queue-head age [engine ticks] past which the backlog is admitted
        regardless of price — the SLO guard.
    min_active:
        Keep at least this many slots busy while the queue is non-empty,
        price notwithstanding (0 = pure price + SLO).
    """

    def __init__(self, inner: LutController, defer_premium: float = 1.15,
                 max_wait: float = 64.0, min_active: int = 0,
                 preempt: bool = False):
        self.inner = inner
        self.defer_premium = float(defer_premium)
        self.max_wait = float(max_wait)
        self.min_active = int(min_active)
        # opt-in §9 escalation: while the inner thermal-emergency throttle
        # is armed AND more slots are active than it allows, emit a Preempt
        # evicting the excess low-priority work (admission caps only stop
        # NEW work; a runaway needs active load shed too)
        self.preempt = bool(preempt)
        self.stats = AdmissionStats()
        self._thermal_cap: Optional[int] = None  # inner emergency throttle

    # ------------------------------------------------------------------
    @property
    def field(self):
        return self.inner.field

    def reset(self) -> None:
        """Scenario-replay cold start (stats stay cumulative, like inner)."""
        self.inner.reset()
        self._thermal_cap = None

    # ------------------------------------------------------------------
    def _pod_power(self, t_amb: float, load: float) -> float:
        """Pod nominal power at a load fraction.  Below the field's solved
        utilization axis the table clamps — which would price the first
        admissions of an idle pod at zero — so extend linearly to the
        origin instead (chip power is ~proportional to utilization)."""
        f = self.field
        if load < f.u_min:
            return float(np.sum(f.nominal_power(t_amb, f.u_min))) \
                * (load / f.u_min)
        return float(np.sum(f.nominal_power(t_amb, load)))

    def _budget(self, snap: Snapshot) -> int:
        """Admission budget k*: price each marginal admission against the
        cheapest ambient the field knows; SLO pressure admits everything.

        The slot bound is additionally clipped to the engine's *actual*
        free KV pages (``pages_free``; -1 = page telemetry absent): with
        the paged allocator any free page serves any slot, so the page
        count IS the admission capacity — no fragmentation haircut."""
        slots = snap.slots
        want = min(snap.queued, max(slots - snap.active, 0))
        if snap.pages_free >= 0:
            want = min(want, snap.pages_free)
        if want <= 0:
            return 0
        if snap.oldest_wait >= self.max_wait:
            self.stats.forced += 1
            return want  # SLO guard: the deadline outranks the price
        k = 0
        for i in range(1, want + 1):
            u_prev = (snap.active + i - 1) / slots
            u_next = (snap.active + i) / slots
            m_now = (self._pod_power(snap.t_amb, u_next)
                     - self._pod_power(snap.t_amb, u_prev))
            m_best = min(self._pod_power(float(t), u_next)
                         - self._pod_power(float(t), u_prev)
                         for t in self.field.t)
            if m_best <= _EPS or m_now <= self.defer_premium * m_best + _EPS:
                k = i  # within premium of the day's best price: admit
            else:
                break  # pricier marginals only get worse — defer the rest
        if snap.active + k < self.min_active:
            k = min(want, self.min_active - snap.active)
        self.stats.deferred += want - k
        return k

    # ------------------------------------------------------------------
    def decide(self, snap: Snapshot,
               util: Optional[np.ndarray] = None) -> List[Action]:
        if snap.t_amb is None:
            return self.inner.decide(snap, util=util)
        priced = (snap.slots > 0 and self.field is not None
                  and self.field.p_nom is not None)
        if not priced:
            # no pricing signal: rail decisions pass through unchanged and
            # admission stays uncapped (the throughput-only behavior)
            self.stats.passthrough += 1
            return self.inner.decide(snap, util=util)
        self.stats.priced += 1
        k = self._budget(snap)
        self.stats.granted += k
        # rails are computed at the PLANNED utilization — the load the pod
        # runs once the k admissions land, not the load it sensed
        load = max((snap.active + k) / snap.slots, Snapshot.LOAD_FLOOR)
        shares = (np.asarray(snap.shares, np.float32)
                  if snap.shares is not None
                  else np.ones(self.field.chips, np.float32))
        actions = self.inner.decide(snap, util=shares * np.float32(load))
        # the inner thermal-emergency throttle (transition-emitted) floors
        # our per-tick budget for as long as it stays armed
        kept: List[Action] = []
        for a in actions:
            if isinstance(a, Throttle):
                self._thermal_cap = a.admit_cap
            else:
                kept.append(a)
        cap = k if self._thermal_cap is None else min(k, self._thermal_cap)
        kept.append(Throttle(cap))
        if (self.preempt and self._thermal_cap is not None
                and snap.active > self._thermal_cap):
            self.stats.preempts += 1
            kept.append(Preempt(keep_active=self._thermal_cap))
        return kept
