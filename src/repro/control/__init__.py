"""repro.control — the telemetry -> controller -> actuator control plane.

The paper's §III-B dynamic scheme is an *online* controller: sense ambient,
answer from the precomputed LUT, fall back to the full Algorithm-1 fixed
point only when the fast path can't be trusted.  This package is that loop,
grown production-shaped (DESIGN.md §3):

    sensors ──> TelemetryBus ──> Snapshot ──> Controller ──> Actions
       ^                                          │
       └── FleetActuator.settle (thermal) <───────┘──> EngineActuator

    from repro import control as ctl

    rt = EnergyAwareRuntime(prof, policy="power_save")
    controller = ctl.LutController(rt.planner, sweep=(10.0, 45.0, 8))
    fleet = ctl.FleetActuator.from_runtime(rt)
    loop = ctl.ControlLoop(
        ctl.TelemetryBus([ctl.AmbientSensor(trace), fleet]),
        controller, [fleet])
    report = loop.step(now)

``EnergyAwareRuntime`` (core/runtime.py) is a thin composition over
:class:`FleetPlanner`; its ``plan()``/``dynamic_lut()`` wrappers keep the
pre-refactor golden numbers (tests/test_policy_api.py).
"""
from repro.control.actuator import (Actuator, EngineActuator, FleetActuator,
                                    FleetReadout)
from repro.control.admission import AdmissionController, AdmissionStats
from repro.control.controller import (Action, BoostRail, Controller,
                                      ControllerStats, LutController,
                                      Preempt, RailBackoff, Rebalance,
                                      Restore, SafeState, SetRails, Throttle)
from repro.control.faults import ChaosTelemetry, ControlFaultModel
from repro.control.fleet import (DEGRADED, DRAINED, HEALTHY, QUARANTINED,
                                 FanoutTelemetry, FleetLoop, FleetReport,
                                 PodDomain, PodPlanner, PodRailChannel,
                                 PodTelemetryView, TickContext)
from repro.control.loop import ControlLoop, LoopReport
from repro.control.lut import (DEFAULT_UTIL_KNOTS, DynamicLut, RailField,
                               sweep_points)
from repro.control.planner import FleetPlanner, PlanOut
from repro.control.telemetry import (AmbientSample, AmbientSensor,
                                     ChipTempSample, EngineTelemetry,
                                     HeartbeatSample, MonitorTelemetry,
                                     SafeStateSample, SdcSample, Snapshot,
                                     StepSample, StragglerSample,
                                     TelemetryBus, TelemetrySource,
                                     TickSample, UtilSample)

__all__ = [
    # telemetry
    "TelemetrySource", "TelemetryBus", "Snapshot",
    "AmbientSensor", "EngineTelemetry", "MonitorTelemetry",
    "AmbientSample", "ChipTempSample", "StepSample", "TickSample",
    "UtilSample", "StragglerSample", "HeartbeatSample", "SdcSample",
    "SafeStateSample",
    # fault containment (§9)
    "ControlFaultModel", "ChaosTelemetry",
    # fleet failure domains (§10)
    "FleetLoop", "FleetReport", "PodDomain", "PodRailChannel",
    "PodPlanner", "TickContext", "FanoutTelemetry", "PodTelemetryView",
    "HEALTHY", "DEGRADED", "QUARANTINED", "DRAINED",
    # decisions
    "Controller", "LutController", "ControllerStats",
    "AdmissionController", "AdmissionStats",
    "Action", "SetRails", "BoostRail", "Rebalance", "Throttle",
    "RailBackoff", "Restore", "SafeState", "Preempt",
    # actuation
    "Actuator", "FleetActuator", "EngineActuator", "FleetReadout",
    # planning + loop
    "FleetPlanner", "PlanOut", "DynamicLut", "RailField", "sweep_points",
    "DEFAULT_UTIL_KNOTS", "ControlLoop", "LoopReport",
]
