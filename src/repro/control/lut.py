"""The §III-B dynamic-scheme lookup structures of the control fast path.

Two tiers live here:

- :class:`RailField` — the control plane's primary fast path: a **per-chip,
  two-axis** table of ``(v_core, v_sram)`` rails over an
  ``ambient x utilization`` knot grid, built by ONE batched ``solve_batch``
  call over the 2-D sweep (``FleetPlanner.rail_field``) and **bilinearly
  interpolated** at lookup.  Ambient is a pod-level scalar; utilization may
  be per chip — each chip interpolates the utilization axis at its own
  sensed load, so a load spike rides the fast path instead of forcing a
  ``util_drift`` replan, and every chip gets the solver's spatial rail
  gradient instead of the pod median.
- :class:`DynamicLut` — the legacy scalar facade: the paper's raw
  ``{t_amb: (v_core, v_sram)}`` pod-median table with 1-D linear
  interpolation, clamped at the sweep edges.  ``RailField.median_lut()``
  reduces the 2-D table back to exactly this shape (pod median over chips
  at the full-utilization slice) — golden-pinned in ``tests/test_railfield.
  py`` against the pre-refactor ``dynamic_lut`` build.

Rails fall with ambient (colder -> more margin -> lower rails) and rise with
utilization (hotter chip -> less margin), so linear interpolation between
knots errs on the order of the knot spacing times the rail slope —
``tests/test_railfield.py`` pins the per-chip interp-vs-full-solve error
under one 10 mV rail step across the 2-D sweep interior.
"""
from __future__ import annotations

from typing import Dict, Iterable, Optional, Tuple, Union

import numpy as np

# the canonical default utilization axis — every field builder (planner,
# runtime, controller) references this one constant so their defaults can
# never drift apart
DEFAULT_UTIL_KNOTS = (0.25, 0.5, 0.75, 1.0)


class DynamicLut:
    """Interpolated ``t_amb -> (v_core, v_sram)`` lookup over a solved sweep.

    ``table`` is the raw dict produced by ``dynamic_lut`` /
    ``FleetPlanner.lut``; knots are sorted internally.  Lookups outside
    ``[t_min, t_max]`` clamp to the edge knots (the solver, not the
    interpolant, is the right tool out there — see
    :meth:`covers` and the controller's guard band).
    """

    def __init__(self, table: Dict[float, Tuple[float, float]]):
        if not table:
            raise ValueError("DynamicLut needs at least one solved knot")
        knots = sorted(table.items())
        self.t = np.asarray([k for k, _ in knots], np.float64)
        self.vc = np.asarray([v[0] for _, v in knots], np.float64)
        self.vs = np.asarray([v[1] for _, v in knots], np.float64)

    # ------------------------------------------------------------------
    @property
    def t_min(self) -> float:
        return float(self.t[0])

    @property
    def t_max(self) -> float:
        return float(self.t[-1])

    def covers(self, t_amb: float, margin: float = 0.0) -> bool:
        """True when ``t_amb`` lies within the solved sweep (± margin)."""
        return (self.t_min - margin) <= t_amb <= (self.t_max + margin)

    def lookup(self, t_amb) -> Tuple[float, float]:
        """Interpolated rails at ``t_amb`` (clamped at the sweep edges).

        Accepts a scalar (returns floats) or an array (returns arrays).
        """
        vc = np.interp(t_amb, self.t, self.vc)  # np.interp clamps at edges
        vs = np.interp(t_amb, self.t, self.vs)
        if np.ndim(t_amb) == 0:
            return float(vc), float(vs)
        return vc, vs

    def as_table(self) -> Dict[float, Tuple[float, float]]:
        """The raw knot table (the legacy ``dynamic_lut`` return shape)."""
        return {float(t): (float(c), float(s))
                for t, c, s in zip(self.t, self.vc, self.vs)}

    def __len__(self) -> int:
        return int(self.t.shape[0])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"DynamicLut({len(self)} knots, "
                f"[{self.t_min:.1f}C, {self.t_max:.1f}C])")


class RailField:
    """Per-chip bilinear ``(t_amb, util) -> (v_core, v_sram)`` rail tables.

    ``vc``/``vs`` are ``(K_t, K_u, chips)`` tables solved on the
    ``t_knots x u_knots`` grid (each grid point is one full Algorithm-1
    fixed point at uniform utilization ``u``); ``p_nom`` optionally carries
    the per-chip nominal-baseline power on the same grid so readouts can
    interpolate the nominal reference instead of re-solving it.

    Lookups clamp on both axes.  Below ``u_min`` the clamp is conservative
    (rails solved for a *hotter* pod than sensed); above ``u_max`` it is
    not — the controller treats that as a replan trigger, exactly like an
    ambient excursion past the sweep.
    """

    RAIL_STEP_V = 0.010  # one 10 mV rail step: the per-chip trust contract

    def __init__(self, t_knots, u_knots, vc: np.ndarray, vs: np.ndarray,
                 p_nom: Optional[np.ndarray] = None):
        self.t = np.asarray(t_knots, np.float64)
        self.u = np.asarray(u_knots, np.float64)
        if self.t.ndim != 1 or self.t.size == 0:
            raise ValueError("RailField needs >= 1 ambient knot")
        if self.u.ndim != 1 or self.u.size == 0:
            raise ValueError("RailField needs >= 1 utilization knot")
        if np.any(np.diff(self.t) <= 0) or np.any(np.diff(self.u) <= 0):
            raise ValueError("RailField knots must be strictly increasing")
        shape = (self.t.size, self.u.size)
        self.vc = np.asarray(vc, np.float64)
        self.vs = np.asarray(vs, np.float64)
        if self.vc.shape[:2] != shape or self.vc.shape != self.vs.shape \
                or self.vc.ndim != 3:
            raise ValueError(
                f"rail tables must be (K_t, K_u, chips) = {shape} + (D,); "
                f"got vc {self.vc.shape}, vs {self.vs.shape}")
        self.chips = int(self.vc.shape[2])
        self.p_nom = (None if p_nom is None
                      else np.asarray(p_nom, np.float64))
        if self.p_nom is not None and self.p_nom.shape != self.vc.shape:
            raise ValueError("p_nom must match the rail-table shape")
        # observability: lookups that clamped below the utilization axis
        # (conservative, but an excursion worth counting — ROADMAP item 3)
        self.clamped_below = 0

    def slice_chips(self, lo: int, hi: int) -> "RailField":
        """A pod's view of the fleet field: chip columns ``[lo, hi)`` of
        every table, same knots.  Bilinear lookup interpolates each chip
        independently, so looking up a slice is bitwise what slicing a
        full-fleet lookup would return — the per-pod controllers of
        ``control.fleet`` share ONE ``FleetPlanner.rail_field`` build."""
        if not (0 <= lo < hi <= self.chips):
            raise ValueError(f"chip slice [{lo}, {hi}) outside the fleet's "
                             f"{self.chips} chips")
        return RailField(
            self.t, self.u, self.vc[:, :, lo:hi], self.vs[:, :, lo:hi],
            p_nom=None if self.p_nom is None else self.p_nom[:, :, lo:hi])

    # ------------------------------------------------------------------
    @property
    def t_min(self) -> float:
        return float(self.t[0])

    @property
    def t_max(self) -> float:
        return float(self.t[-1])

    @property
    def u_min(self) -> float:
        return float(self.u[0])

    @property
    def u_max(self) -> float:
        return float(self.u[-1])

    def covers(self, t_amb: float, margin: float = 0.0) -> bool:
        """Ambient-axis coverage (the controller's LUT-range guard)."""
        return (self.t_min - margin) <= t_amb <= (self.t_max + margin)

    def covers_util(self, util, margin: float = 0.0) -> bool:
        """Utilization-axis coverage.  Only the *upper* edge matters for
        trust: below ``u_min`` the clamped lookup is conservative (rails
        solved at higher utilization than sensed)."""
        return bool(np.max(np.asarray(util)) <= self.u_max + margin)

    # ------------------------------------------------------------------
    @staticmethod
    def _axis_weights(knots: np.ndarray, x) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray]:
        """(lo index, hi index, hi weight) of clamped linear interpolation."""
        x = np.clip(np.asarray(x, np.float64), knots[0], knots[-1])
        hi = np.clip(np.searchsorted(knots, x, side="left"), 1,
                     knots.size - 1) if knots.size > 1 else np.zeros_like(
                         x, np.int64)
        lo = hi - 1 if knots.size > 1 else hi
        if knots.size > 1:
            w = (x - knots[lo]) / (knots[hi] - knots[lo])
        else:
            w = np.zeros_like(x)
        return lo, hi, w

    def _interp(self, tables, t_amb: float,
                util: Union[None, float, np.ndarray]):
        """Bilinear per-chip interpolation of (K_t, K_u, chips) tables at
        ``(t_amb, util[c])`` — the one implementation every lookup shares.
        Both axes clamp; ``util`` broadcasts from None (-> u_max) / scalar
        to per chip."""
        ti, tj, tw = self._axis_weights(self.t, float(t_amb))
        u = np.broadcast_to(
            np.asarray(self.u_max if util is None else util, np.float64),
            (self.chips,))
        ui, uj, uw = self._axis_weights(self.u, u)
        c = np.arange(self.chips)
        out = []
        for tab in tables:
            tab_t = (1.0 - tw) * tab[ti] + tw * tab[tj]  # (K_u, chips)
            out.append((1.0 - uw) * tab_t[ui, c] + uw * tab_t[uj, c])
        return out

    def lookup(self, t_amb: float,
               util: Union[None, float, np.ndarray] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-chip rails at ``(t_amb, util)`` -> two ``(chips,)`` arrays.

        ``util`` may be omitted (full utilization), a pod-level scalar, or a
        per-chip ``(chips,)`` array — each chip interpolates the
        utilization axis at its own value (the cross-chip thermal coupling
        of a *non*-uniform load is the guard band's job; the pinned trust
        contract holds on the solved uniform grid).  Both axes clamp; a
        below-axis utilization clamp increments ``clamped_below`` (the
        rails answered are the conservative ``u_min`` slice).
        """
        if (util is not None and np.size(util)
                and float(np.min(np.asarray(util))) < self.u_min - 1e-9):
            self.clamped_below += 1
        vc, vs = self._interp((self.vc, self.vs), t_amb, util)
        return vc, vs

    def nominal_power(self, t_amb: float,
                      util: Union[None, float, np.ndarray] = None
                      ) -> Optional[np.ndarray]:
        """Interpolated per-chip nominal-baseline power [W] (None when the
        field was built without the baseline grid)."""
        if self.p_nom is None:
            return None
        return self._interp((self.p_nom,), t_amb, util)[0]

    # ------------------------------------------------------------------
    def median_lut(self, u: Optional[float] = None) -> DynamicLut:
        """The pod-median 1-D reduction — the legacy §III-B scalar scheme.

        At the full-utilization slice (``u=None`` -> ``u_max``) this
        reproduces ``FleetPlanner.lut`` / ``dynamic_lut`` exactly when the
        slice sits on a solved knot (same fixed points, median over chips)
        — golden-pinned in ``tests/test_railfield.py``.
        """
        k = (int(self.u.size - 1) if u is None
             else int(np.argmin(np.abs(self.u - u))))
        return DynamicLut({
            float(t): (float(np.median(self.vc[i, k])),
                       float(np.median(self.vs[i, k])))
            for i, t in enumerate(self.t)})

    def __len__(self) -> int:
        return int(self.t.size * self.u.size)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"RailField({self.t.size}x{self.u.size} knots x "
                f"{self.chips} chips, [{self.t_min:.1f}C, {self.t_max:.1f}C]"
                f" x [{self.u_min:.2f}, {self.u_max:.2f}] util)")


def sweep_points(lo: float, hi: float, n: int) -> Iterable[float]:
    """Evenly spaced LUT knots over [lo, hi] — convenience for builders."""
    return [float(x) for x in np.linspace(lo, hi, n)]
