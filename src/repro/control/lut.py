"""The §III-B dynamic-scheme LUT as a *lookup function*.

``EnergyAwareRuntime.dynamic_lut`` (and the FPGA ``voltage_scaling.
dynamic_lut``) return the paper's raw ``{t_amb: (v_core, v_sram)}`` table —
one batched ``solve_batch`` call over the ambient sweep.  :class:`DynamicLut`
wraps that table with linear interpolation between knots, clamped at the
sweep edges, so the controller fast path can answer *any* sensed ambient in
O(log K) without touching the solver.

Rails fall with ambient (colder -> more margin -> lower rails), so linear
interpolation between knots errs on the order of the knot spacing times the
rail slope — ``tests/test_control.py`` pins interp-vs-full-solve error under
the controller guard band.
"""
from __future__ import annotations

from typing import Dict, Iterable, Tuple

import numpy as np


class DynamicLut:
    """Interpolated ``t_amb -> (v_core, v_sram)`` lookup over a solved sweep.

    ``table`` is the raw dict produced by ``dynamic_lut`` /
    ``FleetPlanner.lut``; knots are sorted internally.  Lookups outside
    ``[t_min, t_max]`` clamp to the edge knots (the solver, not the
    interpolant, is the right tool out there — see
    :meth:`covers` and the controller's guard band).
    """

    def __init__(self, table: Dict[float, Tuple[float, float]]):
        if not table:
            raise ValueError("DynamicLut needs at least one solved knot")
        knots = sorted(table.items())
        self.t = np.asarray([k for k, _ in knots], np.float64)
        self.vc = np.asarray([v[0] for _, v in knots], np.float64)
        self.vs = np.asarray([v[1] for _, v in knots], np.float64)

    # ------------------------------------------------------------------
    @property
    def t_min(self) -> float:
        return float(self.t[0])

    @property
    def t_max(self) -> float:
        return float(self.t[-1])

    def covers(self, t_amb: float, margin: float = 0.0) -> bool:
        """True when ``t_amb`` lies within the solved sweep (± margin)."""
        return (self.t_min - margin) <= t_amb <= (self.t_max + margin)

    def lookup(self, t_amb) -> Tuple[float, float]:
        """Interpolated rails at ``t_amb`` (clamped at the sweep edges).

        Accepts a scalar (returns floats) or an array (returns arrays).
        """
        vc = np.interp(t_amb, self.t, self.vc)  # np.interp clamps at edges
        vs = np.interp(t_amb, self.t, self.vs)
        if np.ndim(t_amb) == 0:
            return float(vc), float(vs)
        return vc, vs

    def as_table(self) -> Dict[float, Tuple[float, float]]:
        """The raw knot table (the legacy ``dynamic_lut`` return shape)."""
        return {float(t): (float(c), float(s))
                for t, c, s in zip(self.t, self.vc, self.vs)}

    def __len__(self) -> int:
        return int(self.t.shape[0])

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"DynamicLut({len(self)} knots, "
                f"[{self.t_min:.1f}C, {self.t_max:.1f}C])")


def sweep_points(lo: float, hi: float, n: int) -> Iterable[float]:
    """Evenly spaced LUT knots over [lo, hi] — convenience for builders."""
    return [float(x) for x in np.linspace(lo, hi, n)]
