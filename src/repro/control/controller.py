"""Controllers — the decision layer between telemetry and actuation.

A :class:`Controller` maps one telemetry :class:`~repro.control.telemetry.
Snapshot` to a list of :class:`Action` commands.  Actions are plain
dataclasses; every actuator applies the ones it understands and ignores the
rest, so one decision can fan out to the fleet (rails) and the serve engine
(admission) simultaneously.

:class:`LutController` is the paper's §III-B online scheme, upgraded to the
per-chip two-axis fast path:

- **fast path** — the sensed ``(t_amb, util)`` pair is answered from the
  bilinear per-chip :class:`~repro.control.lut.RailField` (no solver):
  quasi-static ambient drift AND load swings both ride the table, and
  every chip gets the solver's spatial rail gradient.  When constructed
  with an explicit scalar :class:`~repro.control.lut.DynamicLut` the
  legacy pod-median ambient-only path is preserved unchanged.
- **slow path** — a full :class:`repro.policy.Solver` fixed point
  (via :class:`~repro.control.planner.FleetPlanner`) when the fast path
  can no longer be trusted: an ambient *jump* beyond ``guard_band_c``
  between ticks (the table is calibrated for quasi-static drift), a
  sensed ambient outside the solved sweep, utilization beyond the solved
  utilization axis (+ ``util_band``; *below* the axis the clamp is
  conservative and stays fast — scalar-LUT mode keeps the legacy
  ``util_drift`` trigger instead), or chip temperature within
  ``t_headroom_c`` of the rated junction limit.  The guard band is
  enforced per chip: the RailField's trust contract (interp within one
  10 mV rail step of the full fixed point) is pinned chip-wise, and the
  thermal triggers act on the per-chip temperature field.
- **straggler policy** — flagged stragglers route through
  ``FleetPlanner.mitigate``: rail-boost while nominal rails can still hold
  the clock at the chip's temperature, rebalance otherwise.
- **admission throttle** — when junction temperature crowds the limit the
  serve engine's admission is capped; the cap lifts once temperature
  drops out of the emergency band.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Union, runtime_checkable

import numpy as np

from repro.core import tpu_fleet as TF
from repro.control.lut import (DEFAULT_UTIL_KNOTS, DynamicLut, RailField,
                               sweep_points)
from repro.control.planner import FleetPlanner, PlanOut
from repro.control.telemetry import Snapshot

# ---------------------------------------------------------------------------
# actions
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SetRails:
    """Program (v_core, v_sram) — scalars (uniform pod) from the LUT fast
    path, or per-chip arrays from a full solver replan."""
    v_core: Union[float, np.ndarray]
    v_sram: Union[float, np.ndarray]
    source: str  # "lut" | "solver"
    plan: Optional[PlanOut] = None  # attached on solver replans


@dataclass(frozen=True)
class BoostRail:
    """Straggler mitigation: pin one chip back to nominal rails."""
    chip: int
    v_core: float
    v_sram: float
    extra_power_w: float


@dataclass(frozen=True)
class Rebalance:
    """Rails alone cannot hold the clock — shed/move work off this chip."""
    chip: int
    reason: str


@dataclass(frozen=True)
class Throttle:
    """Cap serve-engine admissions per tick (None lifts the throttle)."""
    admit_cap: Optional[int]


@dataclass(frozen=True)
class RailBackoff:
    """§V closed loop: the observed escaped-SDC rate exceeded the accuracy
    budget — retreat the below-guard-band rails one 10 mV step (``steps``
    is the cumulative retreat depth).  The adjusted rails ride in the same
    tick's :class:`SetRails`; this action is the observable event the
    actuators log."""
    steps: int
    rate: float
    budget: float


@dataclass(frozen=True)
class Restore:
    """Re-admit a cooled condemned chip: its work share migrates back
    (the ``ElasticWorkAssignment.restore`` actuation)."""
    chip: int


@dataclass(frozen=True)
class SafeState:
    """Pin one chip to nominal safe-state rails.  Originates in the
    :class:`~repro.control.actuator.FleetActuator` write channel when a
    rail write exhausts its retries (observable in ``safe_log``, like
    ``RailBackoff``); applying it by hand force-pins a chip."""
    chip: int
    v_core: float
    v_sram: float
    reason: str = "write_nack"


@dataclass(frozen=True)
class Preempt:
    """Thermal emergency outranks running work: evict active low-priority
    requests until at most ``keep_active`` slots stay busy.  The engine
    moves their KV pages to the host page pool and re-queues them for
    bitwise-identical resumption once the emergency clears."""
    keep_active: int
    reason: str = "thermal_emergency"


Action = Union[SetRails, BoostRail, Rebalance, Throttle, RailBackoff,
               Restore, SafeState, Preempt]


@runtime_checkable
class Controller(Protocol):
    def decide(self, snap: Snapshot) -> List[Action]: ...


# ---------------------------------------------------------------------------
# the §III-B online controller
# ---------------------------------------------------------------------------


@dataclass
class ControllerStats:
    lut_hits: int = 0
    replans: int = 0
    boosts: int = 0
    rebalances: int = 0
    throttles: int = 0
    unmapped: int = 0  # straggler events whose worker maps to no chip
    backoffs: int = 0  # SDC-budget rail retreats (error-tolerant tier)
    restores: int = 0  # cooled condemned chips re-admitted
    replan_reasons: List[str] = field(default_factory=list)
    # §9 fault containment
    quarantined: int = 0       # bus-rejected samples seen (cumulative)
    stale_fallbacks: int = 0   # ticks answered at last-good + guard band
    degraded_ticks: int = 0    # ticks run at watchdog level >= 1
    frozen_ticks: int = 0      # ticks run at watchdog level 2 (frozen)
    safe_states: int = 0       # chips seen entering rail safe state
    below_axis_clamps: int = 0  # fast-path lookups clamped below u_min
    watchdog_events: List[str] = field(default_factory=list)
    recover_ticks: List[float] = field(default_factory=list)  # per episode


class LutController:
    """Batched-table fast path with a guard-banded full-solver fallback.

    The default fast path is a per-chip 2-axis :class:`RailField` (built by
    one early-freeze ``solve_batch`` over the ``sweep x util_sweep`` grid).
    Passing an explicit scalar ``lut=DynamicLut(...)`` selects the legacy
    pod-median ambient-only behavior (the pre-RailField controller,
    preserved as a facade and used as the comparison baseline by
    ``repro.scenarios``).
    """

    DEFAULT_SWEEP = (10.0, 45.0, 8)  # (lo degC, hi degC, knots)

    def __init__(self, planner: FleetPlanner,
                 lut: Optional[DynamicLut] = None,
                 field: Optional[RailField] = None,
                 sweep=None,
                 util_sweep=None,
                 guard_band_c: float = 2.0,
                 util_band: float = 0.25,
                 t_headroom_c: float = 5.0,
                 throttle_cap: int = 1,
                 sdc_budget: Optional[float] = None,
                 sdc_hysteresis: int = 3,
                 backoff_step_v: float = 0.010,
                 restore_after: Optional[int] = None,
                 restore_below_c: float = 70.0,
                 faults=None,
                 stale_after: Optional[float] = 2.0,
                 watchdog_hysteresis: int = 3):
        self.planner = planner
        if field is None and lut is None:
            lo, hi, n = sweep if sweep is not None else self.DEFAULT_SWEEP
            u_knots = (sweep_points(*util_sweep)
                       if util_sweep is not None else DEFAULT_UTIL_KNOTS)
            # ONE early-freeze solve_batch covers the whole 2-D sweep grid
            field = planner.rail_field(sweep_points(lo, hi, n), u_knots)
        self.field = field
        # the scalar facade: explicit legacy mode, or the field's pod-median
        # reduction (kept for introspection / repr / legacy callers)
        self.lut = lut if lut is not None else field.median_lut()
        self.guard_band_c = guard_band_c
        self.util_band = util_band
        self.t_headroom_c = t_headroom_c
        self.throttle_cap = throttle_cap
        # error-tolerant tier (§V): back one rail step off when the sensed
        # escaped-SDC rate exceeds the budget, re-descend one step per
        # clean hysteresis window.  None disables (legacy behavior).
        self.sdc_budget = sdc_budget
        self.sdc_hysteresis = max(int(sdc_hysteresis), 1)
        self.backoff_step_v = backoff_step_v
        # hysteresis-based restore of cooled condemned chips; None disables
        self.restore_after = restore_after
        self.restore_below_c = restore_below_c
        # §9 fault containment: chaos scripting (scripted deadline-miss /
        # solver-fault ticks), stale-sensor fallback bound, and the
        # watchdog's clean-tick de-escalation window
        self.faults = faults
        self.stale_after = stale_after
        self.watchdog_hysteresis = max(int(watchdog_hysteresis), 1)
        self.stats = ControllerStats()
        self.plan: Optional[PlanOut] = None  # last full-solver plan
        self._t_prev: Optional[float] = None
        self._util_planned: Optional[np.ndarray] = None
        self._T_warm = None  # warm start for replans
        self._throttled = False
        self._backoff = 0          # cumulative SDC rail-retreat steps
        self._sdc_clean = 0        # consecutive within-budget ticks
        self._cool: Dict[int, int] = {}  # condemned chip -> cool ticks
        # watchdog ladder: 0 = normal, 1 = fast path only, 2 = frozen
        self._degrade = 0
        self._clean = 0            # consecutive event-free ticks
        self._degrade_since: Optional[float] = None
        self._last_rails = None    # (vc, vs) as last programmed
        self._pending_trips: List[str] = []  # loop-reported deadline misses
        self._safe_seen: set = set()  # safe-state chips already rebalanced

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Forget the online state (the field/luts and compiled solvers
        stay warm): the next tick is a cold start.  Scenario replays call
        this so a reused controller starts every replayed day from the
        same state — stats are NOT cleared (they are cumulative; replays
        report deltas)."""
        self.plan = None
        self._t_prev = None
        self._util_planned = None
        self._T_warm = None
        self._throttled = False
        self._backoff = 0
        self._sdc_clean = 0
        self._cool = {}
        self._degrade = 0
        self._clean = 0
        self._degrade_since = None
        self._last_rails = None
        self._pending_trips = []
        self._safe_seen = set()
        if self.faults is not None:
            self.faults.reset()
        self.planner.T_last = None  # first replan restarts deterministic

    # ------------------------------------------------------------------
    @property
    def watchdog_level(self) -> int:
        """Current watchdog ladder rung: 0 normal, 1 fast-path only,
        2 frozen rails.  The fleet health state machine (``control.fleet``)
        aggregates this per pod."""
        return self._degrade

    # ------------------------------------------------------------------
    def _replan_reason(self, snap: Snapshot,
                       util: Optional[np.ndarray]) -> Optional[str]:
        t = snap.t_amb
        if self._t_prev is None:
            return "cold_start"
        if abs(t - self._t_prev) > self.guard_band_c:
            return f"ambient_jump({t - self._t_prev:+.1f}C)"
        table = self.field if self.field is not None else self.lut
        if not table.covers(t, margin=self.guard_band_c):
            return f"lut_range({t:.1f}C)"
        if util is not None:
            if self.field is not None:
                # load swings ride the utilization axis; only an excursion
                # PAST the solved axis (where the clamp would under-volt
                # nothing and under-protect everything) forces the solver
                if not self.field.covers_util(util, margin=self.util_band):
                    return f"util_range({float(np.max(util)):.2f})"
            else:
                ref = (self._util_planned if self._util_planned is not None
                       else np.ones_like(util))
                if float(np.max(np.abs(util - ref))) > self.util_band:
                    return "util_drift"
        if (snap.t_max is not None
                and snap.t_max > TF.T_MAX_CHIP - self.t_headroom_c):
            return f"thermal_emergency({snap.t_max:.1f}C)"
        return None

    # -- §9 watchdog ----------------------------------------------------
    def note_deadline_miss(self) -> None:
        """Report a missed tick deadline (called by the loop, between
        ticks): the next decision degrades one watchdog level."""
        self._pending_trips.append("deadline_miss")

    def _trip(self, event: str, now: float) -> None:
        if self._degrade == 0:
            self._degrade_since = now
        self._degrade = min(self._degrade + 1, 2)
        self._clean = 0
        self.stats.watchdog_events.append(f"{event}@{now:g}")

    def _fast_rails(self, t_amb: float, util):
        """The interpolated fast path, with the below-axis clamp counted
        (a silent clamp hid sub-``u_min`` load excursions — ROADMAP 3)."""
        if self.field is not None:
            if (util is not None and np.size(util)
                    and float(np.min(np.asarray(util)))
                    < self.field.u_min - 1e-9):
                self.stats.below_axis_clamps += 1
            return self.field.lookup(t_amb, util)
        return self.lut.lookup(t_amb)

    def _plan_ok(self, plan: PlanOut) -> bool:
        """Reject a diverged solver fallback: non-finite or out-of-band
        rails / junction temperature (bounds loose enough that every
        healthy fixed point passes untouched)."""
        vc = np.asarray(plan.v_core, np.float64)
        vs = np.asarray(plan.v_sram, np.float64)
        return bool(np.all(np.isfinite(vc)) and np.all(np.isfinite(vs))
                    and np.all(vc > 0.2) and np.all(vs > 0.2)
                    and np.all(vc <= TF.V_CORE_NOM + 0.1)
                    and np.all(vs <= TF.V_SRAM_NOM + 0.1)
                    and np.isfinite(plan.t_max)
                    and plan.t_max <= TF.T_MAX_CHIP + 40.0)

    def decide(self, snap: Snapshot,
               util: Optional[np.ndarray] = None) -> List[Action]:
        if snap.t_amb is None:
            return []  # nothing sensed yet
        if util is None:
            # serve-engine load x elastic work shares, when telemetry
            # carries them (None otherwise: the legacy ambient-only tick)
            util = snap.util(self.planner.substrate.n_domains)
        actions: List[Action] = []
        self.stats.quarantined += snap.quarantined
        # watchdog events first: this tick's rails already reflect them
        tripped = False
        for ev in self._pending_trips:
            self._trip(ev, snap.now)
            tripped = True
        self._pending_trips = []
        if self.faults is not None and self.faults.deadline_miss(snap.now):
            self._trip("deadline_miss", snap.now)
            tripped = True
        # §V error-tolerant tier: fold the observed escaped-SDC rate into
        # the cumulative back-off depth BEFORE programming rails, so this
        # tick's SetRails already carries the retreat.  One 10 mV step per
        # over-budget tick; one step back down per clean hysteresis window.
        if self.sdc_budget is not None and snap.sdc_checked > 0:
            rate = snap.sdc_escaped / snap.sdc_checked
            if rate > self.sdc_budget:
                self._backoff = min(self._backoff + 1, 20)
                self._sdc_clean = 0
                self.stats.backoffs += 1
                actions.append(RailBackoff(steps=self._backoff, rate=rate,
                                           budget=self.sdc_budget))
            elif self._backoff > 0:
                self._sdc_clean += 1
                if self._sdc_clean >= self.sdc_hysteresis:
                    self._backoff -= 1
                    self._sdc_clean = 0
        # stale-sensor fallback: the bus quarantined / lost the fresh
        # ambient reading, so answer at last-good PLUS the guard band
        # (conservatively hot => conservatively high rails) and never hand
        # a stale value to the solver.
        stale = (self.stale_after is not None
                 and snap.t_amb_age > self.stale_after)
        t_sense = snap.t_amb + (self.guard_band_c if stale else 0.0)
        if stale:
            self.stats.stale_fallbacks += 1
        reason = None
        if self._degrade == 0:
            if not stale:
                reason = self._replan_reason(snap, util)
            elif (snap.t_max is not None
                    and snap.t_max > TF.T_MAX_CHIP - self.t_headroom_c):
                # chip-side thermal emergency outranks sensor staleness
                reason = f"thermal_emergency({snap.t_max:.1f}C)"
        if self._degrade >= 2 and self._last_rails is not None:
            # watchdog level 2: freeze at the last programmed rails (which
            # already carry any SDC back-off — do NOT re-add dv below)
            vc, vs = self._last_rails
            self.stats.frozen_ticks += 1
            self.stats.degraded_ticks += 1
            source, plan_out = "frozen", None
        elif reason is not None:
            faulted = (self.faults is not None
                       and self.faults.solver_fault(snap.now))
            plan = None
            if not faulted:
                plan, T = self.planner.plan_at(snap.t_amb, util,
                                               T0=self._T_warm)
                if not self._plan_ok(plan):
                    faulted = True
            if faulted:
                # solver divergence: trip the watchdog and answer this
                # tick from the fast path instead of programming garbage
                self._trip("solver_divergence", snap.now)
                tripped = True
                vc, vs = self._fast_rails(t_sense, util)
                self.stats.lut_hits += 1
                source, plan_out = "lut", None
            else:
                self._T_warm = T
                self._util_planned = (None if util is None
                                      else np.asarray(util, np.float32))
                self.plan = plan
                self.stats.replans += 1
                self.stats.replan_reasons.append(reason)
                vc, vs = plan.v_core, plan.v_sram
                source, plan_out = "solver", plan
        else:
            vc, vs = self._fast_rails(t_sense, util)
            if self._degrade == 1:
                self.stats.degraded_ticks += 1
            self.stats.lut_hits += 1
            source, plan_out = "lut", None
        if self._backoff > 0 and source != "frozen":
            dv = np.float32(self._backoff * self.backoff_step_v)
            vc = np.minimum(np.asarray(vc, np.float32) + dv,
                            np.float32(TF.V_CORE_NOM))
            vs = np.minimum(np.asarray(vs, np.float32) + dv,
                            np.float32(TF.V_SRAM_NOM))
        actions.append(SetRails(vc, vs, source=source, plan=plan_out))
        self._last_rails = (vc, vs)
        self._t_prev = snap.t_amb

        # straggler policy: boost while nominal rails can hold the clock
        chips = self.planner.substrate.n_domains
        for s in snap.stragglers:
            if not 0 <= s.chip < chips:  # unmappable worker name: no chip
                self.stats.unmapped += 1  # to boost — surface, don't crash
                continue
            if (snap.shares is not None and s.chip < len(snap.shares)
                    and snap.shares[s.chip] <= 0.0):
                continue  # work already migrated off (condemned): a boost
                # would burn power on a draining chip
            T_chip = (float(snap.t_chip[s.chip]) if snap.t_chip is not None
                      else (self.plan.t_max if self.plan else 60.0))
            ref = self.plan or _nominal_plan(self.planner)
            d = self.planner.mitigate(ref, s.chip, T_chip)
            if d["action"] == "boost_rail":
                self.stats.boosts += 1
                actions.append(BoostRail(d["chip"], d["v_core"],
                                         d["v_sram"], d["extra_power_w"]))
            else:
                self.stats.rebalances += 1
                actions.append(Rebalance(d["chip"], d["reason"]))

        # admission throttle on thermal pressure (hysteresis: lift 2C lower)
        if snap.t_max is not None:
            hot = snap.t_max > TF.T_MAX_CHIP - self.t_headroom_c
            cool = snap.t_max < TF.T_MAX_CHIP - self.t_headroom_c - 2.0
            if hot and not self._throttled:
                self._throttled = True
                self.stats.throttles += 1
                actions.append(Throttle(self.throttle_cap))
            elif cool and self._throttled:
                self._throttled = False
                actions.append(Throttle(None))

        # re-admit a condemned chip (share 0) once its junction stays under
        # restore_below_c for restore_after consecutive ticks (cool-down
        # hysteresis: one hot tick resets the counter).  Off by default —
        # legacy replays keep the condemned chip condemned.
        if (self.restore_after is not None and snap.shares is not None
                and snap.t_chip is not None):
            n = min(len(snap.shares), len(snap.t_chip))
            for chip in range(n):
                if snap.shares[chip] > 0.0:
                    self._cool.pop(chip, None)
                    continue
                if float(snap.t_chip[chip]) >= self.restore_below_c:
                    self._cool.pop(chip, None)
                    continue
                ticks = self._cool.get(chip, 0) + 1
                if ticks >= self.restore_after:
                    self._cool.pop(chip, None)
                    self.stats.restores += 1
                    actions.append(Restore(chip))
                else:
                    self._cool[chip] = ticks

        # chips pinned to safe-state rails (rail-write NACK exhaustion):
        # migrate their work once each so the planner rebalances around
        # the nominal-rail island instead of budgeting scaled power for it
        for chip in sorted(snap.safe_state):
            if chip not in self._safe_seen:
                self._safe_seen.add(chip)
                self.stats.safe_states += 1
                self.stats.rebalances += 1
                actions.append(Rebalance(chip, "safe_state_rails"))

        # watchdog hysteresis: one clean-tick window per de-escalation
        # step (mirror of sdc_hysteresis), full recovery closes the
        # episode and records its tick count
        if tripped:
            self._clean = 0
        elif self._degrade > 0:
            self._clean += 1
            if self._clean >= self.watchdog_hysteresis:
                self._degrade -= 1
                self._clean = 0
                if self._degrade == 0 and self._degrade_since is not None:
                    self.stats.recover_ticks.append(
                        float(snap.now - self._degrade_since))
                    self._degrade_since = None
        return actions


def _nominal_plan(planner: FleetPlanner) -> PlanOut:
    """Fallback mitigation reference before any replan has run: nominal
    rails, per-chip nominal busy power (only ``power_w[chip]`` is read)."""
    chips = planner.substrate.n_domains
    p_nom = float(TF.chip_power(planner.lib, planner.prof, TF.V_CORE_NOM,
                                TF.V_SRAM_NOM, 1.0, 60.0))
    return PlanOut(
        v_core=np.full(chips, TF.V_CORE_NOM, np.float32),
        v_sram=np.full(chips, TF.V_SRAM_NOM, np.float32),
        f_rel=np.ones(chips, np.float32),
        power_w=np.full(chips, p_nom, np.float32),
        step_s=planner.prof.step_s, pod_power_w=p_nom * chips,
        baseline_power_w=p_nom * chips, saving=0.0,
        t_mean=60.0, t_max=60.0)
