"""Telemetry — what the control plane *senses* (DESIGN.md §3).

Every producer implements the tiny :class:`TelemetrySource` protocol:
``poll(now) -> [samples]``.  Samples are plain dataclasses; the
:class:`TelemetryBus` folds whatever arrived into one :class:`Snapshot`
per control tick, which is all a :class:`~repro.control.controller.Controller`
ever sees.  Sources are push- or pull-natured as fits the producer:

- :class:`AmbientSensor` — the §III-B thermal sensor (TSD): a trace
  function ``now -> degC`` for simulated diurnal sweeps, step functions,
  or a constant.
- :class:`EngineTelemetry` — subscribes to ``serve.Engine.on_tick`` and
  buffers :class:`TickSample`\\ s (queue depth, active slots, tick wall
  time) until the next poll.
- :class:`MonitorTelemetry` — drains ``ft.monitor.StragglerDetector``
  events (and optionally a ``Heartbeat`` dead-set) so mitigation becomes a
  controller decision instead of a dangling helper.
- :class:`~repro.control.actuator.FleetActuator` is also a source: it
  reports the chip-temperature field of the rails it last applied, closing
  the thermal loop.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import (Callable, Dict, FrozenSet, List, Optional, Protocol,
                    Sequence, Union, runtime_checkable)

import numpy as np

# ---------------------------------------------------------------------------
# samples
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AmbientSample:
    """Ambient (inlet) temperature from the thermal sensor [degC].

    ``stamp`` is the poll time the reading was actually taken (None =
    fresh, i.e. taken at the delivering poll).  A stale-repeat fault
    (``control.faults``) carries the *original* stamp, which is how the
    bus's freshness check catches it."""
    t_amb: float
    stamp: Optional[float] = None


@dataclass(frozen=True)
class ChipTempSample:
    """Per-chip junction temperature field [degC] (from the actuator's
    last thermal evaluation — the simulated TSD readout).  ``stamp`` as in
    :class:`AmbientSample`."""
    t_chip: np.ndarray  # (chips,)
    stamp: Optional[float] = None


@dataclass(frozen=True)
class StepSample:
    """One training/serving step wall time."""
    worker: str
    step: int
    step_s: float


@dataclass(frozen=True)
class TickSample:
    """One serve-engine scheduler tick.  ``slots`` (total cache slots) lets
    the snapshot derive a load fraction — the utilization axis of the
    RailField fast path; 0 means the producer predates the field."""
    tick: int
    queued: int
    active: int
    finished: int
    tokens: int
    tick_s: float
    slots: int = 0
    admitted: int = 0      # requests admitted this tick
    oldest_wait: float = 0.0  # ticks the oldest queued request has waited
    # actual free KV pages (paged allocator free list); -1 = producer
    # predates page telemetry, admission pricing ignores the bound
    pages_free: int = -1


@dataclass(frozen=True)
class UtilSample:
    """Per-chip work shares (1.0 = one chip's fair share; a condemned chip
    reports 0).  Produced by ``ft.elastic.ElasticActuator`` after
    ``Rebalance`` actions migrate work."""
    shares: np.ndarray  # (chips,)


@dataclass(frozen=True)
class StragglerSample:
    """A flagged straggler, mapped to the chip the controller can act on."""
    worker: str
    step: int
    ratio: float
    chip: int


@dataclass(frozen=True)
class HeartbeatSample:
    dead: FrozenSet[str]


@dataclass(frozen=True)
class SafeStateSample:
    """Chips the rail-write channel pinned to nominal safe-state rails
    (retries exhausted) — reported by the :class:`~repro.control.actuator.
    FleetActuator` so the controller can rebalance work around them."""
    chips: FrozenSet[int]


@dataclass(frozen=True)
class SdcSample:
    """One tick's ABFT SDC counters (from ``repro.tolerance.SdcTelemetry``
    or a real checksum-counter readout): detected/corrected/escaped
    injections over ``checked`` MACs of checksummed traffic."""
    detected: int
    corrected: int
    escaped: int
    checked: int


Sample = Union[AmbientSample, ChipTempSample, StepSample, TickSample,
               UtilSample, StragglerSample, HeartbeatSample, SdcSample,
               SafeStateSample]


# ---------------------------------------------------------------------------
# source protocol + snapshot
# ---------------------------------------------------------------------------


@runtime_checkable
class TelemetrySource(Protocol):
    """Anything that can be polled for samples at a control tick."""

    def poll(self, now: float) -> List[Sample]: ...


@dataclass
class Snapshot:
    """Folded telemetry state at one control tick — the controller's whole
    world view.  Scalar fields keep the latest sample; event-like fields
    (stragglers, ticks) hold everything since the previous snapshot."""

    now: float = 0.0
    t_amb: Optional[float] = None
    t_chip: Optional[np.ndarray] = None
    step_s: Optional[float] = None
    queued: int = 0
    active: int = 0
    tokens: int = 0
    tick_s: Optional[float] = None
    slots: int = 0
    admitted: int = 0           # admissions since previous snapshot
    oldest_wait: float = 0.0    # queue-head age [ticks] at latest sample
    pages_free: int = -1        # free KV pages at latest sample (-1 unknown)
    shares: Optional[np.ndarray] = None  # elastic per-chip work shares
    stragglers: List[StragglerSample] = field(default_factory=list)
    dead: FrozenSet[str] = frozenset()
    # sample freshness [ticks since the last ACCEPTED reading]: 0 on a
    # fresh tick, grows under sensor dropout/quarantine, inf before the
    # first reading — the controller's stale-fallback trigger
    t_amb_age: float = 0.0
    t_chip_age: float = 0.0
    quarantined: int = 0  # stale/range-violating samples rejected this tick
    # chips the rail-write channel pinned to nominal (SafeStateSample)
    safe_state: FrozenSet[int] = frozenset()
    # event-like ABFT SDC counters (summed over the tick's samples)
    sdc_detected: int = 0
    sdc_corrected: int = 0
    sdc_escaped: int = 0
    sdc_checked: int = 0

    # an idle pod still clocks (host traffic, refresh, collective keepalive):
    # the sensed load never folds below this floor
    LOAD_FLOOR = 0.1

    @property
    def t_max(self) -> Optional[float]:
        return None if self.t_chip is None else float(np.max(self.t_chip))

    @property
    def sdc_rate(self) -> Optional[float]:
        """Observed escaped-SDC rate per checked MAC this tick; None when
        no checksummed traffic was sensed."""
        if self.sdc_checked <= 0:
            return None
        return self.sdc_escaped / self.sdc_checked

    @property
    def load(self) -> Optional[float]:
        """Serve-engine load fraction (active slots / total), floored at
        :data:`LOAD_FLOOR`; None before any slot-aware tick arrived."""
        if self.slots <= 0:
            return None
        return max(self.active / self.slots, self.LOAD_FLOOR)

    def util(self, chips: int) -> Optional[np.ndarray]:
        """Per-chip utilization estimate for the RailField's second axis:
        elastic work shares scaled by the engine load fraction.  None when
        neither signal has been sensed (legacy ambient-only ticks)."""
        if self.shares is None and self.load is None:
            return None
        shares = (np.asarray(self.shares, np.float32)
                  if self.shares is not None
                  else np.ones(chips, np.float32))
        return (shares * (1.0 if self.load is None else self.load)
                ).astype(np.float32)


class TelemetryBus:
    """Polls every attached source and folds the samples into a Snapshot.

    Scalar state (ambient, chip temps, queue depth) persists across ticks —
    a source that has nothing new simply returns ``[]`` and the last known
    value carries forward; events (stragglers) are delivered exactly once.

    Temperature samples are **validated** before folding (the §9 fault
    containment tier): a reading older than ``max_age`` ticks (per its
    ``stamp``) or outside the plausibility range is *quarantined* — the
    last-good value carries forward and its age keeps growing, which is
    exactly the signal the controller's stale fallback keys on.  Honest
    sources stamp nothing (stamp ``None`` = fresh) and always read
    in-range, so validation is a no-op on a clean day.

    Freshness is tracked **per source** (§10 fleet tier): each accepted
    temperature reading stamps the *source* it came from, and the
    snapshot's ``t_amb_age`` / ``t_chip_age`` describe the provenance of
    the value currently folded (the last writer).  One pod's sensor going
    stale therefore cannot age out a sibling pod's last-good state when
    several pod buses share fan-out sources during a fleet tick.  With a
    single source per temperature kind this is exactly the old global
    horizon.
    """

    # plausibility ranges [degC]: anything outside is a sensor fault, not
    # a reading (chips melt long before 200C; a machine room is not -60C)
    T_AMB_VALID = (-40.0, 80.0)
    T_CHIP_VALID = (-40.0, 200.0)

    def __init__(self, sources: Sequence[TelemetrySource] = (),
                 max_age: Optional[float] = 2.0):
        self.sources: List[TelemetrySource] = list(sources)
        self.max_age = max_age
        self._state = Snapshot()
        # last ACCEPTED reading per *source* (keyed by identity), plus the
        # source whose value is currently folded — its stamp is the age
        self._amb_stamp: Dict[int, float] = {}
        self._chip_stamp: Dict[int, float] = {}
        self._amb_src: Optional[int] = None
        self._chip_src: Optional[int] = None
        self.quarantined_total = 0

    def attach(self, source: TelemetrySource) -> None:
        self.sources.append(source)

    def _valid(self, smp, now: float, rng) -> bool:
        stamp = smp.stamp
        if (self.max_age is not None and stamp is not None
                and now - stamp > self.max_age):
            return False  # stale-repeat: older than the freshness bound
        v = np.asarray(smp.t_chip if isinstance(smp, ChipTempSample)
                       else smp.t_amb, np.float64)
        return bool(np.all(np.isfinite(v))
                    and np.all(v >= rng[0]) and np.all(v <= rng[1]))

    def poll(self, now: float) -> Snapshot:
        s = self._state
        s.now = now
        s.stragglers = []
        s.tokens = 0
        s.admitted = 0
        s.quarantined = 0
        s.sdc_detected = s.sdc_corrected = 0
        s.sdc_escaped = s.sdc_checked = 0
        for src in self.sources:
            for smp in src.poll(now):
                if isinstance(smp, AmbientSample):
                    if not self._valid(smp, now, self.T_AMB_VALID):
                        s.quarantined += 1
                        continue
                    s.t_amb = float(smp.t_amb)
                    self._amb_stamp[id(src)] = now
                    self._amb_src = id(src)
                elif isinstance(smp, ChipTempSample):
                    if not self._valid(smp, now, self.T_CHIP_VALID):
                        s.quarantined += 1
                        continue
                    s.t_chip = np.asarray(smp.t_chip)
                    self._chip_stamp[id(src)] = now
                    self._chip_src = id(src)
                elif isinstance(smp, SafeStateSample):
                    s.safe_state = smp.chips
                elif isinstance(smp, StepSample):
                    s.step_s = float(smp.step_s)
                elif isinstance(smp, TickSample):
                    s.queued, s.active = smp.queued, smp.active
                    s.tokens += smp.tokens
                    s.admitted += smp.admitted
                    s.oldest_wait = smp.oldest_wait
                    s.tick_s = smp.tick_s
                    if smp.slots:
                        s.slots = smp.slots
                    if smp.pages_free >= 0:
                        s.pages_free = smp.pages_free
                elif isinstance(smp, UtilSample):
                    s.shares = np.asarray(smp.shares, np.float32)
                elif isinstance(smp, StragglerSample):
                    s.stragglers.append(smp)
                elif isinstance(smp, HeartbeatSample):
                    s.dead = smp.dead
                elif isinstance(smp, SdcSample):
                    s.sdc_detected += smp.detected
                    s.sdc_corrected += smp.corrected
                    s.sdc_escaped += smp.escaped
                    s.sdc_checked += smp.checked
        self.quarantined_total += s.quarantined
        s.t_amb_age = (float("inf") if self._amb_src is None
                       else now - self._amb_stamp[self._amb_src])
        s.t_chip_age = (float("inf") if self._chip_src is None
                        else now - self._chip_stamp[self._chip_src])
        # hand the controller a stable copy; persistent state keeps arrays
        return Snapshot(now=s.now, t_amb=s.t_amb, t_chip=s.t_chip,
                        step_s=s.step_s, queued=s.queued, active=s.active,
                        tokens=s.tokens, tick_s=s.tick_s, slots=s.slots,
                        admitted=s.admitted, oldest_wait=s.oldest_wait,
                        pages_free=s.pages_free, shares=s.shares,
                        stragglers=list(s.stragglers), dead=s.dead,
                        t_amb_age=s.t_amb_age, t_chip_age=s.t_chip_age,
                        quarantined=s.quarantined, safe_state=s.safe_state,
                        sdc_detected=s.sdc_detected,
                        sdc_corrected=s.sdc_corrected,
                        sdc_escaped=s.sdc_escaped,
                        sdc_checked=s.sdc_checked)


# ---------------------------------------------------------------------------
# concrete sources
# ---------------------------------------------------------------------------


class AmbientSensor:
    """Simulated TSD: ``trace`` is a constant or a ``now -> degC`` callable
    (diurnal sine, step change, replayed datacenter trace)."""

    def __init__(self, trace: Union[float, Callable[[float], float]]):
        self.trace = trace

    def poll(self, now: float) -> List[Sample]:
        t = self.trace(now) if callable(self.trace) else self.trace
        return [AmbientSample(float(t))]


class EngineTelemetry:
    """Buffers serve-engine tick stats; attach with
    ``engine.on_tick.append(src.on_tick)``."""

    def __init__(self) -> None:
        self._buf: List[Sample] = []

    def on_tick(self, smp: TickSample) -> None:
        self._buf.append(smp)

    def poll(self, now: float) -> List[Sample]:
        out, self._buf = self._buf, []
        return out


def _default_chip_of(worker: str) -> int:
    m = re.search(r"(\d+)$", worker)  # trailing rank: "host1-worker7" -> 7
    return int(m.group(1)) if m else 0


class MonitorTelemetry:
    """Drains ``StragglerDetector.events`` (exactly once each) and reports
    the ``Heartbeat`` dead-set; ``chip_of`` maps worker names to the chip
    index the actuator can boost.

    Pass ``topology`` (a :class:`repro.launch.mesh.PodTopology`) for the
    real rank -> pod-coordinate mapping with validation: non-numeric worker
    names and ranks beyond the pod map to ``-1`` (the controller counts
    them as ``unmapped`` instead of boosting a phantom chip 0 / crashing on
    an out-of-range index).  The bare trailing-digit parser remains the
    legacy default when neither ``topology`` nor ``chip_of`` is given.
    """

    def __init__(self, detector, heartbeat=None,
                 chip_of: Optional[Callable[[str], int]] = None,
                 topology=None):
        self.detector = detector
        self.heartbeat = heartbeat
        if chip_of is None:
            chip_of = (topology.chip_of if topology is not None
                       else _default_chip_of)
        self.chip_of = chip_of
        self._seen = len(detector.events)

    def record_step(self, worker: str, step: int, step_s: float):
        """Convenience passthrough so callers feed one object."""
        return self.detector.record(worker, step, step_s)

    def poll(self, now: float) -> List[Sample]:
        out: List[Sample] = []
        new = self.detector.events[self._seen:]
        self._seen = len(self.detector.events)
        for ev in new:
            out.append(StragglerSample(ev.worker, ev.step, ev.ratio,
                                       self.chip_of(ev.worker)))
        if self.heartbeat is not None:
            out.append(HeartbeatSample(frozenset(self.heartbeat.dead())))
        return out
