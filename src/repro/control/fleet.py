"""control.fleet — hierarchical multi-pod control with failure domains.

The control plane so far drives ONE pod: a :class:`~repro.control.loop.
ControlLoop` over one :class:`~repro.control.controller.LutController` and
one :class:`~repro.control.actuator.FleetActuator`.  ``launch.mesh`` maps
512+-chip multi-pod fleets; this module scales the loop to match without
giving up the single-pod bitwise guarantees (DESIGN.md §10):

hierarchy (the VolTune split, one level up)
    One **global planner** (the shared :class:`~repro.control.planner.
    FleetPlanner` plus this module's power budgeting) over N **per-pod
    fast loops**.  Each pod owns a :class:`~repro.control.controller.
    LutController` whose :class:`~repro.control.lut.RailField` is a
    ``slice_chips`` view of ONE fleet-wide field build, a
    :class:`PodRailChannel` addressing only its chip slice of the shared
    rail actuator, and its own :class:`~repro.control.telemetry.
    TelemetryBus` fed by :class:`FanoutTelemetry` slices of the shared
    sources plus its own ambient sensor.

failure domains
    A pod is the containment unit.  Per-pod watchdog ladders escalate
    independently (one pod's solver divergence never freezes a sibling's
    rails); the fleet-level health machine aggregates each pod's fault
    signals into ``healthy -> degraded -> quarantined -> drained`` and
    back.  Quarantine freezes the pod's rails at nominal safe state,
    migrates its work share to the survivors (``ElasticWorkAssignment``),
    and live-migrates its in-flight serve requests through the shared
    :class:`~repro.serve.cache.HostPagePool` — page-exact eviction, so a
    request resumed on a healthy pod decodes bitwise what it would have
    decoded at home.  A drained pod re-joins through the same cool-down
    hysteresis the chip-level restore path uses.

asynchrony
    :class:`PodRailChannel` double-buffers rail writes when
    ``write_latency_s > 0``: a ``SetRails`` staged this tick lands at the
    next tick's ``begin_tick`` (modeled PMBus write latency), so a replan
    in one pod overlaps decode everywhere else and a wedged pod cannot
    stall its siblings — the fleet tick never blocks on a pod's channel.

degenerate guarantee (pinned in ``tests/test_fleet.py``)
    With ``n_pods=1`` every phase of :meth:`FleetLoop.step` reduces to the
    exact call sequence of ``ControlLoop.step`` — same polls, same
    ``decide``, same ``FleetActuator.apply``/``settle`` — so the single-pod
    fleet replays ``diurnal_load_spike`` and ``chaos_day`` bitwise
    identical to the flat loop.
"""
from __future__ import annotations

import inspect
import time
from dataclasses import dataclass, field as dc_field, replace
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import tpu_fleet as TF
from repro.control.controller import (Action, BoostRail, Rebalance, Restore,
                                      SafeState, SetRails)
from repro.control.loop import LoopReport
from repro.control.lut import DEFAULT_UTIL_KNOTS
from repro.control.planner import PlanOut
from repro.control.telemetry import (ChipTempSample, SafeStateSample, Sample,
                                     SdcSample, Snapshot, StragglerSample,
                                     TelemetryBus, UtilSample)

# pod health states (the §10 containment ladder)
HEALTHY = "healthy"
DEGRADED = "degraded"
QUARANTINED = "quarantined"
DRAINED = "drained"

_UNSET = object()  # PodRailChannel: "inherit the actuator's fault model"


# ---------------------------------------------------------------------------
# per-pod rail write channel
# ---------------------------------------------------------------------------


class PodRailChannel:
    """One pod's rail write channel over the shared :class:`FleetActuator`.

    Translates slice-width ``SetRails`` (the pod controller plans only its
    own chips) into writes on the fleet actuator's ``[lo, hi)`` chip slice,
    preserving straggler boost overrides and safe-state pins exactly like
    the full-width legacy path.  A channel covering the whole fleet
    (``full``) delegates to ``FleetActuator.apply`` verbatim — the
    single-pod degenerate case is bitwise the flat loop.

    ``write_latency_s > 0`` arms the double buffer: ``apply`` stages the
    write (latest wins) and ``begin_tick`` commits it once the modeled
    PMBus latency has elapsed, so one pod's in-flight write never serializes
    against a sibling's tick.

    ``write_faults`` (default: inherit) swaps the actuator's NACK model for
    this slice's writes only — chaos confined to one pod's rail channel.
    """

    def __init__(self, fleet, lo: int, hi: int,
                 write_latency_s: float = 0.0, write_faults=_UNSET):
        self.fleet = fleet
        self.lo, self.hi = int(lo), int(hi)
        if not 0 <= self.lo < self.hi <= fleet.substrate.n_domains:
            raise ValueError(f"chip slice [{lo}, {hi}) outside the fleet's "
                             f"{fleet.substrate.n_domains} chips")
        self.write_latency_s = float(write_latency_s)
        self.write_faults = write_faults
        self._now = 0.0
        self._staged = None  # (SetRails, staged_at)
        self.staged_commits = 0

    @property
    def width(self) -> int:
        return self.hi - self.lo

    @property
    def full(self) -> bool:
        return self.lo == 0 and self.hi == self.fleet.substrate.n_domains

    # ------------------------------------------------------------------
    def begin_tick(self, now: float) -> None:
        # commit the back buffer BEFORE adopting the new tick time: a write
        # staged at tick t lands at the first tick >= t + latency, clocked
        # as a write of THIS tick (the fault windows see the landing time)
        if (self._staged is not None
                and now - self._staged[1] >= self.write_latency_s):
            action, _ = self._staged
            self._staged = None
            self.staged_commits += 1
            self._land(action)
        self._now = float(now)

    def apply(self, action: Action) -> bool:
        if isinstance(action, SetRails):
            if self.write_latency_s > 0.0:
                self._staged = (action, self._now)  # latest write wins
                return True
            self._land(action)
            return True
        # chip-carrying actions arrive fleet-globalized (FleetLoop); the
        # shared actuator applies the ones it understands
        return self.fleet.apply(action)

    def _land(self, action: SetRails) -> None:
        swap = (self.write_faults is not _UNSET
                and self.write_faults is not self.fleet.write_faults)
        if swap:
            prev = self.fleet.write_faults
            self.fleet.write_faults = self.write_faults
        try:
            if self.full:
                self.fleet.apply(action)  # legacy full-width path, bitwise
                return
            vc = np.broadcast_to(np.asarray(action.v_core, np.float32),
                                 (self.width,)).copy()
            vs = np.broadcast_to(np.asarray(action.v_sram, np.float32),
                                 (self.width,)).copy()
            for c in self.fleet.boosted:  # boosts survive field rewrites
                if self.lo <= c < self.hi:
                    bc, bs = self.fleet._boost_rails.get(
                        c, (TF.V_CORE_NOM, TF.V_SRAM_NOM))
                    vc[c - self.lo] = bc
                    vs[c - self.lo] = bs
            self.fleet._program(vc, vs,
                                chips=np.arange(self.lo, self.hi))
        finally:
            if swap:
                self.fleet.write_faults = prev

    def freeze_safe(self) -> None:
        """Quarantine containment: drop any staged write and pin every
        chip of the slice to nominal safe-state rails until restore."""
        self._staged = None
        for c in range(self.lo, self.hi):
            self.fleet._pin_safe(c)


# ---------------------------------------------------------------------------
# per-pod planner view over the shared FleetPlanner
# ---------------------------------------------------------------------------


class TickContext:
    """Per-fleet-tick shared state: the assembled fleet utilization and
    the replan memo every :class:`PodPlanner` consults.  Cleared by
    :meth:`FleetLoop.step` at the top of each tick."""

    def __init__(self):
        self.util: Optional[np.ndarray] = None
        self.memo: Dict = {}

    def clear(self) -> None:
        self.util = None
        self.memo.clear()


class _PodSubstrate:
    """Duck-typed substrate view: ``n_domains`` is the pod width (all the
    controller reads); everything else passes through to the fleet."""

    def __init__(self, inner, width: int):
        self._inner = inner
        self.n_domains = int(width)

    def __getattr__(self, name):
        return getattr(self._inner, name)


class PodPlanner:
    """One pod's planner facade over the shared :class:`FleetPlanner`.

    The controller talks to a planner sized like its pod
    (``substrate.n_domains == hi - lo``); replans go through the FULL
    fleet solve — a pod cannot plan its slice in isolation, the thermal
    field couples every chip — with this pod's sensed utilization embedded
    into the tick's assembled fleet utilization (:class:`TickContext`).
    Solves are memoized per ``(t_amb, util)`` within a tick, so all pods
    replanning at the same sensed environment (the common case: a fleet-
    wide ambient jump) share ONE solver call and receive bitwise-equal
    slices of the same plan.  The first replanning pod pays the solve with
    *its* warm start; order over pods is deterministic.
    """

    def __init__(self, inner, lo: int, hi: int,
                 ctx: Optional[TickContext] = None):
        self.inner = inner
        self.lo, self.hi = int(lo), int(hi)
        if not 0 <= self.lo < self.hi <= inner.substrate.n_domains:
            raise ValueError(f"chip slice [{lo}, {hi}) outside the fleet's "
                             f"{inner.substrate.n_domains} chips")
        self.substrate = _PodSubstrate(inner.substrate, self.hi - self.lo)
        self.ctx = ctx if ctx is not None else TickContext()

    @property
    def full(self) -> bool:
        return self.lo == 0 and self.hi == self.inner.substrate.n_domains

    # passthroughs the controller / _nominal_plan read
    @property
    def lib(self):
        return self.inner.lib

    @property
    def prof(self):
        return self.inner.prof

    @property
    def policy(self):
        return self.inner.policy

    @property
    def T_last(self):
        return self.inner.T_last

    @T_last.setter
    def T_last(self, v) -> None:  # controller.reset() clears the warm field
        self.inner.T_last = v

    def env(self, t_amb: float, util=None) -> Dict:
        return self.inner.env(t_amb, util)

    def baseline_power(self, env: Dict, **kw) -> np.ndarray:
        return self.inner.baseline_power(env, **kw)

    # ------------------------------------------------------------------
    def _embed(self, util) -> Optional[np.ndarray]:
        """This pod's sensed utilization embedded in the tick's fleet
        utilization (ones where nothing was sensed)."""
        base = self.ctx.util
        if base is None and util is None:
            return None
        n = self.inner.substrate.n_domains
        full = (np.ones(n, np.float32) if base is None
                else np.asarray(base, np.float32).copy())
        if util is not None:
            full[self.lo:self.hi] = np.asarray(util, np.float32)
        return full

    def plan_at(self, t_amb: float, util=None, T0=None):
        if self.full:
            return self.inner.plan_at(t_amb, util, T0=T0)
        u = self._embed(util)
        key = (float(t_amb), None if u is None else u.tobytes())
        if key not in self.ctx.memo:
            self.ctx.memo[key] = self.inner.plan_at(t_amb, u, T0=T0)
        plan, T = self.ctx.memo[key]
        # the pod keeps the FULL converged field as its warm start —
        # exactly what the shared solver wants back next replan
        return self._slice(plan), T

    def _slice(self, plan: PlanOut) -> PlanOut:
        lo, hi = self.lo, self.hi
        p = np.asarray(plan.power_w)[lo:hi]
        return PlanOut(
            v_core=np.asarray(plan.v_core)[lo:hi],
            v_sram=np.asarray(plan.v_sram)[lo:hi],
            f_rel=np.asarray(plan.f_rel)[lo:hi],
            power_w=p, step_s=plan.step_s,
            pod_power_w=float(p.sum()),
            # thermal/baseline stats stay fleet-global: the pod's sanity
            # checks (t_max bounds) must see the coupled field, not a
            # slice that happens to exclude the hot corner
            baseline_power_w=plan.baseline_power_w,
            saving=plan.saving, t_mean=plan.t_mean, t_max=plan.t_max)

    def mitigate(self, plan: PlanOut, chip: int, T_chip: float) -> Dict:
        # plan is this pod's slice and chip is pod-local: power_w[chip]
        # reads the right chip either way
        return self.inner.mitigate(plan, chip, T_chip)

    def rail_field(self, t_ambs, u_levels=DEFAULT_UTIL_KNOTS, **kw):
        f = self.inner.rail_field(t_ambs, u_levels, **kw)
        return f if self.full else f.slice_chips(self.lo, self.hi)


# ---------------------------------------------------------------------------
# shared-source fan-out telemetry
# ---------------------------------------------------------------------------


class FanoutTelemetry:
    """Poll a shared source ONCE per fleet tick and fan per-pod slices out
    to the pod buses.  The inner poll is memoized on ``now`` — event-like
    sources (straggler monitors) are still drained exactly once per tick
    even though every pod's bus polls its view."""

    def __init__(self, source):
        self.source = source
        self._at: Optional[float] = None
        self._samples: List[Sample] = []

    def _poll(self, now: float) -> List[Sample]:
        if self._at != now:
            self._samples = list(self.source.poll(now))
            self._at = now
        return self._samples

    def view(self, lo: int, hi: int,
             primary: bool = False) -> "PodTelemetryView":
        return PodTelemetryView(self, lo, hi, primary=primary)


class PodTelemetryView:
    """One pod's slice of a fan-out source.

    Chip-indexed samples are sliced and translated to the pod-local frame
    (the pod controller lives in ``[0, width)``); fleet-global event
    samples (SDC counters, unmapped stragglers) are delivered only to the
    ``primary`` view so nothing is double-counted.  The degenerate single
    view (``primary=True`` over the full slice) delivers every sample
    exactly once with identical values — the flat-loop bitwise guarantee.
    """

    def __init__(self, fanout: FanoutTelemetry, lo: int, hi: int,
                 primary: bool = False):
        self.fanout = fanout
        self.lo, self.hi = int(lo), int(hi)
        self.primary = bool(primary)

    def poll(self, now: float) -> List[Sample]:
        out: List[Sample] = []
        for smp in self.fanout._poll(now):
            if isinstance(smp, ChipTempSample):
                out.append(ChipTempSample(
                    np.asarray(smp.t_chip)[self.lo:self.hi],
                    stamp=smp.stamp))
            elif isinstance(smp, UtilSample):
                out.append(UtilSample(
                    np.asarray(smp.shares)[self.lo:self.hi]))
            elif isinstance(smp, SafeStateSample):
                # emitted even when the slice is empty: the pod bus's
                # persistent safe set must CLEAR when the pins clear
                out.append(SafeStateSample(frozenset(
                    c - self.lo for c in smp.chips
                    if self.lo <= c < self.hi)))
            elif isinstance(smp, StragglerSample):
                if self.lo <= smp.chip < self.hi:
                    out.append(StragglerSample(smp.worker, smp.step,
                                               smp.ratio,
                                               smp.chip - self.lo))
                elif smp.chip < 0 and self.primary:
                    out.append(smp)  # unmapped: surfaced once, by pod 0
            elif isinstance(smp, SdcSample):
                if self.primary:
                    out.append(smp)  # fleet counters: never double-count
            else:
                out.append(smp)
        return out


# ---------------------------------------------------------------------------
# the fleet loop
# ---------------------------------------------------------------------------


@dataclass
class PodDomain:
    """One failure domain: chips ``[lo, hi)`` with their own bus,
    controller, rail channel, optional serve engine, and health state."""

    index: int
    lo: int
    hi: int
    bus: TelemetryBus
    controller: object
    rails: PodRailChannel
    engine: object = None  # serve.Engine — migration source AND target
    extra: List = dc_field(default_factory=list)  # per-pod actuators
    # health machine state (owned by FleetLoop)
    state: str = HEALTHY
    bad_ticks: int = 0
    clean_ticks: int = 0
    cool_ticks: int = 0
    safe_prev: int = 0

    def __post_init__(self):
        self._wants_util = "util" in inspect.signature(
            self.controller.decide).parameters

    @property
    def width(self) -> int:
        return self.hi - self.lo


@dataclass
class FleetReport:
    """One fleet tick: the per-pod loop reports plus fleet-level state."""

    now: float
    reports: List[LoopReport]
    readout: object = None  # the global FleetReadout of this tick's settle
    states: Dict[int, str] = dc_field(default_factory=dict)
    events: List[str] = dc_field(default_factory=list)
    pod_power_w: Optional[np.ndarray] = None
    pod_budget_w: Optional[np.ndarray] = None
    migrated: int = 0

    @property
    def snapshot(self) -> Snapshot:
        """Pod 0's snapshot (the machine-room reference sensor) — keeps
        ``LoopReport``-shaped consumers working on the degenerate fleet."""
        return self.reports[0].snapshot

    @property
    def actions(self) -> List[Action]:
        return [a for r in self.reports for a in r.actions]


def _globalize(action: Action, lo: int) -> Action:
    """Translate a pod-local chip index into the fleet frame.  Pod 0
    returns the SAME object — the degenerate path applies the controller's
    actions untouched, like the flat loop."""
    if lo == 0:
        return action
    if isinstance(action, (BoostRail, Rebalance, Restore, SafeState)):
        return replace(action, chip=action.chip + lo)
    return action


class FleetLoop:
    """N per-pod control loops under one global planner/health authority.

    ``step(now)`` runs four phases:

    1. **poll** — every pod's bus polls first (quarantined pods included:
       recovery is judged on their own telemetry), and the tick's fleet
       utilization is assembled, so all pods decide against the same
       world state and share one memoized replan per environment.
    2. **decide + apply** — per pod, in index order: the pod's rail
       channel clocks (committing any latency-staged write), its
       controller decides on its slice snapshot, and the actions — chip
       indices translated to the fleet frame — land on the pod's rail
       channel, the shared elastic actuator, and the pod's extra
       actuators.  Quarantined/drained pods skip this phase entirely:
       their rails stay frozen, their watchdogs cannot stall a sibling.
    3. **settle** — ONE global thermal/power evaluation (the field couples
       every chip; there is exactly one physics).
    4. **health** — per-pod fault signals (bus quarantines, watchdog
       level, safe-state growth) drive ``healthy -> degraded ->
       quarantined -> drained`` and the cool-down restore; quarantine
       freezes rails, migrates work shares and live serve requests to the
       survivors; the optional fleet power budget re-shares over the
       remaining healthy pods.
    """

    def __init__(self, pods: Sequence[PodDomain], fleet,
                 elastic=None, ctx: Optional[TickContext] = None,
                 tick_deadline_s: Optional[float] = None,
                 power_budget_w: Optional[float] = None,
                 enforce_budget: bool = False,
                 degrade_after: int = 2, quarantine_after: int = 4,
                 restore_after: int = 3, restore_below_c: float = 70.0):
        self.pods = list(pods)
        self.fleet = fleet
        self.elastic = elastic
        self.ctx = ctx if ctx is not None else TickContext()
        self.tick_deadline_s = tick_deadline_s
        self.power_budget_w = power_budget_w
        self.enforce_budget = bool(enforce_budget)
        self.degrade_after = max(int(degrade_after), 1)
        self.quarantine_after = max(int(quarantine_after), 1)
        self.restore_after = max(int(restore_after), 1)
        self.restore_below_c = float(restore_below_c)
        self.deadline_misses = 0
        self.migrated_total = 0
        self.events: List[str] = []
        self.history: List[FleetReport] = []
        self._rr = 0  # migration round-robin cursor (deterministic)
        n = fleet.substrate.n_domains
        cur = 0
        for pod in self.pods:
            if pod.lo != cur or pod.hi <= pod.lo:
                raise ValueError(
                    "pods must tile the fleet contiguously in index order; "
                    f"pod{pod.index} spans [{pod.lo}, {pod.hi}) at chip "
                    f"{cur}")
            cur = pod.hi
        if cur != n:
            raise ValueError(f"pods cover [0, {cur}) of {n} fleet chips")

    @property
    def n_pods(self) -> int:
        return len(self.pods)

    # ------------------------------------------------------------------
    def step(self, now: float = 0.0,
             util: Optional[np.ndarray] = None) -> FleetReport:
        # phase 1 — poll everything first
        snaps = [pod.bus.poll(now) for pod in self.pods]
        if hasattr(self.fleet, "begin_tick"):
            self.fleet.begin_tick(now)
        self.ctx.clear()
        self.ctx.util = self._assemble_util(snaps, util)
        # phase 2 — per-pod decide + apply
        reports = [self._tick_pod(pod, snap, now, util)
                   for pod, snap in zip(self.pods, snaps)]
        # phase 3 — one global settle
        readout = self._settle(snaps, now, util)
        # phase 4 — health machine, containment, budget
        events: List[str] = []
        migrated = self._update_health(snaps, now, events)
        pod_power = self._pod_power()
        budget = self._apply_budget(pod_power, now, events)
        rep = FleetReport(now=now, reports=reports, readout=readout,
                          states={p.index: p.state for p in self.pods},
                          events=events, pod_power_w=pod_power,
                          pod_budget_w=budget, migrated=migrated)
        self.events.extend(events)
        self.history.append(rep)
        return rep

    # ------------------------------------------------------------------
    def _tick_pod(self, pod: PodDomain, snap: Snapshot, now: float,
                  util) -> LoopReport:
        if pod.state in (QUARANTINED, DRAINED):
            # contained: rails frozen at safe state, work migrated away —
            # the pod neither decides nor actuates until restored
            return LoopReport(now=now, snapshot=snap, actions=[],
                              pod=pod.index)
        t0 = time.monotonic() if self.tick_deadline_s is not None else None
        pod.rails.begin_tick(now)
        u = None if util is None else np.asarray(util)[pod.lo:pod.hi]
        actions = (pod.controller.decide(snap, util=u)
                   if pod._wants_util else pod.controller.decide(snap))
        targets = ([pod.rails]
                   + ([self.elastic] if self.elastic is not None else [])
                   + list(pod.extra))
        applied: List[Action] = []
        for a in actions:
            g = _globalize(a, pod.lo)
            applied.append(g)
            for act in targets:
                act.apply(g)
        if (t0 is not None
                and time.monotonic() - t0 > self.tick_deadline_s
                and hasattr(pod.controller, "note_deadline_miss")):
            self.deadline_misses += 1
            pod.controller.note_deadline_miss()
        return LoopReport(now=now, snapshot=snap, actions=applied,
                          pod=pod.index)

    # ------------------------------------------------------------------
    def _assemble_util(self, snaps: List[Snapshot],
                       util) -> Optional[np.ndarray]:
        if util is not None:
            return np.asarray(util, np.float32)
        parts = [snap.util(pod.width)
                 for pod, snap in zip(self.pods, snaps)]
        if all(p is None for p in parts):
            return None
        full = np.concatenate(
            [np.ones(pod.width, np.float32) if p is None
             else np.asarray(p, np.float32)
             for pod, p in zip(self.pods, parts)])
        # a chip's duty cycle saturates at 1: post-quarantine survivors
        # carry 2x the work SHARE (longer queues), not 2x the
        # instantaneous power — unclamped, the settle's leakage-thermal
        # feedback diverges at share x occupancy > ~1.5
        return np.clip(full, 0.0, 1.0)

    def _settle(self, snaps: List[Snapshot], now: float, util):
        if not hasattr(self.fleet, "settle"):
            return None
        if self.n_pods == 1:
            return self.fleet.settle(snaps[0], util=util)
        # pod 0 carries the machine-room reference sensor; per-pod ambient
        # offsets enter through each pod's own controller while the shared
        # thermal field settles at the reference ambient
        u = (self.ctx.util if util is None
             else np.asarray(util, np.float32))
        return self.fleet.settle(Snapshot(now=now, t_amb=snaps[0].t_amb),
                                 util=u)

    # -- health machine -------------------------------------------------
    def _survivors(self, pod: PodDomain) -> List[PodDomain]:
        return [p for p in self.pods
                if p is not pod and p.state in (HEALTHY, DEGRADED)]

    def _update_health(self, snaps: List[Snapshot], now: float,
                       events: List[str]) -> int:
        migrated = 0
        for pod, snap in zip(self.pods, snaps):
            safe_now = sum(1 for c in self.fleet.safe_state
                           if pod.lo <= c < pod.hi)
            grew = safe_now > pod.safe_prev
            pod.safe_prev = safe_now
            if pod.state in (HEALTHY, DEGRADED):
                bad = (snap.quarantined > 0 or grew
                       or getattr(pod.controller, "watchdog_level", 0) >= 1)
                if bad:
                    pod.bad_ticks += 1
                    pod.clean_ticks = 0
                else:
                    pod.bad_ticks = 0
                    pod.clean_ticks += 1
                if (pod.state == HEALTHY
                        and pod.bad_ticks >= self.degrade_after):
                    pod.state = DEGRADED
                    events.append(f"pod{pod.index}:degraded@{now:g}")
                if (pod.state == DEGRADED
                        and pod.bad_ticks >= self.quarantine_after):
                    if self._survivors(pod):
                        migrated += self._quarantine(pod, now, events)
                    elif pod.bad_ticks == self.quarantine_after:
                        # someone has to run the fleet: the last healthy
                        # pod stays degraded under its own watchdog
                        events.append(f"pod{pod.index}:quarantine_deferred"
                                      f"(last_pod)@{now:g}")
                elif (pod.state == DEGRADED
                        and pod.clean_ticks >= self.restore_after):
                    pod.state = HEALTHY
                    events.append(f"pod{pod.index}:recovered@{now:g}")
            elif pod.state == QUARANTINED:
                pod.state = DRAINED  # containment landed last tick
                events.append(f"pod{pod.index}:drained@{now:g}")
            elif pod.state == DRAINED:
                t_slice = float(np.max(self.fleet.T[pod.lo:pod.hi]))
                cool = (snap.quarantined == 0
                        and t_slice < self.restore_below_c)
                pod.cool_ticks = pod.cool_ticks + 1 if cool else 0
                if pod.cool_ticks >= self.restore_after:
                    self._restore(pod, now, events)
        return migrated

    def _quarantine(self, pod: PodDomain, now: float,
                    events: List[str]) -> int:
        pod.state = QUARANTINED
        pod.cool_ticks = 0
        events.append(f"pod{pod.index}:quarantined@{now:g}")
        # rails: drop any staged write, pin the slice to nominal safe state
        pod.rails.freeze_safe()
        # work: condemn every chip — the elastic assignment spreads the
        # pod's share over the survivors, so the very next tick's rails
        # are planned for the migrated load
        if self.elastic is not None:
            for c in range(pod.lo, pod.hi):
                self.elastic.apply(Rebalance(c, "pod_quarantine"))
        # serve: page-exact eviction through the shared HostPagePool, then
        # live-migrate the in-flight requests to the survivors' engines.
        # Greedy decode with shared weights makes the resumed outputs
        # bitwise what the home pod would have produced.
        migrated = 0
        if pod.engine is not None:
            targets = [p for p in self._survivors(pod)
                       if p.engine is not None]
            if targets:
                for req in pod.engine.drain():
                    tgt = targets[self._rr % len(targets)]
                    self._rr += 1
                    tgt.engine.submit(req)
                    migrated += 1
                if migrated:
                    events.append(
                        f"pod{pod.index}:migrated({migrated})@{now:g}")
            # no surviving engine: requests stay parked in the drained
            # pod's queue and resume on restore — never dropped
        self.migrated_total += migrated
        return migrated

    def _restore(self, pod: PodDomain, now: float,
                 events: List[str]) -> None:
        for c in range(pod.lo, pod.hi):
            self.fleet.clear_safe_state(c)
        if self.elastic is not None:
            for c in range(pod.lo, pod.hi):
                self.elastic.apply(Restore(c))
        # the pod bus's persistent safe-state set would otherwise keep
        # reporting the quarantine pins forever (the actuator only emits
        # SafeStateSample while chips are pinned): clear it so the pod's
        # controller does not re-condemn freshly restored chips
        pod.bus._state.safe_state = frozenset()
        ctl = pod.controller
        for attr, v in (("_degrade", 0), ("_clean", 0),
                        ("_degrade_since", None), ("_pending_trips", [])):
            if hasattr(ctl, attr):
                setattr(ctl, attr, v)
        pod.state = HEALTHY
        pod.bad_ticks = pod.clean_ticks = pod.cool_ticks = 0
        pod.safe_prev = 0
        events.append(f"pod{pod.index}:restored@{now:g}")

    # -- fleet power budget ---------------------------------------------
    def _pod_power(self) -> Optional[np.ndarray]:
        p = getattr(self.fleet, "p_chip", None)
        if p is None:
            return None
        p = np.asarray(p, np.float64)
        return np.asarray([float(p[pod.lo:pod.hi].sum())
                           for pod in self.pods])

    def _apply_budget(self, pod_power: Optional[np.ndarray], now: float,
                      events: List[str]) -> Optional[np.ndarray]:
        if self.power_budget_w is None:
            return None
        alive = [p for p in self.pods if p.state in (HEALTHY, DEGRADED)]
        chips_alive = sum(p.width for p in alive) or 1
        asg = getattr(self.elastic, "assignment", None)
        budget = np.zeros(self.n_pods)
        for i, pod in enumerate(self.pods):
            if pod.state in (HEALTHY, DEGRADED):
                # weight by live work share when the elastic assignment is
                # attached (a pod that absorbed a sibling's migrated load
                # gets the matching headroom); plain chip count otherwise
                budget[i] = (self.power_budget_w
                             * (asg.pod_share(pod.lo, pod.hi)
                                if asg is not None
                                else pod.width / chips_alive))
        if self.enforce_budget and pod_power is not None:
            for i, pod in enumerate(self.pods):
                eng = pod.engine
                if eng is None or pod.state not in (HEALTHY, DEGRADED):
                    continue
                if pod_power[i] > budget[i]:
                    if eng.admit_cap != 0:
                        events.append(
                            f"pod{pod.index}:over_budget"
                            f"({pod_power[i]:.0f}W>{budget[i]:.0f}W)"
                            f"@{now:g}")
                    eng.admit_cap = 0
                elif eng.admit_cap == 0:
                    eng.admit_cap = None
        return budget
