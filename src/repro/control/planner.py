"""FleetPlanner — the Algorithm 1/2 planning core of the control plane.

This is the computational heart that ``core.runtime.EnergyAwareRuntime``
composes (the PR-1 wrapper playbook: the legacy class keeps its API and
golden-pinned numbers, the logic lives here where the controller can call
it directly):

- :meth:`plan` — one full fixed point (rails -> thermal solve -> repeat)
  through the shared :class:`repro.policy.Solver`, returning the legacy
  :class:`PlanOut` plus the converged temperature field for warm restarts.
- the **nominal-baseline cache**: the baseline solve (nominal rails at
  their own fixed point) is policy-independent per environment
  ``(t_amb, util)`` — gamma only enters feasibility, and the nominal-only
  substrate has a single candidate that the fallback re-selects either
  way — so it is solved once per environment and memoized
  (``baseline_solves`` counts actual solves for tests/benchmarks).
- :meth:`lut` / :meth:`build_lut` — the §III-B dynamic scheme: replans for
  *many* ambient environments go through ONE ``solve_batch`` device call;
  ``build_lut`` wraps the result in an interpolating :class:`DynamicLut`.
- :meth:`rail_field` — the 2-axis per-chip fast
  path: ONE ``solve_batch`` (early-freeze) call over the whole
  ``ambient x utilization`` knot grid, plus one batched nominal-only solve
  producing the per-chip baseline on the same grid (prefilled into the
  nominal-baseline cache, carried on the :class:`RailField` for
  interpolated readouts).
- :meth:`mitigate` — straggler rail-boost-or-rebalance as a pure decision
  (the controller turns it into an actuator command).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro import policy as pol
from repro.core import tpu_fleet as TF
from repro.control.lut import DEFAULT_UTIL_KNOTS, DynamicLut, RailField


@dataclass
class PlanOut:
    """The legacy fleet plan record (golden-pinned in test_policy_api.py)."""
    v_core: np.ndarray  # (chips,)
    v_sram: np.ndarray
    f_rel: np.ndarray
    power_w: np.ndarray
    step_s: float
    pod_power_w: float
    baseline_power_w: float
    saving: float
    t_mean: float
    t_max: float


_BASELINE_CACHE_LIMIT = 64  # environments; ambient sweeps must not pin RAM


class FleetPlanner:
    """Planning + mitigation decisions over one ``TpuFleetSubstrate``."""

    def __init__(self, substrate: pol.TpuFleetSubstrate, policy: pol.Policy,
                 prof: TF.StepProfile, lib: TF.TpuLibrary,
                 delta_t: float = 0.5, max_iters: int = 6):
        self.substrate = substrate
        self.policy = policy
        self.prof = prof
        self.lib = lib
        self.delta_t = delta_t
        self.max_iters = max_iters
        self._baseline: "OrderedDict" = OrderedDict()
        self.baseline_solves = 0  # cache-miss counter (tests/benchmarks)
        self.T_last: Optional[np.ndarray] = None  # last converged field

    # ------------------------------------------------------------------
    def env(self, t_amb: float, util: Optional[np.ndarray] = None) -> Dict:
        chips = self.substrate.n_domains
        us = np.asarray(util if util is not None else np.ones(chips),
                        np.float32)
        e = {"t_amb": t_amb, "util": us, "gamma": self.policy.gamma}
        # budget-carrying policies (ErrorTolerant) ride their accuracy
        # budget in the env so budget sweeps batch like gamma sweeps do;
        # other policies keep the legacy env signature (stable jit keys)
        b = getattr(self.policy, "budget", None)
        if b is not None:
            e["budget"] = float(b)
        return e

    # ------------------------------------------------------------------
    def baseline_power(self, env: Dict, delta_t: Optional[float] = None,
                       max_iters: Optional[int] = None) -> np.ndarray:
        """Nominal rails at their own fixed point — cached per environment.

        Keyed on (t_amb, util): the nominal-only substrate has exactly one
        candidate and ``nominal_fallback`` re-selects it whether or not the
        gamma-relaxed contract holds, so gamma (the only policy-dependent
        env leaf) cannot change the result.
        """
        delta_t = self.delta_t if delta_t is None else delta_t
        max_iters = self.max_iters if max_iters is None else max_iters
        key = (float(env["t_amb"]),
               np.asarray(env["util"], np.float32).tobytes(),
               float(delta_t), int(max_iters))
        if key in self._baseline:
            self._baseline.move_to_end(key)
            return self._baseline[key]
        bsolver = pol.cached_solver(self.substrate.nominal_only(),
                                    pol.PowerSave(), delta_t, max_iters)
        bsol = bsolver.solve(env)
        pb = np.asarray(bsol.power)  # legacy: last-search power
        self._baseline[key] = pb
        self.baseline_solves += 1
        if len(self._baseline) > _BASELINE_CACHE_LIMIT:
            self._baseline.popitem(last=False)
        return pb

    # ------------------------------------------------------------------
    def plan(self, env: Dict, T0, max_iters: Optional[int] = None,
             delta_t: Optional[float] = None) -> Tuple[PlanOut, np.ndarray]:
        """Fixed point: choose rails -> thermal solve -> repeat.

        Returns ``(PlanOut, T_converged)``; the caller owns the warm
        temperature estimate (EnergyAwareRuntime keeps it on ``self.T``).
        """
        mi = self.max_iters if max_iters is None else max_iters
        dt = self.delta_t if delta_t is None else delta_t
        solver = pol.cached_solver(self.substrate, self.policy, dt, mi)
        sol = solver.solve(env, T0=T0)
        self.T_last = np.asarray(sol.T)

        pb = self.baseline_power(env, dt, mi)

        vc, vs = self.substrate.decode(sol.idx)
        f = np.asarray(sol.f)
        p = np.asarray(sol.power)
        f_pod = float(f.min())  # synchronous step: slowest chip rules
        step_s = float(TF.step_time(self.prof, f_pod))
        if self.policy.metric == "energy":
            # energy-per-step ratio (P x t), the paper's Algorithm-2 metric
            saving = 1.0 - (float(p.sum()) * step_s) / (
                float(pb.sum()) * self.prof.step_s)
        else:
            saving = 1.0 - float(p.sum()) / float(pb.sum())
        out = PlanOut(
            v_core=vc, v_sram=vs, f_rel=f, power_w=p, step_s=step_s,
            pod_power_w=float(p.sum()),
            baseline_power_w=float(pb.sum()),
            saving=saving,
            t_mean=float(np.mean(sol.T)), t_max=float(np.max(sol.T)),
        )
        return out, np.asarray(sol.T)

    def plan_at(self, t_amb: float, util: Optional[np.ndarray] = None,
                T0=None) -> Tuple[PlanOut, np.ndarray]:
        """Plan for a sensed environment.

        ``T0=None`` warm-starts from the last converged field (ambient
        replans move the steady state by a few degrees, so the multigrid
        solve restarts within a V-cycle or two of converged); cold start
        only before any plan has run.
        """
        env = self.env(t_amb, util)
        if T0 is None:
            T0 = (self.T_last if self.T_last is not None
                  else self.substrate.T0({"t_amb": t_amb}))
        return self.plan(env, T0)

    # ------------------------------------------------------------------
    def lut(self, t_ambs,
            util: Optional[np.ndarray] = None
            ) -> Dict[float, Tuple[float, float]]:
        """§III-B dynamic scheme: per-ambient (v_core, v_sram) medians.

        ONE batched solve over the whole ambient sweep (`solve_batch`
        vmaps the fixed point), exactly the legacy ``dynamic_lut``.
        """
        chips = self.substrate.n_domains
        t = np.asarray([float(x) for x in t_ambs], np.float32)
        B = len(t)
        us = np.asarray(util if util is not None else np.ones(chips),
                        np.float32)
        solver = pol.cached_solver(self.substrate, self.policy,
                                   self.delta_t, self.max_iters)
        envs = {
            "t_amb": t,
            "util": np.broadcast_to(us, (B, chips)).copy(),
            "gamma": np.full((B,), self.policy.gamma, np.float32),
        }
        b = getattr(self.policy, "budget", None)
        if b is not None:
            envs["budget"] = np.full((B,), float(b), np.float32)
        sol = solver.solve_batch(envs)
        out = {}
        for i in range(B):
            vc, vs = self.substrate.decode(sol.idx[i])
            out[float(t[i])] = (float(np.median(vc)), float(np.median(vs)))
        return out

    def build_lut(self, t_ambs,
                  util: Optional[np.ndarray] = None) -> DynamicLut:
        """The interpolating scalar lookup (legacy pod-median fast path)."""
        return DynamicLut(self.lut(t_ambs, util))

    # ------------------------------------------------------------------
    def _grid_envs(self, t_ambs, u_levels) -> Dict:
        """The flattened ``K_t x K_u`` environment batch (row-major: the
        utilization axis varies fastest)."""
        chips = self.substrate.n_domains
        t = np.asarray([float(x) for x in t_ambs], np.float32)
        u = np.asarray([float(x) for x in u_levels], np.float32)
        B = t.size * u.size
        tt = np.repeat(t, u.size)  # (B,)
        uu = np.tile(u, t.size)    # (B,)
        envs = {
            "t_amb": tt,
            "util": uu[:, None] * np.ones((1, chips), np.float32),
            "gamma": np.full((B,), self.policy.gamma, np.float32),
        }
        b = getattr(self.policy, "budget", None)
        if b is not None:
            envs["budget"] = np.full((B,), float(b), np.float32)
        return envs

    def rail_field(self, t_ambs, u_levels=DEFAULT_UTIL_KNOTS,
                   with_baseline: bool = True,
                   early_freeze: bool = True) -> RailField:
        """Solve the per-chip 2-axis rail table: ONE batched fixed point
        over the whole ``ambient x utilization`` grid.

        ``early_freeze`` lets converged grid points stop iterating instead
        of riding lockstep with the slowest corner of the grid (the hot,
        fully-utilized one) — rail decisions bitwise-identical to the
        lockstep path, fewer wasted search+thermal passes.
        ``with_baseline`` additionally runs one
        batched *nominal-only* solve over the same grid, prefilling the
        per-environment baseline cache and attaching the per-chip nominal
        power to the field for interpolated readouts.
        """
        t = [float(x) for x in t_ambs]
        u = [float(x) for x in u_levels]
        Kt, Ku = len(t), len(u)
        chips = self.substrate.n_domains
        envs = self._grid_envs(t, u)
        solver = pol.cached_solver(self.substrate, self.policy,
                                   self.delta_t, self.max_iters)
        sol = solver.solve_batch(envs, early_freeze=early_freeze)
        vc, vs = self.substrate.decode(sol.idx)  # (B, chips)
        p_nom = None
        if with_baseline:
            p_nom = self._baseline_grid(envs, (Kt, Ku, chips), early_freeze,
                                        t, u)
        return RailField(t, u,
                         np.asarray(vc).reshape(Kt, Ku, chips),
                         np.asarray(vs).reshape(Kt, Ku, chips),
                         p_nom=p_nom)

    def _baseline_grid(self, envs: Dict, shape, early_freeze: bool,
                       t_knots, u_levels) -> np.ndarray:
        """Per-chip nominal-baseline power over the sweep grid — one
        batched nominal-only solve, prefilled into the per-environment
        cache so a replan/readout AT a grid knot never re-solves it.

        Cache keys are built from the ORIGINAL python-float knots:
        ``baseline_power`` keys on the caller's float64 ambient, so keying
        on the float32 env batch would miss even exact-knot queries."""
        bsolver = pol.cached_solver(self.substrate.nominal_only(),
                                    pol.PowerSave(), self.delta_t,
                                    self.max_iters)
        bsol = bsolver.solve_batch(envs, early_freeze=early_freeze)
        pb = np.asarray(bsol.power)  # (B, chips); legacy last-search power
        # warm the SINGLE-env nominal fixed point too: the prefilled cache
        # serves grid-knot ambients, so without this the first *off-knot*
        # control tick would pay this jit compile (~0.7 s) inside the
        # online loop instead of here at build time
        bsolver.solve({k: v[0] for k, v in envs.items()})
        for i in range(pb.shape[0]):
            key = (float(t_knots[i // len(u_levels)]),
                   np.asarray(envs["util"][i], np.float32).tobytes(),
                   float(self.delta_t), int(self.max_iters))
            if key not in self._baseline:
                self._baseline[key] = pb[i]
                if len(self._baseline) > _BASELINE_CACHE_LIMIT:
                    self._baseline.popitem(last=False)
        return pb.reshape(shape)

    # ------------------------------------------------------------------
    def mitigate(self, plan: PlanOut, chip: int, T_chip: float) -> Dict:
        """Hot/slow chip: try boosting its rails back to nominal (perf-
        preserving, costs power); report if even that can't hold the clock.

        Pure decision — application is the actuator's job.
        """
        f_at_nom = float(TF.f_max_rel(self.lib, TF.V_CORE_NOM,
                                      TF.V_SRAM_NOM, T_chip + 2.0))
        if f_at_nom >= 1.0:
            return {"action": "boost_rail", "chip": chip,
                    "v_core": TF.V_CORE_NOM, "v_sram": TF.V_SRAM_NOM,
                    "extra_power_w": float(
                        TF.chip_power(self.lib, self.prof, TF.V_CORE_NOM,
                                      TF.V_SRAM_NOM, 1.0, T_chip)
                        - plan.power_w[chip])}
        return {"action": "rebalance", "chip": chip,
                "reason": f"T={T_chip:.1f}C cannot hold f_nom even at "
                          f"nominal rails (f_max={f_at_nom:.3f})"}
