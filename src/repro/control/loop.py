"""ControlLoop — one telemetry -> controller -> actuator tick.

The composition root of the control plane: a :class:`TelemetryBus` of
sources, one :class:`Controller`, and a list of actuators.  ``step(now)``
polls, decides, applies every action to every actuator (each takes the ones
it understands), then lets stateful actuators *settle* (the
:class:`FleetActuator` thermal re-evaluation whose readout feeds the next
poll).  Reports accumulate in ``history`` for run summaries.
"""
from __future__ import annotations

import inspect
import time
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np

from repro.control.controller import Action, Controller
from repro.control.telemetry import Snapshot, TelemetryBus


@dataclass
class LoopReport:
    now: float
    snapshot: Snapshot
    actions: List[Action]
    readouts: List = field(default_factory=list)
    pod: Optional[int] = None  # which pod ticked (None = single-pod loop)

    @property
    def readout(self):
        """The first settled readout (the fleet one in standard wiring)."""
        return self.readouts[0] if self.readouts else None


class ControlLoop:
    """``tick_deadline_s`` (off by default — replays must stay free of
    wall-clock) arms a *measured* watchdog: a tick whose decide+apply
    exceeds the deadline reports ``note_deadline_miss`` to the controller,
    degrading the NEXT tick.  Deterministic chaos scripts deadline misses
    through the fault model instead."""

    def __init__(self, bus: TelemetryBus, controller: Controller,
                 actuators: Sequence,
                 tick_deadline_s: Optional[float] = None):
        self.bus = bus
        self.controller = controller
        self.actuators = list(actuators)
        self.tick_deadline_s = tick_deadline_s
        self.deadline_misses = 0
        self.history: List[LoopReport] = []
        self._wants_util = "util" in inspect.signature(
            controller.decide).parameters

    def step(self, now: float = 0.0,
             util: Optional[np.ndarray] = None) -> LoopReport:
        t0 = time.monotonic() if self.tick_deadline_s is not None else None
        snap = self.bus.poll(now)
        for act in self.actuators:  # clock write channels before actions
            if hasattr(act, "begin_tick"):
                act.begin_tick(now)
        actions = (self.controller.decide(snap, util=util)
                   if self._wants_util else self.controller.decide(snap))
        for a in actions:
            for act in self.actuators:
                act.apply(a)
        if (t0 is not None
                and time.monotonic() - t0 > self.tick_deadline_s
                and hasattr(self.controller, "note_deadline_miss")):
            self.deadline_misses += 1
            self.controller.note_deadline_miss()
        readouts = [act.settle(snap, util=util) for act in self.actuators
                    if hasattr(act, "settle")]
        rep = LoopReport(now=now, snapshot=snap, actions=list(actions),
                         readouts=readouts)
        self.history.append(rep)
        return rep
