"""Deterministic synthetic data pipeline (no external corpora in-container).

Tokens come from a seeded sparse-bigram generator, so models have real
structure to learn (loss decreases) and every (seed, step, shard) triple maps
to exactly one batch — restart-determinism and elastic re-sharding are free:
after restoring step k, the pipeline resumes at k+1 with identical data, for
any data-parallel shard count that divides the global batch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4  # bigram out-degree (lower = easier to learn)


class SyntheticLM:
    """Sparse-bigram token stream: token_{t+1} in successors[token_t]."""

    def __init__(self, dc: DataConfig):
        self.dc = dc
        rng = np.random.default_rng(dc.seed)
        V = dc.vocab_size
        self.successors = rng.integers(0, V, size=(V, dc.branch))

    def batch(self, step: int, shard: int = 0, n_shards: int = 1,
              extras: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        dc = self.dc
        assert dc.global_batch % n_shards == 0
        bs = dc.global_batch // n_shards
        rng = np.random.default_rng(
            (dc.seed * 1_000_003 + step) * 65_537 + shard)
        V = dc.vocab_size
        toks = np.empty((bs, dc.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=bs)
        choice = rng.integers(0, dc.branch, size=(bs, dc.seq_len))
        for t in range(dc.seq_len):
            toks[:, t + 1] = self.successors[toks[:, t], choice[:, t]]
        out = {"tokens": jnp.asarray(toks[:, :-1]),
               "labels": jnp.asarray(toks[:, 1:])}
        if extras:
            out.update(extras)
        return out


def make_iterator(cfg: ModelConfig, dc: DataConfig, start_step: int = 0,
                  shard: int = 0, n_shards: int = 1) -> Iterator[Dict[str, Any]]:
    """Per-host sharded iterator with modality-stub extras."""
    src = SyntheticLM(dc)
    step = start_step
    bs = dc.global_batch // n_shards
    while True:
        extras: Dict[str, Any] = {}
        if cfg.family == "vlm":
            key = jax.random.PRNGKey(dc.seed * 7 + step)
            extras["image_embeds"] = 0.1 * jax.random.normal(
                key, (bs, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            key = jax.random.PRNGKey(dc.seed * 11 + step)
            extras["audio_frames"] = 0.1 * jax.random.normal(
                key, (bs, cfg.encoder_frames, cfg.d_model), jnp.float32)
        yield src.batch(step, shard, n_shards, extras)
        step += 1
