"""Policy objects — the paper's three objectives over one substrate.

A :class:`Policy` declares, over the substrate's candidate grid,

- ``frequency``: the clock each candidate would run at,
- ``feasible``:  the timing constraint (vs the substrate's ``d_worst``),
- ``objective``: the quantity the Solver minimizes per selection domain.

All three are traceable and broadcast over the ``(domains, candidates)``
evaluation arrays, so the Solver's entire search -> thermal -> repeat loop
stays inside one ``lax.while_loop``.

Paper mapping (DESIGN.md §1):

- :class:`PowerSave`  — Algorithm 1 (§III-A): hold the guardbanded clock,
  minimize total power subject to ``delay <= d_worst``.
- :class:`Overscale`  — §III-D: Algorithm 1 with the constraint relaxed to
  ``delay <= gamma * d_worst`` while the clock stays at ``d_worst``
  (violations become bit errors, not slowdown).
- :class:`MinEnergy`  — Algorithm 2 (§III-C): every candidate runs at its
  own maximum frequency ``f = f_nom * d_worst / delay`` (capped by the
  substrate); minimize energy ``P x exec_time(f)``.
- :class:`ErrorTolerant` — §V: Algorithm 1 with the guard band replaced by
  a workload-declared *accuracy budget*: rails below the guard band are
  feasible whenever the predicted escaped-SDC rate behind the ABFT
  checksums (``repro.tolerance``) fits the budget.  ``budget -> 0``
  collapses to :class:`PowerSave` exactly (golden-pinned).

``gamma`` (and ``budget`` for :class:`ErrorTolerant`) is read from ``env``
when present so gamma/budget-sweeps batch through ``Solver.solve_batch``
as a single device call.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

# --- §V escaped-SDC rate model ----------------------------------------------
# Raw SDC rate per MAC at timing overshoot x = delay / d_worst - 1: zero at
# or below the guard band, rising sharply past the critical point (the
# reduced-voltage FPGA NN studies' measured shape — see PAPERS.md).  The
# same constants parameterize the live injector (repro.tolerance.faults), so
# the policy's prediction and the telemetry it is judged against agree.
SDC_RATE0 = 2e-4   # per-MAC rate scale at the critical point
SDC_RATE_K = 28.0  # sharpness of the rise past the critical point
#: fraction of injected SDCs the ABFT row/column checksums cannot repair
#: (multi-flip aliasing within one checksummed block)
ABFT_ESCAPE = 0.02


def escaped_sdc_rate(x):
    """Predicted escaped-SDC rate per MAC behind ABFT at overshoot ``x``.

    Traceable, monotone in ``x`` and exactly zero for ``x <= 0`` (rails at
    or above the guard band inject nothing).
    """
    x = jnp.maximum(jnp.asarray(x, jnp.float32), 0.0)
    return ABFT_ESCAPE * SDC_RATE0 * jnp.expm1(SDC_RATE_K * x)


def overshoot_budget(budget):
    """Inverse of :func:`escaped_sdc_rate`: the largest timing overshoot
    whose predicted escaped rate still fits ``budget`` (0 at budget 0)."""
    b = jnp.maximum(jnp.asarray(budget, jnp.float32), 0.0)
    return jnp.log1p(b / (ABFT_ESCAPE * SDC_RATE0)) / SDC_RATE_K


@dataclass(frozen=True)
class Policy:
    """Base: feasibility at gamma, nominal clock, minimize power."""

    gamma: float = 1.0
    #: which ratio the fleet runtime reports as "saving"
    metric: str = "power"  # "power" | "energy"
    #: route infeasible domains to the nominal candidate (Algorithm 1's
    #: "no margin at this temperature -> stay at nominal rails")
    nominal_fallback: bool = True

    def _gamma(self, env):
        return env.get("gamma", jnp.asarray(self.gamma, jnp.float32))

    def frequency(self, sub, d, env):
        """Clock per (domain, candidate); constraint policies hold f_nom."""
        return jnp.broadcast_to(jnp.asarray(sub.f_nom, jnp.float32), d.shape)

    def feasible(self, sub, d, env):
        return d <= sub.d_worst * self._gamma(env) * (1.0 + 1e-6)

    def objective(self, sub, d, p, f, env):
        return p


@dataclass(frozen=True)
class PowerSave(Policy):
    """Algorithm 1 — minimum power at the guardbanded clock."""


@dataclass(frozen=True)
class Overscale(Policy):
    """§III-D — Algorithm 1 with the timing budget relaxed by gamma >= 1."""

    gamma: float = 1.2


@dataclass(frozen=True)
class MinEnergy(Policy):
    """Algorithm 2 — run each candidate at its own f_max, minimize P x t.

    §III-C: at fixed voltage, max frequency is energy-optimal (leakage
    energy scales with time; dynamic energy does not) — so frequency is
    derived, not searched.
    """

    metric: str = "energy"
    nominal_fallback: bool = False

    def frequency(self, sub, d, env):
        f = sub.f_nom * sub.d_worst / d
        return jnp.minimum(f, sub.f_cap)

    def feasible(self, sub, d, env):
        return jnp.ones_like(d, dtype=bool)  # delay is the clock, not a bound

    def objective(self, sub, d, p, f, env):
        return p * sub.exec_time(f)


@dataclass(frozen=True)
class ErrorTolerant(Policy):
    """§V — Algorithm 1 under an accuracy budget instead of a guard band.

    The timing constraint relaxes to ``delay <= (1 + x_max) * d_worst``
    where ``x_max = overshoot_budget(budget)``: every admitted rail's
    predicted escaped-SDC rate (what leaks past the ABFT checksums into
    the workload) fits the declared budget.  The clock stays at the
    contract — violations become bit errors the ``repro.tolerance`` tier
    detects/corrects, not slowdown — so this is :class:`Overscale` with
    gamma *derived from the error model* rather than hand-picked.

    ``budget`` is read from ``env`` when present, so budget sweeps batch
    through ``Solver.solve_batch`` as one device call.  ``budget=0`` gives
    ``x_max=0`` and reproduces :class:`PowerSave` rails exactly.
    """

    budget: float = 0.0

    def _gamma(self, env):
        b = env.get("budget", jnp.asarray(self.budget, jnp.float32))
        return 1.0 + overshoot_budget(b)


def from_spec(spec) -> Policy:
    """Parse the CLI/runtime policy spec: 'power_save' | 'min_energy' |
    'overscale:<gamma>' | 'error_tolerant:<budget>' — or pass a Policy
    instance through unchanged."""
    if isinstance(spec, Policy):
        return spec
    if spec == "power_save":
        return PowerSave()
    if spec == "min_energy":
        return MinEnergy()
    if spec.startswith("overscale:"):
        try:
            gamma = float(spec.split(":", 1)[1])
        except ValueError:
            raise ValueError(
                f"overscale spec needs a numeric gamma, e.g. "
                f"'overscale:1.2'; got {spec!r}") from None
        return Overscale(gamma=gamma)
    if spec.startswith("error_tolerant"):
        try:
            budget = (float(spec.split(":", 1)[1]) if ":" in spec else 0.0)
        except ValueError:
            raise ValueError(
                f"error_tolerant spec needs a numeric escaped-SDC budget, "
                f"e.g. 'error_tolerant:1e-5'; got {spec!r}") from None
        return ErrorTolerant(budget=budget)
    raise ValueError(f"unknown energy policy spec: {spec!r}")
