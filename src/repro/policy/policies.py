"""Policy objects — the paper's three objectives over one substrate.

A :class:`Policy` declares, over the substrate's candidate grid,

- ``frequency``: the clock each candidate would run at,
- ``feasible``:  the timing constraint (vs the substrate's ``d_worst``),
- ``objective``: the quantity the Solver minimizes per selection domain.

All three are traceable and broadcast over the ``(domains, candidates)``
evaluation arrays, so the Solver's entire search -> thermal -> repeat loop
stays inside one ``lax.while_loop``.

Paper mapping (DESIGN.md §1):

- :class:`PowerSave`  — Algorithm 1 (§III-A): hold the guardbanded clock,
  minimize total power subject to ``delay <= d_worst``.
- :class:`Overscale`  — §III-D: Algorithm 1 with the constraint relaxed to
  ``delay <= gamma * d_worst`` while the clock stays at ``d_worst``
  (violations become bit errors, not slowdown).
- :class:`MinEnergy`  — Algorithm 2 (§III-C): every candidate runs at its
  own maximum frequency ``f = f_nom * d_worst / delay`` (capped by the
  substrate); minimize energy ``P x exec_time(f)``.

``gamma`` is read from ``env`` when present so gamma-sweeps batch through
``Solver.solve_batch`` as a single device call.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class Policy:
    """Base: feasibility at gamma, nominal clock, minimize power."""

    gamma: float = 1.0
    #: which ratio the fleet runtime reports as "saving"
    metric: str = "power"  # "power" | "energy"
    #: route infeasible domains to the nominal candidate (Algorithm 1's
    #: "no margin at this temperature -> stay at nominal rails")
    nominal_fallback: bool = True

    def _gamma(self, env):
        return env.get("gamma", jnp.asarray(self.gamma, jnp.float32))

    def frequency(self, sub, d, env):
        """Clock per (domain, candidate); constraint policies hold f_nom."""
        return jnp.broadcast_to(jnp.asarray(sub.f_nom, jnp.float32), d.shape)

    def feasible(self, sub, d, env):
        return d <= sub.d_worst * self._gamma(env) * (1.0 + 1e-6)

    def objective(self, sub, d, p, f, env):
        return p


@dataclass(frozen=True)
class PowerSave(Policy):
    """Algorithm 1 — minimum power at the guardbanded clock."""


@dataclass(frozen=True)
class Overscale(Policy):
    """§III-D — Algorithm 1 with the timing budget relaxed by gamma >= 1."""

    gamma: float = 1.2


@dataclass(frozen=True)
class MinEnergy(Policy):
    """Algorithm 2 — run each candidate at its own f_max, minimize P x t.

    §III-C: at fixed voltage, max frequency is energy-optimal (leakage
    energy scales with time; dynamic energy does not) — so frequency is
    derived, not searched.
    """

    metric: str = "energy"
    nominal_fallback: bool = False

    def frequency(self, sub, d, env):
        f = sub.f_nom * sub.d_worst / d
        return jnp.minimum(f, sub.f_cap)

    def feasible(self, sub, d, env):
        return jnp.ones_like(d, dtype=bool)  # delay is the clock, not a bound

    def objective(self, sub, d, p, f, env):
        return p * sub.exec_time(f)


def from_spec(spec) -> Policy:
    """Parse the CLI/runtime policy spec: 'power_save' | 'min_energy' |
    'overscale:<gamma>' — or pass a Policy instance through unchanged."""
    if isinstance(spec, Policy):
        return spec
    if spec == "power_save":
        return PowerSave()
    if spec == "min_energy":
        return MinEnergy()
    if spec.startswith("overscale:"):
        try:
            gamma = float(spec.split(":", 1)[1])
        except ValueError:
            raise ValueError(
                f"overscale spec needs a numeric gamma, e.g. "
                f"'overscale:1.2'; got {spec!r}") from None
        return Overscale(gamma=gamma)
    raise ValueError(f"unknown energy policy spec: {spec!r}")
