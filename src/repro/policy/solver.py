"""The shared fixed-point Solver (search -> thermal solve -> repeat).

One engine runs every flow in the repo: Algorithm 1 (PowerSave), Algorithm 2
(MinEnergy), §III-D over-scaling (Overscale), and the TPU fleet runtime —
specialization lives entirely in the :class:`Policy` and the
:class:`Substrate`.

The loop is a single ``lax.while_loop`` — no Python iteration anywhere:

    d      = substrate.cand_delay(T)            # (domains, candidates)
    f      = policy.frequency(d)                #   "
    p      = substrate.cand_power(T, f)         #   "
    idx    = argmin over feasible candidates    # (domains,)
    T_new  = thermal.solve(site_power(idx), T0=T)  # (sites,) warm-started
    done   = ||T_new - T||_inf < delta_t

``d_worst`` (the STA / step contract) is computed once by the substrate and
closed over as a constant.  ``solve_batch`` vmaps the whole fixed point over
an environment batch (ambient temperatures, activities, gamma budgets), so a
dynamic-scheme LUT or a gamma sweep is ONE compiled device call instead of N
sequential ``run()``s.  Converged batch elements freeze (their state is
re-selected) so batched results equal the sequential ones exactly.

``solve_batch(..., early_freeze=True)`` goes one step further: instead of
every element running lockstep until the slowest converges (frozen elements
still pay the candidate search each iteration under vmap), the fixed point
runs in short jitted *segments* and converged elements are compacted out of
the batch between segments (padded to power-of-two buckets so the number of
compiled shapes stays logarithmic).  The per-element iteration bodies are
the same traced code, so every *decision* (chosen candidates, iteration
counts, convergence flags, per-iteration choice history) is bit-identical
to the lockstep path; the continuous thermal/power leaves agree to f32
round-off (XLA picks a batch-shape-dependent summation order inside the
vmapped solves — ~1e-4 degC on T, orders below ``delta_t`` and the 10 mV
rail grid).  Pinned in ``tests/test_railfield.py``; the 2-D RailField
sweep build uses this path.

Per-iteration history (chosen candidate, total power, mean junction
temperature) is recorded into fixed ``max_iters`` slots for the legacy trace
dataclasses.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thermal
from repro.policy.policies import Policy
from repro.policy.substrate import Env, Substrate


class Solution(NamedTuple):
    """Converged operating point; all leaves gain a leading batch axis
    under :meth:`Solver.solve_batch`."""

    idx: jnp.ndarray        # (D,)  chosen candidate per domain
    f: jnp.ndarray          # (D,)  chosen clock at the last search
    power: jnp.ndarray      # (D,)  domain power at the last search T
    obj: jnp.ndarray        # (D,)  objective value at the last search
    T: jnp.ndarray          # (S,)  converged temperature field
    n_iters: jnp.ndarray    # ()    fixed-point iterations performed
    converged: jnp.ndarray  # ()    bool
    d_final: jnp.ndarray    # (D,)  delay of the choice at the converged T
    f_final: jnp.ndarray    # (D,)  clock of the choice at the converged T
    p_final: jnp.ndarray    # (D,)  domain power of the choice at converged T
    idx_hist: jnp.ndarray   # (I, D) per-iteration choices
    p_hist: jnp.ndarray     # (I,)  per-iteration total power
    tj_hist: jnp.ndarray    # (I,)  per-iteration mean junction temperature


class _State(NamedTuple):
    T: jnp.ndarray
    it: jnp.ndarray
    idx: jnp.ndarray
    f_sel: jnp.ndarray
    p_sel: jnp.ndarray
    obj_sel: jnp.ndarray
    done: jnp.ndarray
    idx_hist: jnp.ndarray
    p_hist: jnp.ndarray
    tj_hist: jnp.ndarray


class Solver:
    """Jitted fixed point of (policy, substrate); reusable across calls.

    ``refine_window`` (volts) enables the paper's O(1) refinement: after the
    first iteration the search is masked to a +-window neighbourhood of the
    previous solution.  The nominal fallback ignores the window, exactly as
    the legacy boundary search fell back to nominal rails.
    """

    def __init__(self, substrate: Substrate, policy: Policy,
                 delta_t: float = 0.1, max_iters: int = 10,
                 refine_window: Optional[float] = None):
        if max_iters < 1:  # guard: a zero-iteration loop has no solution
            max_iters = 1
        self.substrate = substrate
        self.policy = policy
        substrate.d_worst  # force the cached STA eagerly, outside any trace
        self.delta_t = float(delta_t)
        self.max_iters = int(max_iters)
        self.refine_window = refine_window
        self._jit_solve = jax.jit(self._fixed_point)
        self._jit_batch = jax.jit(jax.vmap(self._fixed_point,
                                           in_axes=(0, 0)))
        self._jit_segments: Dict[int, Any] = {}  # seg -> vmapped segment
        self._jit_finalize = None  # built lazily (early-freeze path only)

    # ------------------------------------------------------------------
    def _select(self, T, it, idx_prev, env):
        """One grid search at temperature field T -> (idx, f, p, obj)."""
        sub, pol = self.substrate, self.policy
        d = sub.cand_delay(T, env)                      # (D, C)
        f = pol.frequency(sub, d, env)                  # (D, C)
        p = sub.cand_power(T, f, env)                   # (D, C)
        feas = pol.feasible(sub, d, env)                # (D, C)
        if self.refine_window is not None:
            wmask = sub.window_mask(idx_prev, self.refine_window)
            feas = feas & (wmask | (it == 0))
        obj = pol.objective(sub, d, p, f, env)
        obj_m = jnp.where(feas, obj, jnp.inf)
        idx = jnp.argmin(obj_m, axis=-1)                # (D,)
        if pol.nominal_fallback:
            ok = jnp.any(feas, axis=-1)
            idx = jnp.where(ok, idx, sub.nominal_idx)
        take = lambda a: jnp.take_along_axis(a, idx[:, None], -1)[:, 0]
        return idx, take(f), take(p), take(obj)

    def _body(self, env: Env, st: _State) -> _State:
        """One fixed-point iteration (select -> thermal -> convergence)."""
        sub = self.substrate
        m, n = sub.grid
        idx, f_sel, p_sel, obj_sel = self._select(st.T, st.it, st.idx, env)
        sp = sub.site_power(st.T, idx, f_sel, env)
        # warm-start the multigrid solve from the previous iteration's
        # field: consecutive fixed-point iterates differ by at most a
        # rail step's worth of heating, so late iterations converge in
        # one or two V-cycles
        T_new = thermal.solve(sp, m, n, env["t_amb"], sub.thermal_cfg,
                              st.T)
        dT = jnp.max(jnp.abs(T_new - st.T))
        new = _State(
            T=T_new, it=st.it + 1, idx=idx, f_sel=f_sel, p_sel=p_sel,
            obj_sel=obj_sel, done=dT < self.delta_t,
            idx_hist=st.idx_hist.at[st.it].set(idx),
            p_hist=st.p_hist.at[st.it].set(jnp.sum(p_sel)),
            tj_hist=st.tj_hist.at[st.it].set(jnp.mean(T_new)),
        )
        # under vmap the loop runs until ALL batch elements converge;
        # freezing finished elements keeps batched == sequential
        return jax.tree_util.tree_map(
            lambda old, upd: jnp.where(st.done, old, upd), st, new)

    def _init_np(self, B: int, T0: np.ndarray) -> _State:
        """The batched start state as host arrays (the compaction loop
        scatters segment results back into these in place)."""
        I, D = self.max_iters, self.substrate.n_domains
        return _State(
            T=np.asarray(T0, np.float32).copy(),
            it=np.zeros((B,), np.int32),
            idx=np.full((B, D), self.substrate.nominal_idx, np.int32),
            f_sel=np.zeros((B, D), np.float32),
            p_sel=np.zeros((B, D), np.float32),
            obj_sel=np.zeros((B, D), np.float32),
            done=np.zeros((B,), bool),
            idx_hist=np.zeros((B, I, D), np.int32),
            p_hist=np.zeros((B, I), np.float32),
            tj_hist=np.zeros((B, I), np.float32),
        )

    def _run_segment(self, env: Env, st: _State, seg: int) -> _State:
        """Up to ``seg`` fixed-point iterations (stops early on done)."""
        def body(c):
            st, k = c
            return self._body(env, st), k + 1

        def cond(c):
            st, k = c
            return (~st.done) & (st.it < self.max_iters) & (k < seg)

        st, _ = jax.lax.while_loop(cond, body, (st, jnp.int32(0)))
        return st

    def _finalize(self, env: Env, st: _State) -> Solution:
        # re-evaluate the final choice at the converged temperature field
        # (the legacy flows report baseline power / Algorithm-2 delay there)
        sub = self.substrate
        d_fin = sub.delay_at(st.T, st.idx, env)
        f_fin = self.policy.frequency(sub, d_fin, env)
        p_fin = sub.power_at(st.T, st.idx, f_fin, env)
        return Solution(
            idx=st.idx, f=st.f_sel, power=st.p_sel, obj=st.obj_sel, T=st.T,
            n_iters=st.it, converged=st.done,
            d_final=d_fin, f_final=f_fin, p_final=p_fin,
            idx_hist=st.idx_hist, p_hist=st.p_hist, tj_hist=st.tj_hist,
        )

    def _fixed_point(self, env: Env, T0) -> Solution:
        sub = self.substrate
        I, D = self.max_iters, sub.n_domains
        st0 = _State(
            T=jnp.asarray(T0, jnp.float32),
            it=jnp.int32(0),
            idx=jnp.full((D,), sub.nominal_idx, jnp.int32),
            f_sel=jnp.zeros((D,), jnp.float32),
            p_sel=jnp.zeros((D,), jnp.float32),
            obj_sel=jnp.zeros((D,), jnp.float32),
            done=jnp.bool_(False),
            idx_hist=jnp.zeros((I, D), jnp.int32),
            p_hist=jnp.zeros((I,), jnp.float32),
            tj_hist=jnp.zeros((I,), jnp.float32),
        )
        st = jax.lax.while_loop(
            lambda st: (~st.done) & (st.it < I),
            lambda st: self._body(env, st), st0)
        return self._finalize(env, st)

    # ------------------------------------------------------------------
    @staticmethod
    def _env_arrays(env: Dict[str, Any]) -> Env:
        return {k: jnp.asarray(v, jnp.float32) for k, v in env.items()}

    def solve(self, env: Dict[str, Any], T0=None) -> Solution:
        """Run the fixed point for one environment (concrete result)."""
        env = self._env_arrays(env)
        if T0 is None:
            T0 = self.substrate.T0(env)
        return jax.tree_util.tree_map(
            lambda x: jax.device_get(x), self._jit_solve(env, T0))

    def solve_batch(self, envs: Dict[str, Any], T0=None, *,
                    early_freeze: bool = False,
                    segment: int = 2) -> Solution:
        """vmap the fixed point over the leading axis of every env leaf.

        One compiled call evaluates the whole batch — this is the dynamic
        scheme's LUT build and the gamma sweep of §III-D.

        ``early_freeze=True`` runs the batch in jitted segments of
        ``segment`` fixed-point iterations, compacting converged elements
        out of the batch between segments (they stop iterating instead of
        riding lockstep until the slowest element converges).  Sub-batches
        are padded to power-of-two buckets so at most ``log2(B)`` segment
        shapes ever compile.  Decisions are bit-identical to the lockstep
        path; continuous leaves agree to f32 round-off (see the module
        docstring) — pinned in ``tests/test_railfield.py``.
        """
        envs = self._env_arrays(envs)
        B = int(next(iter(envs.values())).shape[0])
        for k, v in envs.items():
            if v.shape[:1] != (B,):
                raise ValueError(
                    f"env leaf {k!r} must lead with the batch axis {B}, "
                    f"got shape {v.shape}")
        if T0 is None:
            # one vmapped device call instead of B host-side T0 solves
            T0 = jax.vmap(self.substrate.T0)(envs)
        if not early_freeze:
            return jax.tree_util.tree_map(
                lambda x: jax.device_get(x), self._jit_batch(envs, T0))
        return self._solve_batch_freeze(envs, np.asarray(T0), B,
                                        max(int(segment), 1))

    # -- early-freeze batched fixed point ------------------------------
    def _segment_fn(self, seg: int):
        fn = self._jit_segments.get(seg)
        if fn is None:
            fn = jax.jit(jax.vmap(
                lambda env, st: self._run_segment(env, st, seg),
                in_axes=(0, 0)))
            self._jit_segments[seg] = fn
        return fn

    def _solve_batch_freeze(self, envs: Env, T0: np.ndarray, B: int,
                            seg: int) -> Solution:
        env_np = {k: np.asarray(v) for k, v in envs.items()}
        st = self._init_np(B, T0)
        run_seg = self._segment_fn(seg)
        active = np.arange(B)
        while active.size:
            # pad the active set to the next power-of-two bucket, capped at
            # the full batch (the first segment must not waste lanes past
            # B); padding repeats the first active element and its
            # duplicate rows are discarded
            P = min(1 << (int(active.size) - 1).bit_length(), B)
            pad = np.concatenate(
                [active, np.repeat(active[:1], P - active.size)])
            sub_env = {k: v[pad] for k, v in env_np.items()}
            sub_st = jax.tree_util.tree_map(lambda x: x[pad], st)
            out = jax.device_get(run_seg(sub_env, sub_st))
            n = int(active.size)
            for cur, new in zip(st, out):
                cur[active] = np.asarray(new)[:n]
            keep = (~st.done[active]) & (st.it[active] < self.max_iters)
            active = active[keep]
        if self._jit_finalize is None:
            self._jit_finalize = jax.jit(jax.vmap(self._finalize,
                                                  in_axes=(0, 0)))
        st_dev = jax.tree_util.tree_map(jnp.asarray, st)
        return jax.tree_util.tree_map(
            lambda x: jax.device_get(x), self._jit_finalize(envs, st_dev))


# =============================================================================
# solver cache — repeated wrapper calls reuse compiled fixed points
# =============================================================================

_CACHE_LIMIT = 32  # LRU bound: sweeps over generated netlists must not
_SOLVER_CACHE: "OrderedDict" = OrderedDict()  # pin jits for process lifetime


def cached_solver(substrate: Substrate, policy: Policy,
                  delta_t: float = 0.1, max_iters: int = 10,
                  refine_window: Optional[float] = None) -> Solver:
    """Memoize Solver instances (and so their jit caches) by configuration.

    Substrates are compared by identity — pair with the memoized substrate
    constructors in ``repro.policy.substrate``.  Policies are frozen
    dataclasses and compare by value.  Entries hold the substrate (via the
    Solver), so an id key can never alias a collected substrate.
    """
    key = (id(substrate), policy, float(delta_t), int(max_iters),
           refine_window)
    if key in _SOLVER_CACHE:
        _SOLVER_CACHE.move_to_end(key)
        return _SOLVER_CACHE[key]
    solver = _SOLVER_CACHE[key] = Solver(substrate, policy, delta_t,
                                         max_iters, refine_window)
    if len(_SOLVER_CACHE) > _CACHE_LIMIT:
        _SOLVER_CACHE.popitem(last=False)
    return solver
