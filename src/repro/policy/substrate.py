"""Substrate protocol — the device a thermal-aware policy optimizes.

A :class:`Substrate` is everything Algorithm 1/2 need to know about a piece
of silicon (DESIGN.md §2):

- a *site grid* ``(m, n)`` the thermal solver runs on (FPGA tiles / pod
  chips) with a :class:`~repro.core.thermal.ThermalConfig`,
- one or more *selection domains* ``D`` that each pick a candidate operating
  point independently (the whole die for an FPGA — one shared rail pair —
  and every chip of a TPU pod),
- a flat *candidate grid* of ``C`` operating points (the (V_core, V_bram) /
  (v_core, v_sram) mesh) with the nominal point at ``nominal_idx``,
- traceable physics: per-candidate delay at a temperature field
  (``cand_delay``), per-candidate domain power (``cand_power``), and the
  per-site power map of a chosen selection (``site_power``) that feeds the
  thermal solve,
- the timing reference ``d_worst`` (STA at T_MAX and nominal rails for the
  FPGA; the relative step-time contract ``1.0`` for the TPU pod), computed
  once and cached.

Two implementations live here: :class:`FpgaNetlistSubstrate` wraps
``core/netlist.py`` (the paper's placed-and-routed designs) and
:class:`TpuFleetSubstrate` wraps ``core/tpu_fleet.py`` (the pod
re-parameterization).  Policies and the Solver never import either module —
they only see this protocol, which is what lets one fixed-point engine serve
Algorithm 1, Algorithm 2, over-scaling, and the fleet runtime.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import netlist as NL
from repro.core import thermal
from repro.core import tpu_fleet as TF
from repro.core.netlist import Netlist

# degC guard on timing eval (TSD error / spatial gradients, paper §III-B)
T_GUARD = 2.0

# the paper's Algorithm-1 voltage mesh (10 mV steps)
V_CORE_GRID = np.round(np.arange(0.55, 0.801, 0.01), 3)
V_BRAM_GRID = np.round(np.arange(0.55, 0.951, 0.01), 3)

Env = Dict[str, jnp.ndarray]


@runtime_checkable
class Substrate(Protocol):
    """Structural protocol; see the module docstring for the contract."""

    grid: Tuple[int, int]
    thermal_cfg: thermal.ThermalConfig
    n_domains: int
    n_candidates: int
    nominal_idx: int
    f_nom: float        # frequency held by constraint policies (GHz or rel)
    f_cap: float        # upper clock bound for frequency-scaling policies

    @property
    def d_worst(self) -> float: ...

    def T0(self, env: Env) -> jnp.ndarray: ...
    def cand_delay(self, T_sites, env: Env) -> jnp.ndarray: ...
    def cand_power(self, T_sites, f, env: Env) -> jnp.ndarray: ...
    def site_power(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray: ...
    def delay_at(self, T_sites, idx, env: Env) -> jnp.ndarray: ...
    def power_at(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray: ...
    def window_mask(self, idx_prev, window: float) -> jnp.ndarray: ...
    def exec_time(self, f) -> jnp.ndarray: ...
    def nominal_only(self) -> "Substrate": ...


# =============================================================================
# FPGA netlist substrate (Algorithm 1/2 on the paper's designs)
# =============================================================================

class FpgaNetlistSubstrate:
    """One placed-and-routed design; a single (V_core, V_bram) domain.

    ``env`` keys: ``t_amb`` (ambient degC), ``act`` (primary-input activity).
    Delay is evaluated at ``T + T_GUARD`` (paper §III-B guard), power at T.
    """

    def __init__(self, netlist: Netlist,
                 lib: Optional[C.DeviceLibrary] = None,
                 tc: thermal.ThermalConfig = thermal.ThermalConfig(),
                 v_core_grid=None, v_bram_grid=None,
                 _d_worst: Optional[float] = None):
        self.netlist = netlist
        self.lib = lib or C.default_library()
        self.thermal_cfg = tc
        self.grid = (netlist.m, netlist.n)
        self.nlj = netlist.as_jax()
        vc = np.asarray(V_CORE_GRID if v_core_grid is None else v_core_grid,
                        np.float32)
        vb = np.asarray(V_BRAM_GRID if v_bram_grid is None else v_bram_grid,
                        np.float32)
        VC, VB = np.meshgrid(vc, vb, indexing="ij")
        self.vc_flat = jnp.asarray(VC.reshape(-1))
        self.vb_flat = jnp.asarray(VB.reshape(-1))
        self.n_domains = 1
        self.n_candidates = int(self.vc_flat.shape[0])
        nom = (np.abs(VC.reshape(-1) - C.V_CORE_NOM)
               + np.abs(VB.reshape(-1) - C.V_BRAM_NOM))
        self.nominal_idx = int(np.argmin(nom))
        self._d_worst = _d_worst
        self.f_cap = np.inf  # Algorithm 2 may overclock past f_base

    @property
    def d_worst(self) -> float:
        """STA at (T_MAX, nominal rails) [ns] — the guardbanded clock."""
        if self._d_worst is None:
            n_tiles = self.netlist.n_tiles
            self._d_worst = float(NL.crit_delay(
                self.lib, self.nlj, jnp.full((n_tiles,), C.T_MAX),
                C.V_CORE_NOM, C.V_BRAM_NOM))
        return self._d_worst

    @property
    def f_nom(self) -> float:
        return 1.0 / self.d_worst  # GHz; the clock stays at d_worst

    def T0(self, env: Env) -> jnp.ndarray:
        return jnp.full((self.netlist.n_tiles,),
                        jnp.asarray(env["t_amb"], jnp.float32))

    def cand_delay(self, T_sites, env: Env) -> jnp.ndarray:
        d = jax.vmap(lambda vc, vb: NL.crit_delay(
            self.lib, self.nlj, T_sites + T_GUARD, vc, vb))(
                self.vc_flat, self.vb_flat)
        return d[None, :]  # (1, C)

    def cand_power(self, T_sites, f, env: Env) -> jnp.ndarray:
        act = env["act"]

        def total(vc, vb, f_ghz):
            lkg, dyn = NL.tile_power(self.lib, self.nlj, T_sites, vc, vb,
                                     f_ghz, act)
            return jnp.sum(lkg) + jnp.sum(dyn)

        f_c = jnp.broadcast_to(f, (1, self.n_candidates))[0]
        return jax.vmap(total)(self.vc_flat, self.vb_flat, f_c)[None, :]

    def site_power(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray:
        vc, vb = self.vc_flat[idx[0]], self.vb_flat[idx[0]]
        lkg, dyn = NL.tile_power(self.lib, self.nlj, T_sites, vc, vb,
                                 f_sel[0], env["act"])
        return lkg + dyn  # (n_tiles,) [mW]

    def delay_at(self, T_sites, idx, env: Env) -> jnp.ndarray:
        d = NL.crit_delay(self.lib, self.nlj, T_sites + T_GUARD,
                          self.vc_flat[idx[0]], self.vb_flat[idx[0]])
        return d[None]

    def power_at(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray:
        return jnp.sum(self.site_power(T_sites, idx, f_sel, env))[None]

    def window_mask(self, idx_prev, window: float) -> jnp.ndarray:
        """Paper's O(1) refinement: candidates within ±window V of the
        previous solution on both rails."""
        vc_p, vb_p = self.vc_flat[idx_prev[0]], self.vb_flat[idx_prev[0]]
        m = ((jnp.abs(self.vc_flat - vc_p) <= window)
             & (jnp.abs(self.vb_flat - vb_p) <= window))
        return m[None, :]

    def exec_time(self, f) -> jnp.ndarray:
        return 1.0 / f  # one clock period [ns]

    def nominal_only(self) -> "FpgaNetlistSubstrate":
        if getattr(self, "_nominal", None) is None:
            self._nominal = FpgaNetlistSubstrate(
                self.netlist, self.lib, self.thermal_cfg,
                v_core_grid=[C.V_CORE_NOM], v_bram_grid=[C.V_BRAM_NOM],
                _d_worst=self.d_worst)
        return self._nominal

    def decode(self, idx) -> Tuple[np.ndarray, np.ndarray]:
        """Candidate index -> (v_core, v_bram) as numpy."""
        return (np.asarray(self.vc_flat)[np.asarray(idx)],
                np.asarray(self.vb_flat)[np.asarray(idx)])


# =============================================================================
# TPU fleet substrate (the pod re-parameterization, DESIGN.md §2)
# =============================================================================

class TpuFleetSubstrate:
    """A (m x n)-chip pod; every chip is its own selection domain.

    ``env`` keys: ``t_amb``, ``util`` (per-chip utilization scale, (D,)).
    ``d_worst`` is the *relative* step-time contract 1.0: a candidate is
    feasible when its worst pipeline delay factor stays within gamma of it.
    """

    def __init__(self, prof: TF.StepProfile,
                 lib: Optional[TF.TpuLibrary] = None,
                 grid: Tuple[int, int] = (16, 16),
                 theta_chip: float = 0.20,
                 tc: Optional[thermal.ThermalConfig] = None,
                 v_core_grid=None, v_sram_grid=None,
                 warm_offset: float = 25.0):
        self.prof = prof
        self.lib = lib or TF.TpuLibrary()
        self.grid = grid
        self.thermal_cfg = tc or TF.pod_thermal_config(theta_chip,
                                                       grid[0] * grid[1])
        vc = np.asarray(
            np.arange(0.55, TF.V_CORE_NOM + 0.001, 0.01)
            if v_core_grid is None else v_core_grid, np.float32)
        vs = np.asarray(
            np.arange(0.60, TF.V_SRAM_NOM + 0.001, 0.01)
            if v_sram_grid is None else v_sram_grid, np.float32)
        VC, VS = np.meshgrid(vc, vs, indexing="ij")
        self.vc_flat = jnp.asarray(VC.reshape(-1))
        self.vs_flat = jnp.asarray(VS.reshape(-1))
        self.n_domains = grid[0] * grid[1]
        self.n_candidates = int(self.vc_flat.shape[0])
        nom = (np.abs(VC.reshape(-1) - TF.V_CORE_NOM)
               + np.abs(VS.reshape(-1) - TF.V_SRAM_NOM))
        self.nominal_idx = int(np.argmin(nom))
        self.warm_offset = warm_offset
        self.f_nom = 1.0
        self.f_cap = 1.0  # the pod never overclocks past the rated step

    @property
    def d_worst(self) -> float:
        return 1.0  # the step-time contract, in relative units

    def T0(self, env: Env) -> jnp.ndarray:
        return jnp.full((self.n_domains,),
                        jnp.asarray(env["t_amb"], jnp.float32)
                        + self.warm_offset)

    def cand_delay(self, T_sites, env: Env) -> jnp.ndarray:
        """Worst relative pipeline delay 1/f_max per (chip, candidate)."""
        Tg = T_sites[:, None] + T_GUARD
        fmax = TF.f_max_rel(self.lib, self.vc_flat[None, :],
                            self.vs_flat[None, :], Tg)
        return 1.0 / fmax  # (D, C)

    def cand_power(self, T_sites, f, env: Env) -> jnp.ndarray:
        p = TF.chip_power(self.lib, self.prof, self.vc_flat[None, :],
                          self.vs_flat[None, :], f, T_sites[:, None])
        return p * env["util"][:, None]  # (D, C) [W]

    def site_power(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray:
        p = TF.chip_power(self.lib, self.prof, self.vc_flat[idx],
                          self.vs_flat[idx], f_sel, T_sites)
        return p * env["util"] * 1e3  # (D,) [mW] for the thermal solver

    def delay_at(self, T_sites, idx, env: Env) -> jnp.ndarray:
        fmax = TF.f_max_rel(self.lib, self.vc_flat[idx], self.vs_flat[idx],
                            T_sites + T_GUARD)
        return 1.0 / fmax

    def power_at(self, T_sites, idx, f_sel, env: Env) -> jnp.ndarray:
        p = TF.chip_power(self.lib, self.prof, self.vc_flat[idx],
                          self.vs_flat[idx], f_sel, T_sites)
        return p * env["util"]

    def window_mask(self, idx_prev, window: float) -> jnp.ndarray:
        vc_p = self.vc_flat[idx_prev][:, None]
        vs_p = self.vs_flat[idx_prev][:, None]
        return ((jnp.abs(self.vc_flat[None, :] - vc_p) <= window)
                & (jnp.abs(self.vs_flat[None, :] - vs_p) <= window))

    def exec_time(self, f) -> jnp.ndarray:
        """Relative step time when the core clock runs at f x nominal."""
        scal = self.prof.f_scalable
        return scal / f + (1.0 - scal)

    def nominal_only(self) -> "TpuFleetSubstrate":
        if getattr(self, "_nominal", None) is None:
            self._nominal = TpuFleetSubstrate(
                self.prof, self.lib, self.grid, tc=self.thermal_cfg,
                v_core_grid=[TF.V_CORE_NOM], v_sram_grid=[TF.V_SRAM_NOM],
                warm_offset=self.warm_offset)
        return self._nominal

    def decode(self, idx) -> Tuple[np.ndarray, np.ndarray]:
        return (np.asarray(self.vc_flat)[np.asarray(idx)],
                np.asarray(self.vs_flat)[np.asarray(idx)])


# =============================================================================
# substrate caches (stable jit keys for the Solver cache in solver.py)
# =============================================================================

_CACHE_LIMIT = 16  # LRU bound: a netlist sweep must not pin jits forever
_FPGA_CACHE: "OrderedDict" = OrderedDict()
_TPU_CACHE: "OrderedDict" = OrderedDict()


def _lru_get(cache, key, make):
    if key in cache:
        cache.move_to_end(key)
        return cache[key]
    val = cache[key] = make()
    if len(cache) > _CACHE_LIMIT:
        cache.popitem(last=False)
    return val


def fpga_substrate(netlist: Netlist, lib=None,
                   tc: thermal.ThermalConfig = thermal.ThermalConfig()
                   ) -> FpgaNetlistSubstrate:
    """Memoized substrate so repeated ``run()`` calls share compiled solvers.

    Keyed by netlist identity (netlists are cached by vtr_benchmarks.load)
    and by library/thermal *value* (both are frozen dataclasses); LRU-bounded
    so ad-hoc ``NL.generate`` netlists don't pin memory for the process
    lifetime.
    """
    lib = lib or C.default_library()
    key = (id(netlist), lib, tc)
    return _lru_get(_FPGA_CACHE, key,
                    lambda: FpgaNetlistSubstrate(netlist, lib, tc))


def tpu_substrate(prof: TF.StepProfile, lib=None,
                  grid: Tuple[int, int] = (16, 16),
                  theta_chip: float = 0.20) -> TpuFleetSubstrate:
    lib = lib or TF.TpuLibrary()
    key = (prof, lib, grid, theta_chip)
    return _lru_get(_TPU_CACHE, key,
                    lambda: TpuFleetSubstrate(prof, lib, grid, theta_chip))
