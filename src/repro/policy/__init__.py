"""repro.policy — one Substrate/Policy/Solver stack for every thermal-aware
flow in the repo (see DESIGN.md).

    from repro import policy as pol

    sub = pol.fpga_substrate(netlist, tc=thermal.ThermalConfig(theta_ja=12.0))
    sol = pol.cached_solver(sub, pol.PowerSave()).solve(
        {"t_amb": 60.0, "act": 1.0})
    v_core, v_bram = sub.decode(sol.idx)

Legacy entry points (``core.voltage_scaling.run``, ``core.energy_opt.run``,
``core.overscaling.run``, ``core.runtime.EnergyAwareRuntime``) are thin
wrappers over this API and keep their result dataclasses.
"""
from repro.policy.policies import (ABFT_ESCAPE, SDC_RATE0, SDC_RATE_K,
                                   ErrorTolerant, MinEnergy, Overscale,
                                   Policy, PowerSave, escaped_sdc_rate,
                                   from_spec, overshoot_budget)
from repro.policy.solver import Solution, Solver, cached_solver
from repro.policy.substrate import (T_GUARD, V_BRAM_GRID, V_CORE_GRID,
                                    FpgaNetlistSubstrate, Substrate,
                                    TpuFleetSubstrate, fpga_substrate,
                                    tpu_substrate)

__all__ = [
    "Policy", "PowerSave", "MinEnergy", "Overscale", "ErrorTolerant",
    "from_spec", "escaped_sdc_rate", "overshoot_budget",
    "SDC_RATE0", "SDC_RATE_K", "ABFT_ESCAPE",
    "Solver", "Solution", "cached_solver",
    "Substrate", "FpgaNetlistSubstrate", "TpuFleetSubstrate",
    "fpga_substrate", "tpu_substrate",
    "T_GUARD", "V_CORE_GRID", "V_BRAM_GRID",
]
