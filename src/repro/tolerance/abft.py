"""ABFT detect/correct over the checksummed over-scaled matmul (§V).

The kernel (``kernels/abft_matmul``) produces the corrupted product C' and
its fused row/column sums; this module compares them with the protected
references (``row_ref = A @ colsum(B)``, ``col_ref = rowsum(A) @ B``) and
repairs what the syndromes localize:

- an XOR flip of bit b in element (i, j) shifts ``rowsum[i]`` and
  ``colsum[j]`` by the same delta (mod 2^32) — a matching nonzero pair
  ``dr[i] == dc[j]`` pinpoints the cell, and subtracting the delta restores
  it exactly;
- multiple flips sharing a row/column alias: their syndromes are detected
  but not uniquely localizable — those remain as *escapes* (the residue the
  ``ErrorTolerant`` accuracy budget is declared against).

:class:`AbftMatmul` is the app-facing drop-in (mirrors
``kernels.overscale_matmul.make_int8_error_matmul``): quantize -> inject ->
detect/correct -> requantize, accumulating detect/correct/escape counters.
:func:`routed_matmuls` installs it on the model layers' matmul hook so a
full inference config (e.g. ``configs/llama3_2_1b``) runs its MLP matmuls
through the checksummed kernel — the accuracy-vs-rail curve machinery of
``examples/overscaling_study.py``.
"""
from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref as kref
from repro.kernels.abft_matmul import abft_matmul, checksum_refs
from repro.kernels.overscale_matmul import bit_probs_to_cdf, quantize


@dataclass
class AbftCounters:
    """Cumulative SDC ledger of one :class:`AbftMatmul` stream."""
    checked: int = 0    # output elements covered by the checksums
    injected: int = 0   # ground-truth corrupted elements (simulation-only)
    detected: int = 0   # elements the syndromes flagged
    corrected: int = 0  # elements repaired exactly
    escaped: int = 0    # still-wrong elements after repair

    @property
    def detect_rate(self) -> float:
        return self.detected / self.injected if self.injected else 0.0

    @property
    def escape_rate(self) -> float:
        return self.escaped / self.checked if self.checked else 0.0


def detect_and_correct(c, rowsum, colsum, row_ref, col_ref
                       ) -> Tuple[np.ndarray, int, int]:
    """Repair uniquely-localized single flips; return (c_fixed, detected,
    corrected).  All int32, arithmetic wrapping mod 2^32 on both sides of
    every syndrome."""
    c = np.asarray(c, np.int32).copy()
    dr = np.subtract(np.asarray(rowsum, np.int32),
                     np.asarray(row_ref, np.int32), dtype=np.int32)
    dc = np.subtract(np.asarray(colsum, np.int32),
                     np.asarray(col_ref, np.int32), dtype=np.int32)
    # corrupted cells announce themselves on both axes; aliasing (several
    # flips sharing a row or column) can hide some — count the larger axis
    detected = int(max(np.count_nonzero(dr), np.count_nonzero(dc)))
    if detected == 0:
        return c, 0, 0
    match = (dr[:, None] == dc[None, :]) & (dr != 0)[:, None]
    # unique row-col pairing only: an ambiguous syndrome must not "repair"
    # a healthy cell
    fix = (match & (match.sum(axis=1) == 1)[:, None]
           & (match.sum(axis=0) == 1)[None, :])
    c -= np.where(fix, dr[:, None], np.int32(0)).astype(np.int32)
    return c, detected, int(fix.sum())


class AbftMatmul:
    """Drop-in f32 matmul through the ABFT-checksummed over-scaled kernel.

    Mirrors ``make_int8_error_matmul`` (quantize -> inject -> requantize
    with calibrated clipping) with the detect/correct pass in between and
    a :class:`AbftCounters` ledger on the side.  ``use_pallas`` selects the
    fused Pallas kernel (interpret mode off-TPU) over the jnp oracle.
    """

    def __init__(self, bit_probs, key, use_pallas: bool = False):
        self.cdf = bit_probs_to_cdf(bit_probs)
        self.key = key
        self.use_pallas = use_pallas
        self.counters = AbftCounters()
        self._n = 0

    def __call__(self, a, b):
        self._n += 1
        k1, k2 = jax.random.split(jax.random.fold_in(self.key, self._n))
        qa, sa = quantize(a)
        qb, sb = quantize(b)
        shape = a.shape[:1] + b.shape[1:]
        u_gate = jax.random.bits(k1, shape, jnp.uint32)
        u_bit = jax.random.bits(k2, shape, jnp.uint32)
        if self.use_pallas:
            c, rs, cs = abft_matmul(qa, qb, u_gate, u_bit, self.cdf)
        else:
            c, rs, cs = kref.abft_matmul_ref(qa, qb, u_gate, u_bit, self.cdf)
        row_ref, col_ref = checksum_refs(qa, qb)
        fixed, detected, corrected = detect_and_correct(
            c, rs, cs, row_ref, col_ref)
        # simulation ground truth: the clean product (already needed for
        # the requantization clip limit) exposes injections and escapes
        clean = np.asarray(jax.lax.dot_general(
            qa.astype(jnp.int32), qb.astype(jnp.int32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
        self.counters.checked += int(fixed.size)
        self.counters.injected += int(np.count_nonzero(np.asarray(c) != clean))
        self.counters.detected += detected
        self.counters.corrected += corrected
        self.counters.escaped += int(np.count_nonzero(fixed != clean))
        lim = np.quantile(np.abs(clean.astype(np.float32)), 0.9995)
        out = np.clip(fixed.astype(np.float32), -lim, lim) \
            * float(sa) * float(sb)
        return jnp.asarray(out)


@contextmanager
def routed_matmuls(mm):
    """Route the model layers' dense matmuls (``models.layers.matmul``)
    through ``mm`` for the duration of the block — non-jitted evaluation
    only (the ABFT wrapper keeps host-side counters)."""
    from repro.models import layers
    prev = layers.MATMUL
    layers.MATMUL = mm
    try:
        yield mm
    finally:
        layers.MATMUL = prev


def topk_agreement(logits, ref_logits, k: int = 1) -> float:
    """Accuracy proxy for the rail curves: fraction of positions whose
    top-k next-token sets agree with the clean-rail reference."""
    a = np.asarray(logits, np.float32).reshape(-1, logits.shape[-1])
    b = np.asarray(ref_logits, np.float32).reshape(-1, ref_logits.shape[-1])
    ta = np.argsort(-a, axis=-1)[:, :k]
    tb = np.argsort(-b, axis=-1)[:, :k]
    agree = [len(set(ta[i]) & set(tb[i])) / k for i in range(ta.shape[0])]
    return float(np.mean(agree))
