"""Live timing-fault model + stochastic SDC injector (§V).

``core/overscaling.error_profile`` computes a *static* per-bit flip profile
from an FPGA netlist's violating-path population.  The control plane needs
the same physics as a *per-tick* function of the live fleet state: which
rails are applied, how hot each chip is, how loaded it is.  This module is
that generalization for the TPU substrate:

- :class:`TimingFaultModel` — pure queries: per-chip timing overshoot
  ``x = delay(v_core, v_sram, T + T_GUARD) / d_worst - 1`` (the depth of
  undervolt past the step-time contract), the raw per-MAC SDC rate
  ``SDC_RATE0 * expm1(SDC_RATE_K * x)`` (monotone in x, exactly zero at or
  above the guard band — gamma = 1.0 rails inject nothing), and the
  carry/MSB-concentrated per-bit flip profile the ABFT matmul consumes
  (same CARRY_BITS/X_FULL tail shape as ``error_profile``).
- :class:`FaultInjector` — seeded stochastic sampling of per-tick
  (injected, detected, corrected, escaped) counts: Poisson injections at
  the model rate over the tick's MAC traffic, binomial ABFT coverage
  (``1 - ABFT_ESCAPE``).  Deterministic given the seed and call order, so
  scenario replays fingerprint identically.
- :class:`SdcTelemetry` — the control-plane adapter: polls the injector at
  the :class:`~repro.control.actuator.FleetActuator`'s *applied* rails and
  temperature field and emits an :class:`~repro.control.telemetry.SdcSample`
  per control tick.

The rate constants are shared with :mod:`repro.policy.policies` so the
``ErrorTolerant`` policy's feasibility prediction and the telemetry that
judges it agree by construction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional

import numpy as np

from repro.core import tpu_fleet as TF
from repro.policy.policies import ABFT_ESCAPE, SDC_RATE0, SDC_RATE_K
from repro.policy.substrate import T_GUARD

# carry-tail shape shared with core/overscaling.error_profile: a violation
# of depth x corrupts the top ceil(x / X_FULL * CARRY_BITS) accumulator bits
CARRY_BITS = 12
X_FULL = 0.40


@dataclass
class TimingFaultModel:
    """Per-chip timing-error physics at the live (v_core, v_sram, T)."""

    lib: TF.TpuLibrary = field(default_factory=TF.TpuLibrary)
    d_worst: float = 1.0  # the relative step-time contract

    def overshoot(self, v_core, v_sram, T) -> np.ndarray:
        """Depth of undervolt past the contract: (delay/d_worst - 1)+ at
        the guarded temperature — 0 for rails the guard band admits."""
        d = 1.0 / np.asarray(TF.f_max_rel(self.lib,
                                          np.asarray(v_core, np.float32),
                                          np.asarray(v_sram, np.float32),
                                          np.asarray(T, np.float32)
                                          + T_GUARD))
        return np.maximum(d / self.d_worst - 1.0, 0.0)

    def sdc_rate(self, v_core, v_sram, T, noise: float = 1.0) -> np.ndarray:
        """Raw per-MAC SDC rate at the applied rails; ``noise`` is a
        multiplicative disturbance (aging, supply noise — the sdc_storm
        spike material)."""
        x = self.overshoot(v_core, v_sram, T)
        return noise * SDC_RATE0 * np.expm1(SDC_RATE_K * x)

    def escaped_rate(self, v_core, v_sram, T, noise: float = 1.0):
        """Predicted per-MAC rate that leaks past the ABFT checksums."""
        return ABFT_ESCAPE * self.sdc_rate(v_core, v_sram, T, noise)

    def bit_probs(self, v_core, v_sram, T, macs: int = 128,
                  word_bits: int = 32) -> np.ndarray:
        """Per-bit flip probability for one output element of a ``macs``-
        deep accumulation — the profile ``kernels/abft_matmul`` (and
        ``overscale_matmul``) consume.  Scalar rails/temperature: one
        profile per operating point."""
        x = float(np.max(self.overshoot(v_core, v_sram, T)))
        probs = np.zeros(word_bits)
        if x <= 0.0:
            return probs
        p_elem = min(float(np.max(self.sdc_rate(v_core, v_sram, T))) * macs,
                     1.0)
        depth = min(int(np.ceil(x / X_FULL * CARRY_BITS)), CARRY_BITS)
        probs[word_bits - depth:] = p_elem / depth
        return probs


@dataclass
class SdcCounts:
    """One tick's (or one accumulated run's) SDC ledger."""
    injected: int = 0
    detected: int = 0
    corrected: int = 0
    escaped: int = 0
    checked: int = 0  # MACs covered by the checksums this tick

    def add(self, other: "SdcCounts") -> None:
        self.injected += other.injected
        self.detected += other.detected
        self.corrected += other.corrected
        self.escaped += other.escaped
        self.checked += other.checked

    @property
    def escape_rate(self) -> float:
        return self.escaped / self.checked if self.checked else 0.0


class FaultInjector:
    """Seeded per-tick SDC sampler at the applied rails.

    ``tick`` draws Poisson injections per chip at the model's raw rate over
    ``macs_per_tick`` MACs (scaled by per-chip utilization), then a
    binomial ABFT repair with coverage ``1 - ABFT_ESCAPE``: what the
    checksums catch is corrected, the aliasing residue escapes into the
    workload.  Same seed + same call sequence -> same counts (replays
    fingerprint identically); ``reset()`` restarts the stream.
    """

    def __init__(self, model: Optional[TimingFaultModel] = None,
                 macs_per_tick: float = 1e9, seed: int = 0,
                 noise: Optional[Callable[[float], float]] = None):
        self.model = model if model is not None else TimingFaultModel()
        self.macs_per_tick = float(macs_per_tick)
        self.seed = int(seed)
        self.noise = noise
        self.rng = np.random.default_rng(self.seed)
        self.totals = SdcCounts()

    def reset(self, seed: Optional[int] = None) -> None:
        if seed is not None:
            self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.totals = SdcCounts()

    def tick(self, now: float, v_core, v_sram, T,
             util: Optional[np.ndarray] = None) -> SdcCounts:
        noise = float(self.noise(now)) if self.noise is not None else 1.0
        rate = self.model.sdc_rate(v_core, v_sram, T, noise)  # (chips,)
        act = (np.ones_like(rate) if util is None
               else np.asarray(util, np.float64))
        lam = np.maximum(rate * act, 0.0) * self.macs_per_tick
        injected = int(np.sum(self.rng.poisson(lam)))  # scalar rails OK
        detected = (int(self.rng.binomial(injected, 1.0 - ABFT_ESCAPE))
                    if injected else 0)
        counts = SdcCounts(
            injected=injected, detected=detected, corrected=detected,
            escaped=injected - detected,
            checked=int(round(float(act.sum()) * self.macs_per_tick)))
        self.totals.add(counts)
        return counts


class SdcTelemetry:
    """TelemetrySource: samples the injector at the fleet's applied state.

    Reads the :class:`~repro.control.actuator.FleetActuator`'s applied
    per-chip rails, last settled temperature field and utilization — the
    natural one-tick sensor latency of a real SDC counter readout — and
    emits one ``SdcSample`` per poll.
    """

    def __init__(self, injector: FaultInjector, fleet):
        self.injector = injector
        self.fleet = fleet

    def poll(self, now: float) -> List:
        from repro.control.telemetry import SdcSample
        c = self.injector.tick(
            now, self.fleet.v_core, self.fleet.v_sram,
            np.asarray(self.fleet.T),
            util=getattr(self.fleet, "util_applied", None))
        return [SdcSample(detected=c.detected, corrected=c.corrected,
                          escaped=c.escaped, checked=c.checked)]
