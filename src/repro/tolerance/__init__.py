"""repro.tolerance — the §V error-tolerant over-scaling tier.

The paper's third contribution: for workloads that tolerate a bounded
amount of error, rails *below* the guard band convert the remaining thermal
margin into power — provided the resulting timing-violation bit errors are
detected, repaired, and fed back.  This package closes that loop
(DESIGN.md §7):

- :mod:`~repro.tolerance.faults` — a stochastic timing-error injector
  parameterized by the live (v_core, v_sram, T) state of the fleet
  substrate, generalizing ``core/overscaling.error_profile`` into a
  per-tick model the control plane can query.  Calibrated so guard-band
  rails (gamma = 1.0) inject nothing.
- :mod:`~repro.tolerance.abft` — ABFT row/column-checksummed int8 matmul
  (Pallas kernel in ``kernels/abft_matmul`` + jnp oracle in
  ``kernels/ref``): detects SDCs online, corrects single flips, and
  exports detect/correct/escape counters.
- the :class:`repro.policy.ErrorTolerant` policy picks rails below the
  guard band whenever the predicted escaped-SDC rate fits a declared
  accuracy budget (same jitted Solver path; budget=0 == PowerSave).
- control closure: :class:`SdcTelemetry` feeds
  :class:`~repro.control.telemetry.SdcSample` counters to the bus; the
  :class:`~repro.control.controller.LutController` backs rails off one
  step when the observed escape rate exceeds the budget and re-descends
  after a clean hysteresis window (``scenarios.sdc_storm`` replays the
  whole day).
"""
from repro.tolerance.abft import (AbftCounters, AbftMatmul, checksum_refs,
                                  detect_and_correct, routed_matmuls,
                                  topk_agreement)
from repro.tolerance.faults import (FaultInjector, SdcCounts, SdcTelemetry,
                                    TimingFaultModel)

__all__ = [
    "TimingFaultModel", "FaultInjector", "SdcCounts", "SdcTelemetry",
    "AbftCounters", "AbftMatmul", "checksum_refs", "detect_and_correct",
    "routed_matmuls", "topk_agreement",
]
