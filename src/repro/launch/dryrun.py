import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import inputs as I
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.models import params as pm
from repro.serve.step import make_decode_step, make_prefill_step
from repro.sharding.plan import make_plan
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def choose_n_accum(cfg: ModelConfig, shape: ShapeSpec, dp_total: int) -> int:
    if shape.kind != "train":
        return 1
    per_dp = max(shape.global_batch // dp_total, 1)
    seqs_per_mb = 1 if cfg.d_model >= 4096 else 4
    return max(per_dp // seqs_per_mb, 1)


def lower_cell(arch: str, shape_name: str, mesh, *, serve_dtype="bfloat16"):
    """Lower one (arch, shape) on ``mesh``; returns (lowered, meta_info)."""
    cfg = registry.get(arch)
    shape = registry.get_shape(shape_name)
    dp_total = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp_total *= mesh.shape[a]

    sp = os.environ.get("REPRO_SP", "") == "1"  # §Perf knob: sequence parallel
    if shape.kind == "train":
        plan = make_plan(cfg, mesh, sequence_parallel=sp)
        model = Model(cfg, plan)
        meta = model.param_meta()
        opt = make_optimizer(cfg)
        n_accum = choose_n_accum(cfg, shape, dp_total)
        step_fn = make_train_step(model, opt, n_accum=n_accum)
        params_abs = pm.abstract(meta, cfg.param_dtype)
        opt_abs = pm.abstract(opt.state_meta(meta))
        batch_abs = I.train_input_specs(cfg, shape)
        step_abs = jax.ShapeDtypeStruct((), jnp.int32)

        param_sh = plan.param_shardings(meta)
        opt_sh = _ns(mesh, plan.param_specs(opt.state_meta(meta)))
        batch_sh = _ns(mesh, I.train_input_shardings(cfg, plan))
        rep = NamedSharding(mesh, P())
        in_sh = (param_sh, opt_sh, batch_sh, rep)
        out_sh = (param_sh, opt_sh, None)

        with mesh:
            lowered = jax.jit(step_fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=(0, 1)) \
                .lower(params_abs, opt_abs, batch_abs, step_abs)
        info = {"kind": "train", "n_accum": n_accum,
                "n_params": pm.n_params(meta)}
        return lowered, info

    # serving paths use bf16 weights
    cfg_srv = cfg.replace(param_dtype=serve_dtype)
    if shape.kind == "prefill":
        plan = make_plan(cfg_srv, mesh)
        model = Model(cfg_srv, plan)
        meta = model.param_meta()
        fn = make_prefill_step(model, max_len=shape.seq_len)
        params_abs = pm.abstract(meta, serve_dtype)
        batch_abs = I.prefill_input_specs(cfg_srv, shape)
        in_sh = (plan.param_shardings(meta),
                 _ns(mesh, I.prefill_input_shardings(cfg_srv, plan)))
        cache_sh = _ns(mesh, model.cache_specs())
        out_sh = (None, cache_sh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh) \
                .lower(params_abs, batch_abs)
        return lowered, {"kind": "prefill", "n_params": pm.n_params(meta)}

    # decode
    replicate_batch = shape.global_batch % dp_total != 0
    seq_axis = "data" if replicate_batch else None  # long_500k: shard cache seq
    plan = make_plan(cfg_srv, mesh, replicate_batch=replicate_batch)
    model = Model(cfg_srv, plan)
    meta = model.param_meta()
    fn = make_decode_step(model)
    params_abs = pm.abstract(meta, serve_dtype)
    cache_abs, tok_abs, pos_abs = I.decode_input_specs(cfg_srv, shape, model)
    cache_sh, tok_sh, pos_sh = I.decode_input_shardings(
        cfg_srv, plan, model, seq_axis=seq_axis)
    in_sh = (plan.param_shardings(meta), _ns(mesh, cache_sh),
             NamedSharding(mesh, tok_sh), NamedSharding(mesh, pos_sh))
    out_sh = (None, _ns(mesh, cache_sh))
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(1,)) \
            .lower(params_abs, cache_abs, tok_abs, pos_abs)
    return lowered, {"kind": "decode", "n_params": pm.n_params(meta)}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             save_hlo: Optional[str] = None) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    try:
        lowered, info = lower_cell(arch, shape_name, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        n_dev = mesh.size
        rec.update(info)
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "flops_hlo": (cost or {}).get("flops"),
            "bytes_hlo": (cost or {}).get("bytes accessed"),
        })
        if save_hlo:
            with open(save_hlo, "w") as f:
                f.write(compiled.as_text())
            rec["hlo_path"] = save_hlo
        print(f"[dryrun] {arch} {shape_name} {mesh_kind}: OK "
              f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s, "
              f"args/dev {rec['argument_bytes_per_device']}, "
              f"temp/dev {rec['temp_bytes_per_device']})")
        print(f"[dryrun]   memory_analysis: {mem}")
        print(f"[dryrun]   cost_analysis: flops={rec['flops_hlo']} "
              f"bytes={rec['bytes_hlo']}")
    except Exception as e:  # noqa
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[dryrun] {arch} {shape_name} {mesh_kind}: FAIL {rec['error']}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--hlo-dir", default=None)
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = list(registry.all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        for mk in meshes:
            hlo = None
            if args.hlo_dir:
                os.makedirs(args.hlo_dir, exist_ok=True)
                hlo = os.path.join(args.hlo_dir, f"{arch}_{shape}_{mk}.hlo")
            results.append(run_cell(arch, shape, mk, save_hlo=hlo))

    n_ok = sum(r["ok"] for r in results)
    print(f"[dryrun] {n_ok}/{len(results)} cells OK")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    if n_ok < len(results):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
