"""``input_specs``: ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
train/prefill/decode steps against these. Modality frontends are stubs:
vlm gets precomputed patch embeddings, audio gets precomputed frame
embeddings, exactly as the assignment specifies.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.sharding.plan import Plan


def _extras(cfg: ModelConfig, batch: int):
    out: Dict[str, Any] = {}
    if cfg.family == "vlm":
        out["image_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        out["audio_frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_frames, cfg.d_model), jnp.dtype(cfg.dtype))
    return out


def _extras_specs(cfg: ModelConfig, plan: Plan):
    out: Dict[str, Any] = {}
    b = plan.batch_axes
    if cfg.family == "vlm":
        out["image_embeds"] = P(b, None, None)
    if cfg.family == "audio":
        out["audio_frames"] = P(b, None, None)
    return out


def train_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        **_extras(cfg, B),
    }
    return batch


def train_input_shardings(cfg: ModelConfig, plan: Plan):
    b = plan.batch_axes
    out = {"tokens": P(b, None), "labels": P(b, None),
           **_extras_specs(cfg, plan)}
    return out


def prefill_input_specs(cfg: ModelConfig, shape: ShapeSpec):
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32), **_extras(cfg, B)}


def prefill_input_shardings(cfg: ModelConfig, plan: Plan):
    return {"tokens": P(plan.batch_axes, None), **_extras_specs(cfg, plan)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeSpec, model):
    """(cache, tokens, pos) stand-ins. Cache capacity = shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache = model.cache(B, S, abstract=True)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, pos


def decode_input_shardings(cfg: ModelConfig, plan: Plan, model, seq_axis=None):
    cache_specs = model.cache_specs(seq_axis=seq_axis)
    return cache_specs, P(plan.batch_axes, None), P()
