"""Production meshes + pod topology.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. Single pod: 16x16 = 256 chips
(TPU v5e pod); multi-pod: 2 pods = 512 chips with a leading 'pod' axis (outer
data / pipeline axis across the inter-pod DCN/ICI boundary).

:class:`PodTopology` is the control plane's worker -> chip mapping: it
resolves a worker name to a validated pod-local chip index (and 2-D pod
coordinate) instead of the old trailing-digit guess, so straggler telemetry
lands on the chip the actuator can really touch.  It is pure numpy/stdlib —
constructing one never initializes jax.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional, Tuple

import jax

_DIGITS = re.compile(r"\d+")
# host/worker composition only applies to names that really carry BOTH
# labels — a bare version digit ("tpu-v4-rank12") must not be mistaken
# for a host index
_HOST_WORKER = re.compile(r"host(\d+).*?worker(\d+)")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over the actually-available local devices (tests/examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


@dataclass(frozen=True)
class PodTopology:
    """Rank -> pod-coordinate mapping for one (or several) ``grid`` pods.

    Worker names carry their global rank as the trailing integer
    (``worker7``, ``tpu-v4-rank12``); with ``workers_per_host`` set, a
    ``host<h>-worker<w>`` pair composes the global rank ``h * wph + w``.
    Everything is *validated*: a name without digits, or a rank beyond the
    fleet, maps to chip ``-1`` — the telemetry layer's explicit "unmapped"
    sentinel (the controller surfaces it in ``stats.unmapped`` instead of
    acting on a phantom chip).
    """

    grid: Tuple[int, int] = (16, 16)
    n_pods: int = 1
    workers_per_host: Optional[int] = None
    # the pod THIS controller/actuator pair owns: ranks from other pods
    # are unmapped (-1), never silently folded onto this pod's chips.
    # None = a fleet-wide view (pod-local indices for every pod's ranks)
    pod_index: Optional[int] = 0

    # ------------------------------------------------------------------
    @property
    def chips_per_pod(self) -> int:
        return self.grid[0] * self.grid[1]

    @property
    def n_chips(self) -> int:
        return self.n_pods * self.chips_per_pod

    # ------------------------------------------------------------------
    def rank_of(self, worker: str) -> Optional[int]:
        """Global rank parsed from a worker name; None when unparseable.

        ``host<h>-worker<w>`` composes ``h * workers_per_host + w`` (only
        when both labels are present — stray digit groups like the "4" in
        ``tpu-v4-rank12`` never masquerade as a host index); otherwise the
        trailing digit group is the global rank."""
        if self.workers_per_host is not None:
            m = _HOST_WORKER.search(worker)
            if m:
                return (int(m.group(1)) * self.workers_per_host
                        + int(m.group(2)))
        groups = _DIGITS.findall(worker)
        return int(groups[-1]) if groups else None

    def pod_of(self, rank: int) -> int:
        return rank // self.chips_per_pod

    def coords(self, rank: int) -> Tuple[int, int]:
        """(row, col) of a rank inside its pod (row-major chip layout)."""
        local = rank % self.chips_per_pod
        return local // self.grid[1], local % self.grid[1]

    def chip_of_rank(self, rank: int) -> int:
        """Pod-local flat chip index; -1 when the rank is outside the
        fleet (a stale worker name, a coordinator process) or belongs to
        a pod this controller does not own (``pod_index``)."""
        if not 0 <= rank < self.n_chips:
            return -1
        if (self.pod_index is not None
                and self.pod_of(rank) != self.pod_index):
            return -1
        return rank % self.chips_per_pod

    def chip_of(self, worker: str) -> int:
        """Validated worker-name -> chip mapping (-1 = unmapped)."""
        rank = self.rank_of(worker)
        return -1 if rank is None else self.chip_of_rank(rank)

    # ------------------------------------------------------------------
    def chip_range(self, pod: int) -> Tuple[int, int]:
        """Fleet-wide ``[lo, hi)`` chip indices of one pod's slice (chips
        are laid out pod-major, row-major inside the pod)."""
        if not 0 <= pod < self.n_pods:
            raise ValueError(f"pod {pod} outside fleet of {self.n_pods}")
        return pod * self.chips_per_pod, (pod + 1) * self.chips_per_pod

    @staticmethod
    def partition(n_chips: int, n_pods: int) -> Tuple[Tuple[int, int], ...]:
        """Contiguous ``[lo, hi)`` chip slices dividing ``n_chips`` into
        ``n_pods`` failure domains (``control.fleet``'s default layout).
        Requires an even split: a pod is a physical unit, not a remainder."""
        if n_pods <= 0 or n_chips % n_pods:
            raise ValueError(
                f"{n_chips} chips do not split into {n_pods} equal pods")
        per = n_chips // n_pods
        return tuple((p * per, (p + 1) * per) for p in range(n_pods))

    # ------------------------------------------------------------------
    @classmethod
    def from_mesh(cls, mesh, workers_per_host: Optional[int] = None
                  ) -> "PodTopology":
        """Topology of a jax mesh: the trailing two axes are the pod grid,
        any leading axes multiply into ``n_pods``."""
        shape = tuple(mesh.devices.shape)
        if len(shape) == 1:
            shape = (1,) + shape
        grid = shape[-2:]
        n_pods = 1
        for d in shape[:-2]:
            n_pods *= int(d)
        return cls(grid=(int(grid[0]), int(grid[1])), n_pods=n_pods,
                   workers_per_host=workers_per_host)

    @classmethod
    def production(cls, *, multi_pod: bool = False) -> "PodTopology":
        """The ``make_production_mesh`` topology without touching jax."""
        return cls(grid=(16, 16), n_pods=2 if multi_pod else 1)
