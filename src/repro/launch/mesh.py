"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state. Single pod: 16x16 = 256 chips
(TPU v5e pod); multi-pod: 2 pods = 512 chips with a leading 'pod' axis (outer
data / pipeline axis across the inter-pod DCN/ICI boundary).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over the actually-available local devices (tests/examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
