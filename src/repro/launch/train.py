"""End-to-end training driver (CPU-runnable; production flags wired through).

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
        --steps 50 --energy-policy power_save --checkpoint-dir /tmp/ckpt

Features exercised here are the production ones: jit'd train_step with
plan shardings on a host mesh, deterministic data pipeline, async
checkpointing + restore (--resume), failure injection + bounded retry,
straggler detection, and the EnergyAwareRuntime (paper technique) reporting
per-step fleet savings from the step's measured utilization profile.

With ``--energy-policy`` the run closes the loop through ``repro.control``:
step times feed the straggler detector, whose events route through the
``LutController`` (rail-boost-or-rebalance becomes a policy decision), and
a ``FleetActuator`` applies rails + reports the thermal readout each
control tick.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import control as ctl
from repro import policy as pol
from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.core import runtime as energy_rt
from repro.core import tpu_fleet as TF
from repro.data.pipeline import DataConfig, make_iterator
from repro.ft.elastic import ElasticActuator, ElasticWorkAssignment
from repro.ft.monitor import (FailureInjector, StragglerDetector,
                              TransientError, retry_step)
from repro.launch.mesh import PodTopology, make_host_mesh
from repro.models import params as pm
from repro.models.model import Model
from repro.sharding.plan import make_plan
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step


def build(arch: str, smoke: bool, mesh, batch: int, seq: int, n_accum: int):
    cfg = registry.get(arch)
    if smoke:
        cfg = cfg.reduced()
    plan = make_plan(cfg, mesh)
    model = Model(cfg, plan)
    opt = make_optimizer(cfg, total_steps=10_000)
    step_fn = make_train_step(model, opt, n_accum=n_accum)
    meta = model.param_meta()

    in_sh = (plan.param_shardings(meta),
             jax.tree_util.tree_map(
                 lambda s: NamedSharding(mesh, s),
                 plan.param_specs(opt.state_meta(meta)),
                 is_leaf=lambda x: isinstance(x, P)),
             None, None)
    jit_step = jax.jit(step_fn, in_shardings=in_sh, donate_argnums=(0, 1))
    return cfg, plan, model, opt, jit_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-accum", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--energy-policy", default="off",
                    help="off | power_save | min_energy | overscale:<g>")
    ap.add_argument("--t-amb", type=float, default=25.0,
                    help="ambient degC the control plane senses")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    mesh = make_host_mesh(model=args.model_parallel)
    cfg, plan, model, opt, jit_step = build(
        args.arch, args.smoke, mesh, args.batch, args.seq, args.n_accum)
    print(f"[train] arch={cfg.name} params={model.n_params():,} "
          f"mesh={dict(mesh.shape)}")

    key = jax.random.PRNGKey(0)
    with mesh:
        params = model.init(key)
        opt_state = opt.init(params)

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    start_step = 0

    ckpt = CheckpointManager(args.checkpoint_dir) if args.checkpoint_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state_like = {"params": params, "opt": opt_state}
        restored, start_step = ckpt.restore(state_like)
        params, opt_state = restored["params"], restored["opt"]
        print(f"[train] resumed from step {start_step}")

    it = make_iterator(cfg, dc, start_step=start_step)
    injector = FailureInjector(
        fail_at={args.inject_failure_at} if args.inject_failure_at >= 0 else set())
    straggler = StragglerDetector()

    # paper technique: fleet energy controller fed by the step profile;
    # the CLI spec becomes a first-class repro.policy Policy object, and the
    # telemetry->controller->actuator loop closes over the same planner
    rt: Optional[energy_rt.EnergyAwareRuntime] = None
    loop: Optional[ctl.ControlLoop] = None
    if args.energy_policy != "off":
        prof = TF.StepProfile.from_roofline(
            compute_s=0.7, memory_s=0.4, collective_s=0.15)
        rt = energy_rt.EnergyAwareRuntime(
            prof, policy=pol.from_spec(args.energy_policy),
            t_amb=args.t_amb)
        # straggler workers resolve to pod coordinates through the mesh
        # topology (out-of-pod ranks surface as unmapped, never chip 0),
        # and Rebalance decisions actually migrate work via the elastic
        # assignment, whose shares feed the RailField utilization axis
        topo = PodTopology(grid=rt.substrate.grid)
        mon = ctl.MonitorTelemetry(straggler, topology=topo)
        elastic = ElasticActuator(ElasticWorkAssignment(
            rt.substrate.n_domains))
        controller = rt.controller()  # per-chip RailField fast path
        fleet = ctl.FleetActuator.from_runtime(rt, field=controller.field)
        loop = ctl.ControlLoop(
            ctl.TelemetryBus([ctl.AmbientSensor(args.t_amb), mon, elastic,
                              fleet]),
            controller, [fleet, elastic])

    step = start_step
    t_train0 = time.time()
    while step < args.steps:
        batch = next(it)

        def do_step():
            injector.maybe_fail(step)
            return jit_step(params, opt_state, batch, jnp.int32(step))

        def on_fail(attempt, e):
            print(f"[ft] step {step} attempt {attempt} failed: {e}; retrying")

        t0 = time.time()
        params, opt_state, metrics = retry_step(do_step, on_failure=on_fail)
        jax.block_until_ready(metrics["loss"])
        dt = time.time() - t0
        ev = straggler.record("worker0", step, dt)
        if ev:
            print(f"[ft] straggler: step {ev.step} {ev.ratio:.2f}x median")

        if step % args.log_every == 0 or step == args.steps - 1:
            msg = (f"[train] step {step}: loss={float(metrics['loss']):.4f} "
                   f"acc={float(metrics['accuracy']):.3f} "
                   f"gnorm={float(metrics['grad_norm']):.2f} ({dt:.2f}s)")
            if loop is not None:
                # control tick: straggler events become policy decisions
                # (rail boost / rebalance), rails land on the actuator.
                # the energy line reads the controller's own plan — LUT
                # ticks must not pay a fixed point just to print a log
                rep = loop.step(now=float(step))
                for a in rep.actions:
                    if isinstance(a, (ctl.BoostRail, ctl.Rebalance)):
                        print(f"[ctl] {a}")
                rails = next(a for a in rep.actions
                             if isinstance(a, ctl.SetRails))
                p, ro = loop.controller.plan, rep.readout
                msg += (f" | energy[{args.energy_policy}]: "
                        f"save={p.saving*100:.1f}% Tmax={ro.t_max:.0f}C"
                        f" | ctl[{rails.source}]")
            elif rt is not None:  # planner without the loop (not wired)
                p = rt.plan()
                msg += (f" | energy[{args.energy_policy}]: "
                        f"save={p.saving*100:.1f}% Tmax={p.t_max:.0f}C")
            print(msg)

        if ckpt and (step + 1) % args.checkpoint_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state},
                      metadata={"arch": cfg.name})
        step += 1

    if ckpt:
        ckpt.wait()
    print(f"[train] done: {args.steps - start_step} steps in "
          f"{time.time() - t_train0:.1f}s; final loss "
          f"{float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
