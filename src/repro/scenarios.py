"""Replayable control-plane scenarios (the §III-B day-in-the-life library).

The diurnal sweep, the forced ambient jump and the straggler storm used to
live as ad-hoc lambdas inside tests and the closed-loop example; this module
promotes them — plus the load-spike day the RailField was built for — to
first-class, *deterministic* scenario objects:

- a :class:`Scenario` is pure data: an ambient trace, an optional load
  trace (the serve-engine slot-occupancy fraction), scripted worker step
  times (straggler material), and optional hotspot injections (a failed
  fan / blocked airflow on one chip);
- :func:`replay` runs a scenario through the full telemetry -> controller
  -> actuator loop (ambient sensor, load telemetry, straggler monitor with
  the mesh topology mapping, fleet actuator, elastic work migration) and
  returns a :class:`ReplayResult` with the decisions, the energy ledger and
  a fingerprint over the applied per-chip rail trace;
- same trace -> same rail decisions, same replan count, same energy:
  pinned by ``tests/test_scenarios.py``.

The replan-economy comparison (scalar pod-median LUT vs per-chip RailField
on ``diurnal_load_spike``) also lives in those tests: the RailField serves
the same day with >=2x fewer full replans at >= equal mean power saving.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field as dfield
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import control as ctl
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.ft.elastic import ElasticActuator, ElasticWorkAssignment
from repro.ft.monitor import StragglerDetector
from repro.launch.mesh import PodTopology

# ---------------------------------------------------------------------------
# scenario data model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StepRecord:
    """One scripted worker step time, delivered at ``tick``."""
    tick: int
    worker: str
    step_s: float


@dataclass(frozen=True)
class Hotspot:
    """A localized cooling fault: chip ``chip`` reads ``t_chip`` degC at
    ``tick`` (failed fan, blocked airflow) — the straggler/rebalance
    trigger material."""
    tick: int
    chip: int
    t_chip: float


@dataclass(frozen=True)
class Scenario:
    name: str
    ticks: int
    ambient: Callable[[float], float]
    load: Optional[Callable[[float], float]] = None
    steps: Tuple[StepRecord, ...] = ()
    hotspots: Tuple[Hotspot, ...] = ()
    # multiplicative SDC-rate disturbance trace (aging / supply-noise
    # spikes) fed to the replay's FaultInjector; None = quiet day (x1)
    sdc_noise: Optional[Callable[[float], float]] = None
    # §9 chaos: a factory returning a fresh seeded ControlFaultModel per
    # replay (a factory keeps Scenario pure data and every replay aligned
    # on the same fault streams); None = clean control plane
    chaos: Optional[Callable[[], "ctl.ControlFaultModel"]] = None
    # §10 fleet tier: confine the chaos to ONE pod's failure domain
    # (fleet_replay); None = fleet-wide chaos (every pod draws its own
    # pod-seeded stream via ControlFaultModel.for_pod)
    chaos_pod: Optional[int] = None
    description: str = ""

    def ambient_at(self, tick: int) -> float:
        return float(self.ambient(float(tick)))

    def load_at(self, tick: int) -> Optional[float]:
        return None if self.load is None else float(self.load(float(tick)))


# ---------------------------------------------------------------------------
# the library
# ---------------------------------------------------------------------------


def diurnal(ticks: int = 48, base: float = 25.0, amp: float = 7.0,
            period: Optional[int] = None) -> Scenario:
    """The quasi-static day: a sine between ``base - amp`` and
    ``base + amp`` — everything should ride the fast path after the cold
    start."""
    p = float(period if period is not None else ticks)
    return Scenario(
        name="diurnal", ticks=ticks,
        ambient=lambda now: base + amp * np.sin(2.0 * np.pi * now / p),
        description="quasi-static diurnal ambient sine")


def ambient_jump(ticks: int = 16, t0: float = 22.0, t1: float = 34.0,
                 at: int = 8) -> Scenario:
    """A cooling failure / hot-aisle event: step change ``t0 -> t1``."""
    return Scenario(
        name="ambient_jump", ticks=ticks,
        ambient=lambda now: t1 if now >= at else t0,
        description=f"step {t0}C -> {t1}C at tick {at}")


def straggler_storm(ticks: int = 24, workers: int = 4, storm_at: int = 12,
                    slow_worker: int = 2, slow_factor: float = 2.2,
                    hot_chip_c: float = 94.5) -> Scenario:
    """A worker turns slow on a chip whose cooling just failed: healthy
    baseline steps establish the rolling median, then ``slow_worker``
    reports ``slow_factor`` x median steps while its chip reads
    ``hot_chip_c`` — boost cannot hold the clock there, so the controller
    must escalate to ``Rebalance`` and the elastic assignment must migrate
    the work off the chip."""
    steps: List[StepRecord] = []
    for t in range(ticks):
        for w in range(workers):
            s = 1.0
            if t >= storm_at and w == slow_worker:
                s = slow_factor
            steps.append(StepRecord(t, f"worker{w}", s))
    hotspots = tuple(Hotspot(t, slow_worker, hot_chip_c)
                     for t in range(storm_at, min(storm_at + 2, ticks)))
    return Scenario(
        name="straggler_storm", ticks=ticks,
        ambient=lambda now: 25.0,
        steps=tuple(steps), hotspots=hotspots,
        description="hot-chip straggler escalating to rebalance")


def load_spike(ticks: int = 48, base: float = 0.95, low: float = 0.45,
               dips: Tuple[Tuple[int, int], ...] = ((12, 8), (32, 8))
               ) -> Scenario:
    """Serving load swinging between ``base`` and ``low`` (off-peak dips /
    recovery spikes).  Every swing crosses the scalar controller's
    ``util_band`` and forces a ``util_drift`` replan; the RailField answers
    it from the utilization axis."""
    def trace(now: float) -> float:
        for start, width in dips:
            if start <= now < start + width:
                return low
        return base

    return Scenario(
        name="load_spike", ticks=ticks,
        ambient=lambda now: 25.0, load=trace,
        description="load swings riding the utilization axis")


def diurnal_load_spike(ticks: int = 48, base: float = 25.0,
                       amp: float = 7.0) -> Scenario:
    """The acceptance day: diurnal ambient AND load spikes at once — the
    scenario the scalar LUT replans through and the RailField serves from
    the table."""
    d = diurnal(ticks, base, amp)
    ls = load_spike(ticks)
    return Scenario(
        name="diurnal_load_spike", ticks=ticks,
        ambient=d.ambient, load=ls.load,
        description="diurnal ambient + serving load spikes")


def sdc_storm(ticks: int = 48, t_amb: float = 28.0, spike_at: int = 20,
              spike_len: int = 6, spike_gain: float = 4.0) -> Scenario:
    """The §V acceptance day: steady warm ambient with an SDC-noise spike
    (aging / supply droop multiplying the raw flip rate by ``spike_gain``)
    in the middle.  An ``ErrorTolerant`` closed loop rides below the guard
    band all day — beating PowerSave on mean power — and the spike forces
    the controller's ``RailBackoff`` retreat; the cumulative escaped-SDC
    rate must still land inside the declared budget."""
    def noise(now: float) -> float:
        return spike_gain if spike_at <= now < spike_at + spike_len else 1.0

    return Scenario(
        name="sdc_storm", ticks=ticks,
        ambient=lambda now: t_amb, sdc_noise=noise,
        description=f"x{spike_gain} SDC-noise spike at tick {spike_at}")


def serve_day(ticks: int = 14, hot: float = 42.0, cool: float = 12.0,
              cool_at: int = 7) -> Scenario:
    """The serving acceptance day (§8): a hot window (peak ambient, rails
    near nominal) followed by a machine-room cool-down.  Tokens served
    during the hot window cost more joules than the same tokens after the
    cool-down — the intertemporal arbitrage the thermal-aware admission
    controller prices."""
    return Scenario(
        name="serve_day", ticks=ticks,
        ambient=lambda now: hot if now < cool_at else cool,
        description=f"hot window {hot}C, cool-down to {cool}C at {cool_at}")


def chaos_day(ticks: int = 48, base: float = 25.0, amp: float = 7.0,
              rate: float = 0.6, nack_rate: float = 0.45, seed: int = 0,
              runaway_chip: int = 3, runaway_c: float = 93.5) -> Scenario:
    """The §9 acceptance day: a diurnal trace carrying, in order, a sensor
    storm (dropout/spike/stale/stuck bursts + one missed tick deadline), a
    rail-write NACK burst (driving chips into safe-state rails), and a
    thermal runaway on one chip (hotspot + a scripted solver fault, so the
    watchdog — not the solver — must contain it).  A load dip below the
    RailField's utilization axis rides along for the clamp counter.
    Fingerprint-pinned: same seed -> the identical day."""
    storm = (ticks // 6, ticks // 6 + max(ticks // 4, 3))
    nack_w = (ticks // 2, ticks // 2 + max(ticks // 8, 2))
    runaway_at = 3 * ticks // 4
    d = diurnal(ticks, base, amp)

    def load(now: float) -> float:
        return 0.15 if storm[0] <= now < storm[0] + 2 else 0.9

    return Scenario(
        name="chaos_day", ticks=ticks,
        ambient=d.ambient, load=load,
        hotspots=tuple(Hotspot(t, runaway_chip, runaway_c)
                       for t in range(runaway_at,
                                      min(runaway_at + 3, ticks))),
        chaos=lambda: ctl.ControlFaultModel(
            rate=rate, seed=seed, nack=nack_rate,
            # weight the mix toward dropout so the ambient stream loses
            # enough consecutive ticks to trip the stale fallback (stuck
            # replays keep resetting the age at the uniform rate/4 mix)
            dropout=rate * 0.75,
            sensor_window=storm, nack_window=nack_w,
            # two consecutive missed deadlines: the ladder must reach
            # level 2 (frozen last-applied rails) and climb back down
            deadline_misses=(storm[0] + 1, storm[0] + 2),
            solver_faults=(runaway_at,)),
        description="sensor storm + rail NACK burst + thermal runaway")


def pod_loss_day(ticks: int = 48, base: float = 25.0, amp: float = 7.0,
                 rate: float = 0.8, nack_rate: float = 0.6, seed: int = 0,
                 fail_pod: int = 1) -> Scenario:
    """The §10 acceptance day: a diurnal fleet where ONE pod's control
    plane goes bad mid-morning — a sensor storm, a rail-write NACK burst
    and three consecutive missed tick deadlines, all confined to
    ``fail_pod`` — while its siblings keep serving.  The fleet health
    machine must walk the pod through degraded -> quarantined -> drained
    (rails frozen at safe state, its work share and in-flight requests
    migrated to the survivors) and, once the storm passes and the slice
    cools below the hysteresis threshold, restore it — all inside the day.

    The three scripted deadline misses pin the pod's watchdog at level
    >= 1 across the storm head, so the walk to quarantine is
    deterministic whatever the sensor-fault draws do.  Replayed by
    :func:`fleet_replay` with ``n_pods >= 2``; fingerprint-pinned by
    ``tests/test_fleet.py``."""
    storm = (ticks // 6, ticks // 6 + max(ticks // 4, 4))
    d = diurnal(ticks, base, amp)
    return Scenario(
        name="pod_loss_day", ticks=ticks,
        ambient=d.ambient,
        # moderate constant load: survivors absorb the lost pod's share
        # (~2x their own) without leaving the RailField utilization axis
        load=lambda now: 0.45,
        chaos=lambda: ctl.ControlFaultModel(
            rate=rate, seed=seed, nack=nack_rate,
            # quarantinable classes dominate: the health machine keys on
            # bus rejections and watchdog trips, not silent dropouts
            dropout=rate * 0.25,
            sensor_window=storm, nack_window=(storm[0], storm[0] + 2),
            deadline_misses=(storm[0], storm[0] + 1, storm[0] + 2)),
        chaos_pod=fail_pod,
        description="one pod lost to control-plane chaos, then restored")


SCENARIOS: Dict[str, Callable[[], Scenario]] = {
    "diurnal": diurnal,
    "ambient_jump": ambient_jump,
    "straggler_storm": straggler_storm,
    "load_spike": load_spike,
    "diurnal_load_spike": diurnal_load_spike,
    "sdc_storm": sdc_storm,
    "serve_day": serve_day,
    "chaos_day": chaos_day,
    "pod_loss_day": pod_loss_day,
}


# ---------------------------------------------------------------------------
# request workloads (the serving-tier arrival processes)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RequestArrival:
    """One request arriving at control tick ``tick`` (prompt content is
    derived deterministically from ``rid`` at replay time)."""
    tick: int
    rid: int
    prompt_len: int
    max_new: int


@dataclass(frozen=True)
class RequestWorkload:
    """A deterministic arrival trace — pure data, replayable anywhere."""
    name: str
    arrivals: Tuple[RequestArrival, ...]

    @property
    def fingerprint(self) -> str:
        h = hashlib.sha256()
        for a in self.arrivals:
            h.update(np.asarray([a.tick, a.rid, a.prompt_len, a.max_new],
                                np.int64).tobytes())
        return h.hexdigest()[:16]

    def by_tick(self) -> Dict[int, List[RequestArrival]]:
        out: Dict[int, List[RequestArrival]] = {}
        for a in self.arrivals:
            out.setdefault(a.tick, []).append(a)
        return out


def trace_requests(trace, name: str = "trace") -> RequestWorkload:
    """Explicit ``(tick, prompt_len, max_new)`` rows -> a workload (replayed
    datacenter traces; rids are assigned in trace order)."""
    arrivals = tuple(RequestArrival(int(t), rid, int(p), int(m))
                     for rid, (t, p, m) in enumerate(trace))
    return RequestWorkload(name, arrivals)


def poisson_requests(ticks: int = 12, rate: float = 1.0, seed: int = 0,
                     prompt_len: Tuple[int, int] = (4, 12),
                     max_new: Tuple[int, int] = (4, 8),
                     start: int = 1) -> RequestWorkload:
    """Poisson arrivals: ``rate`` requests per control tick in expectation,
    prompt/output lengths uniform over the given ranges.  Same seed ->
    bitwise-identical workload (``numpy`` Generator, no global state)."""
    rng = np.random.default_rng(seed)
    arrivals: List[RequestArrival] = []
    rid = 0
    for t in range(start, ticks):
        for _ in range(int(rng.poisson(rate))):
            arrivals.append(RequestArrival(
                t, rid, int(rng.integers(*prompt_len)),
                int(rng.integers(*max_new))))
            rid += 1
    return RequestWorkload(f"poisson[rate={rate},seed={seed}]",
                           tuple(arrivals))


def poisson_burst(burst_at: int = 1, burst_n: int = 8,
                  prompt_len: int = 6, max_new: int = 6,
                  tail_ticks: int = 0, tail_rate: float = 0.5,
                  seed: int = 0) -> RequestWorkload:
    """The §8 acceptance workload: a burst of ``burst_n`` requests landing
    at ``burst_at`` (inside the hot window of :func:`serve_day`), optionally
    followed by a light Poisson tail.  The burst exceeds the slot count, so
    an admission controller must *choose* what to run hot."""
    arrivals = [RequestArrival(burst_at, rid, prompt_len, max_new)
                for rid in range(burst_n)]
    if tail_ticks > 0:
        tail = poisson_requests(burst_at + 1 + tail_ticks, rate=tail_rate,
                                seed=seed, start=burst_at + 1,
                                prompt_len=(prompt_len, prompt_len + 1),
                                max_new=(max_new, max_new + 1))
        arrivals += [RequestArrival(a.tick, burst_n + a.rid, a.prompt_len,
                                    a.max_new) for a in tail.arrivals]
    return RequestWorkload(f"burst[{burst_n}@{burst_at},seed={seed}]",
                           tuple(arrivals))


def churn_requests(waves: int = 4, per_wave: int = 4, gap: int = 2,
                   prompt_len: int = 5, max_new: int = 5) -> RequestWorkload:
    """The paged-attention acceptance workload: short-lived requests landing
    in overlapping waves, so slots free and refill continuously and the KV
    footprint is many *partial* sequences at once.  A contiguous cache must
    reserve ``max_len`` per slot up front, so its admission capacity is
    ``pages / pages_per_slot``; the paged allocator hands the same page
    budget out one page at a time and admits strictly more concurrently
    (the vLLM fragmentation argument, pinned by tests/test_serve_paged)."""
    arrivals = [RequestArrival(1 + w * gap, w * per_wave + i,
                               prompt_len, max_new)
                for w in range(waves) for i in range(per_wave)]
    return RequestWorkload(f"churn[{waves}x{per_wave},gap={gap}]",
                           tuple(arrivals))


# ---------------------------------------------------------------------------
# replay harness
# ---------------------------------------------------------------------------


class _LoadTelemetry:
    """Scripted serve-engine load as TickSamples (slots=64 quantization)."""

    SLOTS = 64

    def __init__(self, scenario: Scenario):
        self.scenario = scenario

    def poll(self, now: float) -> List:
        load = self.scenario.load_at(int(now))
        if load is None:
            return []
        return [ctl.TickSample(
            tick=int(now), queued=0,
            active=int(round(load * self.SLOTS)), finished=0, tokens=0,
            tick_s=0.0, slots=self.SLOTS)]


@dataclass
class ReplayResult:
    name: str
    ticks: int
    replans: int
    lut_hits: int
    boosts: int
    rebalances: int
    replan_reasons: List[str]
    mean_saving: float
    energy_j: float
    t_max: float
    condemned: Tuple[int, ...]
    shares: np.ndarray       # final elastic work shares (chips,)
    rails: np.ndarray        # (ticks, 2, chips) applied (v_core, v_sram)
    util_trace: np.ndarray   # (ticks, chips) utilization the loop settled at
    # §V error-tolerance ledger (all zero on replays without an injector)
    backoffs: int = 0
    restores: int = 0
    sdc_injected: int = 0
    sdc_detected: int = 0
    sdc_corrected: int = 0
    sdc_escaped: int = 0
    sdc_checked: int = 0
    # §9 fault-containment ledger (all zero/empty on clean replays; NOT
    # hashed into the fingerprint so pre-chaos pins are unchanged)
    quarantined: int = 0
    stale_fallbacks: int = 0
    degraded_ticks: int = 0
    frozen_ticks: int = 0
    safe_states: int = 0
    below_axis_clamps: int = 0
    write_nacks: int = 0
    write_retries: int = 0
    watchdog_events: List[str] = dfield(default_factory=list)
    recover_ticks: List[float] = dfield(default_factory=list)

    @property
    def escape_rate(self) -> float:
        """Cumulative escaped-SDC rate per checked MAC over the day."""
        return self.sdc_escaped / self.sdc_checked if self.sdc_checked else 0.0

    @property
    def mean_ticks_to_recover(self) -> float:
        """Mean watchdog-episode length: trip -> back to normal (0 when the
        day had no completed degrade episode)."""
        return float(np.mean(self.recover_ticks)) if self.recover_ticks \
            else 0.0

    @property
    def fingerprint(self) -> str:
        """Determinism pin: hashes the applied rail trace, the replan
        ledger and the energy integral."""
        h = hashlib.sha256()
        h.update(self.rails.astype(np.float64).tobytes())
        h.update(np.float64(self.energy_j).tobytes())
        h.update(",".join(self.replan_reasons).encode())
        h.update(np.asarray(sorted(self.condemned), np.int64).tobytes())
        return h.hexdigest()[:16]


def replay(scenario: Scenario, runtime: Optional[RT.EnergyAwareRuntime]
           = None, controller: Optional[ctl.LutController] = None,
           tick_s: float = 60.0, guard_band_c: float = 3.0,
           sweep=(10.0, 45.0, 8), util_sweep=(0.25, 1.0, 4),
           injector=None, faults=None) -> ReplayResult:
    """Run ``scenario`` through the full control loop; deterministic.

    ``controller=None`` builds the default RailField controller over the
    runtime's planner; pass a prebuilt controller to compare fast paths
    (e.g. ``rt.controller(lut=rt.build_lut(...))`` for the scalar
    baseline).  ``tick_s`` converts the power readouts into the energy
    ledger (60 s control ticks by default).

    ``injector`` (a ``repro.tolerance.FaultInjector``) attaches the §V SDC
    loop: the injector is reset (same seed -> same replayed day), takes the
    scenario's ``sdc_noise`` trace, and samples the fleet's applied rails
    each tick through ``SdcTelemetry`` — pair it with a controller built
    with ``sdc_budget=...`` to close the back-off loop.

    ``faults`` (a ``ControlFaultModel``; defaults to the scenario's own
    ``chaos`` factory) attaches the §9 chaos plane: the ambient sensor and
    the fleet TSDs are wrapped in ``ChaosTelemetry``, the fleet's rail
    writes go through the verify-after-write NACK channel, and the
    controller consumes the scripted watchdog ticks.  ``rate=0`` is the
    identity model — every clean-day fingerprint is unchanged.
    """
    rt = runtime if runtime is not None else RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")
    if controller is None:
        from repro.control.lut import sweep_points
        controller = rt.controller(
            field=rt.build_field(sweep_points(*sweep),
                                 sweep_points(*util_sweep)),
            guard_band_c=guard_band_c)
    chips = rt.substrate.n_domains
    topo = PodTopology(grid=rt.substrate.grid)

    det = StragglerDetector(threshold=1.5, window=8, min_samples=4)
    mon = ctl.MonitorTelemetry(det, topology=topo)
    assignment = ElasticWorkAssignment(chips)
    elastic = ElasticActuator(assignment)
    fleet = ctl.FleetActuator.from_runtime(
        rt, t_amb=scenario.ambient_at(0),
        field=getattr(controller, "field", None))
    if faults is None and scenario.chaos is not None:
        faults = scenario.chaos()
    amb_src, fleet_src = ctl.AmbientSensor(scenario.ambient), fleet
    if faults is not None:
        amb_src = ctl.ChaosTelemetry(amb_src, faults)
        fleet_src = ctl.ChaosTelemetry(fleet, faults)
        fleet.write_faults = faults
        controller.faults = faults  # scripted deadline/solver-fault ticks
    sources = [amb_src, _LoadTelemetry(scenario), mon, elastic, fleet_src]
    if injector is not None:
        from repro.tolerance.faults import SdcTelemetry
        injector.reset()
        if scenario.sdc_noise is not None:
            injector.noise = scenario.sdc_noise
        sources.append(SdcTelemetry(injector, fleet))
    # ticks are 1 apart: a stale-repeated stamp is >= 1 tick old, so the
    # freshness bound must sit under one tick to quarantine it (stamps are
    # only ever set by ChaosTelemetry — clean replays see no age at all)
    bus = ctl.TelemetryBus(sources,
                           max_age=0.75 if faults is not None else None)
    loop = ctl.ControlLoop(bus, controller, [fleet, elastic])

    # a reused controller (warm jits, shared field) must start the day
    # from scratch: reset the online state (t_prev / warm fields / plan),
    # and report stats as deltas (reset leaves the cumulative counters)
    if hasattr(controller, "reset"):
        controller.reset()
    st = controller.stats
    base = (st.replans, st.lut_hits, st.boosts, st.rebalances,
            len(st.replan_reasons), st.backoffs, st.restores,
            st.quarantined, st.stale_fallbacks, st.degraded_ticks,
            st.frozen_ticks, st.safe_states, st.below_axis_clamps,
            len(st.watchdog_events), len(st.recover_ticks))

    steps_by_tick: Dict[int, List[StepRecord]] = {}
    for rec in scenario.steps:
        steps_by_tick.setdefault(rec.tick, []).append(rec)
    hot_by_tick: Dict[int, List[Hotspot]] = {}
    for h in scenario.hotspots:
        hot_by_tick.setdefault(h.tick, []).append(h)

    rails = np.zeros((scenario.ticks, 2, chips), np.float32)
    util_trace = np.zeros((scenario.ticks, chips), np.float32)
    savings, powers, t_maxes = [], [], []
    for tick in range(scenario.ticks):
        for rec in steps_by_tick.get(tick, ()):
            mon.record_step(rec.worker, tick, rec.step_s)
        for h in hot_by_tick.get(tick, ()):
            fleet.T = np.asarray(fleet.T).copy()
            fleet.T[h.chip] = h.t_chip  # the TSD reads the cooling fault
        rep = loop.step(now=float(tick))
        rails[tick, 0] = fleet.v_core
        rails[tick, 1] = fleet.v_sram
        u = rep.snapshot.util(chips)
        util_trace[tick] = 1.0 if u is None else u
        ro = rep.readout
        savings.append(ro.saving)
        powers.append(ro.pod_power_w)
        t_maxes.append(ro.t_max)

    tot = injector.totals if injector is not None else None
    return ReplayResult(
        name=scenario.name, ticks=scenario.ticks,
        replans=st.replans - base[0], lut_hits=st.lut_hits - base[1],
        boosts=st.boosts - base[2], rebalances=st.rebalances - base[3],
        replan_reasons=list(st.replan_reasons[base[4]:]),
        mean_saving=float(np.mean(savings)),
        energy_j=float(np.sum(powers) * tick_s),
        t_max=float(np.max(t_maxes)),
        condemned=tuple(sorted(assignment.condemned)),
        shares=assignment.shares.copy(),
        rails=rails, util_trace=util_trace,
        backoffs=st.backoffs - base[5], restores=st.restores - base[6],
        sdc_injected=tot.injected if tot else 0,
        sdc_detected=tot.detected if tot else 0,
        sdc_corrected=tot.corrected if tot else 0,
        sdc_escaped=tot.escaped if tot else 0,
        sdc_checked=tot.checked if tot else 0,
        quarantined=st.quarantined - base[7],
        stale_fallbacks=st.stale_fallbacks - base[8],
        degraded_ticks=st.degraded_ticks - base[9],
        frozen_ticks=st.frozen_ticks - base[10],
        safe_states=st.safe_states - base[11],
        below_axis_clamps=st.below_axis_clamps - base[12],
        write_nacks=fleet.write_nacks, write_retries=fleet.write_retries,
        watchdog_events=list(st.watchdog_events[base[13]:]),
        recover_ticks=list(st.recover_ticks[base[14]:]))


# ---------------------------------------------------------------------------
# fleet replay harness (§10: multi-pod failure domains)
# ---------------------------------------------------------------------------


@dataclass
class FleetReplayResult:
    """One fleet day: per-pod control under the global health authority.

    ``fingerprint`` hashes exactly what :attr:`ReplayResult.fingerprint`
    hashes, so the single-pod degenerate fleet pins bitwise against the
    flat loop.  ``fleet_fingerprint`` drops the replan-reason ledger —
    every pod legitimately logs its own ``cold_start`` — and is the
    pod-count-invariance pin (rails + energy + condemned)."""

    name: str
    ticks: int
    n_pods: int
    replans: int
    lut_hits: int
    boosts: int
    rebalances: int
    replan_reasons: List[str]  # pod-major: pod 0's whole day, then pod 1's
    mean_saving: float
    energy_j: float
    t_max: float
    condemned: Tuple[int, ...]
    shares: np.ndarray       # final elastic work shares (chips,)
    rails: np.ndarray        # (ticks, 2, chips) applied (v_core, v_sram)
    states: Dict[int, str]   # final pod health states
    state_trace: List[Dict[int, str]]  # per-tick pod health states
    events: List[str]        # fleet health events, in order
    migrated: int = 0        # live-migrated in-flight requests
    quarantines: int = 0     # pods walked to quarantine
    pod_restores: int = 0    # pods restored through the cool-down
    staged_commits: int = 0  # latency-buffered rail writes committed
    # §9 containment ledger, summed over the pod controllers (NOT hashed)
    quarantined: int = 0
    stale_fallbacks: int = 0
    degraded_ticks: int = 0
    frozen_ticks: int = 0
    safe_states: int = 0
    below_axis_clamps: int = 0
    write_nacks: int = 0
    write_retries: int = 0
    watchdog_events: List[str] = dfield(default_factory=list)

    @property
    def fingerprint(self) -> str:
        """Determinism pin — the :attr:`ReplayResult.fingerprint` formula
        verbatim (the degenerate-fleet bitwise contract)."""
        h = hashlib.sha256()
        h.update(self.rails.astype(np.float64).tobytes())
        h.update(np.float64(self.energy_j).tobytes())
        h.update(",".join(self.replan_reasons).encode())
        h.update(np.asarray(sorted(self.condemned), np.int64).tobytes())
        return h.hexdigest()[:16]

    @property
    def fleet_fingerprint(self) -> str:
        """Pod-count-invariance pin: the physical outcome only (applied
        rails, energy, condemned chips) — no per-pod bookkeeping."""
        h = hashlib.sha256()
        h.update(self.rails.astype(np.float64).tobytes())
        h.update(np.float64(self.energy_j).tobytes())
        h.update(np.asarray(sorted(self.condemned), np.int64).tobytes())
        return h.hexdigest()[:16]


def fleet_replay(scenario: Scenario, n_pods: int = 2,
                 runtime: Optional[RT.EnergyAwareRuntime] = None,
                 tick_s: float = 60.0, guard_band_c: float = 3.0,
                 sweep=(10.0, 45.0, 8), util_sweep=(0.25, 1.0, 4),
                 faults=None, amb_offset_c: float = 0.0,
                 write_latency_s: float = 0.0,
                 power_budget_w: Optional[float] = None,
                 degrade_after: int = 2, quarantine_after: int = 4,
                 restore_after: int = 3, restore_below_c: float = 70.0
                 ) -> FleetReplayResult:
    """Run ``scenario`` through the §10 multi-pod ``FleetLoop``.

    One ``RailField`` build and one ``FleetPlanner`` serve every pod: each
    pod's ``LutController`` sees a ``slice_chips`` view of the shared
    field over a ``PodPlanner`` facade, its own ``TelemetryBus`` fed by
    ``FanoutTelemetry`` slices of the shared monitor/elastic/fleet sources
    plus its own ambient sensor (pod ``i`` reads
    ``scenario.ambient + i * amb_offset_c``; pod 0 is the machine-room
    reference), and a ``PodRailChannel`` over the shared actuator.

    Chaos: ``scenario.chaos`` (or ``faults``) attaches per pod.  With
    ``scenario.chaos_pod`` set, only that pod's sensors/rails/watchdog see
    the fault plane (the pod-loss drill); otherwise every pod draws its
    own decorrelated stream via ``ControlFaultModel.for_pod``.  With
    ``n_pods=1`` the base model attaches exactly as :func:`replay` does.

    Determinism and invariance (pinned by ``tests/test_fleet.py``):

    - ``n_pods=1`` is **bitwise** the flat loop: same polls, same decide,
      same actuator writes — ``fingerprint`` equals the
      :func:`replay` fingerprint on the same runtime/controller config.
    - For clean scenarios (no chaos, no hotspots, no stragglers, zero
      ambient offsets) the physical outcome is **pod-count invariant**:
      the per-tick fleet utilization is assembled before any pod decides,
      replans are memoized per ``(t_amb, util)`` so every pod slices ONE
      shared solve, and the bilinear RailField lookup commutes with chip
      slicing — ``fleet_fingerprint`` is the same for any pod count.
      Scenarios with per-pod fault streams, hotspots, or stragglers are
      *not* invariant (a pod slice changes which controller sees the hot
      chip and decorrelated NACK draws land in different order); their
      multi-pod fingerprints are pinned as their own golden values.
    """
    rt = runtime if runtime is not None else RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")
    from repro.control.lut import sweep_points
    field = rt.build_field(sweep_points(*sweep), sweep_points(*util_sweep))
    chips = rt.substrate.n_domains
    spans = PodTopology.partition(chips, n_pods)
    topo = PodTopology(grid=rt.substrate.grid)

    det = StragglerDetector(threshold=1.5, window=8, min_samples=4)
    mon = ctl.MonitorTelemetry(det, topology=topo)
    assignment = ElasticWorkAssignment(chips)
    elastic = ElasticActuator(assignment)
    fleet = ctl.FleetActuator.from_runtime(
        rt, t_amb=scenario.ambient_at(0), field=field)
    if faults is None and scenario.chaos is not None:
        faults = scenario.chaos()
    if n_pods == 1 and faults is not None:
        fleet.write_faults = faults  # the flat loop's exact wiring

    ctx = ctl.TickContext()
    mon_f = ctl.FanoutTelemetry(mon)
    ela_f = ctl.FanoutTelemetry(elastic)
    flt_f = ctl.FanoutTelemetry(fleet)
    pods: List[ctl.PodDomain] = []
    for i, (lo, hi) in enumerate(spans):
        pf = None
        if faults is not None and (scenario.chaos_pod is None
                                   or scenario.chaos_pod == i):
            pf = faults if n_pods == 1 else faults.for_pod(i)
        planner = ctl.PodPlanner(rt.planner, lo, hi, ctx=ctx)
        controller = ctl.LutController(
            planner,
            field=field if n_pods == 1 else field.slice_chips(lo, hi),
            guard_band_c=guard_band_c)
        trace = (scenario.ambient if i == 0 or amb_offset_c == 0.0 else
                 (lambda now, off=i * amb_offset_c:
                  scenario.ambient(now) + off))
        amb_src = ctl.AmbientSensor(trace)
        flt_src = flt_f.view(lo, hi, primary=(i == 0))
        ch_kw = {}
        if pf is not None:
            amb_src = ctl.ChaosTelemetry(amb_src, pf)
            flt_src = ctl.ChaosTelemetry(flt_src, pf)
            controller.faults = pf  # scripted deadline/solver-fault ticks
            if n_pods > 1:
                ch_kw["write_faults"] = pf  # slice-confined NACK channel
        bus = ctl.TelemetryBus(
            [amb_src, _LoadTelemetry(scenario),
             mon_f.view(lo, hi, primary=(i == 0)),
             ela_f.view(lo, hi, primary=(i == 0)), flt_src],
            max_age=0.75 if faults is not None else None)
        pods.append(ctl.PodDomain(
            index=i, lo=lo, hi=hi, bus=bus, controller=controller,
            rails=ctl.PodRailChannel(fleet, lo, hi,
                                     write_latency_s=write_latency_s,
                                     **ch_kw)))
    loop = ctl.FleetLoop(pods, fleet, elastic=elastic, ctx=ctx,
                         power_budget_w=power_budget_w,
                         degrade_after=degrade_after,
                         quarantine_after=quarantine_after,
                         restore_after=restore_after,
                         restore_below_c=restore_below_c)
    for pod in pods:
        pod.controller.reset()
    bases = []
    for pod in pods:
        st = pod.controller.stats
        bases.append((st.replans, st.lut_hits, st.boosts, st.rebalances,
                      len(st.replan_reasons), st.quarantined,
                      st.stale_fallbacks, st.degraded_ticks,
                      st.frozen_ticks, st.safe_states,
                      st.below_axis_clamps, len(st.watchdog_events)))

    steps_by_tick: Dict[int, List[StepRecord]] = {}
    for rec in scenario.steps:
        steps_by_tick.setdefault(rec.tick, []).append(rec)
    hot_by_tick: Dict[int, List[Hotspot]] = {}
    for h in scenario.hotspots:
        hot_by_tick.setdefault(h.tick, []).append(h)

    rails = np.zeros((scenario.ticks, 2, chips), np.float32)
    savings, powers, t_maxes = [], [], []
    state_trace: List[Dict[int, str]] = []
    for tick in range(scenario.ticks):
        for rec in steps_by_tick.get(tick, ()):
            mon.record_step(rec.worker, tick, rec.step_s)
        for h in hot_by_tick.get(tick, ()):
            fleet.T = np.asarray(fleet.T).copy()
            fleet.T[h.chip] = h.t_chip
        rep = loop.step(now=float(tick))
        rails[tick, 0] = fleet.v_core
        rails[tick, 1] = fleet.v_sram
        ro = rep.readout
        savings.append(ro.saving)
        powers.append(ro.pod_power_w)
        t_maxes.append(ro.t_max)
        state_trace.append(dict(rep.states))

    agg = [0] * 12
    reasons: List[str] = []
    watchdog: List[str] = []
    for pod, base in zip(pods, bases):
        st = pod.controller.stats
        cur = (st.replans, st.lut_hits, st.boosts, st.rebalances,
               len(st.replan_reasons), st.quarantined, st.stale_fallbacks,
               st.degraded_ticks, st.frozen_ticks, st.safe_states,
               st.below_axis_clamps, len(st.watchdog_events))
        agg = [a + (c - b) for a, (c, b) in zip(agg, zip(cur, base))]
        reasons.extend(st.replan_reasons[base[4]:])
        watchdog.extend(f"pod{pod.index}:{e}" if n_pods > 1 else e
                        for e in st.watchdog_events[base[11]:])
    return FleetReplayResult(
        name=scenario.name, ticks=scenario.ticks, n_pods=n_pods,
        replans=agg[0], lut_hits=agg[1], boosts=agg[2], rebalances=agg[3],
        replan_reasons=reasons,
        mean_saving=float(np.mean(savings)),
        energy_j=float(np.sum(powers) * tick_s),
        t_max=float(np.max(t_maxes)),
        condemned=tuple(sorted(assignment.condemned)),
        shares=assignment.shares.copy(), rails=rails,
        states={p.index: p.state for p in pods},
        state_trace=state_trace, events=list(loop.events),
        migrated=loop.migrated_total,
        quarantines=sum(1 for e in loop.events if ":quarantined@" in e),
        pod_restores=sum(1 for e in loop.events if ":restored@" in e),
        staged_commits=sum(p.rails.staged_commits for p in pods),
        quarantined=agg[5], stale_fallbacks=agg[6], degraded_ticks=agg[7],
        frozen_ticks=agg[8], safe_states=agg[9], below_axis_clamps=agg[10],
        write_nacks=fleet.write_nacks, write_retries=fleet.write_retries,
        watchdog_events=watchdog)


# ---------------------------------------------------------------------------
# serving replay harness (engine in the loop)
# ---------------------------------------------------------------------------


@dataclass
class ServeReplayResult:
    """One served day: traffic, energy and SLO ledger, determinism pin."""
    name: str
    workload: str
    ticks: int               # control ticks actually run (incl. drain)
    engine_ticks: int
    finished: int
    rejected: int            # prompt_too_long etc.
    tokens: int              # generated tokens across finished requests
    energy_j: float          # sum(pod_power_w) * tick_s over control ticks
    max_wait: float          # engine ticks, submit -> finish (worst case)
    mean_wait: float
    caps: np.ndarray         # (ticks,) applied admit cap (-1 = uncapped)
    outputs: Tuple[Tuple[int, ...], ...]  # rid-ordered generated tokens
    deferred: int = 0        # AdmissionController ledger (0 for baselines)
    forced: int = 0
    # §9 thermal-emergency preemption ledger (0 unless preempt=True; NOT
    # hashed, so pre-chaos serve fingerprints are unchanged)
    preempts: int = 0        # slot evictions to the host page pool
    preempted_reqs: int = 0  # distinct requests that were evicted
    # §10 fleet ledger (0 unless run through fleet_serve_replay; NOT hashed)
    migrated: int = 0        # requests live-migrated across pods
    quarantines: int = 0     # pods walked to quarantine
    pod_restores: int = 0    # pods restored through the cool-down

    @property
    def tokens_per_joule(self) -> float:
        return self.tokens / self.energy_j if self.energy_j > 0 else 0.0

    @property
    def fingerprint(self) -> str:
        """Determinism pin: hashes the generated tokens, the applied
        admission-cap trace and the energy integral."""
        h = hashlib.sha256()
        for out in self.outputs:
            h.update(np.asarray(out, np.int64).tobytes())
            h.update(b"|")
        h.update(self.caps.astype(np.int64).tobytes())
        h.update(np.float64(self.energy_j).tobytes())
        return h.hexdigest()[:16]


def serve_prompt(rid: int, prompt_len: int, vocab: int) -> np.ndarray:
    """The deterministic prompt for a workload rid (pure function of the
    arrival record, so a workload fingerprint pins the full input)."""
    return ((np.arange(prompt_len, dtype=np.int64) * 3 + rid * 7) % vocab
            ).astype(np.int32)


def serve_replay(scenario: Scenario, workload: RequestWorkload, model,
                 params, controller=None,
                 runtime: Optional[RT.EnergyAwareRuntime] = None,
                 admission: bool = False, defer_premium: float = 1.05,
                 max_wait: Optional[float] = None, preempt: bool = False,
                 engine_steps: int = 6, tick_s: float = 60.0,
                 sweep=(10.0, 45.0, 4), util_sweep=(0.25, 1.0, 4),
                 batch_slots: int = 4, max_len: int = 64,
                 drain_ticks: int = 32, engine_seed: int = 0,
                 **engine_kwargs) -> ServeReplayResult:
    """Run a request workload through a real serve ``Engine`` under the
    full control loop; deterministic (fingerprint-pinned).

    Each control tick: the tick's arrivals are submitted, the engine runs
    ``engine_steps`` scheduler iterations (emitting ``TickSample``\\ s), then
    the control loop polls/decides/settles — so ``Throttle`` decisions made
    from this tick's queue state gate the *next* tick's admissions, exactly
    one control-latency behind, and the energy ledger integrates the
    settled pod power at the utilization the engine actually ran.

    ``admission=True`` wraps the rail controller in an
    :class:`~repro.control.admission.AdmissionController` (thermal-aware
    admission); the default is the throughput-only baseline (same rails,
    uncapped admission).  Pass a prebuilt ``controller`` to override both.
    After the scenario's day the loop keeps ticking (ambient trace
    extended) until the engine drains or ``drain_ticks`` elapse.
    """
    from repro.control.admission import AdmissionController
    from repro.serve import Engine, Request

    rt = runtime if runtime is not None else RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")
    if controller is None:
        from repro.control.lut import sweep_points
        controller = rt.controller(
            field=rt.build_field(sweep_points(*sweep),
                                 sweep_points(*util_sweep)),
            guard_band_c=3.0)
        if admission:
            controller = AdmissionController(
                controller, defer_premium=defer_premium,
                max_wait=(max_wait if max_wait is not None
                          else 4.0 * engine_steps * scenario.ticks),
                preempt=preempt)
    if hasattr(controller, "reset"):
        controller.reset()

    eng = Engine(model, params, batch_slots=batch_slots, max_len=max_len,
                 seed=engine_seed, **engine_kwargs)
    if isinstance(controller, AdmissionController):
        eng.admit_cap = 0  # the controller owns the knob from tick 0
    tel = ctl.EngineTelemetry()
    eng.on_tick.append(tel.on_tick)
    fleet = ctl.FleetActuator.from_runtime(
        rt, t_amb=scenario.ambient_at(0),
        field=getattr(controller, "field", None))
    loop = ctl.ControlLoop(
        ctl.TelemetryBus([ctl.AmbientSensor(scenario.ambient), tel, fleet]),
        controller, [fleet, ctl.EngineActuator(eng)])

    adm_stats = getattr(controller, "stats", None)
    base_def, base_forced = ((adm_stats.deferred, adm_stats.forced)
                             if isinstance(controller, AdmissionController)
                             else (0, 0))
    vocab = model.cfg.vocab_size
    by_tick = workload.by_tick()
    hot_by_tick: Dict[int, List[Hotspot]] = {}
    for h in scenario.hotspots:
        hot_by_tick.setdefault(h.tick, []).append(h)
    reqs: Dict[int, Request] = {}
    powers: List[float] = []
    caps: List[int] = []
    tick = 0
    while tick < scenario.ticks or (
            tick < scenario.ticks + drain_ticks
            and (eng.queue or any(r is not None for r in eng.slot_req))):
        for a in by_tick.get(tick, ()):
            req = Request(a.rid, serve_prompt(a.rid, a.prompt_len, vocab),
                          max_new=a.max_new)
            reqs[a.rid] = req
            eng.submit(req)
        for _ in range(engine_steps):
            eng.step()
        for h in hot_by_tick.get(tick, ()):
            fleet.T = np.asarray(fleet.T).copy()
            fleet.T[h.chip] = h.t_chip  # cooling fault under live traffic
        rep = loop.step(now=float(tick))
        powers.append(rep.readout.pod_power_w)
        caps.append(-1 if eng.admit_cap is None else int(eng.admit_cap))
        tick += 1

    ok = [r for r in eng.finished if r.error is None]
    waits = [float(r.finish_tick - r.submit_tick) for r in ok]
    outputs = tuple(tuple(reqs[rid].out) for rid in sorted(reqs))
    return ServeReplayResult(
        name=scenario.name, workload=workload.name, ticks=tick,
        engine_ticks=eng.ticks, finished=len(ok),
        rejected=len(eng.finished) - len(ok),
        tokens=sum(len(r.out) for r in ok),
        energy_j=float(np.sum(powers) * tick_s),
        max_wait=float(max(waits)) if waits else 0.0,
        mean_wait=float(np.mean(waits)) if waits else 0.0,
        caps=np.asarray(caps, np.int64), outputs=outputs,
        deferred=(adm_stats.deferred - base_def
                  if isinstance(controller, AdmissionController) else 0),
        forced=(adm_stats.forced - base_forced
                if isinstance(controller, AdmissionController) else 0),
        preempts=eng.preempts,
        preempted_reqs=sum(1 for r in reqs.values() if r.preempts > 0))


def fleet_serve_replay(scenario: Scenario, workload: RequestWorkload,
                       model, params, n_pods: int = 2,
                       runtime: Optional[RT.EnergyAwareRuntime] = None,
                       engine_steps: int = 6, tick_s: float = 60.0,
                       sweep=(10.0, 45.0, 4), util_sweep=(0.25, 1.0, 4),
                       guard_band_c: float = 3.0, batch_slots: int = 4,
                       max_len: int = 64, drain_ticks: int = 32,
                       engine_seed: int = 0, faults=None,
                       degrade_after: int = 2, quarantine_after: int = 4,
                       restore_after: int = 3, restore_below_c: float = 70.0,
                       power_budget_w: Optional[float] = None,
                       enforce_budget: bool = False,
                       **engine_kwargs) -> ServeReplayResult:
    """The §10 pod-loss serving drill: a request workload served by
    ``n_pods`` engines (one per failure domain) over ONE shared
    :class:`~repro.serve.cache.HostPagePool`, under the fleet health
    machine.  When a pod is quarantined its engine is drained — active
    slots evicted page-exact to the shared pool — and every in-flight
    request is live-migrated to the survivors' engines, where prefix
    re-prefill plus greedy decode with the shared weights resumes it
    bitwise: ``outputs`` equals the no-failure day's outputs, rid for rid
    (pinned by ``tests/test_fleet.py``).

    Arrivals are routed ``rid % len(live_pods)`` over the pods currently
    accepting work — deterministic, and a drained pod rejoins the rotation
    the tick it is restored.
    """
    from repro.serve import Engine, Request
    from repro.serve.cache import HostPagePool

    rt = runtime if runtime is not None else RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")
    from repro.control.lut import sweep_points
    field = rt.build_field(sweep_points(*sweep), sweep_points(*util_sweep))
    chips = rt.substrate.n_domains
    spans = PodTopology.partition(chips, n_pods)
    assignment = ElasticWorkAssignment(chips)
    elastic = ElasticActuator(assignment)
    fleet = ctl.FleetActuator.from_runtime(
        rt, t_amb=scenario.ambient_at(0), field=field)
    if faults is None and scenario.chaos is not None:
        faults = scenario.chaos()
    if n_pods == 1 and faults is not None:
        fleet.write_faults = faults

    pool = HostPagePool()  # ONE host pool: the migration fabric
    ctx = ctl.TickContext()
    ela_f = ctl.FanoutTelemetry(elastic)
    flt_f = ctl.FanoutTelemetry(fleet)
    pods: List[ctl.PodDomain] = []
    for i, (lo, hi) in enumerate(spans):
        pf = None
        if faults is not None and (scenario.chaos_pod is None
                                   or scenario.chaos_pod == i):
            pf = faults if n_pods == 1 else faults.for_pod(i)
        eng = Engine(model, params, batch_slots=batch_slots,
                     max_len=max_len, seed=engine_seed, pool=pool,
                     **engine_kwargs)
        tel = ctl.EngineTelemetry()
        eng.on_tick.append(tel.on_tick)
        controller = ctl.LutController(
            ctl.PodPlanner(rt.planner, lo, hi, ctx=ctx),
            field=field if n_pods == 1 else field.slice_chips(lo, hi),
            guard_band_c=guard_band_c)
        amb_src = ctl.AmbientSensor(scenario.ambient)
        flt_src = flt_f.view(lo, hi, primary=(i == 0))
        ch_kw = {}
        if pf is not None:
            amb_src = ctl.ChaosTelemetry(amb_src, pf)
            flt_src = ctl.ChaosTelemetry(flt_src, pf)
            controller.faults = pf
            if n_pods > 1:
                ch_kw["write_faults"] = pf
        bus = ctl.TelemetryBus(
            [amb_src, tel, ela_f.view(lo, hi, primary=(i == 0)), flt_src],
            max_age=0.75 if faults is not None else None)
        pods.append(ctl.PodDomain(
            index=i, lo=lo, hi=hi, bus=bus, controller=controller,
            rails=ctl.PodRailChannel(fleet, lo, hi, **ch_kw),
            engine=eng, extra=[ctl.EngineActuator(eng)]))
    loop = ctl.FleetLoop(pods, fleet, elastic=elastic, ctx=ctx,
                         power_budget_w=power_budget_w,
                         enforce_budget=enforce_budget,
                         degrade_after=degrade_after,
                         quarantine_after=quarantine_after,
                         restore_after=restore_after,
                         restore_below_c=restore_below_c)
    for pod in pods:
        pod.controller.reset()

    def live():
        return [p for p in pods if p.state in (ctl.HEALTHY, ctl.DEGRADED)]

    vocab = model.cfg.vocab_size
    by_tick = workload.by_tick()
    hot_by_tick: Dict[int, List[Hotspot]] = {}
    for h in scenario.hotspots:
        hot_by_tick.setdefault(h.tick, []).append(h)
    reqs: Dict[int, Request] = {}
    powers: List[float] = []
    caps: List[int] = []

    def busy():
        return any(p.engine.queue
                   or any(r is not None for r in p.engine.slot_req)
                   for p in pods)

    tick = 0
    while tick < scenario.ticks or (tick < scenario.ticks + drain_ticks
                                    and busy()):
        targets = live()
        for a in by_tick.get(tick, ()):
            req = Request(a.rid, serve_prompt(a.rid, a.prompt_len, vocab),
                          max_new=a.max_new)
            reqs[a.rid] = req
            targets[a.rid % len(targets)].engine.submit(req)
        for p in pods:
            if p.state in (ctl.HEALTHY, ctl.DEGRADED):
                for _ in range(engine_steps):
                    p.engine.step()
        for h in hot_by_tick.get(tick, ()):
            fleet.T = np.asarray(fleet.T).copy()
            fleet.T[h.chip] = h.t_chip
        rep = loop.step(now=float(tick))
        powers.append(rep.readout.pod_power_w)
        pod_caps = [p.engine.admit_cap for p in live()]
        applied = [c for c in pod_caps if c is not None]
        caps.append(min(applied) if applied else -1)
        tick += 1

    ok = [r for p in pods for r in p.engine.finished if r.error is None]
    bad = [r for p in pods for r in p.engine.finished
           if r.error is not None]
    waits = [float(r.finish_tick - r.submit_tick) for r in ok]
    outputs = tuple(tuple(reqs[rid].out) for rid in sorted(reqs))
    return ServeReplayResult(
        name=scenario.name, workload=workload.name, ticks=tick,
        engine_ticks=sum(p.engine.ticks for p in pods),
        finished=len(ok), rejected=len(bad),
        tokens=sum(len(r.out) for r in ok),
        energy_j=float(np.sum(powers) * tick_s),
        max_wait=float(max(waits)) if waits else 0.0,
        mean_wait=float(np.mean(waits)) if waits else 0.0,
        caps=np.asarray(caps, np.int64), outputs=outputs,
        preempts=sum(p.engine.preempts for p in pods),
        preempted_reqs=sum(1 for r in reqs.values() if r.preempts > 0),
        migrated=loop.migrated_total,
        quarantines=sum(1 for e in loop.events if ":quarantined@" in e),
        pod_restores=sum(1 for e in loop.events if ":restored@" in e))


# ---------------------------------------------------------------------------
# CLI smoke: python -m repro.scenarios <scenario> [--quick] [--json]
# ---------------------------------------------------------------------------


def _main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser(
        prog="python -m repro.scenarios",
        description="replay one scenario twice and verify the determinism "
                    "pin (same fingerprint) and the thermal envelope")
    ap.add_argument("scenario", choices=sorted(SCENARIOS))
    ap.add_argument("--quick", action="store_true",
                    help="16-tick day on a coarse sweep (CI smoke)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    sc = SCENARIOS[args.scenario](ticks=16) if args.quick \
        else SCENARIOS[args.scenario]()
    from repro.control.lut import sweep_points
    rt = RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")
    sweep = (15.0, 40.0, 4) if args.quick else (10.0, 45.0, 8)
    u_knots = (0.25, 1.0, 3 if args.quick else 4)
    if args.scenario == "pod_loss_day":
        # the §10 drill replays through the multi-pod FleetLoop: verify
        # determinism AND that the day actually walked a pod through
        # quarantine and back
        kw = dict(n_pods=2, runtime=rt, sweep=sweep, util_sweep=u_knots)
        a = fleet_replay(sc, **kw)
        b = fleet_replay(sc, **kw)
        assert a.fingerprint == b.fingerprint, \
            f"fleet replay not deterministic: {a.fingerprint} != " \
            f"{b.fingerprint}"
        assert a.t_max < TF.T_MAX_CHIP, \
            f"thermal envelope violated: {a.t_max:.1f}C >= {TF.T_MAX_CHIP}C"
        assert a.quarantines >= 1, f"no pod quarantined: {a.events}"
        assert a.pod_restores >= 1, f"no pod restored: {a.events}"
        out = {
            "scenario": a.name, "ticks": a.ticks, "n_pods": a.n_pods,
            "fingerprint": a.fingerprint, "replans": a.replans,
            "mean_saving": round(a.mean_saving, 4),
            "t_max": round(a.t_max, 2), "states": a.states,
            "quarantines": a.quarantines, "pod_restores": a.pod_restores,
            "condemned": list(a.condemned), "events": a.events,
        }
        if args.json:
            print(json.dumps(out, indent=2))
        else:
            print(f"[{out['scenario']}] deterministic over {out['ticks']} "
                  f"ticks x {out['n_pods']} pods "
                  f"(fingerprint {out['fingerprint']})")
            for k in ("replans", "mean_saving", "t_max", "states",
                      "quarantines", "pod_restores"):
                print(f"  {k:>22}: {out[k]}")
            for e in out["events"]:
                print(f"  {'event':>22}: {e}")
        return 0
    controller = rt.controller(
        field=rt.build_field(sweep_points(*sweep),
                             sweep_points(*u_knots)),
        guard_band_c=3.0)
    a = replay(sc, runtime=rt, controller=controller)
    b = replay(sc, runtime=rt, controller=controller)
    assert a.fingerprint == b.fingerprint, \
        f"replay not deterministic: {a.fingerprint} != {b.fingerprint}"
    assert a.t_max < TF.T_MAX_CHIP, \
        f"thermal envelope violated: {a.t_max:.1f}C >= {TF.T_MAX_CHIP}C"
    out = {
        "scenario": a.name, "ticks": a.ticks, "fingerprint": a.fingerprint,
        "replans": a.replans, "lut_hits": a.lut_hits,
        "mean_saving": round(a.mean_saving, 4), "t_max": round(a.t_max, 2),
        "quarantined": a.quarantined, "stale_fallbacks": a.stale_fallbacks,
        "degraded_ticks": a.degraded_ticks, "frozen_ticks": a.frozen_ticks,
        "safe_states": a.safe_states, "write_nacks": a.write_nacks,
        "below_axis_clamps": a.below_axis_clamps,
        "watchdog_events": a.watchdog_events,
        "mean_ticks_to_recover": a.mean_ticks_to_recover,
    }
    if args.json:
        print(json.dumps(out, indent=2))
    else:
        print(f"[{out['scenario']}] deterministic over {out['ticks']} ticks"
              f" (fingerprint {out['fingerprint']})")
        for k in ("replans", "lut_hits", "mean_saving", "t_max",
                  "quarantined", "stale_fallbacks", "degraded_ticks",
                  "frozen_ticks", "safe_states", "write_nacks",
                  "below_axis_clamps", "mean_ticks_to_recover"):
            print(f"  {k:>22}: {out[k]}")
        if out["watchdog_events"]:
            print(f"  {'watchdog_events':>22}: "
                  + ", ".join(out["watchdog_events"]))
    return 0


if __name__ == "__main__":  # pragma: no cover - exercised by CI smoke
    raise SystemExit(_main())
