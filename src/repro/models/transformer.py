"""Block assembly + full LM forward/prefill/decode for dense/moe/ssm/hybrid.

Repeated homogeneous layers are stacked and iterated with ``lax.scan`` (keeps
HLO size O(1) in depth — essential for the 512-device dry-run compiles) with
``jax.checkpoint`` around the block body when ``cfg.remat == 'full'``.

Heterogeneous stacks (zamba2 hybrid) scan over *groups*: each group is an
inner scan over ``hybrid_attn_every`` stacked mamba layers followed by the
single weight-shared attention block (captured, à la Zamba).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.params import stack_tree
from repro.sharding.plan import Plan

ZERO_AUX = lambda: {"moe_aux": jnp.zeros((), jnp.float32),
                    "moe_z": jnp.zeros((), jnp.float32)}


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    return fn


# =============================================================================
# single blocks
# =============================================================================

def attn_block_params(cfg: ModelConfig, plan: Plan, use_moe: bool, d_ff=None):
    p = {
        "ln1": L.norm_params(cfg),
        "ln2": L.norm_params(cfg),
        "attn": (attn.mla_params(cfg, plan) if cfg.attn_type == "mla"
                 else attn.gqa_params(cfg, plan)),
    }
    if use_moe:
        p["moe"] = moe_lib.moe_params(cfg, plan)
    else:
        p["mlp"] = L.mlp_params(cfg, d_ff=d_ff)
    return p


def attn_block_apply(p, x, cfg, plan, positions=None, collect_kv=False):
    h = L.norm_apply(p["ln1"], x, cfg)
    if cfg.attn_type == "mla":
        a, kv = attn.mla_apply(p["attn"], h, cfg, plan, positions)
    else:
        a, kv = attn.gqa_apply(p["attn"], h, cfg, plan, positions)
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg)
    if "moe" in p:
        m, aux = moe_lib.moe_apply(p["moe"], h, cfg, plan)
    else:
        m, aux = L.mlp_apply(p["mlp"], h, cfg, plan), ZERO_AUX()
    x = x + m
    x = plan.act(x, "batch", "seq", None)
    return (x, aux, kv) if collect_kv else (x, aux)


def attn_block_decode(p, x, cache, pos, cfg, plan, n_valid=None):
    h = L.norm_apply(p["ln1"], x, cfg)
    if cfg.attn_type == "mla":
        a, cache = attn.mla_decode(p["attn"], h, cache, pos, cfg, plan,
                                   n_valid=n_valid)
    else:
        a, cache = attn.gqa_decode(p["attn"], h, cache, pos, cfg, plan,
                                   n_valid=n_valid)
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg)
    if "moe" in p:
        m, _ = moe_lib.moe_apply(p["moe"], h, cfg, plan)
    else:
        m = L.mlp_apply(p["mlp"], h, cfg, plan)
    return x + m, cache


def ssm_block_params(cfg, plan):
    return {"ln": L.norm_params(cfg), "ssm": ssm_lib.ssm_params(cfg, plan)}


def ssm_block_apply(p, x, cfg, plan):
    h = L.norm_apply(p["ln"], x, cfg)
    o, state = ssm_lib.ssm_apply(p["ssm"], h, cfg, plan)
    return x + o, state


def ssm_block_decode(p, x, state, cfg, plan):
    h = L.norm_apply(p["ln"], x, cfg)
    o, state = ssm_lib.ssm_decode(p["ssm"], h, state, cfg, plan)
    return x + o, state


# =============================================================================
# homogeneous stacks (dense / moe / ssm): scan over stacked layer params
# =============================================================================

def _uniform_stack_params(cfg: ModelConfig, plan: Plan):
    if cfg.family == "ssm":
        one = ssm_block_params(cfg, plan)
        n_scan = cfg.num_layers
        extra = {}
    elif cfg.is_moe:
        one = attn_block_params(cfg, plan, use_moe=True)
        n_scan = cfg.num_layers - cfg.first_k_dense
        extra = {
            f"dense{i}": attn_block_params(cfg, plan, use_moe=False)
            for i in range(cfg.first_k_dense)
        }
    else:
        one = attn_block_params(cfg, plan, use_moe=False)
        n_scan = cfg.num_layers
        extra = {}
    return {"stack": stack_tree(one, n_scan), **extra}, n_scan


def _scan_blocks(stack_params, x, cfg, plan, block_fn):
    """scan over stacked params; block_fn(p, x) -> (x, aux_or_state)."""

    def body(carry, layer_p):
        x, aux = carry
        x, a = block_fn(layer_p, x)
        if isinstance(a, dict) and "moe_aux" in a:
            aux = {k: aux[k] + a[k] for k in aux}
            return (x, aux), None
        return (x, aux), a

    if not cfg.scan_layers:
        # unrolled python loop: same contract as the scan below, but the
        # block body runs eagerly layer by layer — required when matmuls
        # are routed through a host-side kernel (repro.tolerance ABFT),
        # which cannot execute under a scan trace.
        carry, states = (x, ZERO_AUX()), []
        n = jax.tree_util.tree_leaves(stack_params)[0].shape[0]
        for i in range(n):
            layer_p = jax.tree_util.tree_map(lambda v: v[i], stack_params)
            carry, s = body(carry, layer_p)
            states.append(s)
        x, aux = carry
        if states and states[0] is not None:
            states = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *states)
        else:
            states = None
        return x, aux, states

    body = _maybe_remat(body, cfg)
    (x, aux), states = jax.lax.scan(body, (x, ZERO_AUX()), stack_params)
    return x, aux, states


# =============================================================================
# top-level model params
# =============================================================================

def lm_params(cfg: ModelConfig, plan: Plan):
    p: Dict[str, Any] = {
        "embed": L.embed_params(cfg, plan),
        "final_ln": L.norm_params(cfg),
    }
    if cfg.family in ("dense", "moe", "ssm"):
        blocks, _ = _uniform_stack_params(cfg, plan)
        p["blocks"] = blocks
    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        n_groups, rem = divmod(cfg.num_layers, k)
        p["blocks"] = {
            "groups": stack_tree(stack_tree(ssm_block_params(cfg, plan), k), n_groups),
            "shared_attn": attn_block_params(cfg, plan, use_moe=False),
            "tail": stack_tree(ssm_block_params(cfg, plan), rem) if rem else {},
        }
    else:
        raise ValueError(cfg.family)
    return p


# =============================================================================
# forward (train): logits + aux
# =============================================================================

def lm_apply(params, tokens, cfg: ModelConfig, plan: Plan):
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    aux = ZERO_AUX()

    if cfg.family in ("dense", "moe"):
        for i in range(cfg.first_k_dense):
            x, a = attn_block_apply(params["blocks"][f"dense{i}"], x, cfg, plan)
        x, a, _ = _scan_blocks(
            params["blocks"]["stack"], x, cfg, plan,
            lambda p, x: attn_block_apply(p, x, cfg, plan))
        aux = a
    elif cfg.family == "ssm":
        x, aux, _ = _scan_blocks(
            params["blocks"]["stack"], x, cfg, plan,
            lambda p, x: (ssm_block_apply(p, x, cfg, plan)[0], None))
    elif cfg.family == "hybrid":
        x, aux = _hybrid_apply(params["blocks"], x, cfg, plan)

    x = L.norm_apply(params["final_ln"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg, plan)
    return logits, aux


def _hybrid_apply(bp, x, cfg, plan):
    aux = ZERO_AUX()

    def group_body(carry, gp):
        x, aux = carry

        def inner(c, lp):
            return ssm_block_apply(lp, c, cfg, plan)[0], None

        x, _ = jax.lax.scan(inner, x, gp)
        x, a = attn_block_apply(bp["shared_attn"], x, cfg, plan)
        aux = {k: aux[k] + a[k] for k in aux}
        return (x, aux), None

    (x, aux), _ = jax.lax.scan(
        _maybe_remat(group_body, cfg), (x, aux), bp["groups"])
    if bp["tail"]:
        def inner(c, lp):
            return ssm_block_apply(lp, c, cfg, plan)[0], None
        x, _ = jax.lax.scan(inner, x, bp["tail"])
    return x, aux


# =============================================================================
# decode caches
# =============================================================================

def lm_cache(cfg: ModelConfig, plan: Plan, batch: int, max_len: int,
             dtype, abstract: bool = False):
    """Build (abstract or zero) decode cache pytree for the whole stack."""

    def attn_cache():
        if cfg.attn_type == "mla":
            return attn.mla_cache_init(cfg, plan, batch, max_len, dtype,
                                       abstract=abstract)
        if abstract:
            return attn.gqa_cache_abstract(cfg, plan, batch, max_len, dtype)
        return attn.gqa_cache_init(cfg, plan, batch, max_len, dtype)

    def ssm_state():
        return ssm_lib.ssm_state_init(cfg, plan, batch, dtype, abstract=abstract)

    def rep(tree, n):
        """stack a cache pytree n times along a new leading dim."""
        def do(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
            return jnp.broadcast_to(leaf, (n,) + leaf.shape).copy()
        return jax.tree_util.tree_map(do, tree)

    if cfg.family in ("dense", "moe"):
        n_scan = cfg.num_layers - cfg.first_k_dense
        c = {"stack": rep(attn_cache(), n_scan)}
        for i in range(cfg.first_k_dense):
            c[f"dense{i}"] = attn_cache()
        return c
    if cfg.family == "ssm":
        return {"stack": rep(ssm_state(), cfg.num_layers)}
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        n_groups, remn = divmod(cfg.num_layers, k)
        return {
            "groups": rep(rep(ssm_state(), k), n_groups),
            "shared_attn": rep(attn_cache(), n_groups),
            "tail": rep(ssm_state(), remn) if remn else {},
        }
    raise ValueError(cfg.family)


def lm_cache_specs(cfg: ModelConfig, plan: Plan, seq_axis=None):
    """PartitionSpec tree matching lm_cache structure."""
    from jax.sharding import PartitionSpec as P

    def add_layer_dim(tree):
        return jax.tree_util.tree_map(
            lambda s: P(*((None,) + tuple(s))), tree,
            is_leaf=lambda x: isinstance(x, P))

    if cfg.attn_type == "mla":
        a_spec = attn.mla_cache_spec(plan, seq_axis)
    else:
        a_spec = attn.gqa_cache_spec(plan, seq_axis)
    s_spec = ssm_lib.ssm_state_spec(plan)

    if cfg.family in ("dense", "moe"):
        c = {"stack": add_layer_dim(a_spec)}
        for i in range(cfg.first_k_dense):
            c[f"dense{i}"] = a_spec
        return c
    if cfg.family == "ssm":
        return {"stack": add_layer_dim(s_spec)}
    if cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        n_groups, remn = divmod(cfg.num_layers, k)
        return {
            "groups": add_layer_dim(add_layer_dim(s_spec)),
            "shared_attn": add_layer_dim(a_spec),
            "tail": add_layer_dim(s_spec) if remn else {},
        }
    raise ValueError(cfg.family)


# =============================================================================
# prefill: full forward that also seeds the decode cache
# =============================================================================

def _seed_attn_cache(cfg, plan, kv, max_len, dtype, batch, lengths=None):
    """Build a seeded per-layer cache directly from prefill K/V."""
    if cfg.attn_type == "mla":
        zero = attn.mla_cache_init(cfg, plan, batch, max_len, dtype)
        return attn.mla_seed_cache(zero, kv, kv[0].shape[1], lengths=lengths)
    zero = attn.gqa_cache_init(cfg, plan, batch, max_len, dtype)
    return attn.gqa_seed_cache(zero, kv, kv[0].shape[1], lengths=lengths)


def lm_prefill(params, tokens, cfg: ModelConfig, plan: Plan,
               max_len: Optional[int] = None, lengths=None):
    """tokens:(B,S) -> (logits, seeded cache with capacity max_len or S).

    ``lengths`` (B,) marks per-row true prompt lengths when the batch is
    right-padded: cache positions past a row's length record ``pos_id = -1``
    (attention families only — SSM/hybrid recurrent state has no position
    table, so ragged prefill there must run per-request at exact length).
    """
    B, S = tokens.shape
    max_len = max_len or S
    dtype = L.cdt(cfg)
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    cache: Dict[str, Any] = {}

    if cfg.family in ("dense", "moe"):
        for i in range(cfg.first_k_dense):
            x, _, kv = attn_block_apply(params["blocks"][f"dense{i}"], x, cfg,
                                        plan, collect_kv=True)
            cache[f"dense{i}"] = _seed_attn_cache(cfg, plan, kv, max_len,
                                                  dtype, B, lengths)

        def body(carry, lp):
            x = carry
            x, _, kv = attn_block_apply(lp, x, cfg, plan, collect_kv=True)
            return x, kv

        x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x,
                              params["blocks"]["stack"])
        cache["stack"] = jax.vmap(
            lambda kv: _seed_attn_cache(cfg, plan, kv, max_len, dtype, B,
                                        lengths))(kvs)
    elif cfg.family == "ssm":
        def body(carry, lp):
            x, st = ssm_block_apply(lp, carry, cfg, plan)
            return x, st

        x, states = jax.lax.scan(_maybe_remat(body, cfg), x,
                                 params["blocks"]["stack"])
        cache["stack"] = states
    elif cfg.family == "hybrid":
        bp = params["blocks"]

        def group_body(carry, gp):
            x = carry

            def inner(c, lp):
                c, st = ssm_block_apply(lp, c, cfg, plan)
                return c, st

            x, sts = jax.lax.scan(inner, x, gp)
            x, _, kv = attn_block_apply(bp["shared_attn"], x, cfg, plan,
                                        collect_kv=True)
            return x, (sts, kv)

        x, (g_states, g_kvs) = jax.lax.scan(
            _maybe_remat(group_body, cfg), x, bp["groups"])
        cache["groups"] = g_states
        cache["shared_attn"] = jax.vmap(
            lambda kv: _seed_attn_cache(cfg, plan, kv, max_len, dtype, B,
                                        lengths))(g_kvs)
        if bp["tail"]:
            def inner(c, lp):
                c, st = ssm_block_apply(lp, c, cfg, plan)
                return c, st
            x, t_states = jax.lax.scan(inner, x, bp["tail"])
            cache["tail"] = t_states
        else:
            cache["tail"] = {}

    x = L.norm_apply(params["final_ln"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg, plan)
    return logits, cache


# =============================================================================
# decode step
# =============================================================================

def lm_decode(params, tokens, cache, pos, cfg: ModelConfig, plan: Plan,
              n_valid=None):
    """tokens:(B,S) -> logits:(B,S,V); functional cache update.

    ``pos`` may be a scalar or a (B,) vector of per-slot positions, and S may
    exceed 1 (chunked-prefill extend, attention families); ``n_valid`` (B,)
    marks real tokens per row for ragged extends.
    """
    x = L.embed_apply(params["embed"], tokens, cfg, plan)

    if cfg.family in ("dense", "moe"):
        for i in range(cfg.first_k_dense):
            x, cache[f"dense{i}"] = attn_block_decode(
                params["blocks"][f"dense{i}"], x, cache[f"dense{i}"], pos, cfg,
                plan, n_valid=n_valid)

        def body(x, pc):
            lp, lc = pc
            x, lc = attn_block_decode(lp, x, lc, pos, cfg, plan,
                                      n_valid=n_valid)
            return x, lc

        x, new_stack = jax.lax.scan(
            body, x, (params["blocks"]["stack"], cache["stack"]))
        cache = {**cache, "stack": new_stack}
    elif cfg.family == "ssm":
        def body(x, pc):
            lp, lc = pc
            x, lc = ssm_block_decode(lp, x, lc, cfg, plan)
            return x, lc

        x, new_stack = jax.lax.scan(
            body, x, (params["blocks"]["stack"], cache["stack"]))
        cache = {**cache, "stack": new_stack}
    elif cfg.family == "hybrid":
        bp = params["blocks"]

        def group_body(x, pc):
            gp, gc, ac = pc

            def inner(x, plc):
                lp, lc = plc
                x, lc = ssm_block_decode(lp, x, lc, cfg, plan)
                return x, lc

            x, gc = jax.lax.scan(inner, x, (gp, gc))
            x, ac = attn_block_decode(bp["shared_attn"], x, ac, pos, cfg, plan,
                                      n_valid=n_valid)
            return x, (gc, ac)

        x, (new_groups, new_attn) = jax.lax.scan(
            group_body, x, (bp["groups"], cache["groups"], cache["shared_attn"]))
        cache = {**cache, "groups": new_groups, "shared_attn": new_attn}
        if cache["tail"]:
            def inner(x, plc):
                lp, lc = plc
                x, lc = ssm_block_decode(lp, x, lc, cfg, plan)
                return x, lc
            x, new_tail = jax.lax.scan(inner, x, (bp["tail"], cache["tail"]))
            cache = {**cache, "tail": new_tail}

    x = L.norm_apply(params["final_ln"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg, plan)
    return logits, cache
