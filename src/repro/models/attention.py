"""Attention: GQA (qk-norm, sliding-window), MLA (+absorbed decode), cross-attn.

KV caches are dicts of arrays with an explicit per-slot ``pos_ids`` table
(``(B, T)``) so full and ring-buffer (sliding-window) caches share one
masking rule, evaluated per batch row:
    valid(b, t) = 0 <= pos_ids[b, t] <= pos[b]  and  pos_ids[b, t] > pos[b] - window.

Decode is *ragged*: ``pos`` may be a scalar (the legacy slot-synchronous
engine) or a ``(B,)`` vector of per-slot positions, and the new-token axis
``S`` may exceed 1 (a chunked-prefill "extend" — each row appends up to S
tokens at its own offset; ``n_valid`` marks how many are real, padded tails
write ``pos_id = -1`` and stay invisible to the mask).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamMeta, dense
from repro.models.layers import apply_rope, rms_norm
from repro.sharding.plan import Plan

NEG_INF = -1e30


def decode_positions(pos, B: int, S: int):
    """Absolute query positions ``(B, S)`` from a scalar or ``(B,)`` pos."""
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (B,))
    return p[:, None] + jnp.arange(S, dtype=jnp.int32)[None]


def _row_update(arr, new, start):
    """Write ``new`` (B,S,...) into ``arr`` (B,T,...) at per-row offsets."""
    return jax.vmap(
        lambda a, n, s: jax.lax.dynamic_update_slice_in_dim(a, n, s, axis=0)
    )(arr, new.astype(arr.dtype), start)


def _ring_scatter(arr, new, start, n_valid):
    """Write ``new`` (B,S,...) into ring ``arr`` (B,T,...) at per-row offsets
    modulo T.  Unlike ``_row_update`` (whose dynamic_update_slice *clamps*
    ``start`` so a chunk touching the ring edge lands shifted), entries wrap
    index-wise, and rows' padded tails (past ``n_valid``) are masked out so
    they never overwrite live window entries.  Requires ``S <= T`` (one
    chunk may not lap the window; scatter indices must stay unique)."""
    T, S = arr.shape[1], new.shape[1]
    if S > T:
        raise ValueError(f"chunk of {S} tokens would lap the {T}-entry ring")
    offs = jnp.arange(S, dtype=jnp.int32)
    keep = (jnp.ones((new.shape[0], S), bool) if n_valid is None
            else offs[None] < jnp.asarray(n_valid, jnp.int32)[:, None])

    def row(a, n, s, kb):
        idx = (s + offs) % T
        upd = jnp.where(kb.reshape((S,) + (1,) * (a.ndim - 1)),
                        n.astype(a.dtype), a[idx])
        return a.at[idx].set(upd)

    return jax.vmap(row)(arr, new, start, keep)


def _new_pos_ids(positions, n_valid):
    """Position ids to record for an appended chunk: the absolute position,
    or -1 (invalid) past each row's ``n_valid`` real tokens."""
    if n_valid is None:
        return positions
    S = positions.shape[1]
    keep = jnp.arange(S, dtype=jnp.int32)[None] < \
        jnp.asarray(n_valid, jnp.int32)[:, None]
    return jnp.where(keep, positions, -1)


# =============================================================================
# GQA
# =============================================================================

def gqa_params(cfg: ModelConfig, plan: Plan, cross: bool = False):
    d, dh = cfg.d_model, cfg.head_dim
    h, hkv = plan.num_heads, plan.num_kv_heads
    p = {
        "wq": ParamMeta((d, h, dh), ("embed", "heads", None), fan_in=d),
        "wk": ParamMeta((d, hkv, dh), ("embed", "kv_heads", None), fan_in=d),
        "wv": ParamMeta((d, hkv, dh), ("embed", "kv_heads", None), fan_in=d),
        "wo": ParamMeta((h, dh, d), ("heads", None, "embed"), fan_in=h * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamMeta((dh,), (None,), init="ones")
        p["k_norm"] = ParamMeta((dh,), (None,), init="ones")
    if cross:
        p["gate"] = ParamMeta((1,), (None,), init="zeros")
    return p


def _qkv(p, x, kv_x, cfg: ModelConfig, plan: Plan):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


BLOCKWISE_THRESHOLD = 8192  # self-attention seqs >= this use blockwise softmax
# (§Perf iteration 6 tried 4096: REFUTED — at 4k the 2x2 block grid computes
# the same flops and the scan stacking overhead exceeds the score-matrix
# saving; blockwise pays off from 8k where scores no longer fit)


def _sdpa(q, k, v, mask, plan: Plan):
    """q:(B,S,H,D) k,v:(B,T,Hkv,D) mask:(B,1,1,S,T) or None -> (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    q = q.reshape(B, S, Hkv, G, D)
    # accumulate in f32 via the dot itself — casting inputs would materialize
    # f32 copies of K (and force an f32 cache carry through the decode scan)
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(D).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgst,bthd->bshgd", w, v)
    return o.reshape(B, S, H, v.shape[-1])  # v head dim may differ (MLA)


def blockwise_sdpa(q, k, v, *, causal: bool, window: int = 0,
                   q_block: int = 2048, kv_block: int = 2048):
    """Flash-style online-softmax attention in pure XLA (scan over blocks).

    Never materializes the (S,T) score matrix — per-step live memory is
    O(q_block × kv_block). Used for long self-attention (32k prefill) where
    the naive path would need S² score buffers. q:(B,S,H,D), k/v:(B,T,Hkv,D).
    """
    B, S, H, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = H // Hkv
    Dv = v.shape[-1]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    nq, nk = S // q_block, T // kv_block
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qb = q.reshape(B, nq, q_block, Hkv, G, D)
    kb = k.reshape(B, nk, kv_block, Hkv, D)
    vb = v.reshape(B, nk, kv_block, Hkv, Dv)

    def q_step(_, qi_inp):
        qi, iq = qi_inp  # (B,q_block,Hkv,G,D), scalar block index

        def kv_step(carry, kv_inp):
            m, l, acc = carry
            kj, vj, jk = kv_inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                           preferred_element_type=jnp.float32)
            s = s * scale
            qpos = iq * q_block + jnp.arange(q_block)[:, None]
            kpos = jk * kv_block + jnp.arange(kv_block)[None, :]
            valid = jnp.ones((q_block, kv_block), bool)
            if causal:
                valid &= kpos <= qpos
            if window:
                valid &= kpos > qpos - window
            s = jnp.where(valid[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.astype(v.dtype)  # (B,Hkv,G,q_block,Dv)

    _, outs = jax.lax.scan(
        q_step, None, (qb.swapaxes(0, 1), jnp.arange(nq)))
    # outs: (nq, B, Hkv, G, q_block, Dv)
    out = jnp.moveaxis(outs, 0, 3)  # (B,Hkv,G,nq,q_block,Dv)
    out = out.reshape(B, Hkv, G, S, Dv).transpose(0, 3, 1, 2, 4)
    return out.reshape(B, S, H, Dv)


def causal_mask(S: int, T: int, q_offset, window: int = 0):
    """(1,1,1,S,T) bool; q position i attends kv position j iff j<=i (+window)."""
    qi = q_offset + jnp.arange(S)[:, None]
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    return m[None, None, None]


def gqa_apply(p, x, cfg: ModelConfig, plan: Plan, positions=None,
              kv_x=None, cross: bool = False, causal: bool = True):
    """Train/prefill path. x:(B,S,D). Returns (out, kv) — kv for cache seeding."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, x if kv_x is None else kv_x, cfg, plan)
    if positions is None:
        positions = jnp.arange(S)[None]
    if not cross:
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
        mask = (causal_mask(S, k.shape[1], 0, cfg.sliding_window)
                if causal else None)
    else:
        mask = None
    q = plan.act(q, "batch", None, "heads", None)
    k = plan.act(k, "batch", None, "kv_heads", None)
    if not cross and causal and S == k.shape[1] and S >= BLOCKWISE_THRESHOLD:
        o = blockwise_sdpa(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        o = _sdpa(q, k, v, mask, plan)
    o = jnp.einsum("bshd,hdk->bsk", o, p["wo"].astype(x.dtype))
    if cross:
        o = o * jnp.tanh(p["gate"].astype(x.dtype))
    return o, (k, v)


# --- decode ------------------------------------------------------------------

def gqa_cache_init(cfg: ModelConfig, plan: Plan, batch: int, max_len: int, dtype):
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hkv, dh = plan.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, T, hkv, dh), dtype),
        "v": jnp.zeros((batch, T, hkv, dh), dtype),
        "pos_ids": jnp.full((batch, T), -1, jnp.int32),
    }


def gqa_cache_abstract(cfg: ModelConfig, plan: Plan, batch: int, max_len: int, dtype):
    T = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    hkv, dh = plan.num_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, T, hkv, dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, T, hkv, dh), dtype),
        "pos_ids": jax.ShapeDtypeStruct((batch, T), jnp.int32),
    }


def gqa_cache_spec(plan: Plan, seq_axis=None):
    b = plan.batch_axes
    kvh = plan.rules.get("kv_heads")
    from jax.sharding import PartitionSpec as P
    return {"k": P(b, seq_axis, kvh, None), "v": P(b, seq_axis, kvh, None),
            "pos_ids": P(b, seq_axis)}


def gqa_decode(p, x, cache, pos, cfg: ModelConfig, plan: Plan, n_valid=None):
    """Ragged decode/extend. x:(B,S,D); pos: scalar or (B,) per-slot position.

    Appends S new tokens per row at that row's own offset (ring-modded for
    sliding-window caches). ``n_valid`` (B,) optionally marks how many of the
    S tokens are real per row; padded tails record ``pos_id = -1``.
    """
    B, S, _ = x.shape
    q, k_new, v_new = _qkv(p, x, x, cfg, plan)
    positions = decode_positions(pos, B, S)  # (B,S)
    q = apply_rope(q, positions, cfg)
    k_new = apply_rope(k_new, positions, cfg)
    T = cache["k"].shape[1]
    start = positions[:, 0] % T  # ring for SWA; == pos when T == max_len
    ids = _new_pos_ids(positions, n_valid)
    if cfg.sliding_window:
        # ring cache: token j of the chunk evicts the entry at
        # (pos+j) % T, which for S > 1 may still be inside token i < j's
        # window — so attend against the PRE-update ring plus the chunk's
        # own K/V, then scatter (wrapped, padded tails masked off).
        def win_mask(entry_pos):  # (B,T') -> (B,S,T') validity
            e = entry_pos[:, None, :]
            return ((e >= 0) & (e <= positions[..., None])
                    & (e > positions[..., None] - cfg.sliding_window))
        mask = jnp.concatenate(
            [win_mask(cache["pos_ids"]), win_mask(ids)],
            axis=-1)[:, None, None]  # (B,1,1,S,T+S)
        o = _sdpa(q, jnp.concatenate([cache["k"], k_new], axis=1),
                  jnp.concatenate([cache["v"], v_new], axis=1), mask, plan)
        k = _ring_scatter(cache["k"], k_new, start, n_valid)
        v = _ring_scatter(cache["v"], v_new, start, n_valid)
        pos_ids = _ring_scatter(cache["pos_ids"], ids, start, n_valid)
    else:
        k = _row_update(cache["k"], k_new, start)
        v = _row_update(cache["v"], v_new, start)
        pos_ids = _row_update(cache["pos_ids"], ids, start)  # (B,T)
        valid = (pos_ids >= 0)[:, None, :] & \
            (pos_ids[:, None, :] <= positions[..., None])
        mask = valid[:, None, None]  # (B,1,1,S,T)
        o = _sdpa(q, k, v, mask, plan)
    o = jnp.einsum("bshd,hdk->bsk", o, p["wo"].astype(x.dtype))
    return o, {"k": k, "v": v, "pos_ids": pos_ids}


def gqa_seed_cache(cache, kv, prefill_len: int, lengths=None):
    """Write prefill-time K/V into a decode cache (assumes full, non-ring).

    ``lengths`` (B,) optionally marks per-row true prompt lengths for
    right-padded batched prefill: positions past a row's length record
    ``pos_id = -1`` so they stay invisible to the decode mask.
    """
    k, v = kv
    B = k.shape[0]
    T = cache["k"].shape[1]
    S = k.shape[1]
    if S > T:  # sliding-window cache shorter than prefill: keep the tail
        k, v = k[:, S - T:], v[:, S - T:]
        pos = jnp.arange(S - T, S, dtype=jnp.int32)
        S = T
    else:
        pos = jnp.arange(S, dtype=jnp.int32)
    pos2 = jnp.broadcast_to(pos[None], (B, S))
    if lengths is not None:
        pos2 = jnp.where(pos2 < jnp.asarray(lengths, jnp.int32)[:, None],
                         pos2, -1)
    out = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1),
        "pos_ids": jax.lax.dynamic_update_slice(
            cache["pos_ids"], pos2, (0, 0)),
    }
    return out


# =============================================================================
# MLA (deepseek-v2): low-rank compressed KV, absorbed decode
# =============================================================================

def mla_params(cfg: ModelConfig, plan: Plan):
    d = cfg.d_model
    h = plan.num_heads
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    qk = nope + rope_d
    p = {
        "kv_down": dense(d, cfg.kv_lora_rank + rope_d, "embed", None),
        "kv_norm": ParamMeta((cfg.kv_lora_rank,), (None,), init="ones"),
        "k_up": ParamMeta((cfg.kv_lora_rank, h, nope), (None, "heads", None),
                          fan_in=cfg.kv_lora_rank),
        "v_up": ParamMeta((cfg.kv_lora_rank, h, vd), (None, "heads", None),
                          fan_in=cfg.kv_lora_rank),
        "wo": ParamMeta((h, vd, d), ("heads", None, "embed"), fan_in=h * vd),
    }
    if cfg.q_lora_rank:
        p["q_down"] = dense(d, cfg.q_lora_rank, "embed", None)
        p["q_norm"] = ParamMeta((cfg.q_lora_rank,), (None,), init="ones")
        p["q_up"] = ParamMeta((cfg.q_lora_rank, h, qk), (None, "heads", None),
                              fan_in=cfg.q_lora_rank)
    else:
        p["q_up"] = ParamMeta((d, h, qk), ("embed", "heads", None), fan_in=d)
    return p


def _mla_q(p, x, cfg, positions):
    dt = x.dtype
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(x @ p["q_down"].astype(dt), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["q_up"].astype(dt))
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["q_up"].astype(dt))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg, dim=rope_d)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, positions):
    dt = x.dtype
    rope_d = cfg.qk_rope_head_dim
    kvd = x @ p["kv_down"].astype(dt)
    c_kv = rms_norm(kvd[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kvd[..., cfg.kv_lora_rank:][:, :, None, :]  # (B,T,1,rope)
    k_rope = apply_rope(k_rope, positions, cfg, dim=rope_d)[:, :, 0]
    return c_kv, k_rope


def mla_apply(p, x, cfg: ModelConfig, plan: Plan, positions=None):
    """Train/prefill: expand compressed KV per head; returns (out, (c_kv,k_rope))."""
    B, S, _ = x.shape
    dt = x.dtype
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    if positions is None:
        positions = jnp.arange(S)[None]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_ckv(p, x, cfg, positions)
    k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["k_up"].astype(dt))
    v = jnp.einsum("btr,rhk->bthk", c_kv, p["v_up"].astype(dt))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], k_nope.shape[:3] + (rope_d,))], -1)
    q = plan.act(q, "batch", None, "heads", None)
    k = plan.act(k, "batch", None, "heads", None)
    if S >= BLOCKWISE_THRESHOLD:
        o = blockwise_sdpa(q, k, v, causal=True)
    else:
        o = _sdpa(q, k, v, causal_mask(S, S, 0), plan)
    o = jnp.einsum("bshd,hdk->bsk", o, p["wo"].astype(dt))
    return o, (c_kv, k_rope)


def mla_cache_init(cfg, plan, batch, max_len, dtype, abstract=False):
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "c_kv": mk((batch, max_len, cfg.kv_lora_rank), dtype),
        "k_rope": mk((batch, max_len, cfg.qk_rope_head_dim), dtype),
        "pos_ids": (jax.ShapeDtypeStruct((batch, max_len), jnp.int32) if abstract
                    else jnp.full((batch, max_len), -1, jnp.int32)),
    }


def mla_cache_spec(plan: Plan, seq_axis=None):
    from jax.sharding import PartitionSpec as P
    b = plan.batch_axes
    return {"c_kv": P(b, seq_axis, None), "k_rope": P(b, seq_axis, None),
            "pos_ids": P(b, seq_axis)}


def mla_decode(p, x, cache, pos, cfg: ModelConfig, plan: Plan, n_valid=None):
    """Absorbed decode: score directly against compressed cache (TPU-native).

    Ragged like :func:`gqa_decode`: ``pos`` scalar or (B,), S >= 1, per-row
    append at each row's own offset (full-length cache, no ring).
    """
    B, S, _ = x.shape
    dt = x.dtype
    nope, rope_d, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    positions = decode_positions(pos, B, S)  # (B,S)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)  # (B,S,H,nope/rope)
    c_new, kr_new = _mla_ckv(p, x, cfg, positions)  # (B,S,r), (B,S,rope)
    start = positions[:, 0]
    c_kv = _row_update(cache["c_kv"], c_new, start)
    k_rope = _row_update(cache["k_rope"], kr_new, start)
    pos_ids = _row_update(cache["pos_ids"], _new_pos_ids(positions, n_valid),
                          start)  # (B,T)
    # absorb k_up into q: (B,S,H,r)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["k_up"].astype(dt))
    scores = (jnp.einsum("bshr,btr->bhst", q_c, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32))
    scores = scores / jnp.sqrt(nope + rope_d).astype(jnp.float32)
    valid = (pos_ids >= 0)[:, None, :] & \
        (pos_ids[:, None, :] <= positions[..., None])  # (B,S,T)
    scores = jnp.where(valid[:, None], scores, NEG_INF)  # (B,H,S,T)
    w = jax.nn.softmax(scores, -1).astype(dt)
    ctx_c = jnp.einsum("bhst,btr->bshr", w, c_kv)  # (B,S,H,r)
    o = jnp.einsum("bshr,rhk->bshk", ctx_c, p["v_up"].astype(dt))  # absorbed v_up
    o = jnp.einsum("bshd,hdk->bsk", o, p["wo"].astype(dt))
    return o, {"c_kv": c_kv, "k_rope": k_rope, "pos_ids": pos_ids}


def mla_seed_cache(cache, kv, prefill_len: int, lengths=None):
    c_kv, k_rope = kv
    B, S = c_kv.shape[0], c_kv.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    pos2 = jnp.broadcast_to(pos[None], (B, S))
    if lengths is not None:
        pos2 = jnp.where(pos2 < jnp.asarray(lengths, jnp.int32)[:, None],
                         pos2, -1)
    return {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, 0, 1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope, 0, 1),
        "pos_ids": jax.lax.dynamic_update_slice(cache["pos_ids"], pos2, (0, 0)),
    }
