"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

Dispatch is scatter/gather based (GShard-style positions via one-hot cumsum)
rather than the (tokens × experts × capacity) one-hot einsum — the dispatch
tensors stay O(tokens·k), which is what makes deepseek-v2 (160 experts) fit.

Shard-local grouping (§Perf iteration 2): tokens are reshaped to
(G_loc, n_dp, gs, D) where the n_dp axis carries the data sharding, so each
dispatch group lives entirely on one shard — routing, capacity positions,
scatter and combine are communication-free; the only collectives left are the
mathematically-required expert contractions ('tp' mode: hidden-dim psum;
'ep' mode: token movement to expert shards). The earlier strided grouping
spanned shards and pushed dispatch buffers through data-axis all-reduces
(7.4 TB/chip/step on mixtral train_4k — §Perf log).

Expert sharding comes from the plan: 'ep' (experts over model axis, e.g.
deepseek-v2 160/16) or 'tp' (hidden dim over model axis, e.g. mixtral 8<16).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamMeta, dense
from repro.models import layers
from repro.sharding.plan import Plan


def moe_params(cfg: ModelConfig, plan: Plan):
    d, ff, E = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {
        "router": dense(d, E, "embed", None),
        "wg": ParamMeta((E, d, ff), ("experts", "embed", "expert_ffn"), fan_in=d),
        "wu": ParamMeta((E, d, ff), ("experts", "embed", "expert_ffn"), fan_in=d),
        "wd": ParamMeta((E, ff, d), ("experts", "expert_ffn", "embed"), fan_in=ff),
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.mlp_params(
            cfg, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    return p


def router_topk(logits, k: int):
    """Softmax-then-top-k with renormalized weights (+ aux losses).

    logits: (..., E); weights/idx: (..., k); aux/z are scalars (mean over
    all leading dims)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / (jnp.sum(w, -1, keepdims=True) + 1e-9)
    E = logits.shape[-1]
    lead = tuple(range(logits.ndim - 1))
    me = jnp.mean(probs, axis=lead)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=-2),
                  axis=lead) / k
    aux = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), -1)))
    return w, idx, aux, z


def _dispatch_batched(p, x, cfg: ModelConfig, plan: Plan, capacity: int):
    """x: (n, gs, D) — n shard-local groups. Returns (out, aux, z)."""
    n, T, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    dt = x.dtype
    logits = jnp.einsum("ntd,de->nte", x, p["router"].astype(dt))
    w, idx, aux, z = router_topk(logits, k)  # (n,T,k)

    flat_e = idx.reshape(n, T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (n,T*k,E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos_in_e, flat_e[..., None], axis=2)[..., 0]
    keep = pos < capacity
    slot = jnp.where(keep, flat_e * capacity + pos, E * capacity)  # (n,T*k)

    tok_idx = jnp.repeat(jnp.arange(T), k)  # (T*k,)
    xs = jnp.take(x, tok_idx, axis=1)  # (n,T*k,D)
    xs = xs * keep[..., None].astype(dt)

    def scatter_one(xs_i, slot_i):
        return jnp.zeros((E * capacity + 1, D), dt).at[slot_i].add(xs_i)[:-1]

    buf = jax.vmap(scatter_one)(xs, slot).reshape(n, E, capacity, D)
    buf = plan.act(buf, "batch", "experts", None, None)

    # expert FFN (SwiGLU), batched over groups x experts
    g = jnp.einsum("necd,edf->necf", buf, p["wg"].astype(dt))
    u = jnp.einsum("necd,edf->necf", buf, p["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    h = plan.act(h, "batch", "experts", None, "expert_ffn")
    out_buf = jnp.einsum("necf,efd->necd", h, p["wd"].astype(dt))
    out_buf = plan.act(out_buf, "batch", "experts", None, None)

    flat = out_buf.reshape(n, E * capacity, D)
    safe_slot = jnp.minimum(slot, E * capacity - 1)
    gathered = jnp.take_along_axis(flat, safe_slot[..., None], axis=1)
    gathered = gathered * (keep[..., None]
                           * w.reshape(n, T * k)[..., None]).astype(dt)
    out = jnp.sum(gathered.reshape(n, T, k, D), axis=2)
    return out, aux, z


def moe_apply(p, x, cfg: ModelConfig, plan: Plan) -> Tuple[jax.Array, Dict]:
    """x: (B,S,D) -> (out, {aux, z}) with shared experts added."""
    B, S, D = x.shape
    T = B * S
    n_dp = 1
    if plan.mesh is not None and plan.dp_axes and not plan.replicate_batch:
        import numpy as _np
        n_dp = int(_np.prod([plan.mesh.shape[a] for a in plan.dp_axes]))
        if B % n_dp != 0:
            n_dp = 1
    gs = cfg.moe_group_size or T
    gs = min(gs, T // n_dp)
    g_loc = (T // n_dp) // gs
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    capacity = max(int(gs * k * cfg.moe_capacity_factor / E), 4)

    xf = x.reshape(T, D).reshape(n_dp, g_loc, gs, D).swapaxes(0, 1)
    # (G_loc, n_dp, gs, D): scan axis unsharded, dim 1 carries data sharding
    xf = plan.act(xf, None, "batch", None, None)

    def body(_, xg):
        out, aux, z = _dispatch_batched(p, xg, cfg, plan, capacity)
        return None, (out, aux, z)

    if g_loc == 1:
        o, aux, z = _dispatch_batched(p, xf[0], cfg, plan, capacity)
        outs, auxs, zs = o[None], aux[None], z[None]
    else:
        _, (outs, auxs, zs) = jax.lax.scan(body, None, xf)

    out = outs.swapaxes(0, 1).reshape(B, S, D)
    if cfg.num_shared_experts:
        out = out + layers.mlp_apply(p["shared"], x, cfg, plan)
    losses = {"moe_aux": jnp.mean(auxs), "moe_z": jnp.mean(zs)}
    return out, losses
