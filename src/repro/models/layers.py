"""Core layers: norms, MLPs, embeddings, rotary position embedding.

Functional style: ``*_params(cfg, plan)`` builds a ParamMeta tree,
``*_apply(p, x, ...)`` runs the layer. Compute dtype is bf16 (cast at use);
parameters are stored in ``cfg.param_dtype``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamMeta, dense
from repro.sharding.plan import Plan


def cdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# --- dense-matmul routing hook ------------------------------------------------

#: optional override for the MXU-dominant dense matmuls (the MLP blocks):
#: a callable ``(x_2d_f32, w_2d_f32) -> y_2d_f32``.  None keeps the plain
#: ``@``.  Set via ``repro.tolerance.abft.routed_matmuls`` to run a model
#: through the ABFT-checksummed over-scaled matmul; the override executes
#: host-side state (SDC counters), so route only non-jitted evaluation.
MATMUL = None


def matmul(x, w):
    """x: (..., K) @ w: (K, N), through the routing hook when installed."""
    if MATMUL is None:
        return x @ w
    y = MATMUL(x.reshape(-1, x.shape[-1]).astype(jnp.float32),
               w.astype(jnp.float32))
    return y.reshape(*x.shape[:-1], w.shape[-1]).astype(x.dtype)


# --- norms -------------------------------------------------------------------

def norm_params(cfg: ModelConfig, dim: Optional[int] = None, logical="embed"):
    d = dim or cfg.d_model
    p = {"scale": ParamMeta((d,), (logical,), init="ones")}
    if cfg.norm_type == "layernorm":
        p["bias"] = ParamMeta((d,), (logical,), init="zeros")
    return p


def norm_apply(p, x, cfg: ModelConfig):
    dt = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + cfg.norm_eps)
    x = x * p["scale"].astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x + p["bias"].astype(jnp.float32)
    return x.astype(dt)


def rms_norm(x, scale, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# --- MLP ---------------------------------------------------------------------

def mlp_params(cfg: ModelConfig, d_ff: Optional[int] = None, ffn_logical="ffn"):
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    p = {"wd": dense(ff, d, ffn_logical, "embed")}
    if cfg.mlp_type == "swiglu":
        p["wg"] = dense(d, ff, "embed", ffn_logical)
        p["wu"] = dense(d, ff, "embed", ffn_logical)
    else:  # relu2 | gelu
        p["wu"] = dense(d, ff, "embed", ffn_logical)
    return p


def mlp_apply(p, x, cfg: ModelConfig, plan: Plan):
    dt = x.dtype
    if cfg.mlp_type == "swiglu":
        g = matmul(x, p["wg"].astype(dt))
        u = matmul(x, p["wu"].astype(dt))
        h = jax.nn.silu(g) * u
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(matmul(x, p["wu"].astype(dt))))
    else:
        h = jax.nn.gelu(matmul(x, p["wu"].astype(dt)))
    h = plan.act(h, "batch", None, "ffn")
    return matmul(h, p["wd"].astype(dt))


# --- embeddings ----------------------------------------------------------------

def embed_params(cfg: ModelConfig, plan: Plan):
    p = {"embedding": ParamMeta((plan.vocab, cfg.d_model), ("vocab", "embed"),
                                init="embed", fan_in=cfg.d_model)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense(cfg.d_model, plan.vocab, "embed", "vocab")
    return p


def embed_apply(p, tokens, cfg: ModelConfig, plan: Plan):
    x = jnp.take(p["embedding"].astype(cdt(cfg)), tokens, axis=0)
    return plan.act(x, "batch", "seq", None)


def unembed_apply(p, x, cfg: ModelConfig, plan: Plan):
    dt = x.dtype
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].astype(dt).T
    else:
        logits = x @ p["unembed"].astype(dt)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return plan.act(logits, "batch", None, "vocab")


# --- rotary -------------------------------------------------------------------

def rope_freqs(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.head_dim
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    return inv  # (d/2,)


def apply_rope(x, positions, cfg: ModelConfig, dim: Optional[int] = None):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    if not cfg.use_rope:
        return x
    d = dim or x.shape[-1]
    inv = rope_freqs(cfg, d)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
