"""Unified model facade: one API over all 10 architecture families.

    model = Model(cfg, plan)
    meta   = model.param_meta()                    # ParamMeta tree
    params = model.init(key)                       # materialized (smoke/CPU)
    logits, aux = model.apply(params, batch)       # train forward
    logits, cache = model.prefill(params, batch)   # serve: prefill
    logits, cache = model.decode(params, tok, cache, pos)

``batch`` is a dict: tokens (B,S) [+ labels], image_embeds (vlm),
audio_frames (audio). Frontends for vlm/audio are stubs per the assignment.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import multimodal as mm
from repro.models import params as pm
from repro.models import transformer as tf
from repro.models.layers import cdt
from repro.sharding.plan import Plan, make_plan


class Model:
    def __init__(self, cfg: ModelConfig, plan: Optional[Plan] = None):
        self.cfg = cfg
        self.plan = plan or make_plan(cfg, None)

    # --- params -----------------------------------------------------------
    def param_meta(self):
        cfg, plan = self.cfg, self.plan
        if cfg.family == "vlm":
            return mm.vlm_params(cfg, plan)
        if cfg.family == "audio":
            return mm.whisper_params(cfg, plan)
        return tf.lm_params(cfg, plan)

    def init(self, key):
        return pm.materialize(self.param_meta(), key, self.cfg.param_dtype)

    def abstract_params(self):
        return pm.abstract(self.param_meta(), self.cfg.param_dtype)

    def n_params(self) -> int:
        return pm.n_params(self.param_meta())

    # --- forward ------------------------------------------------------------
    def apply(self, params, batch: Dict[str, Any]):
        cfg, plan = self.cfg, self.plan
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            return mm.vlm_apply(params, tokens, batch["image_embeds"], cfg, plan)
        if cfg.family == "audio":
            return mm.whisper_apply(params, tokens, batch["audio_frames"], cfg, plan)
        return tf.lm_apply(params, tokens, cfg, plan)

    # --- serving ------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any], max_len: Optional[int] = None,
                lengths=None):
        cfg, plan = self.cfg, self.plan
        tokens = batch["tokens"]
        if cfg.family == "vlm":
            return mm.vlm_prefill(params, tokens, batch["image_embeds"], cfg,
                                  plan, max_len, lengths=lengths)
        if cfg.family == "audio":
            return mm.whisper_prefill(params, tokens, batch["audio_frames"],
                                      cfg, plan, max_len, lengths=lengths)
        return tf.lm_prefill(params, tokens, cfg, plan, max_len, lengths=lengths)

    def decode(self, params, tokens, cache, pos, n_valid=None):
        """Ragged decode: ``pos`` scalar or (B,) per-slot; tokens (B,S), S>=1.

        ``n_valid`` (B,) marks real tokens per row for chunked-prefill
        extends (attention families; SSM/hybrid state ignores it).
        """
        cfg, plan = self.cfg, self.plan
        if cfg.family == "vlm":
            return mm.vlm_decode(params, tokens, cache, pos, cfg, plan,
                                 n_valid=n_valid)
        if cfg.family == "audio":
            return mm.whisper_decode(params, tokens, cache, pos, cfg, plan,
                                     n_valid=n_valid)
        return tf.lm_decode(params, tokens, cache, pos, cfg, plan,
                            n_valid=n_valid)

    # --- caches ---------------------------------------------------------------
    def cache(self, batch_size: int, max_len: int, abstract: bool = False):
        cfg, plan = self.cfg, self.plan
        dtype = cdt(cfg)
        if cfg.family == "vlm":
            return mm.vlm_cache(cfg, plan, batch_size, max_len, dtype, abstract)
        if cfg.family == "audio":
            return mm.whisper_cache(cfg, plan, batch_size, max_len, dtype, abstract)
        return tf.lm_cache(cfg, plan, batch_size, max_len, dtype, abstract)

    def cache_specs(self, seq_axis=None):
        cfg, plan = self.cfg, self.plan
        if cfg.family == "vlm":
            return mm.vlm_cache_specs(cfg, plan, seq_axis)
        if cfg.family == "audio":
            return mm.whisper_cache_specs(cfg, plan, seq_axis)
        return tf.lm_cache_specs(cfg, plan, seq_axis)
