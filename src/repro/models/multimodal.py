"""VLM (llama-3.2-vision backbone) and Whisper (enc-dec) model definitions.

Modality frontends are STUBS per the assignment: ``input_specs()`` provides
precomputed patch/frame embeddings at model width; only the transformer
backbone is real. VLM: cross-attention block after every ``cross_attn_every``
self-attention layers (grouped scan). Whisper: 12L encoder (bidirectional) +
12L decoder with cross-attention, sinusoidal positions, unrolled (small model).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.params import stack_tree
from repro.models.transformer import (
    ZERO_AUX, _maybe_remat, _seed_attn_cache, attn_block_apply,
    attn_block_decode, attn_block_params)
from repro.sharding.plan import Plan


# =============================================================================
# VLM: self-attn groups + gated cross-attn blocks
# =============================================================================

def cross_block_params(cfg: ModelConfig, plan: Plan):
    return {
        "ln1": L.norm_params(cfg),
        "attn": attn.gqa_params(cfg, plan, cross=True),
        "ln2": L.norm_params(cfg),
        "mlp": L.mlp_params(cfg),
    }


def cross_block_apply(p, x, img, cfg, plan, collect_kv=False):
    h = L.norm_apply(p["ln1"], x, cfg)
    a, kv = attn.gqa_apply(p["attn"], h, cfg, plan, kv_x=img, cross=True)
    x = x + a
    h = L.norm_apply(p["ln2"], x, cfg)
    x = x + L.mlp_apply(p["mlp"], h, cfg, plan)
    return (x, kv) if collect_kv else (x, None)


def cross_block_decode(p, x, kv_cache, cfg, plan):
    """Decode with frozen (prefill-computed) cross K/V."""
    h = L.norm_apply(p["ln1"], x, cfg)
    k, v = kv_cache["k"], kv_cache["v"]
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", h, p["attn"]["wq"].astype(dt))
    if cfg.qk_norm:
        q = L.rms_norm(q, p["attn"]["q_norm"], cfg.norm_eps)
    o = attn._sdpa(q, k, v, None, plan)
    o = jnp.einsum("bshd,hdk->bsk", o, p["attn"]["wo"].astype(dt))
    o = o * jnp.tanh(p["attn"]["gate"].astype(dt))
    x = x + o
    h = L.norm_apply(p["ln2"], x, cfg)
    return x + L.mlp_apply(p["mlp"], h, cfg, plan)


def vlm_params(cfg: ModelConfig, plan: Plan):
    k = cfg.cross_attn_every
    n_groups = cfg.num_layers // k
    return {
        "embed": L.embed_params(cfg, plan),
        "final_ln": L.norm_params(cfg),
        "blocks": {
            "groups": stack_tree(
                stack_tree(attn_block_params(cfg, plan, use_moe=False), k),
                n_groups),
            "cross": stack_tree(cross_block_params(cfg, plan), n_groups),
        },
    }


def vlm_apply(params, tokens, image_embeds, cfg: ModelConfig, plan: Plan):
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    img = image_embeds.astype(x.dtype)

    def group_body(carry, gp):
        x = carry
        sp, cp = gp

        def inner(c, lp):
            c, _ = attn_block_apply(lp, c, cfg, plan)
            return c, None

        x, _ = jax.lax.scan(inner, x, sp)
        x, _ = cross_block_apply(cp, x, img, cfg, plan)
        return x, None

    x, _ = jax.lax.scan(
        _maybe_remat(group_body, cfg), x,
        (params["blocks"]["groups"], params["blocks"]["cross"]))
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), ZERO_AUX()


def vlm_prefill(params, tokens, image_embeds, cfg, plan,
                max_len: Optional[int] = None, lengths=None):
    B, S = tokens.shape
    max_len = max_len or S
    dtype = L.cdt(cfg)
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    img = image_embeds.astype(x.dtype)

    def group_body(carry, gp):
        x = carry
        sp, cp = gp

        def inner(c, lp):
            c, _, kv = attn_block_apply(lp, c, cfg, plan, collect_kv=True)
            return c, kv

        x, kvs = jax.lax.scan(inner, x, sp)
        x, ckv = cross_block_apply(cp, x, img, cfg, plan, collect_kv=True)
        return x, (kvs, ckv)

    x, (kvs, ckvs) = jax.lax.scan(
        _maybe_remat(group_body, cfg), x,
        (params["blocks"]["groups"], params["blocks"]["cross"]))
    cache = {
        "self": jax.vmap(jax.vmap(
            lambda kv: _seed_attn_cache(cfg, plan, kv, max_len, dtype, B,
                                        lengths)))(kvs),
        "cross": {"k": ckvs[0], "v": ckvs[1]},
    }
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), cache


def vlm_cache(cfg, plan, batch, max_len, dtype, abstract=False):
    k = cfg.cross_attn_every
    n_groups = cfg.num_layers // k
    hkv, dh = plan.num_kv_heads, cfg.head_dim
    I = cfg.num_image_tokens

    def rep(tree, n):
        def do(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
            return jnp.broadcast_to(leaf, (n,) + leaf.shape).copy()
        return jax.tree_util.tree_map(do, tree)

    a = (attn.gqa_cache_abstract(cfg, plan, batch, max_len, dtype) if abstract
         else attn.gqa_cache_init(cfg, plan, batch, max_len, dtype))
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "self": rep(rep(a, k), n_groups),
        "cross": {"k": mk((n_groups, batch, I, hkv, dh), dtype),
                  "v": mk((n_groups, batch, I, hkv, dh), dtype)},
    }


def vlm_cache_specs(cfg, plan, seq_axis=None):
    from jax.sharding import PartitionSpec as P
    a = attn.gqa_cache_spec(plan, seq_axis)

    def add(tree, n=1):
        for _ in range(n):
            tree = jax.tree_util.tree_map(
                lambda s: P(*((None,) + tuple(s))), tree,
                is_leaf=lambda x: isinstance(x, P))
        return tree

    kvh = plan.rules.get("kv_heads")
    return {
        "self": add(a, 2),
        "cross": {"k": P(None, plan.batch_axes, None, kvh, None),
                  "v": P(None, plan.batch_axes, None, kvh, None)},
    }


def vlm_decode(params, tokens, cache, pos, cfg: ModelConfig, plan: Plan,
               n_valid=None):
    x = L.embed_apply(params["embed"], tokens, cfg, plan)

    def group_body(x, pc):
        (sp, cp), (sc, cc) = pc

        def inner(x, plc):
            lp, lc = plc
            x, lc = attn_block_decode(lp, x, lc, pos, cfg, plan,
                                      n_valid=n_valid)
            return x, lc

        x, sc = jax.lax.scan(inner, x, (sp, sc))
        x = cross_block_decode(cp, x, cc, cfg, plan)
        return x, (sc, cc)

    x, (new_self, _) = jax.lax.scan(
        group_body, x,
        ((params["blocks"]["groups"], params["blocks"]["cross"]),
         (cache["self"], cache["cross"])))
    cache = {**cache, "self": new_self}
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), cache


# =============================================================================
# Whisper: encoder-decoder
# =============================================================================

def sinusoidal(S: int, d: int, dtype):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)


def dec_block_params(cfg, plan):
    return {
        "ln1": L.norm_params(cfg),
        "self_attn": attn.gqa_params(cfg, plan),
        "ln_x": L.norm_params(cfg),
        "cross_attn": attn.gqa_params(cfg, plan, cross=True),
        "ln2": L.norm_params(cfg),
        "mlp": L.mlp_params(cfg),
    }


def whisper_params(cfg: ModelConfig, plan: Plan):
    enc_block = {"ln1": L.norm_params(cfg), "attn": attn.gqa_params(cfg, plan),
                 "ln2": L.norm_params(cfg), "mlp": L.mlp_params(cfg)}
    return {
        "embed": L.embed_params(cfg, plan),
        "enc": stack_tree(enc_block, cfg.encoder_layers),
        "enc_ln": L.norm_params(cfg),
        "dec": stack_tree(dec_block_params(cfg, plan), cfg.num_layers),
        "final_ln": L.norm_params(cfg),
    }


def whisper_encode(params, frames, cfg, plan):
    """frames: (B, F, d_model) precomputed (conv frontend stub)."""
    x = frames.astype(L.cdt(cfg))
    x = x + sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, lp):
        h = L.norm_apply(lp["ln1"], x, cfg)
        a, _ = attn.gqa_apply(lp["attn"], h, cfg, plan, causal=False)
        x = x + a
        h = L.norm_apply(lp["ln2"], x, cfg)
        return x + L.mlp_apply(lp["mlp"], h, cfg, plan), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc"])
    return L.norm_apply(params["enc_ln"], x, cfg)


def _dec_block(lp, x, enc_out, cfg, plan, positions=None, collect_kv=False):
    h = L.norm_apply(lp["ln1"], x, cfg)
    a, kv = attn.gqa_apply(lp["self_attn"], h, cfg, plan, positions=positions)
    x = x + a
    h = L.norm_apply(lp["ln_x"], x, cfg)
    a, ckv = attn.gqa_apply(lp["cross_attn"], h, cfg, plan, kv_x=enc_out,
                            cross=True)
    x = x + a
    h = L.norm_apply(lp["ln2"], x, cfg)
    x = x + L.mlp_apply(lp["mlp"], h, cfg, plan)
    return (x, kv, ckv) if collect_kv else (x, None, None)


def whisper_apply(params, tokens, frames, cfg: ModelConfig, plan: Plan):
    enc_out = whisper_encode(params, frames, cfg, plan)
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    x = x + sinusoidal(x.shape[1], cfg.d_model, x.dtype)[None]

    def body(x, lp):
        x, _, _ = _dec_block(lp, x, enc_out, cfg, plan)
        return x, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec"])
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), ZERO_AUX()


def whisper_prefill(params, tokens, frames, cfg, plan,
                    max_len: Optional[int] = None, lengths=None):
    B, S = tokens.shape
    max_len = max_len or S
    dtype = L.cdt(cfg)
    enc_out = whisper_encode(params, frames, cfg, plan)
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    x = x + sinusoidal(S, cfg.d_model, x.dtype)[None]

    def body(x, lp):
        x, kv, ckv = _dec_block(lp, x, enc_out, cfg, plan, collect_kv=True)
        return x, (kv, ckv)

    x, (kvs, ckvs) = jax.lax.scan(_maybe_remat(body, cfg), x, params["dec"])
    cache = {
        "self": jax.vmap(
            lambda kv: _seed_attn_cache(cfg, plan, kv, max_len, dtype, B,
                                        lengths))(kvs),
        "cross": {"k": ckvs[0], "v": ckvs[1]},
    }
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), cache


def whisper_cache(cfg, plan, batch, max_len, dtype, abstract=False):
    hkv, dh = plan.num_kv_heads, cfg.head_dim
    F = cfg.encoder_frames
    nl = cfg.num_layers

    def rep(tree, n):
        def do(leaf):
            if abstract:
                return jax.ShapeDtypeStruct((n,) + leaf.shape, leaf.dtype)
            return jnp.broadcast_to(leaf, (n,) + leaf.shape).copy()
        return jax.tree_util.tree_map(do, tree)

    a = (attn.gqa_cache_abstract(cfg, plan, batch, max_len, dtype) if abstract
         else attn.gqa_cache_init(cfg, plan, batch, max_len, dtype))
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "self": rep(a, nl),
        "cross": {"k": mk((nl, batch, F, hkv, dh), dtype),
                  "v": mk((nl, batch, F, hkv, dh), dtype)},
    }


def whisper_cache_specs(cfg, plan, seq_axis=None):
    from jax.sharding import PartitionSpec as P
    a = attn.gqa_cache_spec(plan, seq_axis)
    add = lambda tree: jax.tree_util.tree_map(
        lambda s: P(*((None,) + tuple(s))), tree,
        is_leaf=lambda x: isinstance(x, P))
    kvh = plan.rules.get("kv_heads")
    return {
        "self": add(a),
        "cross": {"k": P(None, plan.batch_axes, None, kvh, None),
                  "v": P(None, plan.batch_axes, None, kvh, None)},
    }


def whisper_decode(params, tokens, cache, pos, cfg: ModelConfig, plan: Plan,
                   n_valid=None):
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens, cfg, plan)
    x = x + _sin_at(attn.decode_positions(pos, B, S), cfg, x.dtype)

    def body(x, pc):
        lp, (sc, cc) = pc
        h = L.norm_apply(lp["ln1"], x, cfg)
        a, sc = attn.gqa_decode(lp["self_attn"], h, sc, pos, cfg, plan,
                                n_valid=n_valid)
        x = x + a
        h = L.norm_apply(lp["ln_x"], x, cfg)
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"].astype(dt))
        o = attn._sdpa(q, cc["k"], cc["v"], None, plan)
        o = jnp.einsum("bshd,hdk->bsk", o, lp["cross_attn"]["wo"].astype(dt))
        x = x + o * jnp.tanh(lp["cross_attn"]["gate"].astype(dt))
        h = L.norm_apply(lp["ln2"], x, cfg)
        x = x + L.mlp_apply(lp["mlp"], h, cfg, plan)
        return x, sc

    x, new_self = jax.lax.scan(
        body, x, (params["dec"], (cache["self"], cache["cross"])))
    cache = {**cache, "self": new_self}
    x = L.norm_apply(params["final_ln"], x, cfg)
    return L.unembed_apply(params["embed"], x, cfg, plan), cache


def _sin_at(positions, cfg, dtype):
    """Sinusoidal embedding at absolute ``positions`` (B,S) -> (B,S,d)."""
    d = cfg.d_model
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = jnp.asarray(positions, jnp.float32)[..., None] / \
        jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1).astype(dtype)
