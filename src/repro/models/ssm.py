"""Mamba2 (SSD — state-space duality) block, chunked scan + recurrent decode.

Follows arXiv:2405.21060: per-head scalar decay A, rank-1 state updates
S_t = exp(dt·A)·S_{t-1} + dt·B_t ⊗ x_t, read-out y_t = C_t·S_t + D·x_t,
computed chunk-parallel: quadratic attention-like intra-chunk term + a scan
over per-chunk states for the inter-chunk term.

The pure-jnp implementation here is the reference path (and the oracle for
``kernels/mamba_scan``); projections are TP-sharded over ssm heads.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamMeta, dense
from repro.models.layers import rms_norm
from repro.sharding.plan import Plan


def ssm_params(cfg: ModelConfig, plan: Plan):
    d, din = cfg.d_model, cfg.d_inner
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_ngroups
    K = cfg.ssm_conv
    return {
        "wz": dense(d, din, "embed", "dinner"),
        "wx": dense(d, din, "embed", "dinner"),
        "wB": ParamMeta((d, G, N), ("embed", None, None), fan_in=d),
        "wC": ParamMeta((d, G, N), ("embed", None, None), fan_in=d),
        "wdt": ParamMeta((d, H), ("embed", "ssm_heads"), fan_in=d),
        "conv_w": ParamMeta((din, K), ("dinner", None), init="small", fan_in=K),
        "conv_b": ParamMeta((din,), ("dinner",), init="zeros"),
        "A_log": ParamMeta((H,), ("ssm_heads",), init="ones"),
        "D": ParamMeta((H,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamMeta((H,), ("ssm_heads",), init="zeros"),
        "norm": ParamMeta((din,), ("dinner",), init="ones"),
        "wo": dense(din, d, "dinner", "embed"),
    }


def _causal_conv(x, w, b, window: int):
    """Depthwise causal conv via shifted adds. x:(B,S,C), w:(C,K)."""
    out = b.astype(x.dtype) * jnp.ones_like(x)
    for k in range(window):
        shift = window - 1 - k
        xs = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]]
        out = out + xs * w[:, k].astype(x.dtype)
    return out


def _segsum_exp(dA):
    """L[i,j] = exp(sum_{j<k<=i} dA_k) for i>=j else 0. dA:(..., Q).

    The masked (i<j) entries have *positive* diff (cumsum is decreasing), so
    clamp BEFORE exp — otherwise the dead where-branch overflows and poisons
    gradients (where-grad NaN)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(mask, diff, -1e30)
    return jnp.exp(diff)


def ssd_chunked(xh, dt, A, B, C, chunk: int):
    """SSD scan. xh:(b,S,H,P) dt:(b,S,H) A:(H,) B,C:(b,S,H,N) -> y, final state.

    All math in fp32; returns y in xh.dtype and state (b,H,P,N) fp32.
    """
    b, S, H, P = xh.shape
    N = B.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    dtype = xh.dtype
    xh = xh.astype(jnp.float32).reshape(b, nc, Q, H, P)
    dt = dt.astype(jnp.float32).reshape(b, nc, Q, H)
    B = B.astype(jnp.float32).reshape(b, nc, Q, H, N)
    C = C.astype(jnp.float32).reshape(b, nc, Q, H, N)
    A = A.astype(jnp.float32)

    dA = dt * A  # (b,nc,Q,H)
    dAh = jnp.moveaxis(dA, -1, -2)  # (b,nc,H,Q)
    L = _segsum_exp(dAh)  # (b,nc,H,Q,Q)
    # intra-chunk (quadratic within chunk)
    G = jnp.einsum("bcqhn,bckhn->bchqk", C, B)  # (b,nc,H,Q,Q)
    M = G * L
    y_intra = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dt, xh)
    # per-chunk end states
    decay_to_end = jnp.exp(jnp.cumsum(dAh, -1)[..., -1:] - jnp.cumsum(dAh, -1))
    chunk_state = jnp.einsum("bchq,bcqh,bcqhn,bcqhp->bchpn",
                             decay_to_end, dt, B, xh)
    chunk_decay = jnp.exp(jnp.sum(dAh, -1))  # (b,nc,H)

    def scan_fn(s, inp):
        cs_c, dec_c = inp
        s_new = s * dec_c[..., None, None] + cs_c
        return s_new, s  # emit state *entering* the chunk

    init = jnp.zeros((b, H, P, N), jnp.float32)
    final, states_in = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    states_in = jnp.moveaxis(states_in, 0, 1)  # (b,nc,H,P,N)
    # inter-chunk contribution
    in_decay = jnp.exp(jnp.cumsum(dAh, -1))  # (b,nc,H,Q)
    y_inter = jnp.einsum("bcqhn,bchq,bchpn->bcqhp", C, in_decay, states_in)
    y = (y_intra + y_inter).reshape(b, S, H, P).astype(dtype)
    return y, final


def ssm_apply(p, x, cfg: ModelConfig, plan: Plan) -> Tuple[jax.Array, Dict]:
    """Train/prefill. x:(B,S,D) -> (out, final_state_dict for decode seeding)."""
    Bsz, S, D = x.shape
    dt_ = x.dtype
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_ngroups
    z = x @ p["wz"].astype(dt_)
    xr_raw = x @ p["wx"].astype(dt_)
    xin = _causal_conv(xr_raw, p["conv_w"], p["conv_b"], cfg.ssm_conv)
    xin = jax.nn.silu(xin)
    xin = plan.act(xin, "batch", None, "dinner")
    Bm = jnp.einsum("bsd,dgn->bsgn", x, p["wB"].astype(dt_))
    Cm = jnp.einsum("bsd,dgn->bsgn", x, p["wC"].astype(dt_))
    dt = jax.nn.softplus(x @ p["wdt"].astype(dt_) + p["dt_bias"].astype(dt_))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xin.reshape(Bsz, S, H, P)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2)
    Ch = jnp.repeat(Cm, rep, axis=2)
    y, state = ssd_chunked(xh, dt, A, Bh, Ch, cfg.ssm_chunk)
    y = y + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(Bsz, S, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"].astype(dt_)
    # raw (pre-conv) tail seeds the decode conv state
    conv_raw = jnp.moveaxis(xr_raw, 1, 2)[:, :, -(cfg.ssm_conv - 1):]
    return out, {"ssm": state, "conv": conv_raw}


def ssm_state_init(cfg: ModelConfig, plan: Plan, batch: int, dtype, abstract=False):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "ssm": mk((batch, H, P, N), jnp.float32),
        "conv": mk((batch, cfg.d_inner, cfg.ssm_conv - 1), dtype),
    }


def ssm_state_spec(plan: Plan):
    from jax.sharding import PartitionSpec as Pn
    b = plan.batch_axes
    h = plan.rules.get("ssm_heads")
    return {"ssm": Pn(b, h, None, None), "conv": Pn(b, plan.rules.get("dinner"), None)}


def ssm_decode(p, x, state, cfg: ModelConfig, plan: Plan):
    """One-token recurrent step. x:(B,1,D)."""
    Bsz = x.shape[0]
    dt_ = x.dtype
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_ngroups
    xt = x[:, 0]
    z = xt @ p["wz"].astype(dt_)
    xr = xt @ p["wx"].astype(dt_)  # (B, din) raw pre-conv
    conv_hist = jnp.concatenate([state["conv"], xr[:, :, None]], axis=2)  # (B,din,K)
    xin = jnp.einsum("bck,ck->bc", conv_hist.astype(dt_), p["conv_w"].astype(dt_))
    xin = jax.nn.silu(xin + p["conv_b"].astype(dt_))
    Bm = jnp.einsum("bd,dgn->bgn", xt, p["wB"].astype(dt_))
    Cm = jnp.einsum("bd,dgn->bgn", xt, p["wC"].astype(dt_))
    dt = jax.nn.softplus(xt @ p["wdt"].astype(dt_) + p["dt_bias"].astype(dt_))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    xh = xin.reshape(Bsz, H, P).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A)  # (B,H)
    s = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dtf, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", s, Ch).astype(dt_)
    y = y + xh.astype(dt_) * p["D"].astype(dt_)[None, :, None]
    y = y.reshape(Bsz, cfg.d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["wo"].astype(dt_))[:, None]
    return out, {"ssm": s, "conv": conv_hist[:, :, 1:]}
