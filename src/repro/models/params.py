"""Parameter metadata machinery.

Models are built once as a pytree of :class:`ParamMeta` (shape + logical axes
+ init rule). From that single source of truth we derive:

- materialized random params (for smoke tests / real training),
- ``jax.ShapeDtypeStruct`` stand-ins (for the multi-pod dry-run — no allocation),
- ``PartitionSpec`` trees (via ``sharding.plan``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]  # one logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed | small
    fan_in: int = 0  # 0 -> product of all dims except last
    dtype: str = "float32"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def is_meta(x) -> bool:
    return isinstance(x, ParamMeta)


def tree_map_meta(fn: Callable[[ParamMeta], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_meta)


def abstract(tree, dtype: Optional[str] = None):
    """ShapeDtypeStruct tree (dry-run stand-ins; no device allocation)."""
    return tree_map_meta(
        lambda m: jax.ShapeDtypeStruct(m.shape, jnp.dtype(dtype or m.dtype)), tree
    )


def n_params(tree) -> int:
    total = 0
    for m in jax.tree_util.tree_leaves(tree, is_leaf=is_meta):
        total += int(np.prod(m.shape))
    return total


def materialize(tree, key, dtype: Optional[str] = None):
    """Random-initialize a ParamMeta tree (smoke tests / CPU training)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_meta)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for m, k in zip(leaves, keys):
        dt = jnp.dtype(dtype or m.dtype)
        if m.init == "zeros":
            out.append(jnp.zeros(m.shape, dt))
        elif m.init == "ones":
            out.append(jnp.ones(m.shape, dt))
        else:
            fan_in = m.fan_in or (int(np.prod(m.shape[:-1])) or 1)
            scale = {"normal": 1.0, "embed": 1.0, "small": 0.1}[m.init] / np.sqrt(fan_in)
            out.append(jax.random.normal(k, m.shape, dt) * scale)
    return jax.tree_util.tree_unflatten(treedef, out)


# --- small helpers used by the model definitions ---------------------------

def dense(d_in: int, d_out: int, l_in=None, l_out=None, **kw) -> ParamMeta:
    return ParamMeta((d_in, d_out), (l_in, l_out), fan_in=d_in, **kw)


def stack(meta: ParamMeta, n: int, axis_name: str = "layers") -> ParamMeta:
    """Add a leading stacked-layers dim (for scan-over-layers params)."""
    return dataclasses.replace(
        meta, shape=(n,) + meta.shape, logical=(axis_name,) + meta.logical
    )


def stack_tree(tree, n: int):
    return tree_map_meta(lambda m: stack(m, n), tree)
