"""Sharded, async, integrity-checked checkpointing with elastic restore.

Format: one ``step_<k>/`` directory per checkpoint containing ``arrays.npz``
(flattened pytree, '/'-joined key paths) and ``manifest.json`` (treedef repr,
shapes/dtypes, step, sha256 of the npz, user metadata). Saves can run on a
background thread (async) with save-completion fencing; ``keep_last`` prunes.

Elastic restore: arrays are saved unsharded (gathered) and re-placed with the
*current* plan's NamedShardings on load — the mesh shape may differ between
save and restore (elastic rescale), only divisibility must hold. A multi-host
deployment would write per-shard files per process; the manifest format
already records the mesh for that extension.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # --- save ---------------------------------------------------------------
    def save(self, step: int, tree, metadata: Optional[Dict[str, Any]] = None):
        self.wait()  # fence previous async save
        flat = _flatten(tree)  # host copy happens sync (consistent snapshot)
        treedef = jax.tree_util.tree_structure(tree)

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            npz_path = os.path.join(tmp, "arrays.npz")
            np.savez(npz_path, **flat)
            digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
            manifest = {
                "step": step,
                "treedef": str(treedef),
                "keys": sorted(flat.keys()),
                "sha256": digest,
                "time": time.time(),
                "metadata": metadata or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f, indent=1)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)
            self._prune()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None,
                shardings=None, verify: bool = True) -> Tuple[Any, int]:
        """Restore into the structure of ``like_tree``; optional resharding."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        npz_path = os.path.join(path, "arrays.npz")
        if verify:
            digest = hashlib.sha256(open(npz_path, "rb").read()).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"checkpoint {path} corrupt (sha256 mismatch)")
        data = np.load(npz_path)

        leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
        flat_keys = []
        for p, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]:
            flat_keys.append("/".join(str(getattr(x, "key",
                                                  getattr(x, "idx", x)))
                                      for x in p))
        arrays = [data[k] for k in flat_keys]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays), step
