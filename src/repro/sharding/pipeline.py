"""Pipeline parallelism: GPipe-style microbatch streaming over a mesh axis.

``pipeline_apply`` maps a stack of layer groups (stages) onto a mesh axis
with ``shard_map`` + ``ppermute``: each device holds one stage's weights and,
per schedule tick, runs its stage on the microbatch it holds, then passes
activations to the next stage. With M microbatches and P stages the schedule
runs M + P - 1 ticks (bubble fraction (P-1)/(M+P-1), the GPipe bound).

On the production meshes the ``pod`` axis is the natural pipeline axis
(2 stages across pods — inter-pod links are the slow ones, and PP sends only
activations across them once per microbatch, not gradients per layer).
Exercised on host devices by tests/test_pipeline.py.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x, mesh: Mesh,
                   axis: str, n_microbatches: int):
    """Run ``stage_fn(params_i, x) -> x`` through P pipeline stages.

    stage_params: pytree stacked on a leading axis of size P (sharded over
    ``axis``); x: (B, ...) global batch, B % n_microbatches == 0.
    Returns stage_{P-1}(...stage_0(x)) for every microbatch, reassembled.
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_microbatches == 0
    mb = B // n_microbatches
    ticks = n_microbatches + n_stages - 1

    def spmd(params, xs):
        # params: this device's stage params (leading dim 1); xs: (M, mb, ...)
        params = jax.tree_util.tree_map(lambda p: p[0], params)
        idx = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])  # activation held by this stage
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            feed = jnp.where(t < n_microbatches, t, 0)
            buf = jnp.where(idx == 0, xs[feed], buf)
            buf = stage_fn(params, buf)
            # last stage emits microbatch (t - (P-1))
            out_t = t - (n_stages - 1)
            emit = jnp.where(out_t >= 0, out_t, 0)
            outs = jnp.where(
                (idx == n_stages - 1) & (out_t >= 0),
                outs.at[emit].set(buf), outs)
            # pass activations downstream (ring; stage P-1 -> 0 is ignored)
            buf = jax.lax.ppermute(
                buf, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # replicate the last stage's outputs to all shards
        outs = jax.lax.all_gather(outs, axis)[n_stages - 1]
        return outs

    in_specs = (jax.tree_util.tree_map(lambda _: P(axis), stage_params),
                P())
    fn = shard_map(spmd, mesh=mesh, in_specs=in_specs, out_specs=P(),
                   check_rep=False)
    xs = x.reshape((n_microbatches, mb) + x.shape[1:])
    outs = fn(stage_params, xs)
    return outs.reshape(x.shape)
