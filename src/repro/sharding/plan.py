"""Sharding plan: logical axes -> mesh axes, dimension padding, ZeRO specs.

Mesh axis conventions (see launch/mesh.py):
  - ``pod``   outer data axis across pods (also the pipeline axis when PP>1)
  - ``data``  within-pod data-parallel axis
  - ``model`` tensor/expert-parallel axis

Logical parameter axes used by the model definitions:
  vocab, heads, kv_heads, ffn, experts, expert_ffn, dinner, ssm_heads,
  embed (d_model — replicated), layers (scan dim — replicated).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, pad_to_multiple
from repro.models import params as pm


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


@dataclass(frozen=True)
class Plan:
    """Resolved parallelism plan for (cfg, mesh)."""

    mesh: Optional[Mesh]
    tp: int
    dp_axes: Tuple[str, ...]  # ('pod','data') | ('data',) | ()
    tp_axis: Optional[str]
    expert_mode: str  # 'ep' | 'tp' | 'none'
    # effective (padded) model dims
    num_heads: int
    num_kv_heads: int
    kv_repeat: int  # how many times each original kv head is replicated
    vocab: int
    sequence_parallel: bool = False
    zero_opt: bool = True  # ZeRO-1 optimizer-state sharding over dp
    fsdp: bool = True  # fully-shard params over dp axes too (FSDP/ZeRO-3)
    replicate_batch: bool = False  # batch too small for dp (e.g. long_500k B=1)
    rules: Dict[str, Optional[str]] = field(default_factory=dict)

    # -- parameter specs ----------------------------------------------------
    def spec(self, logical: Tuple[Optional[str], ...]) -> P:
        return P(*(self.rules.get(ax) if ax else None for ax in logical))

    def param_spec(self, meta: "pm.ParamMeta") -> P:
        """Param spec; with FSDP the largest replicated dim also shards over dp."""
        if self.fsdp:
            return zero_spec(meta, self)
        return self.spec(meta.logical)

    def param_specs(self, meta_tree):
        return pm.tree_map_meta(self.param_spec, meta_tree)

    def param_shardings(self, meta_tree):
        assert self.mesh is not None
        return pm.tree_map_meta(
            lambda m: NamedSharding(self.mesh, self.param_spec(m)), meta_tree
        )

    # -- activation specs ---------------------------------------------------
    @property
    def batch_axes(self):
        if self.replicate_batch or not self.dp_axes:
            return None
        return self.dp_axes

    def act(self, x, *logical):
        """with_sharding_constraint by logical activation axes.

        logical entries: 'batch', 'seq', 'embed'(=None), 'heads', 'kv_heads',
        'ffn', 'experts', 'dinner', 'vocab', None.
        """
        if self.mesh is None or not self.mesh.shape:
            return x
        spec = []
        for ax in logical:
            if ax == "batch":
                spec.append(self.batch_axes)
            elif ax == "seq":
                spec.append(self.rules.get("seq"))
            elif ax is None or ax == "embed":
                spec.append(None)
            else:
                spec.append(self.rules.get(ax))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))


def make_plan(
    cfg: ModelConfig,
    mesh: Optional[Mesh] = None,
    *,
    sequence_parallel: bool = False,
    seq_shard_decode: bool = False,
    zero_opt: bool = True,
    fsdp: bool = True,
    replicate_batch: bool = False,
) -> Plan:
    """Resolve a parallelism plan for ``cfg`` on ``mesh``.

    ``seq_shard_decode``: shard decode KV caches / sequences over the data axis
    (used by ``long_500k`` where global_batch=1 cannot feed the data axis).
    """
    if mesh is None:
        tp, dp_axes, tp_axis = 1, (), None
    else:
        names = mesh.axis_names
        tp = mesh.shape["model"] if "model" in names else 1
        tp_axis = "model" if "model" in names else None
        dp_axes = tuple(a for a in ("pod", "data") if a in names)

    # --- head padding / kv replication so TP=16 divides everything --------
    num_heads = pad_to_multiple(cfg.num_heads, tp) if cfg.num_heads else 0
    if cfg.num_kv_heads:
        kvh = cfg.num_kv_heads
        if kvh % tp and cfg.num_heads % tp == 0:
            # replicate kv heads up to per-group multiple of tp (GQA -> finer GQA)
            target = _lcm(kvh, tp)
            kv_repeat = target // kvh
            kvh = target
        elif kvh % tp:
            # heads themselves padded (e.g. whisper 12H -> 16H): pad kv too
            kvh, kv_repeat = num_heads, 1
        else:
            kv_repeat = 1
    else:
        kvh, kv_repeat = 0, 1

    vocab = pad_to_multiple(cfg.vocab_size, max(128, tp))

    # --- expert sharding mode ---------------------------------------------
    if cfg.num_experts == 0:
        expert_mode = "none"
    elif cfg.num_experts % tp == 0:
        expert_mode = "ep"  # experts across model axis (deepseek-v2: 160/16=10)
    else:
        expert_mode = "tp"  # TP inside each expert (mixtral: 8 experts < 16)

    rules: Dict[str, Optional[str]] = {
        "vocab": tp_axis,
        "heads": tp_axis,
        "kv_heads": tp_axis,
        "ffn": tp_axis,
        "dinner": tp_axis,
        "ssm_heads": tp_axis,
        "experts": tp_axis if expert_mode == "ep" else None,
        "expert_ffn": tp_axis if expert_mode == "tp" else None,
        "layers": None,
        "embed": None,
        "seq": ("data" if seq_shard_decode else (tp_axis if sequence_parallel else None)),
        "image_tokens": None,
    }

    return Plan(
        mesh=mesh, tp=tp, dp_axes=dp_axes, tp_axis=tp_axis,
        expert_mode=expert_mode, num_heads=num_heads, num_kv_heads=kvh,
        kv_repeat=kv_repeat, vocab=vocab,
        sequence_parallel=sequence_parallel, zero_opt=zero_opt, fsdp=fsdp,
        replicate_batch=replicate_batch, rules=rules,
    )


# --- ZeRO-1: shard optimizer moments over the data axes ---------------------

def zero_spec(meta: pm.ParamMeta, plan: Plan) -> P:
    """Fully-sharded spec: base spec + largest replicated dim over dp axes."""
    base = list(plan.spec(meta.logical))
    while len(base) < len(meta.shape):
        base.append(None)
    if not plan.dp_axes or plan.mesh is None:
        return P(*base)
    dp_size = int(np.prod([plan.mesh.shape[a] for a in plan.dp_axes]))
    # choose the largest dim that is unsharded and divisible by dp
    cand = [
        (meta.shape[i], i)
        for i in range(len(meta.shape))
        if base[i] is None and meta.shape[i] % dp_size == 0 and meta.shape[i] >= dp_size
    ]
    if not cand:
        return P(*base)
    _, i = max(cand)
    base[i] = plan.dp_axes if len(plan.dp_axes) > 1 else plan.dp_axes[0]
    return P(*base)


def zero_specs(meta_tree, plan: Plan):
    return pm.tree_map_meta(lambda m: zero_spec(m, plan), meta_tree)
