"""Elastic scaling: rebuild mesh/plan on device-count change + reshard,
and the control plane's work-migration actuation.

Flow (exercised by tests on the CPU host mesh):
  1. a worker dies -> Heartbeat reports a smaller alive set
  2. ``choose_mesh_shape`` picks the largest usable (data, model) grid
  3. params/opt state are restored from the latest checkpoint with the NEW
     plan's shardings (CheckpointManager.restore is mesh-agnostic)
  4. the data pipeline continues from the restored step (deterministic skip)

The ``repro.control`` tie-in: a controller that decides ``Rebalance(chip)``
(rails alone cannot hold the clock) needs something to actually *move the
work*.  :class:`ElasticWorkAssignment` is that something in simulation: a
per-chip work-share vector that a condemn spreads over the healthy chips,
and :class:`ElasticActuator` is the control-plane adapter — it applies
``Rebalance`` actions to the assignment and feeds the resulting shares back
as :class:`~repro.control.telemetry.UtilSample` telemetry, so the very next
control tick plans rails for the *migrated* load (the condemned chip cools
at ~zero utilization; its former share heats its neighbours).  On real
hardware the same decision triggers :func:`rescale` onto the surviving
device set; ``ElasticWorkAssignment.mesh_hint`` names that shape.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.sharding.plan import Plan, make_plan


def choose_mesh_shape(n_devices: int, prefer_model: int = 1) -> Tuple[int, int]:
    """Largest (data, model) grid with model | prefer_model preserved."""
    model = prefer_model
    while model > 1 and (n_devices % model or model > n_devices):
        model //= 2
    data = n_devices // model
    return data, model


def rebuild(cfg: ModelConfig, n_devices: int,
            prefer_model: int = 1) -> Tuple[Mesh, Plan]:
    devs = jax.devices()[:n_devices]
    data, model = choose_mesh_shape(n_devices, prefer_model)
    import numpy as np
    mesh = Mesh(np.array(devs).reshape(data, model), ("data", "model"))
    return mesh, make_plan(cfg, mesh)


def rescale(cfg: ModelConfig, ckpt_mgr, model_obj, n_devices: int,
            prefer_model: int = 1, step: Optional[int] = None):
    """Restore (params) from checkpoint onto a rebuilt mesh; returns
    (mesh, plan, params, restored_step)."""
    mesh, plan = rebuild(cfg, n_devices, prefer_model)
    meta = model_obj.param_meta()
    from repro.models import params as pm
    like = pm.abstract(meta, cfg.param_dtype)
    shardings = plan.param_shardings(meta)
    params, got_step = ckpt_mgr.restore(like, step=step, shardings=shardings)
    return mesh, plan, params, got_step


# ===========================================================================
# control-plane work migration (Rebalance actuation)
# ===========================================================================


class ElasticWorkAssignment:
    """Per-chip work shares under condemn/restore.

    ``shares`` starts at 1.0 everywhere (every chip carries its fair
    share) and always sums to ``n_chips``: condemning a chip zeroes its
    share and spreads it proportionally over the healthy chips, so total
    work is conserved while the condemned chip drains.  ``util(load)``
    scales the shares by the sensed pod load — exactly the per-chip
    utilization vector the RailField's second axis interpolates.
    """

    def __init__(self, n_chips: int):
        self.n = int(n_chips)
        self.shares = np.ones(self.n, np.float32)
        self.condemned: set = set()

    def condemn(self, chip: int) -> np.ndarray:
        """Migrate ``chip``'s share onto the healthy chips (no-op for an
        already-condemned or out-of-range chip, or when it is the last
        healthy chip — someone has to do the work)."""
        if (not 0 <= chip < self.n or chip in self.condemned
                or len(self.condemned) >= self.n - 1):
            return self.shares
        moved = float(self.shares[chip])
        self.shares[chip] = 0.0
        healthy = self.shares > 0
        total = float(self.shares[healthy].sum())
        if moved > 0 and total > 0:
            self.shares[healthy] *= (total + moved) / total
        self.condemned.add(chip)
        return self.shares

    def restore(self, chip: int) -> np.ndarray:
        """Re-admit a repaired/cooled chip at the mean healthy share."""
        if chip not in self.condemned:
            return self.shares
        self.condemned.discard(chip)
        healthy = self.shares > 0
        n_healthy = int(healthy.sum())
        mean = float(self.shares[healthy].sum()) / max(n_healthy, 1)
        self.shares[chip] = mean
        self.shares *= self.n / float(self.shares.sum())
        return self.shares

    def util(self, load: float = 1.0) -> np.ndarray:
        """Per-chip utilization at pod load fraction ``load``."""
        return (self.shares * np.float32(load)).astype(np.float32)

    # -- §10 fleet failure domains: pod-slice views ---------------------
    def pod_share(self, lo: int, hi: int) -> float:
        """Fraction of the fleet's work currently assigned to chips
        ``[lo, hi)`` — the ``control.fleet`` power-budget weight (0.0
        while the pod is quarantined/drained, its share having been
        spread over the survivors)."""
        return float(self.shares[lo:hi].sum()) / float(self.shares.sum())

    def condemned_in(self, lo: int, hi: int) -> Tuple[int, ...]:
        """Condemned chips inside a pod slice, sorted — the §10 restore
        worklist a drained pod walks when it rejoins the fleet."""
        return tuple(sorted(c for c in self.condemned if lo <= c < hi))

    def mesh_hint(self, prefer_model: int = 1) -> Tuple[int, int]:
        """The (data, model) grid a real rescale would rebuild onto."""
        return choose_mesh_shape(self.n - len(self.condemned), prefer_model)


class ElasticActuator:
    """Control-plane adapter: consumes ``Rebalance``/``Restore`` actions,
    produces ``UtilSample`` telemetry.

    Implements both control protocols — ``Actuator.apply`` (a ``Rebalance``
    condemns the chip on the assignment) and ``TelemetrySource.poll`` (the
    current shares ride back to the bus), closing the migration loop:
    decide -> condemn -> shares -> next tick's utilization -> rails.
    """

    def __init__(self, assignment: ElasticWorkAssignment):
        self.assignment = assignment
        self.log: List = []

    def apply(self, action) -> bool:
        from repro.control.controller import Rebalance, Restore
        if isinstance(action, Rebalance):
            self.assignment.condemn(action.chip)
            self.log.append(action)
            return True
        if isinstance(action, Restore):
            self.assignment.restore(action.chip)
            self.log.append(action)
            return True
        return False

    def poll(self, now: float) -> List:
        from repro.control.telemetry import UtilSample
        return [UtilSample(self.assignment.shares.copy())]
