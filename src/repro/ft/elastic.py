"""Elastic scaling: rebuild mesh/plan on device-count change + reshard.

Flow (exercised by tests on the CPU host mesh):
  1. a worker dies -> Heartbeat reports a smaller alive set
  2. ``choose_mesh_shape`` picks the largest usable (data, model) grid
  3. params/opt state are restored from the latest checkpoint with the NEW
     plan's shardings (CheckpointManager.restore is mesh-agnostic)
  4. the data pipeline continues from the restored step (deterministic skip)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.sharding.plan import Plan, make_plan


def choose_mesh_shape(n_devices: int, prefer_model: int = 1) -> Tuple[int, int]:
    """Largest (data, model) grid with model | prefer_model preserved."""
    model = prefer_model
    while model > 1 and (n_devices % model or model > n_devices):
        model //= 2
    data = n_devices // model
    return data, model


def rebuild(cfg: ModelConfig, n_devices: int,
            prefer_model: int = 1) -> Tuple[Mesh, Plan]:
    devs = jax.devices()[:n_devices]
    data, model = choose_mesh_shape(n_devices, prefer_model)
    import numpy as np
    mesh = Mesh(np.array(devs).reshape(data, model), ("data", "model"))
    return mesh, make_plan(cfg, mesh)


def rescale(cfg: ModelConfig, ckpt_mgr, model_obj, n_devices: int,
            prefer_model: int = 1, step: Optional[int] = None):
    """Restore (params) from checkpoint onto a rebuilt mesh; returns
    (mesh, plan, params, restored_step)."""
    mesh, plan = rebuild(cfg, n_devices, prefer_model)
    meta = model_obj.param_meta()
    from repro.models import params as pm
    like = pm.abstract(meta, cfg.param_dtype)
    shardings = plan.param_shardings(meta)
    params, got_step = ckpt_mgr.restore(like, step=step, shardings=shardings)
    return mesh, plan, params, got_step
