"""Fault tolerance: heartbeats, straggler detection, failure/retry driver.

At 1000+ nodes the interesting failures are partial: one slow chip (thermal
throttling, ECC retries), one dead host, one hung collective. The pieces:

- ``Heartbeat``: per-worker liveness registry with timeout -> dead-set.
- ``StragglerDetector``: rolling step-time stats; flags outliers beyond
  ``threshold`` x median. Mitigations are pluggable; the thermal tie-in
  (core/runtime.py) BOOSTS the hot chip's rail (performance-preserving, the
  paper's knob in reverse) before resorting to rebalancing.
- ``retry_step``: bounded-retry wrapper around a train step for transient
  failures, with checkpoint-restore escalation.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


class Heartbeat:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_seen: Dict[str, float] = {}

    def beat(self, worker: str, t: Optional[float] = None):
        self.last_seen[worker] = time.time() if t is None else t

    def dead(self, now: Optional[float] = None) -> Set[str]:
        now = time.time() if now is None else now
        return {w for w, t in self.last_seen.items()
                if now - t > self.timeout_s}

    def alive(self, now: Optional[float] = None) -> Set[str]:
        return set(self.last_seen) - self.dead(now)


@dataclass
class StragglerEvent:
    worker: str
    step: int
    step_time: float
    median: float
    ratio: float


class StragglerDetector:
    def __init__(self, threshold: float = 1.5, window: int = 32,
                 min_samples: int = 8):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.times: Dict[str, deque] = {}
        self.events: List[StragglerEvent] = []

    def record(self, worker: str, step: int, step_time: float):
        dq = self.times.setdefault(worker, deque(maxlen=self.window))
        dq.append(step_time)
        allt = sorted(t for d in self.times.values() for t in d)
        if len(allt) < self.min_samples:
            return None
        median = allt[len(allt) // 2]
        if step_time > self.threshold * median:
            ev = StragglerEvent(worker, step, step_time, median,
                                step_time / median)
            self.events.append(ev)
            return ev
        return None


class TransientError(RuntimeError):
    pass


def retry_step(fn: Callable, *args, max_retries: int = 3,
               on_failure: Optional[Callable[[int, Exception], None]] = None,
               **kw):
    """Run ``fn`` with bounded retries on TransientError; re-raise otherwise."""
    for attempt in range(max_retries + 1):
        try:
            return fn(*args, **kw)
        except TransientError as e:  # noqa: PERF203
            if on_failure:
                on_failure(attempt, e)
            if attempt == max_retries:
                raise
    raise AssertionError("unreachable")


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail step k once."""
    fail_at: Set[int] = field(default_factory=set)
    seen: Set[int] = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise TransientError(f"injected failure at step {step}")
