"""Fault tolerance: heartbeats, straggler detection, failure/retry driver.

At 1000+ nodes the interesting failures are partial: one slow chip (thermal
throttling, ECC retries), one dead host, one hung collective. The pieces:

- ``Heartbeat``: per-worker liveness registry with timeout -> dead-set.
- ``StragglerDetector``: rolling step-time stats; flags outliers beyond
  ``threshold`` x median. The cross-worker median is maintained
  *incrementally* (two-heap rolling median with lazy deletion), so a
  fleet-scale monitor pays O(log W) per step instead of re-sorting every
  buffered sample. Mitigations are pluggable; the thermal tie-in
  (repro.control.LutController over core/runtime.py) BOOSTS the hot chip's
  rail (performance-preserving, the paper's knob in reverse) before
  resorting to rebalancing — ``repro.control.MonitorTelemetry`` routes the
  events into the control plane.
- ``retry_step``: bounded-retry wrapper around a train step for transient
  failures, with checkpoint-restore escalation.
"""
from __future__ import annotations

import heapq
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


class Heartbeat:
    def __init__(self, timeout_s: float = 60.0):
        self.timeout_s = timeout_s
        self.last_seen: Dict[str, float] = {}

    def beat(self, worker: str, t: Optional[float] = None):
        self.last_seen[worker] = time.time() if t is None else t

    def dead(self, now: Optional[float] = None) -> Set[str]:
        now = time.time() if now is None else now
        return {w for w, t in self.last_seen.items()
                if now - t > self.timeout_s}

    def alive(self, now: Optional[float] = None) -> Set[str]:
        return set(self.last_seen) - self.dead(now)


@dataclass
class StragglerEvent:
    worker: str
    step: int
    step_time: float
    median: float
    ratio: float


class _RollingMedian:
    """Two-heap median over a multiset with O(log n) add/remove.

    ``lo`` is a max-heap (negated) holding the smallest ``n // 2`` values;
    ``hi`` is a min-heap holding the rest, so ``hi[0]`` is the *upper*
    median ``sorted(values)[n // 2]`` — the exact statistic the legacy
    sort-everything implementation reported.

    Removals are lazy with *per-heap* tombstones: a removal is attributed
    to the heap that provably holds an instance of the value (``v`` is in
    ``lo`` iff ``v <= max(lo)``, since the heaps partition the sorted
    order), and the tombstone is consumed only when a copy surfaces at
    *that* heap's top.  A single shared tombstone map would let the other
    heap's prune consume it when duplicates straddle the lo/hi boundary,
    desynchronizing the logical sizes.
    """

    def __init__(self):
        self._lo: List[float] = []  # max-heap via negation
        self._hi: List[float] = []  # min-heap
        self._lo_n = 0  # logical (live) sizes
        self._hi_n = 0
        self._dead_lo: Dict[float, int] = {}
        self._dead_hi: Dict[float, int] = {}

    def __len__(self) -> int:
        return self._lo_n + self._hi_n

    def _prune_lo(self):
        while self._lo and self._dead_lo.get(-self._lo[0], 0):
            v = -heapq.heappop(self._lo)
            self._dead_lo[v] -= 1
            if not self._dead_lo[v]:
                del self._dead_lo[v]

    def _prune_hi(self):
        while self._hi and self._dead_hi.get(self._hi[0], 0):
            v = heapq.heappop(self._hi)
            self._dead_hi[v] -= 1
            if not self._dead_hi[v]:
                del self._dead_hi[v]

    def _rebalance(self):
        want_lo = len(self) // 2
        while self._lo_n > want_lo:
            self._prune_lo()
            v = -heapq.heappop(self._lo)
            self._lo_n -= 1
            heapq.heappush(self._hi, v)
            self._hi_n += 1
        while self._lo_n < want_lo:
            self._prune_hi()
            v = heapq.heappop(self._hi)
            self._hi_n -= 1
            heapq.heappush(self._lo, -v)
            self._lo_n += 1

    def add(self, v: float):
        self._prune_lo()
        if self._lo and v <= -self._lo[0]:
            heapq.heappush(self._lo, -v)
            self._lo_n += 1
        else:
            heapq.heappush(self._hi, v)
            self._hi_n += 1
        self._rebalance()

    def remove(self, v: float):
        """Remove one instance of ``v`` (must be present)."""
        self._prune_lo()
        if self._lo and v <= -self._lo[0]:  # an instance lives in lo
            self._dead_lo[v] = self._dead_lo.get(v, 0) + 1
            self._lo_n -= 1
        else:
            self._dead_hi[v] = self._dead_hi.get(v, 0) + 1
            self._hi_n -= 1
        self._rebalance()

    @property
    def median(self) -> float:
        self._prune_hi()
        return self._hi[0]


class StragglerDetector:
    def __init__(self, threshold: float = 1.5, window: int = 32,
                 min_samples: int = 8):
        self.threshold = threshold
        self.window = window
        self.min_samples = min_samples
        self.times: Dict[str, deque] = {}
        self.events: List[StragglerEvent] = []
        self._median = _RollingMedian()

    def record(self, worker: str, step: int, step_time: float):
        dq = self.times.setdefault(worker, deque(maxlen=self.window))
        if len(dq) == self.window:  # deque is full: append evicts dq[0]
            self._median.remove(dq[0])
        dq.append(step_time)
        self._median.add(step_time)
        if len(self._median) < self.min_samples:
            return None
        median = self._median.median
        if step_time > self.threshold * median:
            ev = StragglerEvent(worker, step, step_time, median,
                                step_time / median)
            self.events.append(ev)
            return ev
        return None


class TransientError(RuntimeError):
    pass


def retry_step(fn: Callable, *args, max_retries: int = 3,
               on_failure: Optional[Callable[[int, Exception], None]] = None,
               **kw):
    """Run ``fn`` with bounded retries on TransientError; re-raise otherwise."""
    for attempt in range(max_retries + 1):
        try:
            return fn(*args, **kw)
        except TransientError as e:  # noqa: PERF203
            if on_failure:
                on_failure(attempt, e)
            if attempt == max_retries:
                raise
    raise AssertionError("unreachable")


@dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: fail step k once."""
    fail_at: Set[int] = field(default_factory=set)
    seen: Set[int] = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.seen:
            self.seen.add(step)
            raise TransientError(f"injected failure at step {step}")
