"""Architecture registry: the 10 assigned architectures.

Each architecture lives in its own ``src/repro/configs/<id>.py`` module
(exact parameters from the assignment sheet, sources noted inline); this
module aggregates them and exposes lookup helpers.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, SHAPES, ShapeSpec
from repro.configs.nemotron_4_15b import CONFIG as NEMOTRON_4_15B
from repro.configs.qwen3_1_7b import CONFIG as QWEN3_1_7B
from repro.configs.llama3_2_1b import CONFIG as LLAMA3_2_1B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.mamba2_780m import CONFIG as MAMBA2_780M
from repro.configs.deepseek_v2_236b import CONFIG as DEEPSEEK_V2_236B
from repro.configs.mixtral_8x7b import CONFIG as MIXTRAL_8X7B
from repro.configs.zamba2_1_2b import CONFIG as ZAMBA2_1_2B
from repro.configs.llama3_2_vision_11b import CONFIG as LLAMA3_2_VISION_11B
from repro.configs.whisper_small import CONFIG as WHISPER_SMALL

ARCHS = {
    c.name: c
    for c in [
        NEMOTRON_4_15B, QWEN3_1_7B, LLAMA3_2_1B, DEEPSEEK_67B, MAMBA2_780M,
        DEEPSEEK_V2_236B, MIXTRAL_8X7B, ZAMBA2_1_2B, LLAMA3_2_VISION_11B,
        WHISPER_SMALL,
    ]
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


def all_cells():
    """Every (arch, shape) dry-run cell, honouring per-family shape skips."""
    for arch in ARCHS.values():
        for shape in arch.shapes():
            yield arch.name, shape
