"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2411.15242] Mamba2 backbone + shared attention block every 6 layers.
CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32000, head_dim=64,
    ssm_state=64, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    hybrid_attn_every=6, scan_layers=False, tie_embeddings=True,
)

ZAMBA2_1_2B = CONFIG
