"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2405.04434] MLA kv_lora=512, 2 shared + 160 routed top-6, first layer dense.
CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    attn_type="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    num_experts=160, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, first_k_dense=1, rope_theta=10_000.0,
    moe_group_size=8192, optimizer="adafactor",
)

DEEPSEEK_V2_236B = CONFIG
