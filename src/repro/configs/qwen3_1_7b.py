"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [hf:Qwen/Qwen3-8B family] qk_norm, GQA kv=8, head_dim 128, tied embeddings.
CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=6144, vocab_size=151936, head_dim=128,
    qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

QWEN3_1_7B = CONFIG
