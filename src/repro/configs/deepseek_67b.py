"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2401.02954] llama-arch, 95L.
CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=22016, vocab_size=102400, head_dim=128,
    rope_theta=10_000.0, optimizer="adafactor",
)

DEEPSEEK_67B = CONFIG
