"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2405.21060] Mamba2 SSD, attention-free.
CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_conv=4, ssm_expand=2, ssm_head_dim=64,
    use_rope=False, tie_embeddings=True,
)

MAMBA2_780M = CONFIG
