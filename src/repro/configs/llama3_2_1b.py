"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [hf:meta-llama/Llama-3.2-1B] small llama3.
CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense",
    num_layers=16, d_model=2048, num_heads=32, num_kv_heads=8,
    d_ff=8192, vocab_size=128256, head_dim=64,
    tie_embeddings=True, rope_theta=500_000.0,
)

LLAMA3_2_1B = CONFIG
