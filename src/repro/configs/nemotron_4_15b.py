"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2402.16819] GQA kv=8, squared-ReLU MLP (no gate), rope.
CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    num_layers=32, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=24576, vocab_size=256000, head_dim=128,
    mlp_type="relu2", rope_theta=10_000.0,
)

NEMOTRON_4_15B = CONFIG
