"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2401.04088] 8 experts top-2, sliding-window attention.
CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_experts=8, num_experts_per_tok=2, moe_d_ff=14336,
    sliding_window=4096, rope_theta=1_000_000.0,
    moe_group_size=16384,
)

MIXTRAL_8X7B = CONFIG
