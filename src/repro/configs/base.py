"""Config system: architecture configs and input-shape specs.

Every assigned architecture gets one ``<arch>.py`` module exporting ``CONFIG``.
``registry.get(name)`` returns the full-size config; ``cfg.reduced()`` returns a
CPU-smoke-test-sized config of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned): every LM arch is paired with these four shapes.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# Archs with O(L^2) full attention skip long_500k (see DESIGN.md §6).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture."""

    name: str
    family: str  # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- norms / activations -------------------------------------------------
    mlp_type: str = "swiglu"  # swiglu | relu2 | gelu
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    qk_norm: bool = False
    rope_theta: float = 500_000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- attention extras ----------------------------------------------------
    attn_type: str = "gqa"  # gqa | mla
    sliding_window: int = 0  # 0 = full attention
    # MLA (deepseek-v2)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden
    first_k_dense: int = 0  # leading dense layers in an MoE stack
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    router_z_coef: float = 0.001

    # --- SSM (mamba2) -----------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_ngroups: int = 1
    ssm_chunk: int = 256
    hybrid_attn_every: int = 0  # zamba2: shared attn block after every k ssm layers

    # --- multimodal -----------------------------------------------------------
    cross_attn_every: int = 0  # vlm: cross-attn layer every k layers
    num_image_tokens: int = 0
    encoder_layers: int = 0  # whisper
    encoder_frames: int = 0
    is_encoder_decoder: bool = False

    # --- infra ------------------------------------------------------------
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    scan_layers: bool = True
    remat: str = "full"  # none | full
    max_seq_len: int = 524_288
    optimizer: str = "adamw"  # adamw | adafactor
    moe_group_size: int = 0  # tokens per dispatch group; 0 = single group

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
        kw = dict(
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 2 if not self.hybrid_attn_every else 4),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            max_seq_len=256,
            scan_layers=self.scan_layers,
            remat="none",
        )
        if self.attn_type == "mla":
            kw.update(q_lora_rank=32, kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16, head_dim=0)
        if self.is_moe:
            kw.update(num_experts=min(self.num_experts, 8),
                      num_experts_per_tok=min(self.num_experts_per_tok, 2),
                      moe_d_ff=64,
                      num_shared_experts=self.num_shared_experts and 1,
                      first_k_dense=min(self.first_k_dense, 1))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32)
        if self.hybrid_attn_every:
            kw.update(hybrid_attn_every=2)
        if self.cross_attn_every:
            kw.update(cross_attn_every=2, num_image_tokens=16)
        if self.encoder_layers:
            kw.update(encoder_layers=2, encoder_frames=32)
        return self.replace(**kw)

    def shapes(self) -> Tuple[str, ...]:
        """Shape names applicable to this arch (long_500k only if sub-quadratic)."""
        names = ["train_4k", "prefill_32k", "decode_32k"]
        if self.family in SUBQUADRATIC_FAMILIES:
            names.append("long_500k")
        return tuple(names)


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
