"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [hf:meta-llama/Llama-3.2-11B-Vision] cross-attn image layers every 5th layer.
CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, head_dim=128,
    cross_attn_every=5, num_image_tokens=1601,
    rope_theta=500_000.0, scan_layers=False,
)

LLAMA3_2_VISION_11B = CONFIG
