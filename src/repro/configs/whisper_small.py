"""Assigned architecture config (see registry for the full pool)."""
from repro.configs.base import ModelConfig

# [arXiv:2212.04356] enc-dec; conv frontend is a STUB (precomputed frame embeds).
CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    norm_type="layernorm", mlp_type="gelu", use_rope=False,
    encoder_layers=12, encoder_frames=1500, is_encoder_decoder=True,
    scan_layers=False,
)

WHISPER_SMALL = CONFIG
