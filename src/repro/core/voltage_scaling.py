"""Algorithm 1 — Thermal-Aware Voltage Selection (the paper's core flow).

Fixed-point loop:
  1. d_worst = T(netlist, T_MAX, V_nom)   # STA worst case; guardbands intact
  2. given the current per-tile temperature estimate, pick the
     (V_core, V_bram) pair minimizing P_lkg + P_dyn subject to
     crit_delay(netlist, T_grid, V_core, V_bram) <= d_worst
  3. run the thermal solver on the resulting per-tile power
  4. repeat until ||dT||_inf < delta_T

The (V_core x V_bram) search is fully vectorized (vmap over the voltage
grid); after the first iteration the search can be restricted to the
neighbourhood of the previous solution (the paper's O(1) refinement) — both
modes are implemented and timed.

Static scheme: run at the worst-case ambient + activity -> one (V_core,
V_bram). Dynamic scheme: precompute a T_amb -> (V_core, V_bram) lookup table
for the on-line TSD-driven controller (paper §III-B).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import netlist as NL
from repro.core import thermal
from repro.core.netlist import Netlist

V_CORE_GRID = np.round(np.arange(0.55, 0.801, 0.01), 3)
V_BRAM_GRID = np.round(np.arange(0.55, 0.951, 0.01), 3)


@dataclass
class IterRecord:
    it: int
    v_core: float
    v_bram: float
    power_mw: float
    t_junct: float
    wall_s: float


@dataclass
class VSResult:
    v_core: float
    v_bram: float
    power_mw: float
    baseline_mw: float
    saving: float
    t_junct_mean: float
    t_junct_max: float
    d_worst_ns: float
    trace: List[IterRecord] = field(default_factory=list)
    converged: bool = True


def _pair_grids(v_core_grid=None, v_bram_grid=None):
    vc = jnp.asarray(v_core_grid if v_core_grid is not None else V_CORE_GRID,
                     jnp.float32)
    vb = jnp.asarray(v_bram_grid if v_bram_grid is not None else V_BRAM_GRID,
                     jnp.float32)
    VC, VB = jnp.meshgrid(vc, vb, indexing="ij")
    return vc, vb, VC.reshape(-1), VB.reshape(-1)


T_GUARD = 2.0  # degC guard on timing eval (TSD error / spatial gradients, §III-B)


def _search(lib, nlj, T_tiles, f_ghz, act_in, d_worst, vc_flat, vb_flat):
    """Min-power feasible pair over the (flattened) voltage grid."""

    def eval_pair(vc, vb):
        d = NL.crit_delay(lib, nlj, T_tiles + T_GUARD, vc, vb)
        lkg, dyn = NL.tile_power(lib, nlj, T_tiles, vc, vb, f_ghz, act_in)
        return d, jnp.sum(lkg) + jnp.sum(dyn)

    d_all, p_all = jax.vmap(eval_pair)(vc_flat, vb_flat)
    feasible = d_all <= d_worst * (1.0 + 1e-6)
    p_masked = jnp.where(feasible, p_all, jnp.inf)
    idx = jnp.argmin(p_masked)
    any_feasible = jnp.any(feasible)
    # fallback: nominal voltages (always feasible by construction of d_worst)
    vc = jnp.where(any_feasible, vc_flat[idx], C.V_CORE_NOM)
    vb = jnp.where(any_feasible, vb_flat[idx], C.V_BRAM_NOM)
    return vc, vb


_search_jit = jax.jit(_search, static_argnums=())


def run(netlist: Netlist, t_amb: float, act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(),
        lib: Optional[C.DeviceLibrary] = None,
        delta_t: float = 0.1, max_iters: int = 10,
        boundary_search: bool = True) -> VSResult:
    """Algorithm 1. ``act_in``: worst-case primary-input activity."""
    lib = lib or C.default_library()
    nlj = netlist.as_jax()
    n_tiles = netlist.n_tiles

    d_worst = float(NL.crit_delay(
        lib, nlj, jnp.full((n_tiles,), C.T_MAX), C.V_CORE_NOM, C.V_BRAM_NOM))
    f_ghz = 1.0 / d_worst  # clock period stays d_worst throughout

    vc_g, vb_g, vc_flat, vb_flat = _pair_grids()
    T = jnp.full((n_tiles,), float(t_amb))
    trace: List[IterRecord] = []
    vc = vb = None
    converged = False

    for it in range(max_iters):
        t0 = time.time()
        if it > 0 and boundary_search:
            # O(1) refinement: +-20 mV window around the previous solution
            sel_c = jnp.asarray(
                [v for v in np.asarray(vc_g) if abs(v - vc_prev) <= 0.021],
                jnp.float32)
            sel_b = jnp.asarray(
                [v for v in np.asarray(vb_g) if abs(v - vb_prev) <= 0.021],
                jnp.float32)
            VC, VB = jnp.meshgrid(sel_c, sel_b, indexing="ij")
            vc, vb = _search(lib, nlj, T, f_ghz, act_in, d_worst,
                             VC.reshape(-1), VB.reshape(-1))
        else:
            vc, vb = _search(lib, nlj, T, f_ghz, act_in, d_worst,
                             vc_flat, vb_flat)
        vc_prev, vb_prev = float(vc), float(vb)
        lkg, dyn = NL.tile_power(lib, nlj, T, vc, vb, f_ghz, act_in)
        T_new = thermal.solve(lkg + dyn, netlist.m, netlist.n, t_amb, tc)
        p_total = float(jnp.sum(lkg) + jnp.sum(dyn))
        trace.append(IterRecord(it + 1, vc_prev, vb_prev, p_total,
                                float(jnp.mean(T_new)), time.time() - t0))
        dT = float(jnp.max(jnp.abs(T_new - T)))
        T = T_new
        if dT < delta_t:
            converged = True
            break

    # baseline: nominal voltages, same thermal fixed point
    baseline_mw, T_base = baseline_power(netlist, t_amb, act_in, tc, lib)

    return VSResult(
        v_core=vc_prev, v_bram=vb_prev, power_mw=trace[-1].power_mw,
        baseline_mw=baseline_mw,
        saving=1.0 - trace[-1].power_mw / baseline_mw,
        t_junct_mean=float(jnp.mean(T)), t_junct_max=float(jnp.max(T)),
        d_worst_ns=d_worst, trace=trace, converged=converged,
    )


def baseline_power(netlist: Netlist, t_amb: float, act_in: float,
                   tc: thermal.ThermalConfig, lib=None,
                   max_iters: int = 10, delta_t: float = 0.1):
    """Nominal-voltage power at its own thermal fixed point."""
    lib = lib or C.default_library()
    nlj = netlist.as_jax()
    n_tiles = netlist.n_tiles
    d_worst = float(NL.crit_delay(
        lib, nlj, jnp.full((n_tiles,), C.T_MAX), C.V_CORE_NOM, C.V_BRAM_NOM))
    f_ghz = 1.0 / d_worst
    T = jnp.full((n_tiles,), float(t_amb))
    for _ in range(max_iters):
        lkg, dyn = NL.tile_power(lib, nlj, T, C.V_CORE_NOM, C.V_BRAM_NOM,
                                 f_ghz, act_in)
        T_new = thermal.solve(lkg + dyn, netlist.m, netlist.n, t_amb, tc)
        if float(jnp.max(jnp.abs(T_new - T))) < delta_t:
            T = T_new
            break
        T = T_new
    lkg, dyn = NL.tile_power(lib, nlj, T, C.V_CORE_NOM, C.V_BRAM_NOM,
                             f_ghz, act_in)
    return float(jnp.sum(lkg) + jnp.sum(dyn)), T


def dynamic_lut(netlist: Netlist, t_ambs, act_in: float = 1.0,
                tc: thermal.ThermalConfig = thermal.ThermalConfig(),
                lib=None) -> Dict[float, Tuple[float, float]]:
    """The on-line scheme's lookup table: T_amb -> (V_core, V_bram).

    Loaded at configure time; the TSD reading (1 ms resolution, paper [38])
    indexes it and the on-chip regulator applies the pair (paper [39])."""
    out = {}
    for t in t_ambs:
        r = run(netlist, float(t), act_in, tc, lib)
        out[float(t)] = (r.v_core, r.v_bram)
    return out
