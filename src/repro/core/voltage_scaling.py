"""Algorithm 1 — Thermal-Aware Voltage Selection (the paper's core flow).

Fixed-point loop:
  1. d_worst = T(netlist, T_MAX, V_nom)   # STA worst case; guardbands intact
  2. given the current per-tile temperature estimate, pick the
     (V_core, V_bram) pair minimizing P_lkg + P_dyn subject to
     crit_delay(netlist, T_grid, V_core, V_bram) <= d_worst
  3. run the thermal solver on the resulting per-tile power
  4. repeat until ||dT||_inf < delta_T

This module is a thin wrapper over :mod:`repro.policy` (see DESIGN.md): the
whole loop — including the vectorized (V_core x V_bram) grid search and the
paper's O(1) boundary refinement — runs jitted inside the shared
``policy.Solver`` (a single ``lax.while_loop``; d_worst computed once and
cached on the substrate).

Static scheme: run at the worst-case ambient + activity -> one (V_core,
V_bram). Dynamic scheme: ``dynamic_lut`` precomputes the T_amb -> (V_core,
V_bram) table for the on-line TSD-driven controller (paper §III-B) as ONE
batched ``Solver.solve_batch`` device call.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import thermal
from repro.core.netlist import Netlist
from repro.policy import (PowerSave, cached_solver, fpga_substrate)
from repro.policy.substrate import T_GUARD, V_BRAM_GRID, V_CORE_GRID  # noqa: F401  (re-exported legacy constants)

#: the legacy boundary search window: +-20 mV (2 grid steps) around the
#: previous solution after the first iteration
REFINE_WINDOW_V = 0.021


@dataclass
class IterRecord:
    it: int
    v_core: float
    v_bram: float
    power_mw: float
    t_junct: float
    wall_s: float


@dataclass
class VSResult:
    v_core: float
    v_bram: float
    power_mw: float
    baseline_mw: float
    saving: float
    t_junct_mean: float
    t_junct_max: float
    d_worst_ns: float
    trace: List[IterRecord] = field(default_factory=list)
    converged: bool = True


def run(netlist: Netlist, t_amb: float, act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(),
        lib: Optional[C.DeviceLibrary] = None,
        delta_t: float = 0.1, max_iters: int = 10,
        boundary_search: bool = True) -> VSResult:
    """Algorithm 1. ``act_in``: worst-case primary-input activity.

    ``max_iters < 1`` is clamped to one iteration (a zero-iteration loop has
    no solution to report); the result is then marked unconverged unless the
    very first thermal update already met ``delta_t``.
    """
    sub = fpga_substrate(netlist, lib, tc)
    solver = cached_solver(
        sub, PowerSave(), delta_t, max(int(max_iters), 1),
        refine_window=REFINE_WINDOW_V if boundary_search else None)
    t0 = time.time()
    sol = solver.solve({"t_amb": t_amb, "act": act_in})
    wall = time.time() - t0

    n_it = int(sol.n_iters)
    vcs, vbs = sub.decode(sol.idx_hist[:n_it, 0])
    trace = [IterRecord(i + 1, float(vcs[i]), float(vbs[i]),
                        float(sol.p_hist[i]), float(sol.tj_hist[i]),
                        wall / n_it)
             for i in range(n_it)]

    baseline_mw, _ = baseline_power(netlist, t_amb, act_in, tc, lib,
                                    max_iters=10, delta_t=delta_t)
    power = trace[-1].power_mw
    return VSResult(
        v_core=trace[-1].v_core, v_bram=trace[-1].v_bram, power_mw=power,
        baseline_mw=baseline_mw,
        saving=1.0 - power / baseline_mw,
        t_junct_mean=float(jnp.mean(sol.T)),
        t_junct_max=float(jnp.max(sol.T)),
        d_worst_ns=sub.d_worst, trace=trace, converged=bool(sol.converged),
    )


def baseline_power(netlist: Netlist, t_amb: float, act_in: float,
                   tc: thermal.ThermalConfig, lib=None,
                   max_iters: int = 10, delta_t: float = 0.1):
    """Nominal-voltage power at its own thermal fixed point."""
    sub = fpga_substrate(netlist, lib, tc).nominal_only()
    solver = cached_solver(sub, PowerSave(), delta_t, max(int(max_iters), 1))
    sol = solver.solve({"t_amb": t_amb, "act": act_in})
    # legacy semantics: power re-evaluated at the converged temperatures
    return float(sol.p_final[0]), sol.T


def dynamic_lut(netlist: Netlist, t_ambs, act_in: float = 1.0,
                tc: thermal.ThermalConfig = thermal.ThermalConfig(),
                lib=None) -> Dict[float, Tuple[float, float]]:
    """The on-line scheme's lookup table: T_amb -> (V_core, V_bram).

    Loaded at configure time; the TSD reading (1 ms resolution, paper [38])
    indexes it and the on-chip regulator applies the pair (paper [39]).
    The whole ambient sweep is ONE batched device call (Solver.solve_batch).
    """
    sub = fpga_substrate(netlist, lib, tc)
    solver = cached_solver(sub, PowerSave(), 0.1, 10,
                           refine_window=REFINE_WINDOW_V)
    t = np.asarray([float(x) for x in t_ambs], np.float32)
    sol = solver.solve_batch({"t_amb": t, "act": np.full_like(t, act_in)})
    vc, vb = sub.decode(sol.idx[:, 0])
    return {float(t[i]): (float(vc[i]), float(vb[i]))
            for i in range(len(t))}
