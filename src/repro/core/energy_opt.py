"""Algorithm 2 — Thermal-Aware Energy Optimization.

For every (V_core, V_bram) pair, find the *maximum frequency* the thermal
steady state allows (d_max = T(netlist, T_grid, V) feeds power feeds
temperature), then pick the pair minimizing E = P_total x d_max
(power-delay product). §III-C proves running at max frequency is
energy-optimal for a fixed voltage (leakage energy scales with time;
dynamic energy does not).

The legacy implementation refined pairs one by one (72 min -> 49 s via the
paper's pruning + thermal-reuse speed-ups).  This wrapper instead routes
through the shared ``repro.policy.Solver`` (DESIGN.md): the ``MinEnergy``
policy evaluates EVERY pair's (delay, power, energy) in one vectorized pass
per fixed-point iteration, entirely inside ``lax.while_loop`` — the whole
grid is "refined" simultaneously in a handful of thermal solves, which
subsumes both paper speed-ups (``use_pruning`` is kept for API
compatibility and ignored).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

from repro.core import characterization as C
from repro.core import thermal
from repro.core.netlist import Netlist
from repro.core.voltage_scaling import baseline_power
from repro.policy import MinEnergy, cached_solver, fpga_substrate


@dataclass
class EnergyResult:
    v_core: float
    v_bram: float
    d_opt_ns: float  # chosen clock period
    d_worst_ns: float  # baseline clock period
    power_mw: float
    energy: float  # P x d  [mW ns]
    baseline_energy: float
    saving: float
    freq_ratio: float  # f_opt / f_base = d_worst / d_opt
    n_refined: int = 0
    n_pruned: int = 0
    wall_full_est_s: float = 0.0
    wall_s: float = 0.0


def _safe_div(num: float, den: float, default: float = 0.0) -> float:
    """Guard the degenerate-loop hazards (zero refined pairs / zero delay)."""
    return num / den if den else default


def run(netlist: Netlist, t_amb: float, act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(),
        lib: Optional[C.DeviceLibrary] = None,
        use_pruning: bool = True,
        delta_t: float = 0.1, max_iters: int = 8) -> EnergyResult:
    t0 = time.time()
    sub = fpga_substrate(netlist, lib, tc)
    solver = cached_solver(sub, MinEnergy(), delta_t, max(int(max_iters), 1))
    sol = solver.solve({"t_amb": t_amb, "act": act_in})

    vc, vb = sub.decode(sol.idx)
    # legacy semantics: delay re-evaluated at the converged temperatures,
    # power from the last search (the refine loop's final iteration)
    d_opt = float(sol.d_final[0])
    power = float(sol.power[0])
    energy = power * d_opt

    d_worst = sub.d_worst
    base_p, _ = baseline_power(netlist, t_amb, act_in, tc, lib)
    base_e = base_p * d_worst
    wall = time.time() - t0

    return EnergyResult(
        v_core=float(vc[0]), v_bram=float(vb[0]),
        d_opt_ns=d_opt, d_worst_ns=d_worst, power_mw=power, energy=energy,
        baseline_energy=base_e,
        saving=1.0 - _safe_div(energy, base_e, default=1.0),
        freq_ratio=_safe_div(d_worst, d_opt),
        # the batched solver sweeps the whole grid each iteration: report
        # fixed-point iterations where the legacy flow reported pair counts
        n_refined=int(sol.n_iters), n_pruned=0,
        wall_full_est_s=wall, wall_s=wall,
    )
