"""Algorithm 2 — Thermal-Aware Energy Optimization.

For every (V_core, V_bram) pair, find the *maximum frequency* the thermal
steady state allows (inner fixed point: d_max = T(netlist, T_grid, V) feeds
power feeds temperature), then pick the pair minimizing
E = P_total x d_max (power-delay product). §III-C proves running at max
frequency is energy-optimal for a fixed voltage (leakage energy scales with
time; dynamic energy does not).

Speed-ups from the paper (two orders of magnitude, 72 min -> 49 s):
  1. prune any pair whose *initial-loop* energy (T = T_amb grid, before the
     temperature feedback raises it) already exceeds the best refined energy —
     the feedback only increases E, so the initial pass is a lower bound;
  2. reuse the thermal solution of a previously-evaluated pair whose total
     power is within 0.1/theta_JA (temperatures match to ~0.1 degC).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import netlist as NL
from repro.core import thermal
from repro.core.netlist import Netlist
from repro.core.voltage_scaling import (T_GUARD, V_BRAM_GRID, V_CORE_GRID,
                                        baseline_power)


@dataclass
class EnergyResult:
    v_core: float
    v_bram: float
    d_opt_ns: float  # chosen clock period
    d_worst_ns: float  # baseline clock period
    power_mw: float
    energy: float  # P x d  [mW ns]
    baseline_energy: float
    saving: float
    freq_ratio: float  # f_opt / f_base = d_worst / d_opt
    n_refined: int = 0
    n_pruned: int = 0
    wall_full_est_s: float = 0.0
    wall_s: float = 0.0


def _initial_pass(lib, nlj, t_amb, act_in, vc_flat, vb_flat):
    """Energy lower bound for all pairs at T = T_amb (vectorized)."""

    def eval_pair(vc, vb):
        T = jnp.full(nlj["tile_act"].shape, t_amb)
        d = NL.crit_delay(lib, nlj, T + T_GUARD, vc, vb)
        f_ghz = 1.0 / d
        lkg, dyn = NL.tile_power(lib, nlj, T, vc, vb, f_ghz, act_in)
        p = jnp.sum(lkg) + jnp.sum(dyn)
        return d, p, p * d

    return jax.vmap(eval_pair)(vc_flat, vb_flat)


def _refine(lib, nlj, m, n, t_amb, act_in, vc, vb, tc,
            delta_t=0.1, max_iters=8, thermal_cache=None):
    """Inner fixed point for one pair; returns (d_max, P, E, iters)."""
    n_tiles = m * n
    T = jnp.full((n_tiles,), t_amb)
    d = p = None
    for it in range(max_iters):
        d = NL.crit_delay(lib, nlj, T + T_GUARD, vc, vb)
        f_ghz = 1.0 / d
        lkg, dyn = NL.tile_power(lib, nlj, T, vc, vb, f_ghz, act_in)
        p = float(jnp.sum(lkg) + jnp.sum(dyn))
        # thermal-solution reuse (paper speed-up #2)
        if thermal_cache is not None:
            tol_mw = 0.1 / tc.theta_ja * 1000.0
            hit = next((Tc for pc, Tc in thermal_cache
                        if abs(pc - p) < tol_mw), None)
            if hit is not None:
                T_new = hit
            else:
                T_new = thermal.solve(lkg + dyn, m, n, t_amb, tc)
                thermal_cache.append((p, T_new))
        else:
            T_new = thermal.solve(lkg + dyn, m, n, t_amb, tc)
        if float(jnp.max(jnp.abs(T_new - T))) < delta_t:
            T = T_new
            break
        T = T_new
    d = float(NL.crit_delay(lib, nlj, T + T_GUARD, vc, vb))
    return d, p, p * d, it + 1


def run(netlist: Netlist, t_amb: float, act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(),
        lib: Optional[C.DeviceLibrary] = None,
        use_pruning: bool = True) -> EnergyResult:
    lib = lib or C.default_library()
    nlj = netlist.as_jax()
    n_tiles = netlist.n_tiles
    t0 = time.time()

    vc = jnp.asarray(V_CORE_GRID, jnp.float32)
    vb = jnp.asarray(V_BRAM_GRID, jnp.float32)
    VC, VB = jnp.meshgrid(vc, vb, indexing="ij")
    vc_flat, vb_flat = VC.reshape(-1), VB.reshape(-1)

    d0, p0, e0 = _initial_pass(lib, nlj, t_amb, act_in, vc_flat, vb_flat)
    order = np.argsort(np.asarray(e0))

    best = EnergyResult(0, 0, 0, 0, 0, np.inf, 0, 0, 0)
    thermal_cache: List[Tuple[float, jnp.ndarray]] = []
    n_refined = n_pruned = 0
    t_refine_total = 0.0

    for idx in order:
        if use_pruning and float(e0[idx]) >= best.energy:
            n_pruned = len(order) - n_refined
            break  # sorted: all remaining pairs are pruned too
        t_r = time.time()
        d, p, e, _ = _refine(lib, nlj, netlist.m, netlist.n, t_amb, act_in,
                             float(vc_flat[idx]), float(vb_flat[idx]), tc,
                             thermal_cache=thermal_cache if use_pruning else None)
        t_refine_total += time.time() - t_r
        n_refined += 1
        if e < best.energy:
            best = EnergyResult(
                v_core=float(vc_flat[idx]), v_bram=float(vb_flat[idx]),
                d_opt_ns=d, d_worst_ns=0.0, power_mw=p, energy=e,
                baseline_energy=0.0, saving=0.0, freq_ratio=0.0)

    # baseline energy: nominal voltages at the worst-case clock
    d_worst = float(NL.crit_delay(
        lib, nlj, jnp.full((n_tiles,), C.T_MAX), C.V_CORE_NOM, C.V_BRAM_NOM))
    base_p, _ = baseline_power(netlist, t_amb, act_in, tc, lib)
    base_e = base_p * d_worst

    best.d_worst_ns = d_worst
    best.baseline_energy = base_e
    best.saving = 1.0 - best.energy / base_e
    best.freq_ratio = d_worst / best.d_opt_ns
    best.n_refined = n_refined
    best.n_pruned = n_pruned
    best.wall_s = time.time() - t0
    # estimated un-pruned runtime: every pair pays the average refine cost
    avg = t_refine_total / max(n_refined, 1)
    best.wall_full_est_s = avg * len(order)
    return best
