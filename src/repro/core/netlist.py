"""Abstract placed-and-routed design model (the VPR output analogue).

A :class:`Netlist` is the object Algorithm 1/2 operate on:

- an (m × n) tile grid with per-tile resource counts (LUT/SB/CB/LOCAL/FF per
  CLB tile; BRAM and DSP columns like commercial devices),
- per-tile activity (derived from primary-input activity via the Fig. 3
  internal-activity model),
- a set of timing paths, each a padded sequence of (resource class, tile id)
  elements — timing analysis under arbitrary (T-grid, V_core, V_bram) is a
  vectorized gather + sum over the characterized library.

Designs are generated deterministically (seeded) from published-benchmark
statistics (see vtr_benchmarks.py): utilization, BRAM/DSP usage, critical-path
composition (routing- vs logic- vs memory-bound), base frequency.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C

# per-CLB-tile effective element counts (COFFE-like tile composition)
TILE_LUT = 10
TILE_SB = 30
TILE_CB = 15
TILE_LOCAL = 25
TILE_FF = 10


@dataclass
class Netlist:
    name: str
    m: int  # rows
    n: int  # cols
    # per-tile resource counts, shape (m*n, N_RESOURCES): *used* resources
    used: np.ndarray
    # per-tile total (used + unused leak too), shape (m*n, N_RESOURCES)
    total: np.ndarray
    # per-tile activity scale in [0,1] (multiplies the design-level activity)
    tile_act: np.ndarray
    # timing paths: (P, L) int arrays; res_class = -1 marks padding
    path_res: np.ndarray
    path_tile: np.ndarray
    f_base_mhz: float  # paper-reported base frequency at worst case
    n_luts: int = 0
    n_brams: int = 0
    n_dsps: int = 0
    delay_scale: float = 1.0  # calibrates CP delay to 1000/f_base_mhz

    @property
    def n_tiles(self) -> int:
        return self.m * self.n

    def as_jax(self) -> Dict[str, jnp.ndarray]:
        return {
            "used": jnp.asarray(self.used, jnp.float32),
            "total": jnp.asarray(self.total, jnp.float32),
            "tile_act": jnp.asarray(self.tile_act, jnp.float32),
            "path_res": jnp.asarray(self.path_res, jnp.int32),
            "path_tile": jnp.asarray(self.path_tile, jnp.int32),
            "delay_scale": jnp.asarray(self.delay_scale, jnp.float32),
        }


# =============================================================================
# timing & power (vectorized; the T / P_lkg / P_dyn of Algorithm 1)
# =============================================================================

def path_delays(lib: C.DeviceLibrary, nl: Dict[str, jnp.ndarray],
                T_tiles, v_core, v_bram):
    """Delay of every path [ns]. T_tiles: (m*n,), voltages scalar (or batched
    via vmap). Padding elements (res=-1) contribute 0."""
    res = nl["path_res"]  # (P, L)
    tile = nl["path_tile"]
    valid = res >= 0
    res_c = jnp.maximum(res, 0)
    T_elem = T_tiles[tile]  # (P, L)
    V_elem = jnp.where(res_c == C.BRAM, v_bram, v_core)
    d = lib.delay(res_c, V_elem, T_elem)
    scale = nl.get("delay_scale", jnp.asarray(1.0, jnp.float32))
    return scale * jnp.sum(jnp.where(valid, d, 0.0), axis=-1)


def crit_delay(lib, nl, T_tiles, v_core, v_bram):
    return jnp.max(path_delays(lib, nl, T_tiles, v_core, v_bram))


def tile_power(lib: C.DeviceLibrary, nl: Dict[str, jnp.ndarray],
               T_tiles, v_core, v_bram, f_ghz, act_in):
    """(P_lkg, P_dyn) per tile [mW]. Leakage counts *all* resources (used and
    unused); dynamic counts used resources at the internal activity level."""
    res_ids = jnp.arange(C.N_RESOURCES)
    V_res = jnp.where(res_ids == C.BRAM, v_bram, v_core)  # (R,)
    act_int = C.internal_activity(act_in)
    # leakage: total counts x per-element leakage(T_tile)
    lkg_e = lib.leakage(res_ids[None, :], V_res[None, :],
                        T_tiles[:, None])  # (tiles, R)
    p_lkg = jnp.sum(nl["total"] * lkg_e, axis=-1)
    # dynamic: used counts x toggle power; DSP has its own activity curve
    act_res = jnp.full((C.N_RESOURCES,), act_int)
    act_res = act_res.at[C.DSP].set(C.dsp_activity_factor(act_in))
    act_res = act_res.at[C.BRAM].set(act_int)
    dyn_e = lib.dynamic(res_ids[None, :], V_res[None, :], f_ghz,
                        act_res[None, :])  # (tiles, R)
    p_dyn = jnp.sum(nl["used"] * dyn_e, axis=-1) * nl["tile_act"]
    return p_lkg, p_dyn


def total_power(lib, nl, T_tiles, v_core, v_bram, f_ghz, act_in):
    lkg, dyn = tile_power(lib, nl, T_tiles, v_core, v_bram, f_ghz, act_in)
    return jnp.sum(lkg) + jnp.sum(dyn)


# =============================================================================
# synthetic design generation from benchmark statistics
# =============================================================================

@dataclass(frozen=True)
class BenchStats:
    """Published-shape statistics for one benchmark (see vtr_benchmarks.py)."""
    name: str
    n_luts: int
    n_brams: int
    n_dsps: int
    f_mhz: float  # VPR frequency at worst case
    cp_profile: str  # 'routing' | 'logic' | 'mixed' | 'memory'
    grid: Optional[Tuple[int, int]] = None
    bram_path_ratio: float = 0.6  # longest-BRAM-path delay / CP delay
    n_paths: int = 256


def _cp_composition(profile: str, rng) -> Dict[int, int]:
    """Element counts of a near-critical path for a given profile."""
    if profile == "routing":
        base = {C.LUT: 6, C.SB: 14, C.CB: 6, C.LOCAL: 5, C.FF: 2}
    elif profile == "logic":
        base = {C.LUT: 12, C.SB: 6, C.CB: 5, C.LOCAL: 8, C.FF: 2}
    elif profile == "memory":
        base = {C.LUT: 5, C.SB: 8, C.CB: 4, C.LOCAL: 4, C.FF: 2}
    else:  # mixed
        base = {C.LUT: 9, C.SB: 10, C.CB: 5, C.LOCAL: 6, C.FF: 2}
    return base


def generate(stats: BenchStats, seed: int = 0) -> Netlist:
    # zlib.crc32, not hash(): PYTHONHASHSEED must not change the benchmarks
    import zlib
    rng = np.random.default_rng(seed + zlib.crc32(stats.name.encode()))
    # --- grid size: CLB tiles to hold the LUTs at ~60% packing + BRAM/DSP cols
    if stats.grid:
        m, n = stats.grid
    else:
        n_clb = int(stats.n_luts / (TILE_LUT * 0.6))
        side = int(np.ceil(np.sqrt(n_clb * 1.3)))
        m = n = max(side, 8)
    n_tiles = m * n

    # --- column types: every 8th column BRAM, every 12th DSP (Stratix-like)
    col_type = np.zeros(n, dtype=int)  # 0 CLB, 1 BRAM, 2 DSP
    col_type[4::8] = 1
    col_type[7::12] = 2

    total = np.zeros((n_tiles, C.N_RESOURCES), np.float32)
    used = np.zeros((n_tiles, C.N_RESOURCES), np.float32)
    tile_act = np.zeros(n_tiles, np.float32)

    tile_idx = np.arange(n_tiles).reshape(m, n)
    clb_tiles = tile_idx[:, col_type == 0].ravel()
    bram_tiles = tile_idx[:, col_type == 1].ravel()[::6]  # BRAM height 6 tiles
    dsp_tiles = tile_idx[:, col_type == 2].ravel()[::4]  # DSP height 4 tiles

    # capacity
    total[clb_tiles, C.LUT] = TILE_LUT
    total[clb_tiles, C.SB] = TILE_SB
    total[clb_tiles, C.CB] = TILE_CB
    total[clb_tiles, C.LOCAL] = TILE_LOCAL
    total[clb_tiles, C.FF] = TILE_FF
    total[bram_tiles, C.BRAM] = 1
    total[bram_tiles, C.SB] = TILE_SB  # routing exists in hard columns too
    total[dsp_tiles, C.DSP] = 1
    total[dsp_tiles, C.SB] = TILE_SB

    # placement: used resources clustered in a centered region (VPR-like)
    n_clb_used = min(int(np.ceil(stats.n_luts / TILE_LUT)), len(clb_tiles))
    center = np.array([m / 2, n / 2])
    coords = np.stack(np.unravel_index(clb_tiles, (m, n)), 1)
    order = np.argsort(((coords - center) ** 2).sum(1)
                       + rng.uniform(0, m, len(clb_tiles)))
    place = clb_tiles[order[:n_clb_used]]
    used[place, C.LUT] = TILE_LUT
    used[place, C.SB] = TILE_SB * 0.7
    used[place, C.CB] = TILE_CB * 0.7
    used[place, C.LOCAL] = TILE_LOCAL * 0.6
    used[place, C.FF] = TILE_FF * 0.8
    ub = bram_tiles[:min(stats.n_brams, len(bram_tiles))]
    used[ub, C.BRAM] = 1
    ud = dsp_tiles[:min(stats.n_dsps, len(dsp_tiles))]
    used[ud, C.DSP] = 1
    tile_act[place] = rng.uniform(0.6, 1.0, len(place))
    tile_act[ub] = rng.uniform(0.7, 1.0, len(ub))
    tile_act[ud] = rng.uniform(0.7, 1.0, len(ud))

    # --- paths: near-critical population + BRAM/DSP paths
    comp = _cp_composition(stats.cp_profile, rng)
    L = sum(comp.values()) + 2
    P = stats.n_paths
    path_res = -np.ones((P, L), np.int64)
    path_tile = np.zeros((P, L), np.int64)

    def fill_path(i, elems, tiles_pool, length_scale):
        seq = []
        for r, cnt in elems.items():
            seq += [r] * max(int(round(cnt * length_scale)), 1)
        rng.shuffle(seq)
        seq = seq[:L]
        path_res[i, :len(seq)] = seq
        # a path traverses a contiguous neighborhood of tiles
        start = tiles_pool[rng.integers(len(tiles_pool))]
        si, sj = np.unravel_index(start, (m, n))
        for e in range(len(seq)):
            di, dj = rng.integers(-2, 3), rng.integers(-2, 3)
            ti = np.clip(si + di + e // 3, 0, m - 1)
            tj = np.clip(sj + dj, 0, n - 1)
            path_tile[i, e] = ti * n + tj

    n_bram_paths = max(P // 8, 4) if stats.n_brams else 0
    n_dsp_paths = max(P // 16, 2) if stats.n_dsps else 0
    for i in range(P):
        if i < n_bram_paths:
            elems = dict(_cp_composition("memory", rng))
            elems[C.BRAM] = 1
            scale = stats.bram_path_ratio * rng.uniform(0.85, 1.0)
            pool = ub if len(ub) else place
        elif i < n_bram_paths + n_dsp_paths:
            elems = dict(_cp_composition("mixed", rng))
            elems[C.DSP] = 1
            scale = rng.uniform(0.5, 0.8)
            pool = ud if len(ud) else place
        else:
            elems = comp
            # near-critical population: top path at 1.0, tail down to 0.7
            scale = 1.0 if i == P - 1 else rng.uniform(0.7, 1.0)
            pool = place
        fill_path(i, elems, pool, scale)

    # --- enforce path-delay structure at worst case -------------------------
    # hard-block paths must sit at their published ratio of the soft CP
    # (e.g. LU8PEEng's longest BRAM path is CP/21); trim soft elements of
    # hard paths until they fit, using worst-case element delays.
    lib = C.default_library()
    res_ids = np.arange(C.N_RESOURCES)
    v_elem = np.where(res_ids == C.BRAM, C.V_BRAM_NOM, C.V_CORE_NOM)
    d_elem = np.asarray(lib.delay(jnp.asarray(res_ids),
                                  jnp.asarray(v_elem, np.float32),
                                  jnp.asarray(C.T_MAX)))

    def wc_delay(i):
        r = path_res[i]
        return d_elem[np.maximum(r, 0)][r >= 0].sum()

    soft = [i for i in range(P)
            if not np.any(np.isin(path_res[i], (C.BRAM, C.DSP)))]
    d_cp = max(wc_delay(i) for i in soft)
    for i in range(P):
        r = path_res[i]
        if np.any(r == C.BRAM):
            target = d_cp * stats.bram_path_ratio * rng.uniform(0.9, 1.0)
        elif np.any(r == C.DSP):
            target = d_cp * rng.uniform(0.5, 0.8)
        else:
            continue
        # drop soft elements (keep hard block) until within target
        order = [e for e in range(L)
                 if r[e] >= 0 and r[e] not in (C.BRAM, C.DSP)]
        rng.shuffle(order)
        for e in order:
            if wc_delay(i) <= target:
                break
            path_res[i, e] = -1

    nl = Netlist(
        name=stats.name, m=m, n=n, used=used, total=total, tile_act=tile_act,
        path_res=path_res, path_tile=path_tile, f_base_mhz=stats.f_mhz,
        n_luts=stats.n_luts, n_brams=stats.n_brams, n_dsps=stats.n_dsps,
    )
    # calibrate absolute delay so worst-case CP matches the published f_max
    d_raw = float(crit_delay(lib, nl.as_jax(),
                             jnp.full((n_tiles,), C.T_MAX), C.V_CORE_NOM,
                             C.V_BRAM_NOM))
    nl.delay_scale = (1000.0 / stats.f_mhz) / d_raw
    return nl
