"""Characterized (temperature, voltage) -> (delay, power) resource library.

The paper obtains these curves from HSPICE sweeps over COFFE-generated
netlists (22 nm PTM). With no SPICE in this environment, we use standard
alpha-power-law / exponential-leakage device models whose per-resource
constants are CALIBRATED to the paper's published behaviour:

- Fig 2(a): switch-box delay at (0.8 V, 40 °C) = 0.85x its (0.8 V, 100 °C)
  value; resources differ in temperature sensitivity.
- Fig 2(b): V_core = 0.68 V uses up exactly that 40 °C margin for SB paths
  (delay back to the 100 °C worst case); LUT delay rises faster at low V
  (pass-gate structure), BRAM fastest (its rail starts at 0.95 V).
- Fig 2(c): the 120 mV scaling cuts SB power by ~32 %; non-memory resources
  follow ~V^2; BRAM power falls faster with V.
- Leakage ~ e^{0.015 T} (paper: measured e^{0.015T}, Intel e^{0.017T}).
- Fig 3: internal-node activity = 0.27 * alpha_in^0.732 (0.1 -> 0.05,
  1.0 -> 0.27); DSP dynamic power saturates over alpha in [0.3, 0.7] and
  declines thereafter (input toggles cancel).

The library is a first-class data object exactly as in the paper's flow —
`DeviceLibrary` can be re-parameterized (e.g. for the TPU resource classes in
core/tpu_fleet.py) without touching the algorithms.

All functions are jnp-traceable and vectorize over voltage grids and tiles.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Resource class ids (order matters: arrays below are indexed by these)
LUT, SB, CB, LOCAL, FF, BRAM, DSP = range(7)
RESOURCE_NAMES = ["LUT", "SB", "CB", "LOCAL", "FF", "BRAM", "DSP"]
N_RESOURCES = 7

T_MAX = 100.0  # junction upper bound [degC] (paper footnote 2)
V_CORE_NOM = 0.80
V_BRAM_NOM = 0.95
V_MIN = 0.55  # BRAM crash floor from [19]; also core sweep floor
KELVIN = 273.15


@dataclass(frozen=True)
class DeviceLibrary:
    """Per-resource device constants (len-7 arrays, indexed by class id)."""

    # delay model: d = d0 * (V/Vnom_class) / (mu(T) * (V - Vth(T))^alpha)
    d0: Tuple[float, ...]  # base delay [ns] at (Vnom, 100C), per *element*
    vth0: Tuple[float, ...]  # threshold at T_MAX [V]
    alpha: Tuple[float, ...]  # velocity-saturation exponent
    mu_exp: Tuple[float, ...]  # mobility temperature exponent m
    vth_kappa: float = 0.0008  # dVth/dT [V/degC] (Vth rises as T drops)
    # power model
    p_dyn0: Tuple[float, ...] = ()  # dynamic energy/toggle [mW/MHz-ish units]
    p_lkg0: Tuple[float, ...] = ()  # leakage at (Vnom, 25C) [mW]
    lkg_T: float = 0.015  # e^{0.015 T} (paper)
    lkg_eta: Tuple[float, ...] = ()  # leakage-voltage exponent e^{eta (V-Vnom)}
    dyn_vexp: Tuple[float, ...] = ()  # dynamic power voltage exponent (~2)
    v_nom: Tuple[float, ...] = ()  # nominal rail per resource

    def _arr(self, name):
        return jnp.asarray(getattr(self, name), jnp.float32)

    # --- delay ---------------------------------------------------------------
    def delay(self, res, V, T):
        """Element delay [ns]. res: int array of class ids; V, T broadcast."""
        d0 = self._arr("d0")[res]
        vth0 = self._arr("vth0")[res]
        alpha = self._arr("alpha")[res]
        m = self._arr("mu_exp")[res]
        vnom = self._arr("v_nom")[res]
        vth = vth0 + self.vth_kappa * (T_MAX - T)  # Vth rises as T drops
        mu = jnp.power((T + KELVIN) / (T_MAX + KELVIN), -m)  # mobility vs T
        vov = jnp.maximum(V - vth, 0.02)
        d_nom = (vnom / 1.0) / jnp.power(vnom - vth0, alpha)  # at (vnom, Tmax)
        d = (V / 1.0) / (mu * jnp.power(vov, alpha))
        return d0 * d / d_nom

    # --- power ----------------------------------------------------------------
    def leakage(self, res, V, T):
        """Static power [mW] per element."""
        p0 = self._arr("p_lkg0")[res]
        eta = self._arr("lkg_eta")[res]
        vnom = self._arr("v_nom")[res]
        return (p0 * jnp.exp(self.lkg_T * (T - 25.0))
                * (V / vnom) * jnp.exp(eta * (V - vnom)))

    def dynamic(self, res, V, f_ghz, act):
        """Dynamic power [mW] per element at toggle activity ``act``."""
        p0 = self._arr("p_dyn0")[res]
        k = self._arr("dyn_vexp")[res]
        vnom = self._arr("v_nom")[res]
        base = p0 * act * f_ghz * jnp.power(V / vnom, k)
        return base

    def rail(self, res):
        """1.0 where the resource sits on the BRAM rail, else 0.0."""
        return (jnp.asarray(res) == BRAM).astype(jnp.float32)


# --- activity models (Fig. 3) --------------------------------------------------

def internal_activity(alpha_in):
    """Average internal-node activity for primary-input activity alpha_in."""
    return 0.27 * jnp.power(jnp.asarray(alpha_in, jnp.float32), 0.732)


def dsp_activity_factor(alpha_in):
    """DSP dynamic-power multiplier vs input activity (saturating bump)."""
    a = jnp.asarray(alpha_in, jnp.float32)
    rise = jnp.clip(a / 0.3, 0.0, 1.0)  # +37% up to alpha=0.3
    decline = jnp.clip((a - 0.7) / 0.3, 0.0, 1.0) * 0.07  # mild drop after 0.7
    return (1.0 + 0.37 * rise - decline) / 1.37  # normalized to peak 1.0


# --- the calibrated 22nm-PTM-like library ---------------------------------------

def default_library() -> DeviceLibrary:
    """Constants calibrated against the paper's Fig. 2 / leakage facts."""
    return DeviceLibrary(
        #      LUT    SB     CB     LOCAL  FF     BRAM   DSP
        # (vth0, alpha, mu_exp) are two-anchor fits per resource:
        #   V-anchor (Fig 2b @40C): LUT 1.42x @0.68V, SB 1.179x (=1/0.848 so
        #   the 40C margin is exactly consumed), BRAM 1.33x @0.83V, ...
        #   deep-V anchor (Fig 7's 2.7x mean delay stretch at V_opt~0.58-0.62)
        #   T-anchor (Fig 2a @ nominal V): SB 0.85x @40C, LUT 0.88x, ...
        d0=(0.180, 0.220, 0.190, 0.090, 0.065, 1.100, 2.300),
        vth0=(0.467, 0.500, 0.495, 0.495, 0.495, 0.620, 0.495),
        alpha=(0.939, 0.506, 0.600, 0.638, 0.600, 0.758, 0.626),
        mu_exp=(1.563, 1.430, 1.447, 1.418, 1.381, 1.155, 1.406),
        # dynamic energy coefficients [mW per GHz at activity 1.0]
        p_dyn0=(0.100, 0.154, 0.072, 0.033, 0.038, 30.0, 22.4),
        # leakage [mW per element at (Vnom, 25C)]; BRAM/DSP are whole blocks
        p_lkg0=(0.0010, 0.00066, 0.00044, 0.00022, 0.00011, 0.055, 0.33),
        lkg_eta=(7.0, 7.0, 7.0, 7.0, 7.0, 9.0, 7.0),
        dyn_vexp=(2.0, 2.0, 2.0, 2.0, 2.0, 2.6, 2.1),
        v_nom=(V_CORE_NOM,) * 5 + (V_BRAM_NOM, V_CORE_NOM),
    )
