"""TPU-fleet power/thermal model — the paper's library, re-parameterized.

DESIGN.md §2: the characterized-library + thermal-fixed-point machinery is
device-agnostic; here the "resource classes" are TPU blocks and the "tiles"
are chips of a 16x16 pod. Per-chip rails mirror the paper's V_core / V_bram
split: ``v_core`` (MXU + vector) and ``v_sram`` (VMEM + HBM PHY) — SRAM keeps
the higher rail and the steeper delay/voltage curve, exactly the BRAM role.

Numbers are v5e-flavored: 197 bf16 TFLOP/s @ ~940 MHz, ~200 W busy chip,
air-cooled theta ~0.25 degC/W per chip, junction limit 95 degC.

The *step-time contract* plays the d_worst role: a training/serving step is
rated at worst-case junction temperature; actual temperatures leave margin
that voltage scaling converts to power (policy 'power_save') or that
frequency scaling converts to minimum energy (policy 'min_energy').
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thermal

# resource classes
MXU, VPU, SRAM, HBMIO, ICI = range(5)
CLASS_NAMES = ["MXU", "VPU", "SRAM", "HBMIO", "ICI"]

T_MAX_CHIP = 95.0  # junction limit
V_CORE_NOM = 0.75
V_SRAM_NOM = 0.85
F_NOM_GHZ = 0.94
KELVIN = 273.15


@dataclass(frozen=True)
class TpuLibrary:
    """Alpha-power delay + exponential leakage per class (paper-style fits)."""
    vth0: Tuple[float, ...] = (0.42, 0.42, 0.52, 0.40, 0.40)
    alpha: Tuple[float, ...] = (0.95, 0.95, 0.80, 1.00, 1.00)
    mu_exp: Tuple[float, ...] = (1.40, 1.40, 1.10, 1.20, 1.20)
    vth_kappa: float = 0.0008
    # busy power at nominal (V, f_nom), per chip [W]
    p_busy: Tuple[float, ...] = (90.0, 20.0, 25.0, 35.0, 15.0)
    # leakage at 25C, nominal V [W]
    p_lkg0: Tuple[float, ...] = (18.0, 5.0, 12.0, 6.0, 4.0)
    lkg_T: float = 0.015
    lkg_eta: float = 7.0
    dyn_vexp: float = 2.0
    v_nom: Tuple[float, ...] = (V_CORE_NOM, V_CORE_NOM, V_SRAM_NOM,
                                V_SRAM_NOM, V_CORE_NOM)

    def _a(self, name):
        return jnp.asarray(getattr(self, name), jnp.float32)

    def delay_factor(self, cls, V, T):
        """d(V,T)/d(Vnom,Tmax) for class cls (scalar or arrays)."""
        vth0 = self._a("vth0")[cls]
        a = self._a("alpha")[cls]
        m = self._a("mu_exp")[cls]
        vn = self._a("v_nom")[cls]
        vth = vth0 + self.vth_kappa * (T_MAX_CHIP - T)
        mu = jnp.power((T + KELVIN) / (T_MAX_CHIP + KELVIN), -m)
        vov = jnp.maximum(V - vth, 0.02)
        d = (V / vn) * jnp.power((vn - vth0) / vov, a) / mu
        return d

    def leakage(self, cls, V, T):
        vn = self._a("v_nom")[cls]
        p0 = self._a("p_lkg0")[cls]
        return (p0 * jnp.exp(self.lkg_T * (T - 25.0)) * (V / vn)
                * jnp.exp(self.lkg_eta * (V - vn)))

    def dynamic(self, cls, V, f_rel, util):
        vn = self._a("v_nom")[cls]
        p0 = self._a("p_busy")[cls]
        return p0 * util * f_rel * jnp.power(V / vn, self.dyn_vexp)


@dataclass(frozen=True)
class StepProfile:
    """Per-step utilizations, derived from the dry-run roofline terms:
    u_class = (class roofline term) / (step time)."""
    u_mxu: float
    u_vpu: float
    u_sram: float
    u_hbm: float
    u_ici: float
    step_s: float  # rated (worst-case) step time = the contract
    # fraction of the step that scales with core clock (compute-bound part)
    f_scalable: float = 0.6

    @classmethod
    def from_roofline(cls, compute_s: float, memory_s: float,
                      collective_s: float, step_s: Optional[float] = None):
        step = step_s or max(compute_s + collective_s * 0.3, memory_s,
                             collective_s)
        return cls(
            u_mxu=min(compute_s / step, 1.0),
            u_vpu=min(0.3 * compute_s / step, 1.0),
            u_sram=min(compute_s / step, 1.0),
            u_hbm=min(memory_s / step, 1.0),
            u_ici=min(collective_s / step, 1.0),
            step_s=step,
            f_scalable=min(compute_s / step, 1.0),
        )


def chip_power(lib: TpuLibrary, prof: StepProfile, v_core, v_sram, f_rel, T):
    """Total chip power [W]; broadcasts over chip arrays."""
    V = [v_core, v_core, v_sram, v_sram, v_core]
    utils = [prof.u_mxu, prof.u_vpu, prof.u_sram, prof.u_hbm, prof.u_ici]
    # memory/ici utilization rises as the compute part slows (fixed work)
    total = 0.0
    for c in range(5):
        fr = f_rel if c in (MXU, VPU, SRAM) else 1.0
        total = total + lib.dynamic(c, V[c], fr * utils[c], 1.0) \
            + lib.leakage(c, V[c], T)
    return total


def f_max_rel(lib: TpuLibrary, v_core, v_sram, T):
    """Max relative clock so every class meets its pipeline timing."""
    d = jnp.stack([
        lib.delay_factor(np.int32(MXU), v_core, T),
        lib.delay_factor(np.int32(VPU), v_core, T),
        lib.delay_factor(np.int32(SRAM), v_sram, T),
    ])
    return 1.0 / jnp.max(d, axis=0)


def step_time(prof: StepProfile, f_rel):
    """Step time when the core clock runs at f_rel x nominal."""
    scal = prof.f_scalable
    return prof.step_s * (scal / f_rel + (1.0 - scal))


def pod_thermal_config(theta_chip: float = 0.25, n_chips: int = 256):
    return thermal.ThermalConfig(theta_ja=theta_chip / n_chips, spreading=2.0,
                                 tol=1e-4, max_iters=20_000)
