"""Steady-state RC thermal grid solver (the HotSpot-6.0 analogue).

The die is the netlist's (m × n) tile grid. Each tile couples laterally to its
4 neighbours (silicon spreading conductance) and vertically to ambient through
the package (convective conductance). Steady state solves

    (G_v + sum_nbr G_lat) T_ij - G_lat * sum_nbr T_nbr = P_ij + G_v * T_amb

Calibration follows the paper: the convective resistance is tuned so a total
power of 1 W raises the (mean) junction temperature by theta_JA — 2 degC/W for
high-end packages (Virtex-7/Stratix-V class), 12 degC/W for mid-size devices
with still air (Spartan/Artix class).

Solver tiers (``ThermalConfig.solver``; DESIGN.md "Thermal solver
hierarchy"):

- ``"multigrid"`` (default) — geometric V-cycles on the 5-point conductance
  stencil: red-black Gauss-Seidel smoothing, full-weighting (block-sum)
  restriction of the extensive residual, bilinear prolongation of the
  coarse correction, and a dense direct solve (precomputed inverse) once
  the level fits ``coarse_cells`` (grids that small — e.g. the 16x16 pod —
  skip iteration entirely: ONE constant-matrix multiply, exact). Cold
  starts descend full-multigrid (coarsest solve prolongated up, one
  V-cycle per level). Convergence is checked ONCE per V-cycle (a cycle is
  ~4*n_smooth fused sweeps), and each cycle contracts the error by ~10x,
  so the loop runs a handful of cycles where Jacobi ran thousands of
  sweeps (its contraction is 1/(1 + 1/(4*spreading)) per sweep — ~0.99 for
  the FPGA packages — with a global reduce after every one).
- ``"jacobi"`` — the seed relaxation, kept as the parity oracle, but with
  *chunked* convergence checks: ``check_every`` fused sweeps between
  |dT|_inf reduces (``check_every=1`` is bit-for-bit the seed loop).

Both tiers accept an explicit ``T0`` warm start (the fixed-point solver
passes the previous iteration's field; the control plane passes the last
converged/applied field) and stop on the same criterion — the per-sweep
(resp. per-cycle) |dT|_inf dropping under ``tol`` — so the steady state is
tier-independent at the configured tolerance.

The smoother dispatches on backend: the fused-K-sweep Pallas kernel
(``kernels/thermal_stencil``, red-black phase) on TPU, pure jnp elsewhere
(``ThermalConfig.backend`` overrides). Everything traces under jit and vmap:
level shapes are static, the per-level diagonals and the coarse-grid inverse
are numpy constants baked in at trace time.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ThermalConfig:
    theta_ja: float = 2.0  # degC/W effective junction-to-ambient resistance
    spreading: float = 25.0  # lateral/vertical conductance ratio (die spread)
    tol: float = 5e-5  # convergence |dT|_inf per sweep/cycle [degC]
    max_iters: int = 50_000  # sweep budget (jacobi tier)
    solver: str = "multigrid"  # "multigrid" | "jacobi"
    backend: str = "auto"  # smoother: "auto" (pallas on TPU) | "pallas" | "jnp"
    n_smooth: int = 1  # RB-GS pre- and post-smoothing sweeps per V-cycle
    coarse_cells: int = 512  # direct-solve at <= this many cells: the whole
    # 16x16 pod (and the coarse tail of every V-cycle) is ONE precomputed
    # A^-1 matmul — exact, while-loop-free, vmap-friendly
    max_cycles: int = 200  # V-cycle budget (multigrid tier)
    check_every: int = 32  # fused sweeps between reduces (jacobi tier)


def conductances(m: int, n: int, tc: ThermalConfig) -> Tuple[float, float]:
    """(G_v per tile [W/degC], G_lat between neighbours)."""
    g_v = 1.0 / (tc.theta_ja * m * n)
    g_lat = g_v * tc.spreading
    return g_v, g_lat


def _nbr_sum(T):
    up = jnp.pad(T[1:, :], ((0, 1), (0, 0)))
    dn = jnp.pad(T[:-1, :], ((1, 0), (0, 0)))
    lf = jnp.pad(T[:, 1:], ((0, 0), (0, 1)))
    rt = jnp.pad(T[:, :-1], ((0, 0), (1, 0)))
    return up + dn + lf + rt


def _diag_np(gv_map: np.ndarray, g_lat: float) -> np.ndarray:
    m, n = gv_map.shape
    nbrc = np.full((m, n), 4.0)
    nbrc[0, :] -= 1
    nbrc[-1, :] -= 1
    nbrc[:, 0] -= 1
    nbrc[:, -1] -= 1
    return gv_map + g_lat * nbrc


def _interp_weights_np(mm: int, mc: int) -> np.ndarray:
    """1D cell-centered linear interpolation matrix (mm x mc).

    Coarse cell j covers fine cells [2j, min(2j+1, mm-1)] (the trailing
    slab of an odd dimension covers one); each fine center interpolates
    between the bracketing coarse-span centers, clamped at the edges.
    """
    centers = np.array([(2 * j + min(2 * j + 1, mm - 1) + 1.0) / 2.0
                        for j in range(mc)])
    W = np.zeros((mm, mc))
    for i in range(mm):
        xi = i + 0.5
        j = int(np.searchsorted(centers, xi))
        if j == 0:
            W[i, 0] = 1.0
        elif j >= mc:
            W[i, mc - 1] = 1.0
        else:
            w = (xi - centers[j - 1]) / (centers[j] - centers[j - 1])
            W[i, j - 1], W[i, j] = 1.0 - w, w
    return W


@lru_cache(maxsize=64)
def _plan_levels(m: int, n: int, g_v: float, g_lat: float,
                 coarse_cells: int):
    """Static multigrid hierarchy (numpy constants baked in at trace time):
    per-level dims + stencil diagonal + prolongation matrices, and the dense
    inverse of the coarsest-level operator.

    Rediscretization: a coarse cell aggregates its fine cells' vertical
    conductances (block sum — exact for odd trailing slabs), while the
    lateral conductance between coarse cells stays ``g_lat`` (interface
    doubles, path length doubles). The restricted residual is extensive
    (W per cell), so restriction is the block SUM — full weighting times
    the 2x2 cell area — and every term of the coarse equation scales
    consistently.
    """
    levels = []
    gv = np.full((m, n), g_v, np.float64)
    while True:
        mm, nn = gv.shape
        levels.append([mm, nn, _diag_np(gv, g_lat).astype(np.float32),
                       None, None])
        if mm * nn <= coarse_cells or (mm == 1 and nn == 1):
            break
        mc, nc = (mm + 1) // 2, (nn + 1) // 2
        levels[-1][3] = _interp_weights_np(mm, mc).astype(np.float32)
        levels[-1][4] = _interp_weights_np(nn, nc).astype(np.float32)
        pad = np.zeros((2 * mc, 2 * nc))
        pad[:mm, :nn] = gv
        gv = pad.reshape(mc, 2, nc, 2).sum(axis=(1, 3))

    mm, nn, diag_c = levels[-1][:3]
    A = np.diag(diag_c.reshape(-1).astype(np.float64))
    idx = np.arange(mm * nn).reshape(mm, nn)
    for di, dj in ((1, 0), (0, 1)):
        src = idx[:mm - di, :nn - dj].reshape(-1)
        dst = idx[di:, dj:].reshape(-1)
        A[src, dst] -= g_lat
        A[dst, src] -= g_lat
    A_inv = np.linalg.inv(A).astype(np.float32)
    return tuple(tuple(lv) for lv in levels), A_inv


def _use_pallas(tc: ThermalConfig) -> bool:
    if tc.backend == "auto":
        return jax.default_backend() == "tpu"
    return tc.backend == "pallas"


def _smooth(T, b, diag, g_lat: float, sweeps: int, pallas: bool):
    """``sweeps`` red-black Gauss-Seidel sweeps (red first)."""
    if pallas:
        from repro.kernels.thermal_stencil import thermal_stencil
        return thermal_stencil(T, b, diag, g_lat=g_lat, g_v_tamb=0.0,
                               iters=sweeps, phase=0)
    m, n = T.shape
    row = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
    par = (row + col) % 2
    for _ in range(sweeps):
        for p in (0, 1):
            T = jnp.where(par == p, (b + g_lat * _nbr_sum(T)) / diag, T)
    return T


def _jacobi_sweeps(T, b, diag, g_lat: float, sweeps: int, pallas: bool):
    if pallas:
        from repro.kernels.thermal_stencil import thermal_stencil
        return thermal_stencil(T, b, diag, g_lat=g_lat, g_v_tamb=0.0,
                               iters=sweeps, phase=None)
    return jax.lax.fori_loop(
        0, sweeps, lambda _, t: (b + g_lat * _nbr_sum(t)) / diag, T)


def _restrict(r, mc: int, nc: int):
    """Full-weighting of the extensive residual: 2x2 block sums (zero-padded
    on odd trailing edges, where the coarse cell covers fewer fine cells)."""
    m, n = r.shape
    r = jnp.pad(r, ((0, 2 * mc - m), (0, 2 * nc - n)))
    return r.reshape(mc, 2, nc, 2).sum(axis=(1, 3))


def _solve_multigrid(b, T0, m: int, n: int, g_v: float, g_lat: float,
                     tc: ThermalConfig):
    levels, A_inv = _plan_levels(m, n, g_v, g_lat, int(tc.coarse_cells))
    A_inv = jnp.asarray(A_inv)
    diags = [jnp.asarray(lv[2]) for lv in levels]
    # bilinear prolongation as two small dense matmuls (constant weights)
    Ws = [(jnp.asarray(lv[3]), jnp.asarray(lv[4]))
          for lv in levels if lv[3] is not None]
    pallas = _use_pallas(tc)

    def coarse_solve(bc, mm, nn):
        return (A_inv @ bc.reshape(-1)).reshape(mm, nn)

    def scaled_residual(T):
        """max |r| / diag — exactly the |dT|_inf one Jacobi sweep would
        apply at T, i.e. the seed solver's stopping metric."""
        r = b - (diags[0] * T - g_lat * _nbr_sum(T))
        return jnp.max(jnp.abs(r) / diags[0])

    def vcycle(lvl, T, b_l):
        mm, nn = levels[lvl][:2]
        diag = diags[lvl]
        if lvl == len(levels) - 1:
            return coarse_solve(b_l, mm, nn)
        T = _smooth(T, b_l, diag, g_lat, tc.n_smooth, pallas)
        r = b_l - (diag * T - g_lat * _nbr_sum(T))
        mc, nc = levels[lvl + 1][:2]
        e = vcycle(lvl + 1, jnp.zeros((mc, nc), jnp.float32),
                   _restrict(r, mc, nc))
        Wr, Wc = Ws[lvl]
        T = T + Wr @ e @ Wc.T  # cell-centered bilinear prolongation
        return _smooth(T, b_l, diag, g_lat, tc.n_smooth, pallas)

    if len(levels) == 1:  # the whole grid fits the direct tier: exact solve
        return coarse_solve(b, m, n)

    if T0 is None:
        # full-multigrid cold start: solve the restricted problem on the
        # coarsest level exactly, prolongate up with one V-cycle per level
        # — ~1.3 cycle-equivalents that land near truncation error, where
        # an analytic estimate would cost 2-3 extra fine cycles
        bs = [b]
        for lvl in range(len(levels) - 1):
            mc, nc = levels[lvl + 1][:2]
            bs.append(_restrict(bs[-1], mc, nc))
        T0 = coarse_solve(bs[-1], *levels[-1][:2])
        for lvl in range(len(levels) - 2, -1, -1):
            Wr, Wc = Ws[lvl]
            T0 = vcycle(lvl, Wr @ T0 @ Wc.T, bs[lvl])

    def body(state):
        T, _, s_prev, i = state
        T = vcycle(0, T, b)
        return T, s_prev, scaled_residual(T), i + 1

    def cond(state):
        # stop when converged under tol OR stalled at the f32 residual
        # floor (each cycle contracts the true error ~10x, so a cycle that
        # no longer shrinks the residual has nothing left to converge)
        _, s_prev, s, i = state
        return (s > tc.tol) & (s < 0.9 * s_prev) & (i < tc.max_cycles)

    s0 = scaled_residual(T0)  # 0 cycles for an already-converged warm start
    T, _, _, _ = jax.lax.while_loop(cond, body,
                                    (T0, jnp.float32(jnp.inf), s0, 0))
    return T


def _solve_jacobi(b, T0, m: int, n: int, g_v: float, g_lat: float,
                  tc: ThermalConfig):
    diag = jnp.asarray(_diag_np(np.full((m, n), g_v), g_lat), jnp.float32)
    pallas = _use_pallas(tc)
    K = max(int(tc.check_every), 1)

    def body(state):
        T, _, i = state
        # K-1 fused sweeps, then one measured sweep: the reduce compares
        # consecutive sweeps — the seed criterion at chunk granularity
        T_mid = _jacobi_sweeps(T, b, diag, g_lat, K - 1, pallas)
        T_new = _jacobi_sweeps(T_mid, b, diag, g_lat, 1, pallas)
        return T_new, jnp.max(jnp.abs(T_new - T_mid)), i + K

    def cond(state):
        _, err, i = state
        return (err > tc.tol) & (i < tc.max_iters)

    T, _, _ = jax.lax.while_loop(cond, body, (T0, jnp.inf, 0))
    return T


@partial(jax.jit, static_argnums=(1, 2, 4))
def solve(power_mw, m: int, n: int, t_amb, tc: ThermalConfig = ThermalConfig(),
          T0=None):
    """power_mw: (m*n,) per-tile power in mW -> (m*n,) temperatures [degC].

    ``T0`` (flat (m*n,) or (m,n)) warm-starts the iteration; every caller
    sitting inside a fixed point should pass its previous field. The default
    is the seed's analytic estimate (ambient + half the vertical rise).
    """
    g_v, g_lat = conductances(m, n, tc)
    P = power_mw.reshape(m, n).astype(jnp.float32) * 1e-3  # W
    t_amb = jnp.asarray(t_amb, jnp.float32)
    b = P + g_v * t_amb

    if T0 is not None:
        T0 = jnp.asarray(T0, jnp.float32).reshape(m, n)

    if tc.solver == "multigrid":
        # a cold multigrid start (T0=None) uses the full-multigrid descent
        T = _solve_multigrid(b, T0, m, n, g_v, g_lat, tc)
    elif tc.solver == "jacobi":
        if T0 is None:  # the seed's analytic warm start
            T0 = jnp.full((m, n), t_amb) + P / g_v * 0.5
        T = _solve_jacobi(b, T0, m, n, g_v, g_lat, tc)
    else:
        raise ValueError(f"unknown thermal solver {tc.solver!r}")
    return T.reshape(-1)


def steady_stats(T_tiles, m: int, n: int):
    return {"mean": jnp.mean(T_tiles), "max": jnp.max(T_tiles),
            "min": jnp.min(T_tiles)}
