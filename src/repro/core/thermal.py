"""Steady-state RC thermal grid solver (the HotSpot-6.0 analogue).

The die is the netlist's (m × n) tile grid. Each tile couples laterally to its
4 neighbours (silicon spreading conductance) and vertically to ambient through
the package (convective conductance). Steady state solves

    (G_v + sum_nbr G_lat) T_ij - G_lat * sum_nbr T_nbr = P_ij + G_v * T_amb

with Jacobi iterations inside ``lax.while_loop`` (the sweep is the hot loop —
``kernels/thermal_stencil`` is the Pallas version; this module holds the
pure-jnp reference used on CPU).

Calibration follows the paper: the convective resistance is tuned so a total
power of 1 W raises the (mean) junction temperature by theta_JA — 2 degC/W for
high-end packages (Virtex-7/Stratix-V class), 12 degC/W for mid-size devices
with still air (Spartan/Artix class).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ThermalConfig:
    theta_ja: float = 2.0  # degC/W effective junction-to-ambient resistance
    spreading: float = 25.0  # lateral/vertical conductance ratio (die spread)
    tol: float = 5e-5  # Jacobi convergence |dT|_inf [degC]
    max_iters: int = 50_000


def conductances(m: int, n: int, tc: ThermalConfig) -> Tuple[float, float]:
    """(G_v per tile [W/degC], G_lat between neighbours)."""
    g_v = 1.0 / (tc.theta_ja * m * n)
    g_lat = g_v * tc.spreading
    return g_v, g_lat


@partial(jax.jit, static_argnums=(1, 2, 4))
def solve(power_mw, m: int, n: int, t_amb, tc: ThermalConfig = ThermalConfig()):
    """power_mw: (m*n,) per-tile power in mW -> (m*n,) temperatures [degC]."""
    g_v, g_lat = conductances(m, n, tc)
    P = power_mw.reshape(m, n).astype(jnp.float32) * 1e-3  # W
    t_amb = jnp.asarray(t_amb, jnp.float32)

    nbr_count = jnp.full((m, n), 4.0)
    nbr_count = nbr_count.at[0, :].add(-1).at[-1, :].add(-1)
    nbr_count = nbr_count.at[:, 0].add(-1).at[:, -1].add(-1)
    diag = g_v + g_lat * nbr_count

    def nbr_sum(T):
        up = jnp.pad(T[1:, :], ((0, 1), (0, 0)))
        dn = jnp.pad(T[:-1, :], ((1, 0), (0, 0)))
        lf = jnp.pad(T[:, 1:], ((0, 0), (0, 1)))
        rt = jnp.pad(T[:, :-1], ((0, 0), (1, 0)))
        return up + dn + lf + rt

    def body(state):
        T, _, i = state
        T_new = (P + g_v * t_amb + g_lat * nbr_sum(T)) / diag
        err = jnp.max(jnp.abs(T_new - T))
        return T_new, err, i + 1

    def cond(state):
        _, err, i = state
        return (err > tc.tol) & (i < tc.max_iters)

    T0 = jnp.full((m, n), t_amb) + P / g_v * 0.5  # warm start
    T, err, iters = jax.lax.while_loop(cond, body, (T0, jnp.inf, 0))
    return T.reshape(-1)


def steady_stats(T_tiles, m: int, n: int):
    return {"mean": jnp.mean(T_tiles), "max": jnp.max(T_tiles),
            "min": jnp.min(T_tiles)}
