"""The 10 industrial VTR benchmarks used by the paper (statistics).

The paper selects VTR-repository benchmarks "from a wide variety of
applications (vision, math, communication, etc.), containing single-/dual-port
memory and DSP blocks, with an average of over 23,800 6-input LUTs (maximum
over 106K)". Named in the paper: mkDelayWorker (6,128 LUTs, 164 BRAM,
92x92 grid, 71.6 MHz), LU8PEEng (CP 21x the longest BRAM path), raygentop,
or1200, mkPktMerge. The remaining five below complete the standard VTR set;
LUT/BRAM/DSP counts follow the published VTR 7.0 characterization tables.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core.netlist import BenchStats, Netlist, generate

BENCHES: List[BenchStats] = [
    BenchStats("bgm", 32384, 0, 11, 65.0, "logic"),
    BenchStats("blob_merge", 6019, 0, 0, 90.0, "routing"),
    BenchStats("boundtop", 2921, 1, 0, 120.0, "mixed"),
    BenchStats("LU8PEEng", 21954, 45, 8, 55.0, "logic", bram_path_ratio=1 / 21),
    BenchStats("mcml", 106069, 38, 27, 50.0, "logic"),
    BenchStats("mkDelayWorker32B", 6128, 164, 0, 71.6, "memory",
               grid=(92, 92), bram_path_ratio=0.96),
    BenchStats("mkPktMerge", 231, 15, 0, 160.0, "memory", bram_path_ratio=0.90),
    BenchStats("or1200", 2963, 2, 1, 95.0, "routing"),
    BenchStats("raygentop", 1884, 1, 18, 110.0, "mixed"),
    BenchStats("stereovision0", 11462, 0, 0, 100.0, "routing"),
]

BY_NAME: Dict[str, BenchStats] = {b.name: b for b in BENCHES}

_cache: Dict[str, Netlist] = {}


def load(name: str, seed: int = 0) -> Netlist:
    key = f"{name}:{seed}"
    if key not in _cache:
        _cache[key] = generate(BY_NAME[name], seed)
    return _cache[key]


def load_all(seed: int = 0) -> Dict[str, Netlist]:
    return {b.name: load(b.name, seed) for b in BENCHES}
