"""Error-tolerant demo applications for voltage over-scaling (paper §III-D).

- LeNet-style CNN mapped as a systolic-array accelerator (im2col matmuls with
  int8 quantization and 32-bit accumulators), trained on a deterministic
  synthetic digit set (no external data in this environment).
- HD (hyperdimensional) 2-class classifier (face / non-face analogue) with
  random-projection binary encoding and Hamming associative memory [44,49].

Inference consumes the per-bit flip profile from core/overscaling.py via the
error-injected matmul (kernels/overscale_matmul ref path): requantization
after each layer clips corrupted accumulators exactly like the fixed-point
hardware would — the mechanism behind DNN error tolerance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.netlist import BenchStats
from repro.kernels import overscale_matmul as om

# FPGA-mapped incarnations of the two apps (for the power side of Fig. 8)
LENET_STATS = BenchStats("lenet_systolic", 14200, 32, 72, 120.0, "mixed")
HD_STATS = BenchStats("hd_encoder", 21800, 16, 0, 140.0, "routing")

# error-model sensitization factor: a violating carry path produces a wrong
# capture only under the sensitizing data pattern (long carry propagation)
SENSITIZE = 0.0017


def scale_bit_probs(bit_probs: np.ndarray) -> np.ndarray:
    return np.clip(np.asarray(bit_probs) * SENSITIZE, 0.0, 1.0)


# =============================================================================
# synthetic digits
# =============================================================================

TEMPLATE_KEY = jax.random.PRNGKey(20190415)  # class templates are the TASK
FACE_KEY = jax.random.PRNGKey(20190416)


def make_digits(key, n: int, img: int = 16):
    """Deterministic parametric digit-ish dataset: class templates + jitter."""
    _, k_lbl, k_shift, k_noise = jax.random.split(key, 4)
    k_tmpl = TEMPLATE_KEY
    base = jax.random.normal(k_tmpl, (10, 8, 8))
    base = jax.image.resize(base, (10, img, img), "cubic")
    base = (base - base.mean()) / (base.std() + 1e-6)
    labels = jax.random.randint(k_lbl, (n,), 0, 10)
    shifts = jax.random.randint(k_shift, (n, 2), -3, 4)
    noise = 0.9 * jax.random.normal(k_noise, (n, img, img))

    def render(lbl, sh, nz):
        t = base[lbl]
        t = jnp.roll(t, sh[0], axis=0)
        t = jnp.roll(t, sh[1], axis=1)
        return t + nz

    x = jax.vmap(render)(labels, shifts, noise)
    return x[..., None], labels


# =============================================================================
# LeNet-mini (conv-pool-conv-pool-fc) — float training, int8 inference
# =============================================================================

@dataclass
class LeNetParams:
    w1: jax.Array  # (3,3,1,8)
    w2: jax.Array  # (3,3,8,16)
    w3: jax.Array  # (256,10)


def lenet_init(key) -> LeNetParams:
    k1, k2, k3 = jax.random.split(key, 3)
    return LeNetParams(
        w1=jax.random.normal(k1, (3, 3, 1, 8)) * 0.3,
        w2=jax.random.normal(k2, (3, 3, 8, 16)) * 0.1,
        w3=jax.random.normal(k3, (4 * 4 * 16, 10)) * 0.05,
    )


def _im2col(x, k: int = 3):
    """x:(B,H,W,C) -> (B,H,W,k*k*C) with SAME padding."""
    B, H, W, C = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [xp[:, i:i + H, j:j + W] for i in range(k) for j in range(k)]
    return jnp.concatenate(cols, axis=-1)


def _pool2(x):
    B, H, W, C = x.shape
    return x.reshape(B, H // 2, 2, W // 2, 2, C).max(axis=(2, 4))


def lenet_apply(p: LeNetParams, x, matmul=None):
    """matmul(a, b) defaults to float; int/error-injected path for inference."""
    mm = matmul or (lambda a, b: a @ b)
    B = x.shape[0]
    c = _im2col(x)  # (B,16,16,9)
    h = mm(c.reshape(-1, c.shape[-1]), p.w1.reshape(-1, 8)).reshape(B, 16, 16, 8)
    h = _pool2(jax.nn.relu(h))  # (B,8,8,8)
    c = _im2col(h)
    h = mm(c.reshape(-1, c.shape[-1]), p.w2.reshape(-1, 16)).reshape(B, 8, 8, 16)
    h = _pool2(jax.nn.relu(h))  # (B,4,4,16)
    return mm(h.reshape(B, -1), p.w3)


def lenet_train(key, steps: int = 400, batch: int = 128,
                n_train: int = 4096) -> Tuple[LeNetParams, Dict]:
    kd, kp = jax.random.split(key)
    x, y = make_digits(kd, n_train)
    p = lenet_init(kp)

    def loss_fn(pt, xb, yb):
        logits = lenet_apply(LeNetParams(*pt), xb)
        return jnp.mean(
            -jax.nn.log_softmax(logits)[jnp.arange(len(yb)), yb])

    @jax.jit
    def step(pt, opt_m, i):
        idx = jax.random.randint(jax.random.fold_in(kd, i), (batch,), 0, n_train)
        l, g = jax.value_and_grad(loss_fn)(pt, x[idx], y[idx])
        opt_m = jax.tree_util.tree_map(lambda m, gg: 0.9 * m + gg, opt_m, g)
        pt = jax.tree_util.tree_map(lambda w, m: w - 0.05 * m, pt, opt_m)
        return pt, opt_m, l

    pt = (p.w1, p.w2, p.w3)
    opt_m = jax.tree_util.tree_map(jnp.zeros_like, pt)
    for i in range(steps):
        pt, opt_m, l = step(pt, opt_m, i)
    return LeNetParams(*pt), {"final_loss": float(l)}


def lenet_accuracy(p: LeNetParams, key, n: int = 1024,
                   bit_probs: Optional[np.ndarray] = None) -> float:
    x, y = make_digits(jax.random.fold_in(key, 999), n)
    if bit_probs is None:
        logits = lenet_apply(p, x)
    else:
        mm = om.make_int8_error_matmul(jnp.asarray(bit_probs, jnp.float32),
                                       jax.random.fold_in(key, 7))
        logits = lenet_apply(p, x, matmul=mm)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


# =============================================================================
# HD classifier
# =============================================================================

def make_faces(key, n: int, dim: int = 256):
    """2-class gaussian-cluster analogue of the Caltech face/non-face task."""
    _, k2, k3 = jax.random.split(key, 3)
    mu = jax.random.normal(FACE_KEY, (2, dim)) * 0.34  # fixed class structure
    y = jax.random.randint(k2, (n,), 0, 2)
    x = mu[y] + jax.random.normal(k3, (n, dim))
    return x, y


@dataclass
class HDModel:
    proj: jax.Array  # (dim, D) random +-1
    prototypes: jax.Array  # (2, D) binary


def hd_encode(proj, x):
    return (x @ proj > 0).astype(jnp.int8)  # (n, D) in {0,1}


def hd_train(key, n: int = 4096, dim: int = 256, D: int = 1024) -> HDModel:
    kp, kd = jax.random.split(key)
    proj = jnp.sign(jax.random.normal(kp, (dim, D)))
    x, y = make_faces(kd, n, dim)
    h = hd_encode(proj, x)
    protos = []
    for c in range(2):
        bundle = jnp.sum(jnp.where((y == c)[:, None], h, 0), axis=0)
        cnt = jnp.sum(y == c)
        protos.append((bundle > cnt / 2).astype(jnp.int8))
    return HDModel(proj, jnp.stack(protos))


def hd_accuracy(model: HDModel, key, n: int = 2048,
                flip_prob: float = 0.0) -> float:
    x, y = make_faces(jax.random.fold_in(key, 123), n)
    h = hd_encode(model.proj, x)
    if flip_prob > 0:
        flips = jax.random.bernoulli(jax.random.fold_in(key, 5), flip_prob,
                                     h.shape)
        h = jnp.where(flips, 1 - h, h)
    dist = jnp.sum(h[:, None, :] != model.prototypes[None], axis=-1)
    return float(jnp.mean(jnp.argmin(dist, -1) == y))


def hd_flip_prob(bit_probs: np.ndarray) -> float:
    """Hypervector-bit flip prob: a bit flips when its sign-accumulator's
    high bits are corrupted; the D-wide reduction exposes ~10x more captures
    per output bit than a single MAC."""
    return float(np.clip(10.0 * scale_bit_probs(bit_probs)[-12:].sum(), 0.0, 0.5))
