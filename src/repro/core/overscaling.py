"""Timing-speculative voltage over-scaling (§III-D) + error model.

For a violation budget gamma >= 1, Algorithm 1's timing constraint is relaxed
to ``delay <= gamma * d_worst`` while the clock stays at d_worst — the
obtained voltages are optimal for that allowed violation (the paper's flow).

The post-P&R *timing simulation* is replaced by a TPU-idiomatic functional
error model (see DESIGN.md §2): gate-level simulation of an FPGA netlist
becomes an error-injection profile derived from the violating-path population:

- a path p with delay d_p(V, T) > d_worst produces an erroneous capture when
  it is exercised (prob = its toggle activity),
- the *depth* of violation determines which accumulator bits are wrong:
  small overshoots corrupt only the last-arriving (high-order / carry) bits,
  matching ThunderVolt/FATE observations on systolic MACs [43,48].

``error_profile`` returns per-bit flip probabilities for a W-bit accumulator;
``kernels/overscale_matmul`` (and its ref) consume it during app inference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import netlist as NL
from repro.core import thermal
from repro.core.netlist import Netlist
from repro.core.voltage_scaling import T_GUARD, _pair_grids, _search, baseline_power


@dataclass
class OverscaleResult:
    gamma: float
    v_core: float
    v_bram: float
    power_mw: float
    baseline_mw: float
    saving: float
    frac_violating: float  # activity-weighted fraction of paths over d_worst
    mean_overshoot: float  # mean (d_p/d_worst - 1)+ over violating paths
    bit_probs: np.ndarray  # (32,) per-bit flip probability per MAC
    t_junct: float = 0.0


def run(netlist: Netlist, gamma: float, t_amb: float = 40.0,
        act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(theta_ja=12.0),
        lib: Optional[C.DeviceLibrary] = None,
        delta_t: float = 0.1, max_iters: int = 8) -> OverscaleResult:
    """Algorithm 1 with relaxed constraint gamma * d_worst."""
    lib = lib or C.default_library()
    nlj = netlist.as_jax()
    n_tiles = netlist.n_tiles
    d_worst = float(NL.crit_delay(
        lib, nlj, jnp.full((n_tiles,), C.T_MAX), C.V_CORE_NOM, C.V_BRAM_NOM))
    f_ghz = 1.0 / d_worst  # clock unchanged: violations, not slowdown
    _, _, vc_flat, vb_flat = _pair_grids()

    T = jnp.full((n_tiles,), float(t_amb))
    vc = vb = None
    for _ in range(max_iters):
        vc, vb = _search(lib, nlj, T, f_ghz, act_in, d_worst * gamma,
                         vc_flat, vb_flat)
        lkg, dyn = NL.tile_power(lib, nlj, T, vc, vb, f_ghz, act_in)
        T_new = thermal.solve(lkg + dyn, netlist.m, netlist.n, t_amb, tc)
        done = float(jnp.max(jnp.abs(T_new - T))) < delta_t
        T = T_new
        if done:
            break
    power = float(jnp.sum(lkg) + jnp.sum(dyn))
    base, _ = baseline_power(netlist, t_amb, act_in, tc, lib)

    frac, overshoot, bit_probs = error_profile(
        lib, nlj, netlist, T, float(vc), float(vb), d_worst, act_in)
    return OverscaleResult(
        gamma=gamma, v_core=float(vc), v_bram=float(vb), power_mw=power,
        baseline_mw=base, saving=1.0 - power / base,
        frac_violating=frac, mean_overshoot=overshoot, bit_probs=bit_probs,
        t_junct=float(jnp.mean(T)))


def error_profile(lib, nlj, netlist: Netlist, T_tiles, v_core, v_bram,
                  d_worst, act_in, word_bits: int = 32):
    """Violating-path population -> per-bit flip probabilities.

    Bits [word_bits-CARRY_BITS, word_bits) are the carry/MSB tail that the
    last-arriving signals feed; a violation of depth x (= d_p/d_worst - 1)
    corrupts the top ceil(x / X_FULL * CARRY_BITS) of them.
    """
    CARRY_BITS = 12
    X_FULL = 0.40  # overshoot at which the whole carry tail is corrupt
    d = np.asarray(NL.path_delays(lib, nlj, T_tiles + T_GUARD, v_core, v_bram))
    v = d / d_worst - 1.0
    viol = v > 0
    frac = float(viol.mean())
    overshoot = float(v[viol].mean()) if viol.any() else 0.0

    # per-path capture probability: exercised with internal activity
    act = float(C.internal_activity(act_in))
    bit_probs = np.zeros(word_bits)
    if viol.any():
        for x in v[viol]:
            depth = min(int(np.ceil(x / X_FULL * CARRY_BITS)), CARRY_BITS)
            lo = word_bits - depth
            bit_probs[lo:] += act / len(d)
    return frac, overshoot, np.clip(bit_probs, 0.0, 1.0)


def sweep(netlist: Netlist, gammas, **kw) -> List[OverscaleResult]:
    return [run(netlist, float(g), **kw) for g in gammas]
