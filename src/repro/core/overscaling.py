"""Timing-speculative voltage over-scaling (§III-D) + error model.

For a violation budget gamma >= 1, Algorithm 1's timing constraint is relaxed
to ``delay <= gamma * d_worst`` while the clock stays at d_worst — the
obtained voltages are optimal for that allowed violation (the paper's flow).
The search itself is the shared ``repro.policy.Solver`` with the
``Overscale(gamma)`` policy (DESIGN.md); gamma rides in the solver
environment, so :func:`sweep` evaluates a whole gamma schedule as ONE
batched device call (``Solver.solve_batch``).

The post-P&R *timing simulation* is replaced by a TPU-idiomatic functional
error model (see DESIGN.md §2): gate-level simulation of an FPGA netlist
becomes an error-injection profile derived from the violating-path population:

- a path p with delay d_p(V, T) > d_worst produces an erroneous capture when
  it is exercised (prob = its toggle activity),
- the *depth* of violation determines which accumulator bits are wrong:
  small overshoots corrupt only the last-arriving (high-order / carry) bits,
  matching ThunderVolt/FATE observations on systolic MACs [43,48].

``error_profile`` returns per-bit flip probabilities for a W-bit accumulator;
``kernels/overscale_matmul`` (and its ref) consume it during app inference.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import characterization as C
from repro.core import netlist as NL
from repro.core import thermal
from repro.core.netlist import Netlist
from repro.core.voltage_scaling import baseline_power
from repro.policy import Overscale, Policy, cached_solver, fpga_substrate
from repro.policy.substrate import T_GUARD


@dataclass
class OverscaleResult:
    gamma: float
    v_core: float
    v_bram: float
    power_mw: float
    baseline_mw: float
    saving: float
    frac_violating: float  # activity-weighted fraction of paths over d_worst
    mean_overshoot: float  # mean (d_p/d_worst - 1)+ over violating paths
    bit_probs: np.ndarray  # (32,) per-bit flip probability per MAC
    t_junct: float = 0.0


def _result(sub, sol, netlist, gamma, act_in, base) -> OverscaleResult:
    vc, vb = sub.decode(sol.idx)
    vc, vb = float(vc[0]), float(vb[0])
    power = float(sol.power[0])
    frac, overshoot, bit_probs = error_profile(
        sub.lib, sub.nlj, netlist, jnp.asarray(sol.T), vc, vb, sub.d_worst,
        act_in)
    return OverscaleResult(
        gamma=float(gamma), v_core=vc, v_bram=vb, power_mw=power,
        baseline_mw=base, saving=1.0 - power / base,
        frac_violating=frac, mean_overshoot=overshoot, bit_probs=bit_probs,
        t_junct=float(np.mean(sol.T)))


def run(netlist: Netlist, gamma: float, t_amb: float = 40.0,
        act_in: float = 1.0,
        tc: thermal.ThermalConfig = thermal.ThermalConfig(theta_ja=12.0),
        lib: Optional[C.DeviceLibrary] = None,
        delta_t: float = 0.1, max_iters: int = 8,
        policy: Optional[Policy] = None) -> OverscaleResult:
    """Algorithm 1 with relaxed constraint gamma * d_worst.

    A custom constraint ``policy`` (e.g. a pre-built ``Overscale``) may be
    supplied; its gamma is superseded by the explicit ``gamma`` argument,
    which always rides in the solver environment.
    """
    sub = fpga_substrate(netlist, lib, tc)
    # gamma rides in the env (not the policy) so every budget reuses one
    # compiled solver
    solver = cached_solver(sub, policy or Overscale(), delta_t,
                           max(int(max_iters), 1))
    sol = solver.solve({"t_amb": t_amb, "act": act_in, "gamma": gamma})
    base, _ = baseline_power(netlist, t_amb, act_in, tc, lib)
    return _result(sub, sol, netlist, gamma, act_in, base)


def sweep(netlist: Netlist, gammas, t_amb: float = 40.0, act_in: float = 1.0,
          tc: thermal.ThermalConfig = thermal.ThermalConfig(theta_ja=12.0),
          lib: Optional[C.DeviceLibrary] = None,
          delta_t: float = 0.1, max_iters: int = 8
          ) -> List[OverscaleResult]:
    """Gamma sweep as one batched fixed-point call (§III-D study)."""
    gammas = [float(x) for x in gammas]
    g = np.asarray(gammas, np.float32)
    sub = fpga_substrate(netlist, lib, tc)
    solver = cached_solver(sub, Overscale(), delta_t, max(int(max_iters), 1))
    sol = solver.solve_batch({
        "t_amb": np.full_like(g, t_amb),
        "act": np.full_like(g, act_in),
        "gamma": g,
    })
    base, _ = baseline_power(netlist, t_amb, act_in, tc, lib)
    # report the exact requested gammas, not their float32 round-trips
    return [_result(sub, jax.tree_util.tree_map(lambda x: x[i], sol),
                    netlist, gammas[i], act_in, base)
            for i in range(len(g))]


def error_profile(lib, nlj, netlist: Netlist, T_tiles, v_core, v_bram,
                  d_worst, act_in, word_bits: int = 32):
    """Violating-path population -> per-bit flip probabilities.

    Bits [word_bits-CARRY_BITS, word_bits) are the carry/MSB tail that the
    last-arriving signals feed; a violation of depth x (= d_p/d_worst - 1)
    corrupts the top ceil(x / X_FULL * CARRY_BITS) of them.
    """
    CARRY_BITS = 12
    X_FULL = 0.40  # overshoot at which the whole carry tail is corrupt
    d = np.asarray(NL.path_delays(lib, nlj, T_tiles + T_GUARD, v_core, v_bram))
    v = d / d_worst - 1.0
    viol = v > 0
    frac = float(viol.mean())
    overshoot = float(v[viol].mean()) if viol.any() else 0.0

    # per-path capture probability: exercised with internal activity
    act = float(C.internal_activity(act_in))
    bit_probs = np.zeros(word_bits)
    if viol.any():
        for x in v[viol]:
            depth = min(int(np.ceil(x / X_FULL * CARRY_BITS)), CARRY_BITS)
            lo = word_bits - depth
            bit_probs[lo:] += act / len(d)
    return frac, overshoot, np.clip(bit_probs, 0.0, 1.0)
