"""EnergyAwareRuntime — Algorithm 1/2 driving a (simulated) TPU pod.

First-class trainer/server feature (launch/train.py --energy-policy ...):

- ``power_save``  (Algorithm 1): per-chip (v_core, v_sram) minimizing pod
  power subject to the step-time contract (f stays nominal); fixed-point with
  the 16x16 chip-grid thermal solve; emits the paper's dynamic-scheme lookup
  table (T -> voltages) for the on-line controller.
- ``min_energy``  (Algorithm 2): additionally scales frequency; minimizes
  energy per step (P x t_step) — the off-peak / batch-window objective.
- ``overscale:g`` (§III-D): relaxes the contract by g for error-tolerant
  training; the overscale error profile is exposed for gradient injection.

On CPU this is a simulation (no rails to program), but the control layer —
telemetry ingestion, planning, thermal feedback, straggler tie-in — is the
real, tested code a TPU deployment would drive VIDs with.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import thermal
from repro.core import tpu_fleet as TF


@dataclass
class PlanOut:
    v_core: np.ndarray  # (chips,)
    v_sram: np.ndarray
    f_rel: np.ndarray
    power_w: np.ndarray
    step_s: float
    pod_power_w: float
    baseline_power_w: float
    saving: float
    t_mean: float
    t_max: float


class EnergyAwareRuntime:
    def __init__(self, profile: TF.StepProfile, policy: str = "power_save",
                 grid: Tuple[int, int] = (16, 16), t_amb: float = 25.0,
                 lib: Optional[TF.TpuLibrary] = None,
                 theta_chip: float = 0.20):
        self.lib = lib or TF.TpuLibrary()
        self.prof = profile
        self.policy = policy
        self.m, self.n = grid
        self.t_amb = t_amb
        self.tc = TF.pod_thermal_config(theta_chip, self.m * self.n)
        self.gamma = 1.0
        if policy.startswith("overscale:"):
            self.gamma = float(policy.split(":")[1])
            self.policy = "overscale"
        self.T = jnp.full((self.m * self.n,), t_amb + 25.0)  # warm estimate
        self.history: List[Dict] = []
        # voltage grids
        self.vc_grid = jnp.asarray(np.arange(0.55, TF.V_CORE_NOM + 0.001, 0.01),
                                   jnp.float32)
        self.vs_grid = jnp.asarray(np.arange(0.60, TF.V_SRAM_NOM + 0.001, 0.01),
                                   jnp.float32)

    # ------------------------------------------------------------------
    def _search_chip(self, T_chips, util_scale):
        """Vectorized per-chip (v_core, v_sram[, f]) search."""
        lib, prof = self.lib, self.prof
        VC, VS = jnp.meshgrid(self.vc_grid, self.vs_grid, indexing="ij")
        vc_flat, vs_flat = VC.reshape(-1), VS.reshape(-1)  # (P,)

        def per_chip(T, us):
            fmax = TF.f_max_rel(lib, vc_flat, vs_flat, T + 2.0)  # T guard
            if self.policy in ("power_save", "overscale"):
                # hold nominal clock; margin budget = gamma
                feasible = fmax >= 1.0 / self.gamma
                p = TF.chip_power(lib, prof, vc_flat, vs_flat, 1.0, T) * us
                p = jnp.where(feasible, p, jnp.inf)
                i = jnp.argmin(p)
                # no margin at this temperature -> stay at nominal rails
                ok = jnp.any(feasible)
                vc = jnp.where(ok, vc_flat[i], TF.V_CORE_NOM)
                vs = jnp.where(ok, vs_flat[i], TF.V_SRAM_NOM)
                p_nom = TF.chip_power(lib, prof, TF.V_CORE_NOM, TF.V_SRAM_NOM,
                                      1.0, T) * us
                return vc, vs, jnp.float32(1.0), jnp.where(ok, p[i], p_nom)
            # min_energy: run at the pair's own max frequency
            f = jnp.minimum(fmax, 1.0)
            t = TF.step_time(prof, f) / prof.step_s
            p = TF.chip_power(lib, prof, vc_flat, vs_flat, f, T) * us
            e = p * t
            i = jnp.argmin(e)
            return vc_flat[i], vs_flat[i], f[i], p[i]

        return jax.vmap(per_chip)(T_chips, util_scale)

    # ------------------------------------------------------------------
    def plan(self, util_scale: Optional[np.ndarray] = None,
             max_iters: int = 6, delta_t: float = 0.5) -> PlanOut:
        """Fixed point: choose rails -> thermal solve -> repeat."""
        chips = self.m * self.n
        us = jnp.asarray(util_scale if util_scale is not None
                         else np.ones(chips), jnp.float32)
        T = self.T
        for _ in range(max_iters):
            vc, vs, f, p = self._search_chip(T, us)
            T_new = thermal.solve(p * 1e3, self.m, self.n, self.t_amb, self.tc)
            done = float(jnp.max(jnp.abs(T_new - T))) < delta_t
            T = T_new
            if done:
                break
        self.T = T
        # baseline: nominal rails at its own fixed point
        Tb = jnp.full((chips,), self.t_amb + 25.0)
        for _ in range(max_iters):
            pb = TF.chip_power(self.lib, self.prof,
                               jnp.full((chips,), TF.V_CORE_NOM),
                               jnp.full((chips,), TF.V_SRAM_NOM), 1.0, Tb) * us
            Tb_new = thermal.solve(pb * 1e3, self.m, self.n, self.t_amb, self.tc)
            if float(jnp.max(jnp.abs(Tb_new - Tb))) < delta_t:
                Tb = Tb_new
                break
            Tb = Tb_new
        f_pod = float(jnp.min(f))  # synchronous step: slowest chip rules
        step_s = float(TF.step_time(self.prof, f_pod))
        if self.policy == "min_energy":
            # energy-per-step ratio (P x t), the paper's Algorithm-2 metric
            saving = 1.0 - (float(jnp.sum(p)) * step_s) / (
                float(jnp.sum(pb)) * self.prof.step_s)
        else:
            saving = 1.0 - float(jnp.sum(p)) / float(jnp.sum(pb))
        out = PlanOut(
            v_core=np.asarray(vc), v_sram=np.asarray(vs), f_rel=np.asarray(f),
            power_w=np.asarray(p), step_s=step_s,
            pod_power_w=float(jnp.sum(p)),
            baseline_power_w=float(jnp.sum(pb)),
            saving=saving,
            t_mean=float(jnp.mean(T)), t_max=float(jnp.max(T)),
        )
        self.history.append({"saving": out.saving, "t_max": out.t_max,
                             "step_s": out.step_s})
        return out

    # ------------------------------------------------------------------
    def dynamic_lut(self, t_ambs) -> Dict[float, Tuple[float, float]]:
        """Paper §III-B dynamic scheme: per-ambient (v_core, v_sram) medians."""
        out = {}
        keep = self.t_amb
        for t in t_ambs:
            self.t_amb = float(t)
            self.T = jnp.full((self.m * self.n,), t + 25.0)
            p = self.plan()
            out[float(t)] = (float(np.median(p.v_core)),
                             float(np.median(p.v_sram)))
        self.t_amb = keep
        return out

    # ------------------------------------------------------------------
    def straggler_mitigation(self, plan: PlanOut, chip: int,
                             slow_factor: float):
        """Hot/slow chip: try boosting its rails back to nominal (perf-
        preserving, costs power); report if even that can't hold the clock."""
        T_chip = float(self.T[chip])
        f_at_nom = float(TF.f_max_rel(self.lib, TF.V_CORE_NOM, TF.V_SRAM_NOM,
                                      T_chip + 2.0))
        if f_at_nom >= 1.0:
            return {"action": "boost_rail", "chip": chip,
                    "v_core": TF.V_CORE_NOM, "v_sram": TF.V_SRAM_NOM,
                    "extra_power_w": float(
                        TF.chip_power(self.lib, self.prof, TF.V_CORE_NOM,
                                      TF.V_SRAM_NOM, 1.0, T_chip)
                        - plan.power_w[chip])}
        return {"action": "rebalance", "chip": chip,
                "reason": f"T={T_chip:.1f}C cannot hold f_nom even at "
                          f"nominal rails (f_max={f_at_nom:.3f})"}
