"""EnergyAwareRuntime — Algorithm 1/2 driving a (simulated) TPU pod.

First-class trainer/server feature (launch/train.py --energy-policy ...):

- ``power_save``  (Algorithm 1): per-chip (v_core, v_sram) minimizing pod
  power subject to the step-time contract (f stays nominal); fixed-point with
  the 16x16 chip-grid thermal solve; emits the paper's dynamic-scheme lookup
  table (T -> voltages) for the on-line controller.
- ``min_energy``  (Algorithm 2): additionally scales frequency; minimizes
  energy per step (P x t_step) — the off-peak / batch-window objective.
- ``overscale:g`` (§III-D): relaxes the contract by g for error-tolerant
  training; the overscale error profile is exposed for gradient injection.

Since the ``repro.control`` redesign this class is a thin composition over
the control plane's :class:`~repro.control.planner.FleetPlanner` (which owns
the fixed point, the cached nominal baseline, the batched §III-B LUT build,
and straggler mitigation decisions).  ``plan()`` / ``dynamic_lut()`` /
``straggler_mitigation()`` keep their legacy signatures and reproduce the
pre-refactor numbers (golden-pinned in tests/test_policy_api.py) — the PR-1
wrapper playbook.  For the online loop itself, compose
``repro.control.LutController`` / ``ControlLoop`` over ``self.planner``
(``controller()`` below is the convenience constructor).

On CPU this is a simulation (no rails to program), but the control layer —
telemetry ingestion, planning, thermal feedback, straggler tie-in — is the
real, tested code a TPU deployment would drive VIDs with.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import tpu_fleet as TF
from repro import policy as pol
from repro.control.lut import DynamicLut
from repro.control.planner import FleetPlanner, PlanOut  # noqa: F401
# PlanOut is re-exported: it remains the public result type of plan().


class EnergyAwareRuntime:
    def __init__(self, profile: TF.StepProfile,
                 policy: Union[str, pol.Policy] = "power_save",
                 grid: Tuple[int, int] = (16, 16), t_amb: float = 25.0,
                 lib: Optional[TF.TpuLibrary] = None,
                 theta_chip: float = 0.20):
        self.lib = lib or TF.TpuLibrary()
        self.prof = profile
        self.policy_obj = pol.from_spec(policy)
        self.gamma = self.policy_obj.gamma
        # legacy string attribute ("power_save" | "min_energy" | "overscale")
        # honoured for Policy-object construction too
        _spec_names = {pol.Overscale: "overscale", pol.MinEnergy: "min_energy",
                       pol.PowerSave: "power_save",
                       pol.ErrorTolerant: "error_tolerant"}
        self.policy = _spec_names.get(type(self.policy_obj),
                                      type(self.policy_obj).__name__)
        self.m, self.n = grid
        self.t_amb = t_amb
        self.substrate = pol.tpu_substrate(profile, self.lib, grid,
                                           theta_chip)
        self.tc = self.substrate.thermal_cfg
        self.planner = FleetPlanner(self.substrate, self.policy_obj,
                                    profile, self.lib)
        self.T = self.substrate.T0({"t_amb": t_amb})  # warm estimate
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _env(self, util_scale) -> Dict:
        return self.planner.env(self.t_amb, util_scale)

    def plan(self, util_scale: Optional[np.ndarray] = None,
             max_iters: int = 6, delta_t: float = 0.5) -> PlanOut:
        """Fixed point: choose rails -> thermal solve -> repeat."""
        out, T = self.planner.plan(self._env(util_scale), T0=self.T,
                                   max_iters=max_iters, delta_t=delta_t)
        self.T = jnp.asarray(T)
        self.history.append({"saving": out.saving, "t_max": out.t_max,
                             "step_s": out.step_s})
        return out

    # ------------------------------------------------------------------
    def dynamic_lut(self, t_ambs) -> Dict[float, Tuple[float, float]]:
        """Paper §III-B dynamic scheme: per-ambient (v_core, v_sram) medians.

        One batched solve over the ambient sweep; runtime state (``t_amb``,
        the warm temperature estimate ``T``) is not touched, so subsequent
        ``plan()`` calls are unaffected.  Returns the raw knot table; use
        :meth:`build_lut` for the interpolating controller fast path.
        """
        return self.planner.lut(t_ambs)

    def build_lut(self, t_ambs) -> DynamicLut:
        """Interpolating (clamped) scalar lookup over an ambient sweep."""
        return self.planner.build_lut(t_ambs)

    def build_field(self, t_ambs, u_levels=None, **kw):
        """Per-chip 2-axis (ambient x utilization) RailField — ONE
        early-freeze ``solve_batch`` over the whole sweep grid."""
        from repro.control.lut import DEFAULT_UTIL_KNOTS
        if u_levels is None:
            u_levels = DEFAULT_UTIL_KNOTS
        return self.planner.rail_field(t_ambs, u_levels, **kw)

    def controller(self, **kw):
        """A ``repro.control.LutController`` over this runtime's planner.

        By default this builds the per-chip RailField fast path; pass
        ``lut=self.build_lut(...)`` for the legacy pod-median scalar
        behavior."""
        from repro.control.controller import LutController
        return LutController(self.planner, **kw)

    # ------------------------------------------------------------------
    def straggler_mitigation(self, plan: PlanOut, chip: int,
                             slow_factor: float):
        """Hot/slow chip: try boosting its rails back to nominal (perf-
        preserving, costs power); report if even that can't hold the clock."""
        return self.planner.mitigate(plan, chip, float(self.T[chip]))
