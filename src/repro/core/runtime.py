"""EnergyAwareRuntime — Algorithm 1/2 driving a (simulated) TPU pod.

First-class trainer/server feature (launch/train.py --energy-policy ...):

- ``power_save``  (Algorithm 1): per-chip (v_core, v_sram) minimizing pod
  power subject to the step-time contract (f stays nominal); fixed-point with
  the 16x16 chip-grid thermal solve; emits the paper's dynamic-scheme lookup
  table (T -> voltages) for the on-line controller.
- ``min_energy``  (Algorithm 2): additionally scales frequency; minimizes
  energy per step (P x t_step) — the off-peak / batch-window objective.
- ``overscale:g`` (§III-D): relaxes the contract by g for error-tolerant
  training; the overscale error profile is exposed for gradient injection.

The planning loop is the shared ``repro.policy.Solver`` over a
``TpuFleetSubstrate`` (DESIGN.md §2) — the same Substrate/Policy/Solver
stack that runs the FPGA flows.  ``policy`` accepts either the legacy spec
string above or a ``repro.policy.Policy`` instance directly.

On CPU this is a simulation (no rails to program), but the control layer —
telemetry ingestion, planning, thermal feedback, straggler tie-in — is the
real, tested code a TPU deployment would drive VIDs with.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tpu_fleet as TF
from repro import policy as pol


@dataclass
class PlanOut:
    v_core: np.ndarray  # (chips,)
    v_sram: np.ndarray
    f_rel: np.ndarray
    power_w: np.ndarray
    step_s: float
    pod_power_w: float
    baseline_power_w: float
    saving: float
    t_mean: float
    t_max: float


class EnergyAwareRuntime:
    def __init__(self, profile: TF.StepProfile,
                 policy: Union[str, pol.Policy] = "power_save",
                 grid: Tuple[int, int] = (16, 16), t_amb: float = 25.0,
                 lib: Optional[TF.TpuLibrary] = None,
                 theta_chip: float = 0.20):
        self.lib = lib or TF.TpuLibrary()
        self.prof = profile
        self.policy_obj = pol.from_spec(policy)
        self.gamma = self.policy_obj.gamma
        # legacy string attribute ("power_save" | "min_energy" | "overscale")
        # honoured for Policy-object construction too
        _spec_names = {pol.Overscale: "overscale", pol.MinEnergy: "min_energy",
                       pol.PowerSave: "power_save"}
        self.policy = _spec_names.get(type(self.policy_obj),
                                      type(self.policy_obj).__name__)
        self.m, self.n = grid
        self.t_amb = t_amb
        self.substrate = pol.tpu_substrate(profile, self.lib, grid,
                                           theta_chip)
        self.tc = self.substrate.thermal_cfg
        self.T = self.substrate.T0({"t_amb": t_amb})  # warm estimate
        self.history: List[Dict] = []

    # ------------------------------------------------------------------
    def _env(self, util_scale) -> Dict:
        chips = self.m * self.n
        us = np.asarray(util_scale if util_scale is not None
                        else np.ones(chips), np.float32)
        return {"t_amb": self.t_amb, "util": us, "gamma": self.gamma}

    def plan(self, util_scale: Optional[np.ndarray] = None,
             max_iters: int = 6, delta_t: float = 0.5) -> PlanOut:
        """Fixed point: choose rails -> thermal solve -> repeat."""
        env = self._env(util_scale)
        solver = pol.cached_solver(self.substrate, self.policy_obj,
                                   delta_t, max_iters)
        sol = solver.solve(env, T0=self.T)
        self.T = jnp.asarray(sol.T)

        # baseline: nominal rails at their own fixed point (fresh warm start)
        bsolver = pol.cached_solver(self.substrate.nominal_only(),
                                    pol.PowerSave(), delta_t, max_iters)
        bsol = bsolver.solve(env)
        pb = bsol.power  # legacy: last-search power, not re-evaluated

        vc, vs = self.substrate.decode(sol.idx)
        f = np.asarray(sol.f)
        p = np.asarray(sol.power)
        f_pod = float(f.min())  # synchronous step: slowest chip rules
        step_s = float(TF.step_time(self.prof, f_pod))
        if self.policy_obj.metric == "energy":
            # energy-per-step ratio (P x t), the paper's Algorithm-2 metric
            saving = 1.0 - (float(p.sum()) * step_s) / (
                float(pb.sum()) * self.prof.step_s)
        else:
            saving = 1.0 - float(p.sum()) / float(pb.sum())
        out = PlanOut(
            v_core=vc, v_sram=vs, f_rel=f, power_w=p, step_s=step_s,
            pod_power_w=float(p.sum()),
            baseline_power_w=float(pb.sum()),
            saving=saving,
            t_mean=float(np.mean(sol.T)), t_max=float(np.max(sol.T)),
        )
        self.history.append({"saving": out.saving, "t_max": out.t_max,
                             "step_s": out.step_s})
        return out

    # ------------------------------------------------------------------
    def dynamic_lut(self, t_ambs) -> Dict[float, Tuple[float, float]]:
        """Paper §III-B dynamic scheme: per-ambient (v_core, v_sram) medians.

        One batched solve over the ambient sweep; runtime state (``t_amb``,
        the warm temperature estimate ``T``) is not touched, so subsequent
        ``plan()`` calls are unaffected.
        """
        chips = self.m * self.n
        t = np.asarray([float(x) for x in t_ambs], np.float32)
        B = len(t)
        solver = pol.cached_solver(self.substrate, self.policy_obj,
                                   delta_t=0.5, max_iters=6)
        sol = solver.solve_batch({
            "t_amb": t,
            "util": np.ones((B, chips), np.float32),
            "gamma": np.full((B,), self.gamma, np.float32),
        })
        out = {}
        for i in range(B):
            vc, vs = self.substrate.decode(sol.idx[i])
            out[float(t[i])] = (float(np.median(vc)), float(np.median(vs)))
        return out

    # ------------------------------------------------------------------
    def straggler_mitigation(self, plan: PlanOut, chip: int,
                             slow_factor: float):
        """Hot/slow chip: try boosting its rails back to nominal (perf-
        preserving, costs power); report if even that can't hold the clock."""
        T_chip = float(self.T[chip])
        f_at_nom = float(TF.f_max_rel(self.lib, TF.V_CORE_NOM, TF.V_SRAM_NOM,
                                      T_chip + 2.0))
        if f_at_nom >= 1.0:
            return {"action": "boost_rail", "chip": chip,
                    "v_core": TF.V_CORE_NOM, "v_sram": TF.V_SRAM_NOM,
                    "extra_power_w": float(
                        TF.chip_power(self.lib, self.prof, TF.V_CORE_NOM,
                                      TF.V_SRAM_NOM, 1.0, T_chip)
                        - plan.power_w[chip])}
        return {"action": "rebalance", "chip": chip,
                "reason": f"T={T_chip:.1f}C cannot hold f_nom even at "
                          f"nominal rails (f_max={f_at_nom:.3f})"}
