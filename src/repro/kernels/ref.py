"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

One reference per kernel, written with plain jnp ops (no pallas):
- overscale_matmul_ref: int8 matmul + identical error-injection math
- abft_matmul_ref: overscale_matmul_ref + row/column checksums of the
  corrupted product (the ABFT syndromes' left-hand side)
- thermal_stencil_ref: K Jacobi sweeps of the 5-point thermal stencil
- flash_attention_ref: naive softmax(QK^T)V with causal mask
- paged_attention_ref: gather K/V pools through the block table, then the
  serving tier's masked dense decode math (attention._sdpa)
- mamba_scan_ref: delegates to the model-level chunked SSD implementation
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def overscale_matmul_ref(a, b, u_gate, u_bit, cdf):
    acc = jax.lax.dot_general(
        a.astype(jnp.int32), b.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    p_total = cdf[-1]
    u = u_gate.astype(jnp.float32) * (1.0 / 4294967296.0)
    flip = u < p_total
    u2 = u_bit.astype(jnp.float32) * (1.0 / 4294967296.0) * p_total
    bit_idx = jnp.sum((u2[..., None] >= cdf[None, None, 1:]).astype(jnp.int32),
                      axis=-1)
    bit_idx = jnp.clip(bit_idx, 0, 31)
    mask = jnp.where(flip, jnp.left_shift(jnp.int32(1), bit_idx), 0)
    return jax.lax.bitwise_xor(acc, mask)


def abft_matmul_ref(a, b, u_gate, u_bit, cdf):
    """Oracle for kernels/abft_matmul: the error-injected product plus its
    row/column checksums (int32, wrapping mod 2^32 like the kernel)."""
    c = overscale_matmul_ref(a, b, u_gate, u_bit, cdf)
    return c, jnp.sum(c, axis=1), jnp.sum(c, axis=0)


def thermal_stencil_ref(T, P, diag, g_lat, g_v_tamb, iters: int,
                        phase=None):
    """T,P,diag:(m,n); iters Jacobi (phase=None) or red-black GS sweeps
    starting on checkerboard colour ``phase`` (0|1)."""
    def nbr(T):
        up = jnp.pad(T[1:, :], ((0, 1), (0, 0)))
        dn = jnp.pad(T[:-1, :], ((1, 0), (0, 0)))
        lf = jnp.pad(T[:, 1:], ((0, 0), (0, 1)))
        rt = jnp.pad(T[:, :-1], ((0, 0), (1, 0)))
        return up + dn + lf + rt

    if phase is None:
        def body(_, T):
            return (P + g_v_tamb + g_lat * nbr(T)) / diag
    else:
        m, n = P.shape
        row = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
        par = (row + col) % 2

        def body(_, T):
            for p in (phase, 1 - phase):
                T = jnp.where(par == p,
                              (P + g_v_tamb + g_lat * nbr(T)) / diag, T)
            return T

    return jax.lax.fori_loop(0, iters, body, T)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v:(S,D)/(T,D) single head."""
    S, D = q.shape
    T = k.shape[0]
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) / jnp.sqrt(D)
    if causal:
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return (w @ v.astype(jnp.float32)).astype(v.dtype)


def paged_attention_ref(q, k_pool, v_pool, ids_pool, block_table, pos, *,
                        window: int = 0):
    """Oracle for kernels/paged_attention: materialize each slot's logical
    cache by gathering its block-table pages, then run the serving tier's
    masked dense decode (attention._sdpa) — so with page_size == max_len
    and an identity block table this IS the fused contiguous decode path,
    bitwise.  q:(B,H,D), pools:(P,ps,...), block_table:(B,n), pos:(B,)."""
    from repro.models.attention import _sdpa
    B, n = block_table.shape
    ps = k_pool.shape[1]
    k = k_pool[block_table].reshape(B, n * ps, *k_pool.shape[2:])
    v = v_pool[block_table].reshape(B, n * ps, *v_pool.shape[2:])
    ids = ids_pool[block_table].reshape(B, n * ps)
    valid = (ids >= 0) & (ids <= pos[:, None])
    if window:
        valid &= ids > pos[:, None] - window
    mask = valid[:, None, None, None, :]  # (B,1,1,S=1,T)
    out = _sdpa(q[:, None], k, v, mask, None)[:, 0]
    # a fully-disabled row (pos = -1: every page masked) is exactly zero,
    # matching the kernel's l == 0 finalize — not _sdpa's uniform-softmax
    # mean(v) over an all-NEG_INF row
    any_valid = jnp.any(valid, axis=-1)
    return jnp.where(any_valid[:, None, None], out, 0.0).astype(out.dtype)


def mamba_scan_ref(xh, dt, A, B, C, chunk: int):
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(xh, dt, A, B, C, chunk)
