"""Pallas TPU kernel: Mamba2 SSD chunked scan (intra-chunk + state carry).

Grid = (num_chunks,) iterated sequentially (TPU grid order) with the running
inter-chunk state (H, P, N) in VMEM scratch — the recurrence never leaves
VMEM. Per chunk the kernel computes the quadratic intra-chunk term, the
read-out from the carried state, and the state update, all in fp32.

Block tiling per chunk c: x (Q, H, P), dt (Q, H), B/C (Q, H, N) — for the
assigned mamba2-780m (Q=256, H=48, P=64, N=128) the chunk working set is
~3 MB, comfortably VMEM-resident; heads can be split over an extra grid dim
(or sharded by TP) for larger models.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, y_ref, state_ref, *,
            q: int, n_chunks: int):
    c = pl.program_id(0)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)  # (Q,H,P)
    dt = dt_ref[...].astype(jnp.float32)  # (Q,H)
    A = A_ref[...].astype(jnp.float32)  # (H,)
    B = B_ref[...].astype(jnp.float32)  # (Q,H,N)
    C = C_ref[...].astype(jnp.float32)  # (Q,H,N)

    dA = dt * A[None, :]  # (Q,H)
    cs = jnp.cumsum(dA, axis=0)  # (Q,H)
    # intra-chunk: L[i,j] = exp(cs_i - cs_j) for i>=j ; score G = C_i . B_j
    diff = cs[:, None, :] - cs[None, :, :]  # (Q,Q,H)
    mask = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(mask[..., None], jnp.exp(diff), 0.0)  # (Q,Q,H)
    G = jnp.einsum("ihn,jhn->ijh", C, B)  # (Q,Q,H)
    M = G * L * dt[None, :, :]  # weight on x_j
    y = jnp.einsum("ijh,jhp->ihp", M, x)
    # read-out from carried state
    in_decay = jnp.exp(cs)  # (Q,H)
    y += jnp.einsum("ihn,hpn,ih->ihp", C, state_ref[...], in_decay)
    y_ref[...] = y.astype(y_ref.dtype)
    # state update
    tot = jnp.exp(cs[-1])  # (H,)
    decay_to_end = jnp.exp(cs[-1][None, :] - cs)  # (Q,H)
    new_state = (state_ref[...] * tot[:, None, None]
                 + jnp.einsum("qh,qhn,qhp->hpn", decay_to_end * dt, B, x))
    state_ref[...] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(xh, dt, A, B, C, *, chunk: int = 256, interpret: bool = True):
    """Single-batch SSD scan. xh:(S,H,P) dt:(S,H) A:(H,) B,C:(S,H,N) -> y.

    vmap over batch. Returns y:(S,H,P) (fp32 math, xh.dtype out).
    """
    S, H, P = xh.shape
    N = B.shape[-1]
    q = min(chunk, S)
    assert S % q == 0
    nc = S // q
    return pl.pallas_call(
        functools.partial(_kernel, q=q, n_chunks=nc),
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((q, H, P), lambda c: (c, 0, 0)),
            pl.BlockSpec((q, H), lambda c: (c, 0)),
            pl.BlockSpec((H,), lambda c: (0,)),
            pl.BlockSpec((q, H, N), lambda c: (c, 0, 0)),
            pl.BlockSpec((q, H, N), lambda c: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((q, H, P), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((H, P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A, B, C)
