"""Pallas TPU kernel: paged-attention decode (block-table K/V gather).

One query token per slot attends a KV cache that lives as **non-contiguous
physical pages**: ``k/v`` pools are ``(P, page_size, Hkv, D)`` with one page
on the leading axis, and each slot's ``block_table`` row names the physical
pages that make up its logical sequence.  The kernel never materializes the
gathered logical cache — the grid is ``(slots, pages_per_slot)`` with the
page axis iterating fastest, and the **scalar-prefetched** block table
drives the K/V BlockSpec index maps so each page is DMA'd into VMEM
directly from its arbitrary pool position (the vLLM PagedAttention access
pattern).  Running max / denominator / accumulator live in VMEM scratch
across one slot's page sweep (the same revisited-output-block pattern as
``flash_attention``).

Masking is the serving tier's ragged contract, evaluated per entry from the
page's ``pos_ids``: ``valid = (0 <= id <= pos) [and id > pos - window]`` —
so dense caches, sliding-window rings (arbitrary id layout within a page),
the permanently invalid null page (``id = -1``), and rows disabled with
``pos = -1`` (``n_valid = 0``) all fall out of one rule.

GQA is handled in-kernel: q ``(H, D)`` is reshaped to ``(Hkv, G, D)`` and
scored against the page's ``(page_size, Hkv, D)`` K with a batched
dot_general — no vmap over heads, one pallas_call per batch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, pos_ref, q_ref, k_ref, v_ref, ids_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_pages: int, hkv: int, g: int,
            scale: float, window: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    d = q_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32).reshape(hkv, g, d)
    k = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)  # (hkv, ps, d)
    v = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)
    # scores (hkv, g, ps): batched over kv heads, contracted over d
    s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32) * scale
    ids = ids_ref[0]  # (ps,) absolute positions; -1 = invalid / null page
    p_i = pos_ref[i]
    valid = (ids >= 0) & (ids <= p_i)
    if window:
        valid &= ids > p_i - window
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    # multiply (not just NEG_INF-mask) so a fully-masked row — the null
    # page, or pos = -1 — keeps l at exactly 0 (exp(NEG_INF - NEG_INF) is
    # 1, not 0) and finalizes to a zero output instead of mean(v)
    p = jnp.exp(s - m_new[..., None]) * valid[None, None, :]
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jax.lax.dot_general(
                        p, v, (((2,), (1,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        o = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = o.reshape(hkv * g, d).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q, k_pool, v_pool, ids_pool, block_table, pos, *,
                    window: int = 0, interpret: bool = True):
    """Paged single-token decode attention.

    q:(B,H,D), k/v pool:(P,ps,Hkv,D), ids pool:(P,ps) int32,
    block_table:(B,n_pages) int32 physical page ids, pos:(B,) int32 query
    positions (-1 disables a row -> zero output).  ``window`` > 0 adds the
    sliding-window bound.  Returns (B,H,D).
    """
    B, H, D = q.shape
    P, ps, Hkv, _ = k_pool.shape
    n_pages = block_table.shape[1]
    G = H // Hkv
    scale = 1.0 / (D ** 0.5)
    bt = jnp.asarray(block_table, jnp.int32).reshape(-1)  # (B * n_pages,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, n_pages),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda i, j, bt, pos: (i, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda i, j, bt, pos: (bt[i * n_pages + j], 0, 0, 0)),
            pl.BlockSpec((1, ps, Hkv, D),
                         lambda i, j, bt, pos: (bt[i * n_pages + j], 0, 0, 0)),
            pl.BlockSpec((1, ps),
                         lambda i, j, bt, pos: (bt[i * n_pages + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda i, j, bt, pos: (i, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G), jnp.float32),
            pltpu.VMEM((Hkv, G), jnp.float32),
            pltpu.VMEM((Hkv, G, D), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_pages=n_pages, hkv=Hkv, g=G,
                          scale=scale, window=window),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )(bt, jnp.asarray(pos, jnp.int32), q, k_pool, v_pool, ids_pool)
