"""Error-injected int8 matmul — the voltage over-scaling timing simulator.

TPU adaptation of the paper's post-P&R timing simulation (§III-D): instead of
gate-level simulating an FPGA netlist, we inject the *consequence* of timing
violations — bit flips in the 32-bit MAC accumulators, MSB/carry-weighted —
directly into the systolic matmul. The per-bit flip profile comes from
core/overscaling.error_profile.

Kernel: C[i,j] = sum_k A[i,k] * B[k,j] (int8 x int8 -> int32), then per
output element: with prob p_total flip one bit drawn from the bit-probability
distribution. Randomness enters as two uint32 planes (u_gate, u_bit) generated
outside (keeps the kernel deterministic and oracle-checkable).

BlockSpec tiling: (BM x BK) x (BK x BN) MXU-aligned blocks, K-major grid with
an int32 VMEM accumulator scratch (revisited output block pattern).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BM, BN, BK = 128, 128, 128


def _kernel(a_ref, b_ref, gate_ref, bit_ref, cdf_ref, c_ref, acc_ref, *,
            n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _finalize():
        acc = acc_ref[...]
        gate = gate_ref[...]  # uint32
        ubit = bit_ref[...]  # uint32
        cdf = cdf_ref[...]  # (33,) float32: [0, cdf..., p_total at end]
        p_total = cdf[-1]
        # flip gate: u < p_total (u uniform in [0,1))
        u = gate.astype(jnp.float32) * (1.0 / 4294967296.0)
        flip = u < p_total
        # bit index: inverse-cdf lookup of second uniform scaled to p_total
        u2 = ubit.astype(jnp.float32) * (1.0 / 4294967296.0) * p_total
        # cdf[1:33] are cumulative probs per bit; count how many are < u2
        bit_idx = jnp.sum(
            (u2[..., None] >= cdf[None, None, 1:]).astype(jnp.int32), axis=-1)
        bit_idx = jnp.clip(bit_idx, 0, 31)
        mask = jnp.where(flip, jnp.left_shift(jnp.int32(1), bit_idx), 0)
        c_ref[...] = jax.lax.bitwise_xor(acc, mask)


@functools.partial(jax.jit, static_argnames=("interpret",))
def overscale_matmul(a, b, u_gate, u_bit, cdf, *, interpret: bool = True):
    """a:(M,K) int8, b:(K,N) int8, u_gate/u_bit:(M,N) uint32,
    cdf:(33,) float32 -> (M,N) int32 with injected errors."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    Mp, Np, Kp = (-(-M // BM) * BM), (-(-N // BN) * BN), (-(-K // BK) * BK)
    a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    u_gate = jnp.pad(u_gate, ((0, Mp - M), (0, Np - N)))
    u_bit = jnp.pad(u_bit, ((0, Mp - M), (0, Np - N)))
    n_k = Kp // BK
    grid = (Mp // BM, Np // BN, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((33,), lambda i, j, k: (0,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.int32)],
        interpret=interpret,
    )(a, b, u_gate, u_bit, cdf)
    return out[:M, :N]


def bit_probs_to_cdf(bit_probs) -> jnp.ndarray:
    p = jnp.asarray(bit_probs, jnp.float32)
    cdf = jnp.concatenate([jnp.zeros((1,), jnp.float32), jnp.cumsum(p)])
    return cdf  # (33,); cdf[-1] = p_total


# --- quantization helpers + app-facing wrapper --------------------------------

def quantize(x, bits: int = 8):
    scale = jnp.max(jnp.abs(x)) / (2 ** (bits - 1) - 1) + 1e-9
    q = jnp.clip(jnp.round(x / scale), -(2 ** (bits - 1)), 2 ** (bits - 1) - 1)
    return q.astype(jnp.int8), scale


def make_int8_error_matmul(bit_probs, key, use_pallas: bool = False):
    """Returns matmul(a_f32, b_f32) -> f32 that quantizes, runs the
    error-injected int8 matmul (ref by default; pallas-interpret opt-in),
    and dequantizes with clipping (the fixed-point requantization step)."""
    from repro.kernels import ref as kref
    cdf = bit_probs_to_cdf(bit_probs)
    counter = [0]

    def mm(a, b):
        counter[0] += 1
        k1, k2 = jax.random.split(jax.random.fold_in(key, counter[0]))
        qa, sa = quantize(a)
        qb, sb = quantize(b)
        u_gate = jax.random.bits(k1, a.shape[:1] + b.shape[1:], jnp.uint32)
        u_bit = jax.random.bits(k2, a.shape[:1] + b.shape[1:], jnp.uint32)
        if use_pallas:
            acc = overscale_matmul(qa, qb, u_gate, u_bit, cdf)
        else:
            acc = kref.overscale_matmul_ref(qa, qb, u_gate, u_bit, cdf)
        # requantize with clipping at the CALIBRATED activation range (the
        # fixed-point pipeline's output scale): a flipped carry/MSB bit
        # saturates instead of exploding — the mechanism behind DNN tolerance.
        clean = jax.lax.dot_general(
            qa.astype(jnp.int32), qb.astype(jnp.int32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        lim = jnp.quantile(jnp.abs(clean.astype(jnp.float32)), 0.9995)
        out = jnp.clip(acc.astype(jnp.float32), -lim, lim) * sa * sb
        return out

    return mm
