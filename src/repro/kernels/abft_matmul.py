"""ABFT row/column-checksummed error-injected int8 matmul (§V).

Algorithm-based fault tolerance over the over-scaled MXU: the kernel runs
the same error-injected systolic matmul as ``overscale_matmul`` (int8 x
int8 -> int32 accumulators, MSB/carry-weighted bit flips at the final K
block) and *fuses* the row/column checksums of the corrupted product into
the same pass — no second trip over C in HBM.  Detection compares them
against the protected references

    row_ref = A @ colsum(B)        col_ref = rowsum(A) @ B

computed from the (clean) inputs; int32 arithmetic wraps mod 2^32 on both
sides, so a flipped bit b shows up as a +-2^b syndrome regardless of
accumulator overflow.  A single flipped element (i, j) satisfies
``dr[i] == dc[j]`` and is repaired exactly; see
``repro.tolerance.abft.detect_and_correct``.

Block structure mirrors ``overscale_matmul`` (K-major grid, int32 VMEM
accumulator scratch, flips at k == n_k-1).  The checksums come out as
per-block partial sums — ``rs_part[(i, j)]`` holds the rowsum of C's
(i, j) block broadcast over one lane tile, ``cs_part`` the colsum over one
sublane tile — written exactly once per block (no non-contiguous output
revisits), and are reduced outside the kernel (a (M, n_j) / (n_i, N) sum,
negligible next to the matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.overscale_matmul import BK, BM, BN

_LANE = 128   # lane tile carrying the broadcast row checksums
_SUB = 8      # sublane tile carrying the broadcast column checksums


def _kernel(a_ref, b_ref, gate_ref, bit_ref, cdf_ref, c_ref, rs_ref, cs_ref,
            acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _finalize():
        acc = acc_ref[...]
        gate = gate_ref[...]  # uint32
        ubit = bit_ref[...]  # uint32
        cdf = cdf_ref[...]  # (33,) float32
        p_total = cdf[-1]
        u = gate.astype(jnp.float32) * (1.0 / 4294967296.0)
        flip = u < p_total
        u2 = ubit.astype(jnp.float32) * (1.0 / 4294967296.0) * p_total
        bit_idx = jnp.sum(
            (u2[..., None] >= cdf[None, None, 1:]).astype(jnp.int32), axis=-1)
        bit_idx = jnp.clip(bit_idx, 0, 31)
        mask = jnp.where(flip, jnp.left_shift(jnp.int32(1), bit_idx), 0)
        c = jax.lax.bitwise_xor(acc, mask)
        c_ref[...] = c
        # fused checksums OF THE CORRUPTED PRODUCT: the syndromes vs the
        # protected references localize exactly the injected flips
        rs_ref[...] = jnp.broadcast_to(
            jnp.sum(c, axis=1, keepdims=True), rs_ref.shape)
        cs_ref[...] = jnp.broadcast_to(
            jnp.sum(c, axis=0, keepdims=True), cs_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def abft_matmul(a, b, u_gate, u_bit, cdf, *, interpret: bool = True):
    """a:(M,K) int8, b:(K,N) int8, u_gate/u_bit:(M,N) uint32, cdf:(33,)
    float32 -> (c:(M,N) int32 with injected errors, rowsum:(M,) int32,
    colsum:(N,) int32) — checksums of the corrupted product."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    Mp, Np, Kp = (-(-M // BM) * BM), (-(-N // BN) * BN), (-(-K // BK) * BK)
    a = jnp.pad(a, ((0, Mp - M), (0, Kp - K)))
    b = jnp.pad(b, ((0, Kp - K), (0, Np - N)))
    # pad the gate planes with u ~= 1.0 (never < p_total): a flip injected
    # into the zero padding would poison the fused checksums
    full = np.uint32(0xFFFFFFFF)
    u_gate = jnp.pad(u_gate, ((0, Mp - M), (0, Np - N)), constant_values=full)
    u_bit = jnp.pad(u_bit, ((0, Mp - M), (0, Np - N)), constant_values=full)
    n_k = Kp // BK
    n_i, n_j = Mp // BM, Np // BN
    grid = (n_i, n_j, n_k)
    c, rs_part, cs_part = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, k: (i, k)),
            pl.BlockSpec((BK, BN), lambda i, j, k: (k, j)),
            pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((33,), lambda i, j, k: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((BM, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BM, _LANE), lambda i, j, k: (i, j)),
            pl.BlockSpec((_SUB, BN), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
            jax.ShapeDtypeStruct((Mp, n_j * _LANE), jnp.int32),
            jax.ShapeDtypeStruct((n_i * _SUB, Np), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.int32)],
        interpret=interpret,
    )(a, b, u_gate, u_bit, cdf)
    # reduce the per-block partials (int32 wraps commute with the split)
    rowsum = jnp.sum(rs_part.reshape(Mp, n_j, _LANE)[:, :, 0], axis=1)
    colsum = jnp.sum(cs_part.reshape(n_i, _SUB, Np)[:, 0, :], axis=0)
    return c[:M, :N], rowsum[:M], colsum[:N]


def checksum_refs(a, b):
    """Protected checksum references from the (clean) int8 inputs:
    ``row_ref = A @ colsum(B)``, ``col_ref = rowsum(A) @ B`` — int32,
    wrapping mod 2^32 exactly like the accumulators they guard."""
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    return a32 @ jnp.sum(b32, axis=1), jnp.sum(a32, axis=0) @ b32
