"""Pallas TPU kernel: fused multi-sweep Jacobi thermal stencil.

Hot loop of the HotSpot-style steady-state solver (core/thermal.py). The
FPGA/TPU thermal grids are small (92x92 .. 256x256 -> <= 256 KB fp32), so the
TPU-native tiling is: keep the WHOLE grid resident in VMEM and fuse K Jacobi
sweeps inside one ``pallas_call`` (a ``fori_loop`` in-kernel), cutting
HBM<->VMEM round-trips by K versus K separate XLA iterations. This is the
hardware-adaptation analogue of blocking for cache: VMEM (~16 MB) dwarfs the
working set, so the bottleneck is launch/HBM overhead, not compute.

Block layout: grid=(1,), whole-array BlockSpecs in VMEM; the neighbour sum is
computed with in-kernel shifts (jnp.pad/slice lower to vector ops on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(T_ref, P_ref, diag_ref, o_ref, *, g_lat: float, g_v_tamb: float,
            iters: int):
    P = P_ref[...]
    diag = diag_ref[...]

    def nbr(T):
        up = jnp.pad(T[1:, :], ((0, 1), (0, 0)))
        dn = jnp.pad(T[:-1, :], ((1, 0), (0, 0)))
        lf = jnp.pad(T[:, 1:], ((0, 0), (0, 1)))
        rt = jnp.pad(T[:, :-1], ((0, 0), (1, 0)))
        return up + dn + lf + rt

    def body(_, T):
        return (P + g_v_tamb + g_lat * nbr(T)) / diag

    o_ref[...] = jax.lax.fori_loop(0, iters, body, T_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("iters", "g_lat", "g_v_tamb", "interpret"))
def thermal_stencil(T, P, diag, *, g_lat: float, g_v_tamb: float,
                    iters: int = 64, interpret: bool = True):
    """K fused Jacobi sweeps. T,P,diag: (m,n) fp32 -> (m,n) fp32."""
    m, n = T.shape
    spec = pl.BlockSpec((m, n), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, g_lat=float(g_lat),
                          g_v_tamb=float(g_v_tamb), iters=iters),
        grid=(),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(T.astype(jnp.float32), P.astype(jnp.float32), diag.astype(jnp.float32))
