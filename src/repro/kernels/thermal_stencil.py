"""Pallas TPU kernel: fused multi-sweep thermal stencil (Jacobi or red-black).

Hot loop of the HotSpot-style steady-state solver (core/thermal.py). The
FPGA/TPU thermal grids are small (92x92 .. 256x256 -> <= 256 KB fp32), so the
TPU-native tiling is: keep the WHOLE grid resident in VMEM and fuse K sweeps
inside one ``pallas_call`` (a ``fori_loop`` in-kernel), cutting HBM<->VMEM
round-trips by K versus K separate XLA iterations. This is the
hardware-adaptation analogue of blocking for cache: VMEM (~16 MB) dwarfs the
working set, so the bottleneck is launch/HBM overhead, not compute.

Two sweep flavours share the kernel body:

- ``phase=None`` — K Jacobi sweeps (the legacy fused relaxation);
- ``phase=0|1``  — K red-black Gauss-Seidel sweeps starting on that
  checkerboard colour: the multigrid smoother of ``core.thermal``. Each
  sweep updates one colour from the *freshly written* other colour, which
  is what gives RB-GS its 2x Jacobi smoothing rate; the colour masks are
  2D ``broadcasted_iota`` parities, which lower to vector ops on TPU.

``interpret`` defaults to auto-detection: compiled on a TPU backend,
interpreter everywhere else (the kwarg remains an explicit override).

Block layout: grid=(), whole-array BlockSpecs in VMEM; the neighbour sum is
computed with in-kernel shifts (jnp.pad/slice lower to vector ops on TPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(T_ref, P_ref, diag_ref, o_ref, *, g_lat: float, g_v_tamb: float,
            iters: int, phase: Optional[int]):
    P = P_ref[...]
    diag = diag_ref[...]

    def nbr(T):
        up = jnp.pad(T[1:, :], ((0, 1), (0, 0)))
        dn = jnp.pad(T[:-1, :], ((1, 0), (0, 0)))
        lf = jnp.pad(T[:, 1:], ((0, 0), (0, 1)))
        rt = jnp.pad(T[:, :-1], ((0, 0), (1, 0)))
        return up + dn + lf + rt

    if phase is None:
        def body(_, T):
            return (P + g_v_tamb + g_lat * nbr(T)) / diag
    else:
        m, n = P_ref.shape
        row = jax.lax.broadcasted_iota(jnp.int32, (m, n), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (m, n), 1)
        par = (row + col) % 2

        def body(_, T):
            for p in (phase, 1 - phase):
                T = jnp.where(par == p,
                              (P + g_v_tamb + g_lat * nbr(T)) / diag, T)
            return T

    o_ref[...] = jax.lax.fori_loop(0, iters, body, T_ref[...])


@functools.partial(jax.jit, static_argnames=("iters", "g_lat", "g_v_tamb",
                                             "phase", "interpret"))
def thermal_stencil(T, P, diag, *, g_lat: float, g_v_tamb: float,
                    iters: int = 64, phase: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """K fused sweeps. T,P,diag: (m,n) fp32 -> (m,n) fp32.

    ``phase=None`` runs Jacobi sweeps; ``phase=0|1`` runs red-black
    Gauss-Seidel sweeps starting on that colour.  ``interpret=None``
    auto-selects: compiled on TPU, interpreter elsewhere.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = T.shape
    spec = pl.BlockSpec((m, n), lambda: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, g_lat=float(g_lat),
                          g_v_tamb=float(g_v_tamb), iters=iters, phase=phase),
        grid=(),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(T.astype(jnp.float32), P.astype(jnp.float32), diag.astype(jnp.float32))
