"""jit'd public wrappers around the Pallas kernels.

``INTERPRET = None`` (the default) auto-detects per call: compiled on a TPU
backend, interpreter everywhere else (interpret mode executes the kernel
body for correctness validation on CPU). Set ``repro.kernels.ops.INTERPRET``
to True/False to force a mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.abft_matmul import abft_matmul as _abft, checksum_refs
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.overscale_matmul import (bit_probs_to_cdf,
                                            make_int8_error_matmul,
                                            overscale_matmul as _omm,
                                            quantize)
from repro.kernels.paged_attention import paged_attention as _paged
from repro.kernels.thermal_stencil import thermal_stencil as _stencil

INTERPRET = None  # None = auto (compiled on TPU, interpreter elsewhere)


def _interpret() -> bool:
    return (jax.default_backend() != "tpu" if INTERPRET is None
            else INTERPRET)


def flash_attention_bh(q, k, v, *, causal=True, bq=128, bk=128):
    """Batched/multi-head wrapper: q:(B,S,H,D), k/v:(B,T,H,D)."""
    def one(q1, k1, v1):
        return _flash(q1, k1, v1, causal=causal, bq=bq, bk=bk,
                      interpret=_interpret())

    return jax.vmap(jax.vmap(one, in_axes=(1, 1, 1), out_axes=1))(q, k, v)


def paged_attention_decode(q, k_pool, v_pool, ids_pool, block_table, pos, *,
                           window=0):
    """Paged single-token decode: q:(B,H,D), pools:(P,ps,Hkv,D)/(P,ps),
    block_table:(B,n_pages) physical page ids, pos:(B,) query positions."""
    return _paged(q, k_pool, v_pool, ids_pool, block_table, pos,
                  window=window, interpret=_interpret())


def mamba_scan_b(xh, dt, A, B, C, *, chunk=256):
    """Batched wrapper: xh:(b,S,H,P), dt:(b,S,H), B/C:(b,S,H,N)."""
    def one(x1, d1, b1, c1):
        return _mamba(x1, d1, A, b1, c1, chunk=chunk, interpret=_interpret())

    return jax.vmap(one)(xh, dt, B, C)


def thermal_sweep(T, P, diag, *, g_lat, g_v_tamb, iters=64, phase=None):
    return _stencil(T, P, diag, g_lat=g_lat, g_v_tamb=g_v_tamb, iters=iters,
                    phase=phase, interpret=_interpret())


def overscale_mm(a, b, u_gate, u_bit, cdf):
    return _omm(a, b, u_gate, u_bit, cdf, interpret=_interpret())


def abft_mm(a, b, u_gate, u_bit, cdf):
    """Error-injected int8 matmul with fused row/column checksums:
    -> (c, rowsum, colsum)."""
    return _abft(a, b, u_gate, u_bit, cdf, interpret=_interpret())
