"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True in this CPU container (TPU is the compile
TARGET; interpret mode executes the kernel body for correctness validation).
On real TPU runtimes set ``repro.kernels.ops.INTERPRET = False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.mamba_scan import mamba_scan as _mamba
from repro.kernels.overscale_matmul import (bit_probs_to_cdf,
                                            make_int8_error_matmul,
                                            overscale_matmul as _omm,
                                            quantize)
from repro.kernels.thermal_stencil import thermal_stencil as _stencil

INTERPRET = True


def flash_attention_bh(q, k, v, *, causal=True, bq=128, bk=128):
    """Batched/multi-head wrapper: q:(B,S,H,D), k/v:(B,T,H,D)."""
    def one(q1, k1, v1):
        return _flash(q1, k1, v1, causal=causal, bq=bq, bk=bk,
                      interpret=INTERPRET)

    return jax.vmap(jax.vmap(one, in_axes=(1, 1, 1), out_axes=1))(q, k, v)


def mamba_scan_b(xh, dt, A, B, C, *, chunk=256):
    """Batched wrapper: xh:(b,S,H,P), dt:(b,S,H), B/C:(b,S,H,N)."""
    def one(x1, d1, b1, c1):
        return _mamba(x1, d1, A, b1, c1, chunk=chunk, interpret=INTERPRET)

    return jax.vmap(one)(xh, dt, B, C)


def thermal_sweep(T, P, diag, *, g_lat, g_v_tamb, iters=64):
    return _stencil(T, P, diag, g_lat=g_lat, g_v_tamb=g_v_tamb, iters=iters,
                    interpret=INTERPRET)


def overscale_mm(a, b, u_gate, u_bit, cdf):
    return _omm(a, b, u_gate, u_bit, cdf, interpret=INTERPRET)
