"""Pallas TPU kernel: FlashAttention (blockwise online-softmax), causal.

Single-head formulation q:(S,D), k/v:(T,D); batch x heads handled by ``vmap``
over the ``pallas_call`` (maps onto leading grid dimensions). Grid is
(num_q_blocks, num_kv_blocks) with the kv axis iterating fastest; the running
max / denominator / accumulator live in VMEM scratch that persists across the
kv sweep for one q block (the canonical revisited-output-block pattern).

BlockSpec tiling: q/o blocks (BQ, D), k/v blocks (BK, D) — MXU-aligned for
D in {64, 128, 256}; the (BQ, BK) score tile stays in registers/VMEM and the
(S, T) score matrix is never materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, n_k: int, causal: bool, scale: float):
    iq = pl.program_id(0)
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(kpos <= qpos, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p, v_ref[...].astype(jnp.float32),
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q:(S,D), k/v:(T,D) -> (S,D). vmap for batch/heads."""
    S, D = q.shape
    T = k.shape[0]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    n_q, n_k = S // bq, T // bk
    scale = 1.0 / (D ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                          scale=scale),
        grid=(n_q, n_k),
        in_specs=[
            pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, D), lambda i, j: (j, 0)),
            pl.BlockSpec((bk, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
