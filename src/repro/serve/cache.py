"""Paged KV-cache management for the continuous-batching engine.

``KVCacheManager`` owns the decode-cache pytree for a fixed set of slots and
all per-slot bookkeeping the scheduler needs:

- **per-slot positions** — ``pos[slot]`` is each slot's next decode position;
  there is no global aligned position, so requests at different depths share
  one fused decode step (the ragged ``pos``/``n_valid`` contract of
  ``Model.decode``).
- **slot recycling** — freeing a slot returns its pages to the pool and
  invalidates its ``pos_ids`` rows; the arrays are allocated once, so cache
  memory never grows with request count.
- **page accounting** — capacity is tracked in fixed-size pages
  (``page_size`` tokens); ``pages_in_use``/``peak_pages`` expose occupancy to
  the admission controller.  The counter is maintained *incrementally* on
  allocate/free/advance/restore (it sits on the per-tick admission hot
  path); ``recount_pages()`` recomputes it from scratch for verification.
- **batch-axis probing** — the cache pytree mixes leaf ranks (attention K/V,
  SSM conv/ssm states, cross-attn K/V, stacked layer dims), so the manager
  finds each leaf's batch axis *structurally*: build the abstract cache at
  two batch sizes and diff the shapes. Scatter/gather then move that axis to
  the front — no shape-matching heuristics (which break when a layer count
  equals the slot count).

``ExpandableKVCacheManager`` (modeled on foundation-model-stack's
ExpandableKVCacheManager) starts with a small sequence capacity and doubles
it on demand up to ``max_len``: sequence axes are probed the same way, new
space is zero-filled except ``pos_ids`` (filled with -1 = invalid).

``PagedKVCacheManager`` makes pages *real* (vLLM-style): the device cache is
a pool of ``total_pages`` physical pages (pages carried on the probed batch
axis, ``page_size`` tokens on the probed sequence axis) plus one permanently
invalid **null page**; each slot owns a block table mapping logical page
index -> physical page, filled from a free-list :class:`PageAllocator` at
``page_size`` granularity.  Layout is non-contiguous by construction — any
free page serves any slot, so admission never fails on fragmentation.  The
engine's fused step gathers a slot-contiguous logical cache through the
block tables, runs the *unchanged* ``Model.decode``, and scatters the pages
back — identical ops on identical visible values, so outputs stay bitwise
identical to the contiguous manager.  Freed/trimmed pages get their
``pos_ids`` invalidated before returning to the pool so a recycled page can
never leak stale entries through another slot's attention mask.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


NO_AXIS = -1  # sentinel: None leaves would vanish from the pytree


def _probe_axes(model, make_a, make_b):
    """Per-leaf axis where two abstract cache builds disagree (else NO_AXIS)."""
    a = make_a()
    b = make_b()

    def diff(x, y):
        for i, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return i
        return NO_AXIS

    return jax.tree_util.tree_map(diff, a, b)


def _is_pos_ids(path) -> bool:
    for p in path:
        if getattr(p, "key", None) == "pos_ids":
            return True
    return False


class KVCacheManager:
    """Fixed-capacity paged cache over ``slots`` rows of length ``max_len``."""

    def __init__(self, model, slots: int, max_len: int,
                 page_size: int = 16, alloc: bool = True):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.batch_axes = _probe_axes(
            model,
            lambda: model.cache(slots, max_len, abstract=True),
            lambda: model.cache(slots + 1, max_len, abstract=True))
        if alloc:
            self.cache = model.cache(slots, max_len)
        # host-side bookkeeping (no device sync needed to schedule)
        self.pos = np.zeros(slots, np.int32)        # next decode position
        self.lengths = np.zeros(slots, np.int32)    # prompt length
        self._free: List[int] = list(range(slots))
        self._pages_per_slot = math.ceil(max_len / page_size)
        self.peak_pages = 0
        # incremental page accounting: per-slot page counts + running total,
        # updated on allocate/free/advance/restore (admission reads
        # pages_in_use every tick — no O(slots) recount on the hot path)
        self._slot_pages = np.zeros(slots, np.int32)
        self._pages_in_use = 0

        def _scatter(cache, rows, slot_ids):
            def put(ax, ec, pc):
                if ax == NO_AXIS:
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                pcm = jnp.moveaxis(pc, ax, 0)
                ecm = ecm.at[slot_ids].set(pcm.astype(ecm.dtype))
                return jnp.moveaxis(ecm, 0, ax)

            return jax.tree_util.tree_map(put, self.batch_axes, cache, rows)

        def _invalidate(cache, slot_ids):
            def inv(path, ax, ec):
                if ax == NO_AXIS or not _is_pos_ids(path):
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                ecm = ecm.at[slot_ids].set(-1)
                return jnp.moveaxis(ecm, 0, ax)

            return jax.tree_util.tree_map_with_path(
                inv, self.batch_axes, cache)

        def _gather(cache, slot_ids):
            def take(ax, ec):
                if ax == NO_AXIS:
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                return jnp.moveaxis(ecm[slot_ids], 0, ax)

            return jax.tree_util.tree_map(take, self.batch_axes, cache)

        self._scatter = jax.jit(_scatter)
        self._invalidate = jax.jit(_invalidate)
        self._gather = jax.jit(_gather)

    # -- slot lifecycle -------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return list(self._free)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self._free]

    def _set_slot_pages(self, slot: int, n: int) -> None:
        self._pages_in_use += n - int(self._slot_pages[slot])
        self._slot_pages[slot] = n
        self.peak_pages = max(self.peak_pages, self._pages_in_use)

    def allocate(self, prompt_len: int) -> int:
        """Claim a free slot for a request; returns the slot id."""
        slot = self._free.pop(0)
        self.pos[slot] = 0
        self.lengths[slot] = prompt_len
        self._set_slot_pages(slot, 1)  # an allocated slot holds >= 1 page
        return slot

    def free(self, slot: int):
        """Recycle a slot: pages return to the pool, row marked invalid.

        Raises on double-free or free-of-unallocated: a silent accept
        would duplicate the slot in the free list, hand it to two requests
        at once, and corrupt the page accounting."""
        if not 0 <= slot < self.slots:
            raise ValueError(
                f"free of invalid slot {slot} (valid: 0..{self.slots - 1})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self.pos[slot] = 0
        self.lengths[slot] = 0
        self._set_slot_pages(slot, 0)
        self._free.append(slot)
        self.cache = self._invalidate(self.cache, jnp.asarray([slot]))

    # -- page accounting ------------------------------------------------------
    @property
    def total_pages(self) -> int:
        return self.slots * self._pages_per_slot

    @property
    def pages_in_use(self) -> int:
        return self._pages_in_use

    @property
    def free_pages(self) -> int:
        return self.total_pages - self._pages_in_use

    def slot_pages(self, slot: int) -> int:
        return int(self._slot_pages[slot])

    def recount_pages(self) -> int:
        """Recompute page occupancy from scratch (O(slots)) — the reference
        the incremental counter is pinned against in tests."""
        used = 0
        for s in range(self.slots):
            if s in self._free:
                continue
            used += max(1, math.ceil(int(self.pos[s]) / self.page_size))
        return used

    # -- cache writes ---------------------------------------------------------
    def write_rows(self, slot_ids, rows):
        """Scatter prefilled cache rows (batch == len(slot_ids)) into slots."""
        self.cache = self._scatter(self.cache, rows,
                                   jnp.asarray(slot_ids, jnp.int32))

    def read_rows(self, slot_ids):
        """Gather cache rows (batch == len(slot_ids)) out of slots — the
        device->host read of thermal-emergency preemption."""
        return self._gather(self.cache, jnp.asarray(slot_ids, jnp.int32))

    def restore(self, slot: int, rows, pos: int):
        """Scatter one preempted row set back into a (re)allocated slot and
        rewind its decode position — the resume half of preemption.  Rows
        captured before an :class:`ExpandableKVCacheManager` growth are
        padded out to the current leaf shapes (fill -1 for ``pos_ids``)."""

        def fit(path, ax, row, cur):
            widths, need = [], False
            for i, (r, c) in enumerate(zip(row.shape, cur.shape)):
                if i == ax:
                    widths.append((0, 0))
                else:
                    widths.append((0, max(c - r, 0)))
                    need = need or c > r
            if not need:
                return row
            fill = -1 if _is_pos_ids(path) else 0
            return jnp.pad(jnp.asarray(row), widths, constant_values=fill)

        rows = jax.tree_util.tree_map_with_path(
            fit, self.batch_axes, rows, self.cache)
        self.write_rows([slot], rows)
        self.pos[slot] = int(pos)
        self._set_slot_pages(
            slot, max(1, math.ceil(int(pos) / self.page_size)))

    def advance(self, slot_ids, counts):
        for s, n in zip(slot_ids, counts):
            self.pos[s] += int(n)
            self._set_slot_pages(
                s, max(1, math.ceil(int(self.pos[s]) / self.page_size)))


class HostPagePool:
    """Host-side page pool for preempted requests: evicted KV rows live in
    host memory (``jax.device_get``) keyed by request id until resumption.
    The device cache slot is freed meanwhile — preemption actually returns
    pages to the admission pool, it does not just hide them.

    Accounting is **page-exact**: ``put`` records how many device pages the
    eviction actually released (a short request holds fewer pages than its
    slot's full span), so ``pages_held``/``peak_pages`` match the allocator
    ledger instead of over-counting whole slots.

    Migration contract (DESIGN.md §10): when pods share one pool, each
    entry carries a provenance ledger — the *origin* allocator, the device
    page ids the eviction covered, and whether the origin actually freed
    them.  ``take(owner=...)`` hard-errors on a cross-allocator resume
    whose origin still owns the pages (resuming would double-represent the
    KV: the stale block table could still scatter into them) and on a
    resume whose position cannot fit the target allocator's block-table
    span — both print the ledger instead of silently corrupting state."""

    def __init__(self):
        self._rows: Dict[Any, Any] = {}
        self._ledger: Dict[Any, Dict[str, Any]] = {}
        self.puts = 0
        self.peak = 0
        self.pages_held = 0   # device pages currently parked host-side
        self.pages_evicted = 0  # cumulative pages moved to host
        self.peak_pages = 0
        self.migrations = 0   # cross-allocator resumes (pod -> pod)

    def put(self, rid, rows, pos: int, pages: int = 1, *,
            owner=None, page_ids=None, freed: bool = True) -> None:
        self._rows[rid] = (jax.device_get(rows), int(pos), int(pages))
        self._ledger[rid] = {
            "owner": owner,
            "page_ids": (None if page_ids is None
                         else [int(p) for p in np.asarray(page_ids).ravel()]),
            "freed": bool(freed),
        }
        self.puts += 1
        self.peak = max(self.peak, len(self._rows))
        self.pages_held += int(pages)
        self.pages_evicted += int(pages)
        self.peak_pages = max(self.peak_pages, self.pages_held)

    def put_pages(self, rid) -> int:
        """Pages a parked request holds (0 if not parked)."""
        entry = self._rows.get(rid)
        return 0 if entry is None else entry[2]

    def ledger(self, rid) -> Optional[Dict[str, Any]]:
        """Provenance of a parked request (origin allocator, device page
        ids, freed flag); None if unknown."""
        return self._ledger.get(rid)

    def take(self, rid, *, owner=None):
        """Pop (rows, pos) for a request being resumed.

        ``owner`` is the allocator about to receive the rows; pass it on
        every resume so cross-pod migrations are checked against the
        provenance ledger recorded at eviction time."""
        led = self._ledger.get(rid, {})
        rows, pos, pages = self._rows[rid]
        if owner is not None:
            origin = led.get("owner")
            foreign = origin is not None and origin is not owner
            if foreign and not led.get("freed", True):
                raise RuntimeError(
                    f"HostPagePool: refusing to resume request {rid!r} into "
                    f"a foreign allocator while its origin still owns the "
                    f"evicted pages (resume would scatter into a stale "
                    f"block table); ledger={led}")
            cap = getattr(owner, "max_len", None)
            if cap is not None and int(pos) > int(cap):
                raise RuntimeError(
                    f"HostPagePool: request {rid!r} parked at pos={pos} "
                    f"exceeds the target allocator's max_len {cap}; "
                    f"ledger={led}")
            if foreign:
                self.migrations += 1
        del self._rows[rid]
        self._ledger.pop(rid, None)
        self.pages_held -= pages
        return rows, pos

    def __contains__(self, rid) -> bool:
        return rid in self._rows

    def __len__(self) -> int:
        return len(self._rows)


class ExpandableKVCacheManager(KVCacheManager):
    """Starts at ``initial_len`` sequence capacity, doubles up to ``max_len``.

    Growth re-allocates only the leaves that actually carry a sequence axis
    (probed structurally — SSM states and window-clamped ring buffers are
    left alone), zero-padding K/V and padding ``pos_ids`` with -1.
    """

    def __init__(self, model, slots: int, max_len: int,
                 initial_len: int = 64, page_size: int = 16):
        initial_len = min(initial_len, max_len)
        super().__init__(model, slots, max_len, page_size, alloc=False)
        self.capacity = initial_len
        self.cache = model.cache(slots, initial_len)
        self.grows = 0

    def _seq_axes(self, old_len: int, new_len: int):
        return _probe_axes(
            self.model,
            lambda: self.model.cache(self.slots, old_len, abstract=True),
            lambda: self.model.cache(self.slots, new_len, abstract=True))

    def ensure(self, needed: int):
        """Grow capacity (doubling) until >= needed tokens per slot."""
        if needed <= self.capacity:
            return
        new_cap = self.capacity
        while new_cap < needed:
            new_cap = min(new_cap * 2, self.max_len)
            if new_cap == self.capacity:
                raise ValueError(
                    f"request needs {needed} tokens; max_len={self.max_len}")
        seq_axes = self._seq_axes(self.capacity, new_cap)

        def grow(path, ax, leaf):
            if ax == NO_AXIS:
                return leaf
            pad = new_cap - leaf.shape[ax]
            widths = [(0, 0)] * leaf.ndim
            widths[ax] = (0, pad)
            fill = -1 if _is_pos_ids(path) else 0
            return jnp.pad(leaf, widths, constant_values=fill)

        self.cache = jax.tree_util.tree_map_with_path(
            grow, seq_axes, self.cache)
        self.capacity = new_cap
        self.grows += 1


# =============================================================================
# true paged attention: free-list allocator + block-table managers
# =============================================================================


class PageAllocator:
    """Free-list allocator over ``total_pages`` physical pages.

    O(1) alloc/free with an ownership bitmap guarding double-frees — the
    same silent-corruption class the slot free list guards against."""

    def __init__(self, total_pages: int):
        self.total = int(total_pages)
        self._free: List[int] = list(range(self.total))
        self._owned = np.zeros(self.total, bool)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.total - len(self._free)

    def alloc(self, n: int) -> List[int]:
        """Claim ``n`` pages; raises when the pool cannot cover them (the
        engine preempts *before* extending, so this firing means a bug)."""
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: want {n}, have {len(self._free)}")
        take, self._free = self._free[:n], self._free[n:]
        for p in take:
            self._owned[p] = True
        return take

    def free(self, pages) -> None:
        for p in pages:
            p = int(p)
            if not 0 <= p < self.total:
                raise ValueError(
                    f"free of invalid page {p} (valid: 0..{self.total - 1})")
            if not self._owned[p]:
                raise ValueError(f"double free of page {p}")
            self._owned[p] = False
            self._free.append(p)


class PagedKVCacheManager:
    """Block-table KV cache: non-contiguous pages behind the same slot API.

    The device cache is ``model.cache(total_pages + 1, page_size)`` — the
    probed batch axis carries physical pages, the probed sequence axis
    carries ``page_size`` tokens, and index ``total_pages`` is the **null
    page**: permanently invalid (``pos_ids = -1``), the target of every
    unallocated block-table entry (so gathers never index negatively and
    padded-tail writes land somewhere inert).

    ``gather_logical``/``scatter_logical`` convert between the pool and the
    slot-contiguous logical layout ``Model.decode`` expects; they are plain
    traceable functions so the engine can fuse gather -> decode -> scatter
    into one jitted step.  Because the gathered logical cache is bitwise
    equal to the contiguous manager's cache at every mask-visible entry
    (and ``pos_ids`` equal everywhere — freed pages are invalidated), the
    paged engine's logits are bitwise identical to the contiguous path.
    """

    def __init__(self, model, slots: int, max_len: int,
                 page_size: int = 16, total_pages: Optional[int] = None):
        cfg = getattr(model, "cfg", None)
        window = getattr(cfg, "sliding_window", 0) or 0
        if window and window <= page_size:
            raise ValueError(
                f"page_size {page_size} must be < sliding_window {window}")
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        # logical per-slot sequence extent: probe the contiguous abstract
        # build — ring caches clamp at min(max_len, window)
        ref = model.cache(slots, max_len, abstract=True)
        ref_axes = _probe_axes(
            model,
            lambda: model.cache(slots, max_len, abstract=True),
            lambda: model.cache(slots + 1, max_len, abstract=True))
        seq_ref = _probe_axes(
            model,
            lambda: model.cache(slots, page_size, abstract=True),
            lambda: model.cache(slots, 2 * page_size, abstract=True))
        extents = set()
        for (ba, sa, leaf) in zip(jax.tree_util.tree_leaves(ref_axes),
                                  jax.tree_util.tree_leaves(seq_ref),
                                  jax.tree_util.tree_leaves(ref)):
            if ba == NO_AXIS:
                continue
            if sa == NO_AXIS:
                raise ValueError(
                    "paged cache requires every per-slot leaf to carry a "
                    "sequence axis (recurrent SSM/hybrid state cannot be "
                    "paged — use the contiguous manager)")
            extents.add(leaf.shape[sa])
        if not extents:
            raise ValueError("model cache has no per-slot leaves to page")
        if len(extents) > 1:
            raise ValueError(
                f"per-slot leaves disagree on sequence extent: {extents}")
        self.seq_len = extents.pop()
        if self.seq_len % page_size:
            raise ValueError(
                f"sequence extent {self.seq_len} not divisible by "
                f"page_size {page_size}")
        self.pages_per_slot = self.seq_len // page_size
        self.total_pages = (slots * self.pages_per_slot
                            if total_pages is None else int(total_pages))
        self.null_page = self.total_pages
        n_pool = self.total_pages + 1
        self.batch_axes = _probe_axes(
            model,
            lambda: model.cache(n_pool, page_size, abstract=True),
            lambda: model.cache(n_pool + 1, page_size, abstract=True))
        self.seq_axes = _probe_axes(
            model,
            lambda: model.cache(n_pool, page_size, abstract=True),
            lambda: model.cache(n_pool, 2 * page_size, abstract=True))
        self.pool = model.cache(n_pool, page_size)
        self.allocator = PageAllocator(self.total_pages)
        self.block_table = np.full((slots, self.pages_per_slot),
                                   self.null_page, np.int32)
        # host-side bookkeeping, mirroring KVCacheManager
        self.pos = np.zeros(slots, np.int32)
        self.lengths = np.zeros(slots, np.int32)
        self._free: List[int] = list(range(slots))
        self._slot_pages = np.zeros(slots, np.int32)
        self._pages_in_use = 0
        self.peak_pages = 0

        def _invalidate_pages(pool, page_ids):
            def inv(path, ba, pc):
                if ba == NO_AXIS or not _is_pos_ids(path):
                    return pc
                pcm = jnp.moveaxis(pc, ba, 0)
                pcm = pcm.at[page_ids].set(-1)
                return jnp.moveaxis(pcm, 0, ba)

            return jax.tree_util.tree_map_with_path(
                inv, self.batch_axes, pool)

        self._invalidate_pages = jax.jit(_invalidate_pages)
        self._gather = jax.jit(self.gather_logical)
        self._scatter = jax.jit(self.scatter_logical)

    # -- pool <-> logical layout (traceable; fused into the engine step) ------
    def gather_logical(self, pool, bt):
        """Gather block tables ``bt`` (n, pages) into a slot-contiguous
        logical cache (n, pages*page_size) — what ``Model.decode`` sees."""
        ps = self.page_size

        def take(ba, sa, leaf):
            if ba == NO_AXIS:
                return leaf
            x = jnp.moveaxis(leaf, (ba, sa), (0, 1))
            g = x[bt]  # (n, pages, page_size, ...)
            g = g.reshape((bt.shape[0], bt.shape[1] * ps) + x.shape[2:])
            return jnp.moveaxis(g, (0, 1), (ba, sa))

        return jax.tree_util.tree_map(
            take, self.batch_axes, self.seq_axes, pool)

    def inverse_map(self) -> np.ndarray:
        """Host-side inverse of the block tables: physical page -> flat
        logical page index (``slot * width + j``), or ``slots * width``
        (the fill source) for unallocated pages and the null page.  Valid
        because the allocator hands each page to exactly one slot, so the
        full-batch scatter is a permutation — :meth:`scatter_all` replays
        it as a cheap gather instead of an XLA scatter."""
        B, W = self.block_table.shape
        inv = np.full(self.total_pages + 1, B * W, np.int32)
        flat = self.block_table.reshape(-1)
        idx = np.arange(B * W, dtype=np.int32)
        alloc = flat != self.null_page
        inv[flat[alloc]] = idx[alloc]
        return inv

    def scatter_all(self, pool, logical, inv):
        """Write the full-batch logical cache back into the pool through
        the :meth:`inverse_map` — one gather per leaf (no scatter op on
        the hot path).  Unallocated pages and the null page come out as
        the fill (``pos_ids = -1``, zeros elsewhere), so stale entries and
        the aliased null writes stay inert by construction."""
        ps = self.page_size

        def put(path, ba, sa, pc, lg):
            if ba == NO_AXIS:
                return pc
            x = jnp.moveaxis(pc, (ba, sa), (0, 1))
            v = jnp.moveaxis(lg, (ba, sa), (0, 1))
            v = v.reshape((-1, ps) + x.shape[2:]).astype(x.dtype)
            fill = -1 if _is_pos_ids(path) else 0
            pad = jnp.full((1,) + v.shape[1:], fill, x.dtype)
            out = jnp.concatenate([v, pad], axis=0)[inv]
            return jnp.moveaxis(out, (0, 1), (ba, sa))

        return jax.tree_util.tree_map_with_path(
            put, self.batch_axes, self.seq_axes, pool, logical)

    def scatter_logical(self, pool, logical, bt):
        """Scatter a logical cache back into the pool through ``bt`` (the
        subset path — ``write_rows``/``restore``; the fused engine step
        uses :meth:`scatter_all`).  The null page is re-zeroed
        (``pos_ids = -1``) afterwards: every slot's unallocated entries
        alias it, so it must stay inert."""
        ps = self.page_size
        null = self.null_page

        def put(path, ba, sa, pc, lg):
            if ba == NO_AXIS:
                return pc
            x = jnp.moveaxis(pc, (ba, sa), (0, 1))
            v = jnp.moveaxis(lg, (ba, sa), (0, 1))
            v = v.reshape((bt.shape[0], bt.shape[1], ps) + x.shape[2:])
            x = x.at[bt].set(v.astype(x.dtype))
            fill = -1 if _is_pos_ids(path) else 0
            x = x.at[null].set(jnp.full(x.shape[1:], fill, x.dtype))
            return jnp.moveaxis(x, (0, 1), (ba, sa))

        return jax.tree_util.tree_map_with_path(
            put, self.batch_axes, self.seq_axes, pool, logical)

    # -- slot lifecycle -------------------------------------------------------
    @property
    def cache(self):
        return self.pool

    @property
    def free_slots(self) -> List[int]:
        return list(self._free)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self._free]

    @property
    def pages_in_use(self) -> int:
        return self._pages_in_use

    @property
    def free_pages(self) -> int:
        return self.allocator.free_pages

    def recount_pages(self) -> int:
        """Count allocated block-table entries from scratch — pinned equal
        to both the incremental counter and the allocator ledger."""
        return int(np.sum(self.block_table != self.null_page))

    def slot_pages(self, slot: int) -> int:
        return int(self._slot_pages[slot])

    def pages_needed(self, slot: int, upto: int) -> int:
        """New pages ``extend(slot, upto)`` would have to claim."""
        upto = min(int(upto), self.block_table.shape[1] * self.page_size)
        need = max(1, math.ceil(upto / self.page_size))
        return max(0, min(need, self.block_table.shape[1])
                   - int(self._slot_pages[slot]))

    def allocate(self, prompt_len: int) -> int:
        """Claim a free slot and its first page; returns the slot id."""
        slot = self._free.pop(0)
        self.pos[slot] = 0
        self.lengths[slot] = prompt_len
        (page,) = self.allocator.alloc(1)
        self.block_table[slot, 0] = page
        self._slot_pages[slot] = 1
        self._pages_in_use += 1
        self.peak_pages = max(self.peak_pages, self._pages_in_use)
        return slot

    def extend(self, slot: int, upto: int) -> int:
        """Grow a slot's block table to cover positions ``[0, upto)``;
        returns the number of pages claimed (non-contiguous, from the free
        list — no relocation, no fragmentation)."""
        width = self.block_table.shape[1]
        upto = min(int(upto), width * self.page_size)
        need = min(max(1, math.ceil(upto / self.page_size)), width)
        have = int(self._slot_pages[slot])
        if need <= have:
            return 0
        new = self.allocator.alloc(need - have)
        self.block_table[slot, have:need] = new
        self._slot_pages[slot] = need
        self._pages_in_use += need - have
        self.peak_pages = max(self.peak_pages, self._pages_in_use)
        return need - have

    def trim(self, slot: int, upto: int) -> int:
        """Return pages past ``ceil(upto / page_size)`` to the pool — the
        speculative-decode rollback.  Freed pages are invalidated
        (``pos_ids = -1``) so their stale entries can never surface under a
        future owner's mask; returns the number of pages freed."""
        keep = max(1, math.ceil(int(upto) / self.page_size))
        have = int(self._slot_pages[slot])
        if keep >= have:
            return 0
        pages = self.block_table[slot, keep:have].copy()
        self.block_table[slot, keep:have] = self.null_page
        self._slot_pages[slot] = keep
        self._pages_in_use -= have - keep
        self.allocator.free(pages)
        self.pool = self._invalidate_pages(
            self.pool, jnp.asarray(pages, jnp.int32))
        return have - keep

    def free(self, slot: int):
        """Recycle a slot: all its pages are invalidated and returned."""
        if not 0 <= slot < self.slots:
            raise ValueError(
                f"free of invalid slot {slot} (valid: 0..{self.slots - 1})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        have = int(self._slot_pages[slot])
        pages = self.block_table[slot, :have].copy()
        self.block_table[slot, :have] = self.null_page
        self._slot_pages[slot] = 0
        self._pages_in_use -= have
        self.allocator.free(pages)
        self.pool = self._invalidate_pages(
            self.pool, jnp.asarray(pages, jnp.int32))
        self.pos[slot] = 0
        self.lengths[slot] = 0
        self._free.append(slot)

    # -- cache reads/writes (logical rows, for preemption + prefill scatter) --
    def write_rows(self, slot_ids, rows):
        """Scatter logical rows (batch == len(slot_ids)) into the slots'
        pages (the rows must already be covered by ``extend``)."""
        bt = jnp.asarray(self.block_table[np.asarray(slot_ids)], jnp.int32)
        rows = self._fit_rows(rows)
        self.pool = self._scatter(self.pool, rows, bt)

    def read_rows(self, slot_ids):
        """Gather logical rows **trimmed to the slots' allocated pages** —
        the page-exact device->host payload of preemption (a short request
        ships its pages, not its slot's full span)."""
        ids = np.asarray(slot_ids)
        width = int(max(1, self._slot_pages[ids].max()))
        bt = jnp.asarray(self.block_table[ids, :width], jnp.int32)
        return self._gather(self.pool, bt)

    def _fit_rows(self, rows):
        """Pad logical rows out to the current block-table width (fill -1
        for ``pos_ids``) — short preemption payloads and pre-growth
        expandable stashes both land here."""
        width = self.block_table.shape[1] * self.page_size

        def fit(path, ba, sa, row):
            if ba == NO_AXIS:
                return row
            row = jnp.asarray(row)
            pad = width - row.shape[sa]
            if pad <= 0:
                return row
            widths = [(0, 0)] * row.ndim
            widths[sa] = (0, pad)
            fill = -1 if _is_pos_ids(path) else 0
            return jnp.pad(row, widths, constant_values=fill)

        return jax.tree_util.tree_map_with_path(
            fit, self.batch_axes, self.seq_axes, rows)

    def restore(self, slot: int, rows, pos: int):
        """Scatter a preempted row set back into a (re)allocated slot —
        possibly onto *different* physical pages than it left (the layout
        is free-list order); bitwise resume holds because pages are carried
        bit for bit and the mask only keys on ``pos_ids``."""
        self.extend(slot, int(pos))
        self.write_rows([slot], rows)
        self.pos[slot] = int(pos)

    def advance(self, slot_ids, counts):
        for s, n in zip(slot_ids, counts):
            self.pos[s] += int(n)
            self.extend(s, int(self.pos[s]))


class ExpandablePagedKVCacheManager(PagedKVCacheManager):
    """Paged manager whose per-slot capacity starts at ``initial_len`` and
    doubles up to ``max_len``.  Growth only **widens the block tables**
    with null-page (invalid) columns — live pages never relocate and the
    physical pool (sized for ``max_len`` worth of pages up front) is
    untouched, so grow-mid-decode is a host-side O(slots) operation."""

    def __init__(self, model, slots: int, max_len: int,
                 initial_len: int = 64, page_size: int = 16,
                 total_pages: Optional[int] = None):
        cfg = getattr(model, "cfg", None)
        window = getattr(cfg, "sliding_window", 0) or 0
        if window and window < max_len:
            raise ValueError(
                "expandable paged cache requires sliding_window >= max_len")
        super().__init__(model, slots, max_len, page_size=page_size,
                         total_pages=total_pages)
        initial_len = min(max(initial_len, page_size), max_len)
        init_pages = max(1, math.ceil(initial_len / page_size))
        self.block_table = self.block_table[:, :init_pages].copy()
        self.capacity = init_pages * page_size
        self.grows = 0

    def ensure(self, needed: int):
        """Grow capacity (doubling) until >= needed tokens per slot; new
        block-table columns point at the null page (invalid) until pages
        are actually claimed by ``extend``."""
        if needed <= self.capacity:
            return
        new_cap = self.capacity
        while new_cap < needed:
            new_cap = min(new_cap * 2, self.seq_len)
            if new_cap == self.capacity:
                raise ValueError(
                    f"request needs {needed} tokens; max_len={self.max_len}")
        width = new_cap // self.page_size
        grown = np.full((self.slots, width), self.null_page, np.int32)
        grown[:, :self.block_table.shape[1]] = self.block_table
        self.block_table = grown
        self.capacity = new_cap
        self.grows += 1
