"""Paged KV-cache management for the continuous-batching engine.

``KVCacheManager`` owns the decode-cache pytree for a fixed set of slots and
all per-slot bookkeeping the scheduler needs:

- **per-slot positions** — ``pos[slot]`` is each slot's next decode position;
  there is no global aligned position, so requests at different depths share
  one fused decode step (the ragged ``pos``/``n_valid`` contract of
  ``Model.decode``).
- **slot recycling** — freeing a slot returns its pages to the pool and
  invalidates its ``pos_ids`` rows; the arrays are allocated once, so cache
  memory never grows with request count.
- **page accounting** — capacity is tracked in fixed-size pages
  (``page_size`` tokens); ``pages_in_use``/``peak_pages`` expose occupancy to
  the admission controller the way a paged allocator would, without the
  gather overhead of real block tables (the reduced configs are far from
  HBM-bound).
- **batch-axis probing** — the cache pytree mixes leaf ranks (attention K/V,
  SSM conv/ssm states, cross-attn K/V, stacked layer dims), so the manager
  finds each leaf's batch axis *structurally*: build the abstract cache at
  two batch sizes and diff the shapes. Scatter/gather then move that axis to
  the front — no shape-matching heuristics (which break when a layer count
  equals the slot count).

``ExpandableKVCacheManager`` (modeled on foundation-model-stack's
ExpandableKVCacheManager) starts with a small sequence capacity and doubles
it on demand up to ``max_len``: sequence axes are probed the same way, new
space is zero-filled except ``pos_ids`` (filled with -1 = invalid).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


NO_AXIS = -1  # sentinel: None leaves would vanish from the pytree


def _probe_axes(model, make_a, make_b):
    """Per-leaf axis where two abstract cache builds disagree (else NO_AXIS)."""
    a = make_a()
    b = make_b()

    def diff(x, y):
        for i, (p, q) in enumerate(zip(x.shape, y.shape)):
            if p != q:
                return i
        return NO_AXIS

    return jax.tree_util.tree_map(diff, a, b)


def _is_pos_ids(path) -> bool:
    for p in path:
        if getattr(p, "key", None) == "pos_ids":
            return True
    return False


class KVCacheManager:
    """Fixed-capacity paged cache over ``slots`` rows of length ``max_len``."""

    def __init__(self, model, slots: int, max_len: int,
                 page_size: int = 16, alloc: bool = True):
        self.model = model
        self.slots = slots
        self.max_len = max_len
        self.page_size = page_size
        self.batch_axes = _probe_axes(
            model,
            lambda: model.cache(slots, max_len, abstract=True),
            lambda: model.cache(slots + 1, max_len, abstract=True))
        if alloc:
            self.cache = model.cache(slots, max_len)
        # host-side bookkeeping (no device sync needed to schedule)
        self.pos = np.zeros(slots, np.int32)        # next decode position
        self.lengths = np.zeros(slots, np.int32)    # prompt length
        self._free: List[int] = list(range(slots))
        self._pages_per_slot = math.ceil(max_len / page_size)
        self.peak_pages = 0

        def _scatter(cache, rows, slot_ids):
            def put(ax, ec, pc):
                if ax == NO_AXIS:
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                pcm = jnp.moveaxis(pc, ax, 0)
                ecm = ecm.at[slot_ids].set(pcm.astype(ecm.dtype))
                return jnp.moveaxis(ecm, 0, ax)

            return jax.tree_util.tree_map(put, self.batch_axes, cache, rows)

        def _invalidate(cache, slot_ids):
            def inv(path, ax, ec):
                if ax == NO_AXIS or not _is_pos_ids(path):
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                ecm = ecm.at[slot_ids].set(-1)
                return jnp.moveaxis(ecm, 0, ax)

            return jax.tree_util.tree_map_with_path(
                inv, self.batch_axes, cache)

        def _gather(cache, slot_ids):
            def take(ax, ec):
                if ax == NO_AXIS:
                    return ec
                ecm = jnp.moveaxis(ec, ax, 0)
                return jnp.moveaxis(ecm[slot_ids], 0, ax)

            return jax.tree_util.tree_map(take, self.batch_axes, cache)

        self._scatter = jax.jit(_scatter)
        self._invalidate = jax.jit(_invalidate)
        self._gather = jax.jit(_gather)

    # -- slot lifecycle -------------------------------------------------------
    @property
    def free_slots(self) -> List[int]:
        return list(self._free)

    @property
    def active_slots(self) -> List[int]:
        return [s for s in range(self.slots) if s not in self._free]

    def allocate(self, prompt_len: int) -> int:
        """Claim a free slot for a request; returns the slot id."""
        slot = self._free.pop(0)
        self.pos[slot] = 0
        self.lengths[slot] = prompt_len
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return slot

    def free(self, slot: int):
        """Recycle a slot: pages return to the pool, row marked invalid.

        Raises on double-free or free-of-unallocated: a silent accept
        would duplicate the slot in the free list, hand it to two requests
        at once, and corrupt the page accounting."""
        if not 0 <= slot < self.slots:
            raise ValueError(
                f"free of invalid slot {slot} (valid: 0..{self.slots - 1})")
        if slot in self._free:
            raise ValueError(f"double free of slot {slot}")
        self.pos[slot] = 0
        self.lengths[slot] = 0
        self._free.append(slot)
        self.cache = self._invalidate(self.cache, jnp.asarray([slot]))

    # -- page accounting ------------------------------------------------------
    @property
    def total_pages(self) -> int:
        return self.slots * self._pages_per_slot

    @property
    def pages_in_use(self) -> int:
        used = 0
        for s in range(self.slots):
            if s in self._free:
                continue
            used += max(1, math.ceil(int(self.pos[s]) / self.page_size))
        return used

    # -- cache writes ---------------------------------------------------------
    def write_rows(self, slot_ids, rows):
        """Scatter prefilled cache rows (batch == len(slot_ids)) into slots."""
        self.cache = self._scatter(self.cache, rows,
                                   jnp.asarray(slot_ids, jnp.int32))

    def read_rows(self, slot_ids):
        """Gather cache rows (batch == len(slot_ids)) out of slots — the
        device->host read of thermal-emergency preemption."""
        return self._gather(self.cache, jnp.asarray(slot_ids, jnp.int32))

    def restore(self, slot: int, rows, pos: int):
        """Scatter one preempted row set back into a (re)allocated slot and
        rewind its decode position — the resume half of preemption.  Rows
        captured before an :class:`ExpandableKVCacheManager` growth are
        padded out to the current leaf shapes (fill -1 for ``pos_ids``)."""

        def fit(path, ax, row, cur):
            widths, need = [], False
            for i, (r, c) in enumerate(zip(row.shape, cur.shape)):
                if i == ax:
                    widths.append((0, 0))
                else:
                    widths.append((0, max(c - r, 0)))
                    need = need or c > r
            if not need:
                return row
            fill = -1 if _is_pos_ids(path) else 0
            return jnp.pad(jnp.asarray(row), widths, constant_values=fill)

        rows = jax.tree_util.tree_map_with_path(
            fit, self.batch_axes, rows, self.cache)
        self.write_rows([slot], rows)
        self.pos[slot] = int(pos)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)

    def advance(self, slot_ids, counts):
        for s, n in zip(slot_ids, counts):
            self.pos[s] += int(n)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)


class HostPagePool:
    """Host-side page pool for preempted requests: evicted KV rows live in
    host memory (``jax.device_get``) keyed by request id until resumption.
    The device cache slot is freed meanwhile — preemption actually returns
    pages to the admission pool, it does not just hide them."""

    def __init__(self):
        self._rows: Dict[Any, Any] = {}
        self.puts = 0
        self.peak = 0

    def put(self, rid, rows, pos: int) -> None:
        self._rows[rid] = (jax.device_get(rows), int(pos))
        self.puts += 1
        self.peak = max(self.peak, len(self._rows))

    def take(self, rid):
        """Pop (rows, pos) for a request being resumed."""
        return self._rows.pop(rid)

    def __contains__(self, rid) -> bool:
        return rid in self._rows

    def __len__(self) -> int:
        return len(self._rows)


class ExpandableKVCacheManager(KVCacheManager):
    """Starts at ``initial_len`` sequence capacity, doubles up to ``max_len``.

    Growth re-allocates only the leaves that actually carry a sequence axis
    (probed structurally — SSM states and window-clamped ring buffers are
    left alone), zero-padding K/V and padding ``pos_ids`` with -1.
    """

    def __init__(self, model, slots: int, max_len: int,
                 initial_len: int = 64, page_size: int = 16):
        initial_len = min(initial_len, max_len)
        super().__init__(model, slots, max_len, page_size, alloc=False)
        self.capacity = initial_len
        self.cache = model.cache(slots, initial_len)
        self.grows = 0

    def _seq_axes(self, old_len: int, new_len: int):
        return _probe_axes(
            self.model,
            lambda: self.model.cache(self.slots, old_len, abstract=True),
            lambda: self.model.cache(self.slots, new_len, abstract=True))

    def ensure(self, needed: int):
        """Grow capacity (doubling) until >= needed tokens per slot."""
        if needed <= self.capacity:
            return
        new_cap = self.capacity
        while new_cap < needed:
            new_cap = min(new_cap * 2, self.max_len)
            if new_cap == self.capacity:
                raise ValueError(
                    f"request needs {needed} tokens; max_len={self.max_len}")
        seq_axes = self._seq_axes(self.capacity, new_cap)

        def grow(path, ax, leaf):
            if ax == NO_AXIS:
                return leaf
            pad = new_cap - leaf.shape[ax]
            widths = [(0, 0)] * leaf.ndim
            widths[ax] = (0, pad)
            fill = -1 if _is_pos_ids(path) else 0
            return jnp.pad(leaf, widths, constant_values=fill)

        self.cache = jax.tree_util.tree_map_with_path(
            grow, seq_axes, self.cache)
        self.capacity = new_cap
        self.grows += 1
