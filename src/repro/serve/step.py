"""Serving steps: prefill and single-token decode (+ sampling).

``prefill_step``: (params, batch) -> (last_logits, cache)
``decode_step``:  (params, cache, tokens(B,1), pos) -> (logits(B,V), cache)

Both are pure functions for jit with shardings from the plan; the batch
scheduler in serve/engine.py drives them.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params, batch):
        logits, cache = model.prefill(params, batch, max_len=max_len)
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        logits, cache = model.decode(params, tokens, cache, pos)
        return logits[:, 0], cache

    return decode_step


def sample(logits, key, temperature: float = 0.0, top_k: int = 0):
    """logits (B,V) -> tokens (B,). temperature 0 = greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1)
    logits = logits / temperature
    if top_k:
        vals, _ = jax.lax.top_k(logits, top_k)
        kth = vals[..., -1:]
        logits = jnp.where(logits < kth, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1)
