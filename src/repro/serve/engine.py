"""Serving engine: continuous batching with per-slot positions.

Requests enter a queue; every ``step()`` the engine (1) admits queued
requests into any free cache slot (honouring ``admit_cap`` — the actuation
knob a ``Throttle`` action programs), and (2) advances ALL active slots with
ONE fused jitted step: chunked-prefill extends for slots still consuming
their prompt, single-token decode for slots mid-generation, sampling fused
on-device (one host sync per tick).  There is no global decode position and
no admission barrier — each slot runs at its own ``pos`` (the ragged
``pos``/``n_valid`` contract of ``Model.decode``), so a request admitted
while others are mid-decode produces outputs identical to running alone.

Cache state lives in :class:`~repro.serve.cache.KVCacheManager`: per-slot
positions, page accounting, slot recycling (freed rows are invalidated via
``pos_ids = -1`` and reused without growing the arrays).

Two scheduling paths, picked by model family:

- **ragged** (attention-only stacks, no sliding window): prompts stream
  through the fused step in ``prefill_chunk``-token extends — admission is
  pure bookkeeping (no model call, no compile), and the fused step compiles
  exactly twice (S in {1, chunk}).
- **stateful** (SSM/hybrid and window-clamped ring caches): recurrent state
  would be polluted by padded prompt tokens, so admission runs an
  exact-length prefill per request and scatters the row; decode then joins
  the same fused step.

Control-plane hooks (repro.control, DESIGN.md §3): EVERY ``step()`` emits a
``TickSample`` — including admit-only and fully-throttled iterations, so
queue-depth bursts are visible exactly when ``Throttle`` decisions matter.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.control.telemetry import TickSample
from repro.models.model import Model
from repro.serve import scheduler as sched
from repro.serve.cache import (ExpandableKVCacheManager, HostPagePool,
                               KVCacheManager)
from repro.serve.step import sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    priority: int = 0     # lower preempts first under thermal emergency
    out: List[int] = field(default_factory=list)
    done: bool = False
    error: Optional[str] = None
    fed: int = 0          # prompt tokens already written to the cache
    submit_tick: int = 0  # engine tick at submission (queue-age / SLO)
    finish_tick: int = 0
    preempts: int = 0     # times evicted to the host page pool


class Engine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int = 1,
                 temperature: float = 0.0,
                 admit_cap: Optional[int] = None,
                 top_k: int = 0, prefill_chunk: int = 16,
                 page_size: int = 16, expandable: bool = False,
                 seed: int = 0, warmup: bool = True):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.temperature = temperature
        self.top_k = top_k
        self.prefill_chunk = max(1, min(prefill_chunk, max_len))
        cfg = model.cfg
        # ragged chunked prefill needs position-table masking all the way
        # down; recurrent state (ssm/hybrid) would absorb the padded chunk
        # tails.  Ring buffers (sliding window) ride the ragged path too —
        # the masked per-row ring scatter keeps padded tails out — as long
        # as one chunk cannot lap the window
        self._ragged = (cfg.family in ("dense", "moe")
                        and (not cfg.sliding_window
                             or self.prefill_chunk <= cfg.sliding_window))
        mgr_cls = ExpandableKVCacheManager if expandable else KVCacheManager
        self.mgr = mgr_cls(model, batch_slots, max_len, page_size=page_size)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.pool = HostPagePool()  # preempted KV rows, host side
        self.preempts = 0
        self.key = jax.random.PRNGKey(seed)
        # control plane: admission throttle + tick telemetry subscribers
        self.admit_cap = admit_cap
        self.on_tick: List[Callable[[TickSample], None]] = []
        self.ticks = 0

        def fused(params, cache, tokens, pos, n_valid, key):
            logits, cache = model.decode(params, tokens, cache, pos,
                                         n_valid=n_valid)
            idx = jnp.clip(n_valid - 1, 0, tokens.shape[1] - 1)
            last = jnp.take_along_axis(
                logits, idx[:, None, None], axis=1)[:, 0]  # (B,V)
            return sample(last, key, self.temperature, self.top_k), cache

        self._fused = jax.jit(fused)
        if warmup:
            self._warmup()

    def _warmup(self):
        """Pre-compile the fused step's two width buckets and the slot
        invalidation so no compile lands mid-traffic (n_valid = 0 rows make
        the warmup calls no-ops on cache contents)."""
        widths = {1, self.prefill_chunk} if self._ragged else {1}
        zero = jnp.zeros((self.B,), jnp.int32)
        for S in sorted(widths):
            self._fused(self.params, self.mgr.cache,
                        jnp.zeros((self.B, S), jnp.int32), zero, zero,
                        self.key)
        self.mgr._invalidate(self.mgr.cache, jnp.asarray([0]))

    # -- public API -----------------------------------------------------------
    @property
    def cache(self):
        return self.mgr.cache

    def submit(self, req: Request):
        req.submit_tick = self.ticks
        self.queue.append(req)

    # -- admission ------------------------------------------------------------
    def _admit(self) -> int:
        """Admit queued requests into free slots (<= admit_cap per step)."""
        cap = self.B if self.admit_cap is None else max(self.admit_cap, 0)
        admitted = 0
        while self.queue and self.mgr.free_slots and admitted < cap:
            req = self.queue.pop(0)
            if req.rid in self.pool:
                # resume a preempted request: its KV rows come back from
                # the host page pool bit for bit — no recompute, no drift
                slot = self.mgr.allocate(len(req.prompt))
                rows, pos = self.pool.take(req.rid)
                if isinstance(self.mgr, ExpandableKVCacheManager):
                    self.mgr.ensure(pos + 1)
                self.mgr.restore(slot, rows, pos)
                self.slot_req[slot] = req
                admitted += 1
                continue
            if len(req.prompt) >= self.max_len:
                req.done = True
                req.error = "prompt_too_long"
                req.finish_tick = self.ticks
                self.finished.append(req)
                continue  # a reject is not an admission
            slot = self.mgr.allocate(len(req.prompt))
            self.slot_req[slot] = req
            req.fed = 0
            if not self._ragged:
                self._prefill_into(slot, req)
            admitted += 1
        return admitted

    # -- thermal-emergency preemption -----------------------------------------
    def preempt_to(self, keep_active: int) -> int:
        """Evict active slots until at most ``keep_active`` stay busy (the
        :class:`~repro.control.controller.Preempt` actuation).  Victims are
        the lowest-priority, newest requests; each one's KV rows move to the
        host page pool, its device slot is freed (pages actually return to
        the admission budget), and the request re-queues at the head for
        bitwise-identical resumption.  Returns the eviction count."""
        active = [(s, r) for s, r in enumerate(self.slot_req)
                  if r is not None]
        n_evict = len(active) - max(int(keep_active), 0)
        if n_evict <= 0:
            return 0
        victims = sorted(active, key=lambda sr: (sr[1].priority,
                                                 -sr[1].submit_tick,
                                                 -sr[0]))[:n_evict]
        requeue = []
        for slot, req in sorted(victims, key=lambda sr: sr[1].submit_tick):
            rows = self.mgr.read_rows([slot])
            self.pool.put(req.rid, rows, int(self.mgr.pos[slot]))
            self.slot_req[slot] = None
            self.mgr.free(slot)
            req.preempts += 1
            self.preempts += 1
            requeue.append(req)
        self.queue[:0] = requeue  # resume first, oldest first
        return n_evict

    def _prefill_into(self, slot: int, req: Request):
        """Stateful-family path: exact-length prefill, scatter one row."""
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        if isinstance(self.mgr, ExpandableKVCacheManager):
            self.mgr.ensure(len(req.prompt) + 1)
            cap = self.mgr.capacity
        else:
            cap = self.max_len
        logits, rows = self.model.prefill(self.params, {"tokens": toks},
                                          max_len=cap)
        self.mgr.write_rows([slot], rows)
        self.mgr.advance([slot], [len(req.prompt)])
        req.fed = len(req.prompt)
        self.key, sk = jax.random.split(self.key)
        tok = int(sample(logits[:, -1], sk, self.temperature, self.top_k)[0])
        self._append(req, slot, tok)

    # -- the fused tick -------------------------------------------------------
    def _compose(self) -> Optional[sched.TickPlan]:
        work: List[sched.SlotWork] = []
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            P = len(req.prompt)
            if req.fed < P:  # ragged path only: stream the prompt
                k = min(self.prefill_chunk, P - req.fed)
                work.append(sched.SlotWork(
                    s, "prefill",
                    np.asarray(req.prompt[req.fed:req.fed + k], np.int32),
                    completes=(req.fed + k == P)))
            else:
                work.append(sched.SlotWork(
                    s, "decode", np.asarray([req.out[-1]], np.int32)))
        return sched.compose(work, self.mgr.pos, self.B, self.prefill_chunk)

    def _tick(self) -> int:
        plan = self._compose()
        if plan is None:
            return 0
        if isinstance(self.mgr, ExpandableKVCacheManager):
            self.mgr.ensure(int(plan.pos.max() + plan.width))
        self.key, sk = jax.random.split(self.key)
        nxt, self.mgr.cache = self._fused(
            self.params, self.mgr.cache, jnp.asarray(plan.tokens),
            jnp.asarray(plan.pos), jnp.asarray(plan.n_valid), sk)
        nxt = np.asarray(nxt)  # the tick's single host sync
        gen = 0
        self.mgr.advance([w.slot for w in plan.work],
                         [len(w.tokens) for w in plan.work])
        for w in plan.work:
            req = self.slot_req[w.slot]
            if w.kind == "prefill":
                req.fed += len(w.tokens)
                if w.completes:  # logit after the last prompt token
                    self._append(req, w.slot, int(nxt[w.slot]))
                    gen += 1
            else:
                self._append(req, w.slot, int(nxt[w.slot]))
                gen += 1
        return gen

    def _append(self, req: Request, slot: int, tok: int):
        req.out.append(tok)
        if (tok == self.eos or len(req.out) >= req.max_new
                or self.mgr.pos[slot] >= self.max_len - 1):
            req.done = True
            req.finish_tick = self.ticks
            self.finished.append(req)
            self.slot_req[slot] = None
            self.mgr.free(slot)

    # -- scheduler loop -------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration (admit, then one fused tick); True while
        there is still work.  ``run`` loops this; control-plane drivers
        interleave it with ``ControlLoop.step`` ticks."""
        if not (self.queue or any(r is not None for r in self.slot_req)):
            return False
        t0 = time.perf_counter()
        admitted = self._admit()
        gen = self._tick()
        oldest = (float(self.ticks - min(r.submit_tick for r in self.queue))
                  if self.queue else 0.0)
        if self.on_tick:
            # slots rides along so the control plane can fold active/slots
            # into the load fraction feeding the RailField utilization axis
            smp = TickSample(
                tick=self.ticks, queued=len(self.queue),
                active=sum(r is not None for r in self.slot_req),
                finished=len(self.finished), tokens=gen,
                tick_s=time.perf_counter() - t0, slots=self.B,
                admitted=admitted, oldest_wait=oldest)
            for cb in self.on_tick:
                cb(smp)
        self.ticks += 1
        return bool(self.queue or any(r is not None for r in self.slot_req))

    def run(self, max_ticks: int = 512) -> List[Request]:
        ticks = 0
        while ticks < max_ticks and self.step():
            ticks += 1
        return self.finished
