"""Serving engine: continuous-batching scheduler over prefill/decode steps.

Requests enter a queue; the engine prefills new requests into free cache
slots (one jit'd prefill per admission batch) and advances all active slots
with a single fused decode step per tick. Slots free on EOS/max-tokens.
This is the slot-based continuous batching of production LLM servers, sized
down to run the reduced configs on CPU.

Control-plane hooks (repro.control, DESIGN.md §3): every tick emits a
``TickSample`` (queue depth, active slots, tokens, wall time) to the
``on_tick`` subscribers, and admission honours ``admit_cap`` — the
actuation knob a ``Throttle`` action programs when junction temperature
crowds the limit. Both default to off; an unwired engine behaves exactly
as before.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.control.telemetry import TickSample
from repro.models.model import Model
from repro.serve.step import sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    out: List[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int = 1,
                 temperature: float = 0.0,
                 admit_cap: Optional[int] = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.temperature = temperature
        cfg = model.cfg
        self.cache = model.cache(self.B, max_len)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.pos = 0  # aligned decoding position (slot-synchronous design)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.key = jax.random.PRNGKey(0)
        # control plane: admission throttle + tick telemetry subscribers
        self.admit_cap = admit_cap
        self.on_tick: List[Callable[[TickSample], None]] = []
        self.ticks = 0

        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode(p, t, c, pos))

    def submit(self, req: Request):
        self.queue.append(req)

    # -- admission: batch-prefill queued requests into free slots ------------
    def _admit(self):
        free = [i for i, r in enumerate(self.slot_req) if r is None]
        if self.admit_cap is not None:  # throttled actuation
            free = free[:max(self.admit_cap, 0)]
        if not free or not self.queue:
            return
        batch = [self.queue.pop(0) for _ in free[: len(self.queue)]]
        if not batch:
            return
        P = max(len(r.prompt) for r in batch)
        toks = np.zeros((len(batch), P), np.int32)
        for i, r in enumerate(batch):
            toks[i, P - len(r.prompt):] = r.prompt  # left-pad
        logits, cache = self.model.prefill(
            self.params, {"tokens": jnp.asarray(toks)}, max_len=self.max_len)
        # scatter each prefilled row into its slot
        for i, (slot, req) in enumerate(zip(free, batch)):
            self.slot_req[slot] = req
            # write row i of each cache leaf into slot of engine cache
            def put(ec, pc):
                # batch axis location differs per leaf rank; match by shape
                for ax in range(ec.ndim):
                    if ec.shape[ax] == self.B and pc.shape[ax] == len(batch):
                        idx = [slice(None)] * ec.ndim
                        idx[ax] = slot
                        src = [slice(None)] * pc.ndim
                        src[ax] = i
                        return ec.at[tuple(idx)].set(pc[tuple(src)])
                return ec  # leaf without batch axis (e.g. pos_ids)
            self.cache = jax.tree_util.tree_map(put, self.cache, cache)
            nxt = int(jnp.argmax(logits[i, -1]))
            req.out.append(nxt)
        self.pos = P

    # -- one decode tick over all active slots --------------------------------
    def _tick(self):
        t0 = time.perf_counter()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return
        toks = np.zeros((self.B, 1), np.int32)
        for i in active:
            toks[i, 0] = self.slot_req[i].out[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(toks), self.pos)
        self.pos += 1
        self.key, sk = jax.random.split(self.key)
        nxt = np.asarray(sample(jnp.asarray(logits)[:, 0], sk,
                                self.temperature))  # logits: (B,1,V)
        for i in active:
            req = self.slot_req[i]
            tok = int(nxt[i])
            req.out.append(tok)
            if tok == self.eos or len(req.out) >= req.max_new \
                    or self.pos >= self.max_len - 1:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
        if self.on_tick:
            # slots rides along so the control plane can fold active/slots
            # into the load fraction feeding the RailField utilization axis
            smp = TickSample(
                tick=self.ticks, queued=len(self.queue),
                active=sum(r is not None for r in self.slot_req),
                finished=len(self.finished), tokens=len(active),
                tick_s=time.perf_counter() - t0, slots=self.B)
            for cb in self.on_tick:
                cb(smp)

    def step(self) -> bool:
        """One scheduler iteration (admit when idle, then decode); True
        while there is still work.  ``run`` loops this; control-plane
        drivers (examples/closed_loop_serving.py) interleave it with
        ``ControlLoop.step`` ticks."""
        if not (self.queue or any(self.slot_req)):
            return False
        if not any(self.slot_req):
            self._admit()
        self._tick()
        self.ticks += 1
        return bool(self.queue or any(self.slot_req))

    def run(self, max_ticks: int = 512) -> List[Request]:
        ticks = 0
        while ticks < max_ticks and self.step():
            ticks += 1
        return self.finished
