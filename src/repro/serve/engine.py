"""Serving engine: continuous batching with per-slot positions.

Requests enter a queue; every ``step()`` the engine (1) admits queued
requests into any free cache slot (honouring ``admit_cap`` — the actuation
knob a ``Throttle`` action programs), and (2) advances ALL active slots with
ONE fused jitted step: chunked-prefill extends for slots still consuming
their prompt, single-token decode for slots mid-generation, sampling fused
on-device (one host sync per tick).  There is no global decode position and
no admission barrier — each slot runs at its own ``pos`` (the ragged
``pos``/``n_valid`` contract of ``Model.decode``), so a request admitted
while others are mid-decode produces outputs identical to running alone.

Cache state lives in :class:`~repro.serve.cache.KVCacheManager` (or, with
``paged=True``, :class:`~repro.serve.cache.PagedKVCacheManager` — free-list
pages behind per-slot block tables): per-slot positions, page accounting,
slot recycling (freed rows/pages are invalidated via ``pos_ids = -1`` and
reused without growing the arrays).  On the paged path the fused step
gathers a slot-contiguous logical cache through the block tables, runs the
unchanged ``Model.decode``, and scatters pages back — one jit, outputs
bitwise identical to the contiguous manager — and admission/extension run
at page granularity off the actual free list, so churn that would fragment
contiguous rows costs nothing.

``speculate=k`` adds draft-k self-speculative decode (greedy only):
n-gram prompt-lookup drafts ride the same ragged ``pos``/``n_valid``
contract as an ``S = k+1`` extend, one fused verify step scores every draft
row, and the accepted prefix (+ the bonus token) is bitwise what sequential
greedy would have produced; the rejected tail's pages roll back through
the allocator (``trim``).  Stale rejected entries are self-healing: their
``pos_ids`` exceed every later query position until the sequential path
overwrites them (chunk K/V is written before attention).

Two scheduling paths, picked by model family:

- **ragged** (attention-only stacks, no sliding window): prompts stream
  through the fused step in ``prefill_chunk``-token extends — admission is
  pure bookkeeping (no model call, no compile), and the fused step compiles
  exactly twice (S in {1, chunk}).
- **stateful** (SSM/hybrid and window-clamped ring caches): recurrent state
  would be polluted by padded prompt tokens, so admission runs an
  exact-length prefill per request and scatters the row; decode then joins
  the same fused step.

Control-plane hooks (repro.control, DESIGN.md §3): EVERY ``step()`` emits a
``TickSample`` — including admit-only and fully-throttled iterations, so
queue-depth bursts are visible exactly when ``Throttle`` decisions matter.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.control.telemetry import TickSample
from repro.models.model import Model
from repro.serve import scheduler as sched
from repro.serve.cache import (ExpandableKVCacheManager,
                               ExpandablePagedKVCacheManager, HostPagePool,
                               KVCacheManager, PagedKVCacheManager)
from repro.serve.step import sample


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    max_new: int = 16
    priority: int = 0     # lower preempts first under thermal emergency
    out: List[int] = field(default_factory=list)
    done: bool = False
    error: Optional[str] = None
    fed: int = 0          # prompt tokens already written to the cache
    submit_tick: int = 0  # engine tick at submission (queue-age / SLO)
    finish_tick: int = 0
    preempts: int = 0     # times evicted to the host page pool


class Engine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_len: int = 256, eos_id: int = 1,
                 temperature: float = 0.0,
                 admit_cap: Optional[int] = None,
                 top_k: int = 0, prefill_chunk: int = 16,
                 page_size: int = 16, expandable: bool = False,
                 paged: bool = False, total_pages: Optional[int] = None,
                 speculate: int = 0,
                 seed: int = 0, warmup: bool = True,
                 pool: Optional[HostPagePool] = None):
        self.model = model
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.temperature = temperature
        self.top_k = top_k
        self.prefill_chunk = max(1, min(prefill_chunk, max_len))
        cfg = model.cfg
        # ragged chunked prefill needs position-table masking all the way
        # down; recurrent state (ssm/hybrid) would absorb the padded chunk
        # tails.  Ring buffers (sliding window) ride the ragged path too —
        # the masked per-row ring scatter keeps padded tails out — as long
        # as one chunk cannot lap the window
        self._ragged = (cfg.family in ("dense", "moe")
                        and (not cfg.sliding_window
                             or self.prefill_chunk <= cfg.sliding_window))
        self._paged = bool(paged)
        if self._paged and not self._ragged:
            raise ValueError(
                "paged=True requires the ragged path (dense/moe attention); "
                "recurrent state cannot be gathered through block tables")
        self._spec_k = max(int(speculate), 0)
        if self._spec_k:
            if temperature != 0.0:
                raise ValueError("speculate requires greedy decoding "
                                 "(temperature=0): verification compares "
                                 "drafts against the argmax rows")
            if not self._ragged:
                raise ValueError("speculate requires the ragged path")
            if cfg.sliding_window and cfg.sliding_window < max_len:
                raise ValueError(
                    "speculate requires sliding_window >= max_len: a "
                    "wrapping ring scatter would destroy live window "
                    "entries a rejected draft cannot restore")
        if self._paged:
            mgr_cls = (ExpandablePagedKVCacheManager if expandable
                       else PagedKVCacheManager)
            self.mgr = mgr_cls(model, batch_slots, max_len,
                               page_size=page_size, total_pages=total_pages)
        else:
            mgr_cls = (ExpandableKVCacheManager if expandable
                       else KVCacheManager)
            self.mgr = mgr_cls(model, batch_slots, max_len,
                               page_size=page_size)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        # preempted KV rows, host side; pass a shared pool to let several
        # pod engines exchange requests (fleet migration, DESIGN.md §10)
        self.pool = pool if pool is not None else HostPagePool()
        self.preempts = 0
        self.spec_proposed = 0  # draft tokens offered to verification
        self.spec_accepted = 0  # draft tokens accepted (bitwise == greedy)
        self._bt_host: Optional[np.ndarray] = None  # device bt cache key
        self._bt_dev = None
        self.key = jax.random.PRNGKey(seed)
        # control plane: admission throttle + tick telemetry subscribers
        self.admit_cap = admit_cap
        self.on_tick: List[Callable[[TickSample], None]] = []
        self.ticks = 0

        if self._paged:
            mgr = self.mgr

            def fused(params, pool, bt, inv, tokens, pos, n_valid, key):
                cache = mgr.gather_logical(pool, bt)
                logits, cache = model.decode(params, tokens, cache, pos,
                                             n_valid=n_valid)
                idx = jnp.clip(n_valid - 1, 0, tokens.shape[1] - 1)
                last = jnp.take_along_axis(
                    logits, idx[:, None, None], axis=1)[:, 0]  # (B,V)
                nxt = sample(last, key, self.temperature, self.top_k)
                return nxt, mgr.scatter_all(pool, cache, inv)

            def fused_spec(params, pool, bt, inv, tokens, pos, n_valid, key):
                cache = mgr.gather_logical(pool, bt)
                logits, cache = model.decode(params, tokens, cache, pos,
                                             n_valid=n_valid)
                rows = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                return rows, mgr.scatter_all(pool, cache, inv)
        else:
            def fused(params, cache, tokens, pos, n_valid, key):
                logits, cache = model.decode(params, tokens, cache, pos,
                                             n_valid=n_valid)
                idx = jnp.clip(n_valid - 1, 0, tokens.shape[1] - 1)
                last = jnp.take_along_axis(
                    logits, idx[:, None, None], axis=1)[:, 0]  # (B,V)
                return sample(last, key, self.temperature, self.top_k), cache

            def fused_spec(params, cache, tokens, pos, n_valid, key):
                logits, cache = model.decode(params, tokens, cache, pos,
                                             n_valid=n_valid)
                # every row's greedy continuation — the verify step
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        # the paged step donates the pool: the scatter then updates the
        # page buffers in place instead of copying the whole pool per
        # layer (the block-table indirection's write path is what keeps
        # the paged tick within the decode-latency tax budget)
        donate = (1,) if self._paged else ()
        self._fused = jax.jit(fused, donate_argnums=donate)
        self._fused_spec = jax.jit(fused_spec, donate_argnums=donate)
        if warmup:
            self._warmup()

    def _run_fused(self, fn, plan: sched.TickPlan, key) -> np.ndarray:
        """One fused device step over the plan (gather -> decode -> scatter
        on the paged path); returns the host copy of the sampled output."""
        toks = jnp.asarray(plan.tokens)
        pos = jnp.asarray(plan.pos)
        nv = jnp.asarray(plan.n_valid)
        if self._paged:
            bt, inv = self._bt_device()
            out, self.mgr.pool = fn(self.params, self.mgr.pool, bt, inv,
                                    toks, pos, nv, key)
        else:
            out, self.mgr.cache = fn(self.params, self.mgr.cache,
                                     toks, pos, nv, key)
        return np.asarray(out)  # the tick's single host sync

    def _bt_device(self):
        """Device copies of the block table and its inverse page map,
        re-uploaded only when the host table actually changed (steady
        decode re-uses pages for page_size ticks at a time, so most ticks
        skip the transfer)."""
        if self._bt_host is None or not np.array_equal(
                self._bt_host, self.mgr.block_table):
            self._bt_host = self.mgr.block_table.copy()
            self._bt_dev = (jnp.asarray(self._bt_host, jnp.int32),
                            jnp.asarray(self.mgr.inverse_map(), jnp.int32))
        return self._bt_dev

    def _warmup(self):
        """Pre-compile the fused step's width buckets and the invalidation
        paths so no compile lands mid-traffic (n_valid = 0 rows make the
        warmup calls no-ops on cache contents)."""
        widths = {1, self.prefill_chunk} if self._ragged else {1}
        zero = jnp.zeros((self.B,), jnp.int32)
        calls = [(self._fused, S) for S in sorted(widths)]
        if self._spec_k:
            calls.append((self._fused_spec, self._spec_k + 1))
        for fn, S in calls:
            toks = jnp.zeros((self.B, S), jnp.int32)
            if self._paged:
                # the pool is donated into the jit — rebind the returned
                # buffer or the manager would hold a deleted array
                bt, inv = self._bt_device()
                _, self.mgr.pool = fn(self.params, self.mgr.pool, bt, inv,
                                      toks, zero, zero, self.key)
            else:
                fn(self.params, self.mgr.cache, toks, zero, zero, self.key)
        if self._paged:
            self.mgr._invalidate_pages(
                self.mgr.pool, jnp.asarray([self.mgr.null_page]))
        else:
            self.mgr._invalidate(self.mgr.cache, jnp.asarray([0]))

    # -- public API -----------------------------------------------------------
    @property
    def cache(self):
        return self.mgr.cache

    def submit(self, req: Request):
        req.submit_tick = self.ticks
        self.queue.append(req)

    # -- admission ------------------------------------------------------------
    def _admit(self) -> int:
        """Admit queued requests into free slots (<= admit_cap per step).
        On the paged path admission is additionally priced off the *actual*
        free page list: a fresh request needs one page now, a resume needs
        exactly the pages it parked — fragmentation-free by construction,
        so "has pages" always means "can admit"."""
        cap = self.B if self.admit_cap is None else max(self.admit_cap, 0)
        admitted = 0
        while self.queue and self.mgr.free_slots and admitted < cap:
            if self._paged:
                head = self.queue[0]
                need = (self.pool.put_pages(head.rid)
                        if head.rid in self.pool else 1)
                if self.mgr.free_pages < max(need, 1):
                    break  # no pages — keep FIFO order, retry next tick
            req = self.queue.pop(0)
            if req.rid in self.pool:
                # resume a preempted request: its KV rows come back from
                # the host page pool bit for bit — no recompute, no drift
                slot = self.mgr.allocate(len(req.prompt))
                rows, pos = self.pool.take(req.rid, owner=self.mgr)
                if isinstance(self.mgr, (ExpandableKVCacheManager,
                                         ExpandablePagedKVCacheManager)):
                    self.mgr.ensure(pos + 1)
                self.mgr.restore(slot, rows, pos)
                self.slot_req[slot] = req
                admitted += 1
                continue
            if len(req.prompt) >= self.max_len:
                req.done = True
                req.error = "prompt_too_long"
                req.finish_tick = self.ticks
                self.finished.append(req)
                continue  # a reject is not an admission
            slot = self.mgr.allocate(len(req.prompt))
            self.slot_req[slot] = req
            req.fed = 0
            if not self._ragged:
                self._prefill_into(slot, req)
            admitted += 1
        return admitted

    # -- thermal-emergency preemption -----------------------------------------
    def preempt_to(self, keep_active: int) -> int:
        """Evict active slots until at most ``keep_active`` stay busy (the
        :class:`~repro.control.controller.Preempt` actuation).  Victims are
        the lowest-priority, newest requests; each one's KV rows move to the
        host page pool, its device slot is freed (pages actually return to
        the admission budget), and the request re-queues at the head for
        bitwise-identical resumption.  Returns the eviction count."""
        active = [(s, r) for s, r in enumerate(self.slot_req)
                  if r is not None]
        n_evict = len(active) - max(int(keep_active), 0)
        if n_evict <= 0:
            return 0
        victims = sorted(active, key=lambda sr: (sr[1].priority,
                                                 -sr[1].submit_tick,
                                                 -sr[0]))[:n_evict]
        requeue = []
        for slot, req in sorted(victims, key=lambda sr: sr[1].submit_tick):
            # page-exact eviction: ship and account exactly the pages the
            # request holds (the paged read gathers only its block-table
            # entries; a short request never pays its slot's full span)
            pages = self.mgr.slot_pages(slot)
            rows = self.mgr.read_rows([slot])
            page_ids = (self.mgr.block_table[slot, :pages].copy()
                        if self._paged else None)
            self.pool.put(req.rid, rows, int(self.mgr.pos[slot]),
                          pages=pages, owner=self.mgr, page_ids=page_ids,
                          freed=True)
            self.slot_req[slot] = None
            self.mgr.free(slot)
            req.preempts += 1
            self.preempts += 1
            requeue.append(req)
        self.queue[:0] = requeue  # resume first, oldest first
        return n_evict

    def drain(self) -> List[Request]:
        """Quarantine drain (DESIGN.md §10): evict every active slot to the
        host page pool and hand back the whole pending queue — resumable
        requests first, oldest first — so a fleet router can resubmit them
        to healthy pods.  The engine is left empty (no active slots, no
        queue) with all device pages back on the free list."""
        self.preempt_to(0)
        out, self.queue = self.queue, []
        return out

    def _prefill_into(self, slot: int, req: Request):
        """Stateful-family path: exact-length prefill, scatter one row."""
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        if isinstance(self.mgr, ExpandableKVCacheManager):
            self.mgr.ensure(len(req.prompt) + 1)
            cap = self.mgr.capacity
        else:
            cap = self.max_len
        logits, rows = self.model.prefill(self.params, {"tokens": toks},
                                          max_len=cap)
        self.mgr.write_rows([slot], rows)
        self.mgr.advance([slot], [len(req.prompt)])
        req.fed = len(req.prompt)
        self.key, sk = jax.random.split(self.key)
        tok = int(sample(logits[:, -1], sk, self.temperature, self.top_k)[0])
        self._append(req, slot, tok)

    # -- speculative drafting -------------------------------------------------
    def _draft(self, req: Request, k: int) -> np.ndarray:
        """n-gram prompt-lookup self-speculation (model-free, greedy): find
        the most recent earlier occurrence of the last generated token in
        the request's own prompt+output context and propose the tokens that
        followed it.  Returns up to ``k`` draft tokens (possibly none)."""
        ctx = np.concatenate([np.asarray(req.prompt, np.int32),
                              np.asarray(req.out, np.int32)])
        hits = np.nonzero(ctx[:-1] == ctx[-1])[0]
        if hits.size == 0:
            return np.zeros(0, np.int32)
        j = int(hits[-1])
        return ctx[j + 1:j + 1 + k].astype(np.int32)

    # -- the fused tick -------------------------------------------------------
    def _compose(self) -> Tuple[Optional[sched.TickPlan], bool]:
        """Compose the tick's work; second return marks a speculative
        (all-decode, width ``k+1``) verify tick.  Speculation stands down
        whenever any slot prefills or sits too close to ``max_len`` for the
        fixed verify width (``_row_update`` would clamp the write)."""
        k = self._spec_k
        active = [(s, r) for s, r in enumerate(self.slot_req)
                  if r is not None]
        spec = bool(k) and bool(active) and all(
            r.fed >= len(r.prompt)
            and int(self.mgr.pos[s]) + k + 1 <= self.max_len
            for s, r in active)
        work: List[sched.SlotWork] = []
        for s, req in active:
            P = len(req.prompt)
            if req.fed < P:  # ragged path only: stream the prompt
                n = min(self.prefill_chunk, P - req.fed)
                work.append(sched.SlotWork(
                    s, "prefill",
                    np.asarray(req.prompt[req.fed:req.fed + n], np.int32),
                    completes=(req.fed + n == P)))
            elif spec:
                drafts = self._draft(req, k)
                toks = np.zeros(k + 1, np.int32)  # fixed width: one bucket
                toks[0] = req.out[-1]
                toks[1:1 + len(drafts)] = drafts
                work.append(sched.SlotWork(
                    s, "decode", toks, n_valid=1 + len(drafts)))
            else:
                work.append(sched.SlotWork(
                    s, "decode", np.asarray([req.out[-1]], np.int32)))
        plan = sched.compose(work, self.mgr.pos, self.B, self.prefill_chunk)
        return plan, spec

    def _reserve_pages(self, plan: sched.TickPlan) -> bool:
        """Claim the pages this tick's real tokens will write (padded tails
        land on the inert null page).  All-or-nothing: False when the free
        list cannot cover the whole plan, so the caller can shed load and
        recompose instead of extending half the slots."""
        need = sum(
            self.mgr.pages_needed(
                w.slot, int(self.mgr.pos[w.slot]) + int(plan.n_valid[w.slot]))
            for w in plan.work)
        if need > self.mgr.free_pages:
            return False
        for w in plan.work:
            self.mgr.extend(
                w.slot, int(self.mgr.pos[w.slot]) + int(plan.n_valid[w.slot]))
        return True

    def _tick(self) -> int:
        plan, spec = self._compose()
        if plan is None:
            return 0
        if self._paged:
            if isinstance(self.mgr, ExpandablePagedKVCacheManager):
                self.mgr.ensure(int(plan.pos.max() + plan.width))
            while not self._reserve_pages(plan):
                # out of pages mid-decode: thermal-preempt the newest
                # low-priority request (pages return to the free list,
                # bitwise resume later) and recompose the tick
                n_active = sum(r is not None for r in self.slot_req)
                if n_active <= 1:
                    raise RuntimeError(
                        "page pool exhausted: one request needs more pages "
                        f"than total_pages={self.mgr.total_pages}")
                self.preempt_to(n_active - 1)
                plan, spec = self._compose()
                if plan is None:
                    return 0
                if isinstance(self.mgr, ExpandablePagedKVCacheManager):
                    self.mgr.ensure(int(plan.pos.max() + plan.width))
        elif isinstance(self.mgr, ExpandableKVCacheManager):
            self.mgr.ensure(int(plan.pos.max() + plan.width))
        self.key, sk = jax.random.split(self.key)
        if spec:
            rows = self._run_fused(self._fused_spec, plan, sk)  # (B, k+1)
            return self._commit_spec(plan, rows)
        nxt = self._run_fused(self._fused, plan, sk)
        gen = 0
        self.mgr.advance([w.slot for w in plan.work],
                         [len(w.tokens) for w in plan.work])
        for w in plan.work:
            req = self.slot_req[w.slot]
            if w.kind == "prefill":
                req.fed += len(w.tokens)
                if w.completes:  # logit after the last prompt token
                    self._append(req, w.slot, int(nxt[w.slot]))
                    gen += 1
            else:
                self._append(req, w.slot, int(nxt[w.slot]))
                gen += 1
        return gen

    def _commit_spec(self, plan: sched.TickPlan, rows: np.ndarray) -> int:
        """Verify draft rows against the greedy argmax and commit the
        accepted prefix plus the bonus token, one token at a time (the
        sequential EOS / max_new / max_len checks apply mid-prefix exactly
        as they would tick by tick); roll the rejected tail's pages back
        through the allocator."""
        gen = 0
        for w in plan.work:
            req = self.slot_req[w.slot]
            nv = int(plan.n_valid[w.slot])
            drafts = w.tokens[1:nv]
            a = 0
            while a < len(drafts) and int(drafts[a]) == int(rows[w.slot, a]):
                a += 1
            self.spec_proposed += len(drafts)
            self.spec_accepted += a
            for i in range(a + 1):  # accepted drafts + the bonus token
                self.mgr.advance([w.slot], [1])
                self._append(req, w.slot, int(rows[w.slot, i]))
                gen += 1
                if req.done:
                    break
            if self._paged and not req.done:
                # rejected tail: return its pages, keeping the span the
                # next verify tick must reserve anyway (the hysteresis
                # avoids a free/invalidate/realloc round trip per tick);
                # stale entries in kept pages self-heal (pos_ids > every
                # later query position until sequentially overwritten)
                self.mgr.trim(w.slot, min(
                    int(self.mgr.pos[w.slot]) + self._spec_k + 1,
                    self.max_len))
        return gen

    @property
    def spec_accept_rate(self) -> float:
        """Fraction of proposed draft tokens verification accepted."""
        return (self.spec_accepted / self.spec_proposed
                if self.spec_proposed else 0.0)

    def _append(self, req: Request, slot: int, tok: int):
        req.out.append(tok)
        if (tok == self.eos or len(req.out) >= req.max_new
                or self.mgr.pos[slot] >= self.max_len - 1):
            req.done = True
            req.finish_tick = self.ticks
            self.finished.append(req)
            self.slot_req[slot] = None
            self.mgr.free(slot)

    # -- scheduler loop -------------------------------------------------------
    def step(self) -> bool:
        """One scheduler iteration (admit, then one fused tick); True while
        there is still work.  ``run`` loops this; control-plane drivers
        interleave it with ``ControlLoop.step`` ticks."""
        if not (self.queue or any(r is not None for r in self.slot_req)):
            return False
        t0 = time.perf_counter()
        admitted = self._admit()
        gen = self._tick()
        oldest = (float(self.ticks - min(r.submit_tick for r in self.queue))
                  if self.queue else 0.0)
        if self.on_tick:
            # slots rides along so the control plane can fold active/slots
            # into the load fraction feeding the RailField utilization axis
            smp = TickSample(
                tick=self.ticks, queued=len(self.queue),
                active=sum(r is not None for r in self.slot_req),
                finished=len(self.finished), tokens=gen,
                tick_s=time.perf_counter() - t0, slots=self.B,
                admitted=admitted, oldest_wait=oldest,
                pages_free=self.mgr.free_pages)
            for cb in self.on_tick:
                cb(smp)
        self.ticks += 1
        return bool(self.queue or any(r is not None for r in self.slot_req))

    def run(self, max_ticks: int = 512) -> List[Request]:
        ticks = 0
        while ticks < max_ticks and self.step():
            ticks += 1
        return self.finished
