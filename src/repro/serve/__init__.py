"""Production serving tier: paged KV-cache manager + continuous batching.

See DESIGN.md §8.  ``Engine`` is the scheduler loop; ``KVCacheManager`` owns
slots/pages/positions (``PagedKVCacheManager`` makes pages real: free-list
:class:`PageAllocator` + per-slot block tables, non-contiguous layout);
``repro.control.AdmissionController`` co-schedules admission with the rail
plan, priced off actual free pages.
"""
from repro.serve.cache import (ExpandableKVCacheManager,
                               ExpandablePagedKVCacheManager, HostPagePool,
                               KVCacheManager, PageAllocator,
                               PagedKVCacheManager)
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import SlotWork, TickPlan, compose
from repro.serve.step import sample

__all__ = ["Engine", "Request", "KVCacheManager", "ExpandableKVCacheManager",
           "PagedKVCacheManager", "ExpandablePagedKVCacheManager",
           "PageAllocator", "HostPagePool",
           "SlotWork", "TickPlan", "compose", "sample"]
