"""Production serving tier: paged KV-cache manager + continuous batching.

See DESIGN.md §8.  ``Engine`` is the scheduler loop; ``KVCacheManager`` owns
slots/pages/positions; ``repro.control.AdmissionController`` co-schedules
admission with the rail plan.
"""
from repro.serve.cache import ExpandableKVCacheManager, KVCacheManager
from repro.serve.engine import Engine, Request
from repro.serve.scheduler import SlotWork, TickPlan, compose
from repro.serve.step import sample

__all__ = ["Engine", "Request", "KVCacheManager", "ExpandableKVCacheManager",
           "SlotWork", "TickPlan", "compose", "sample"]
