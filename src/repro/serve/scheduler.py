"""Continuous-batching tick composition (host side, model-free).

Every engine tick runs ONE fused model step over all slots.  The scheduler's
job is to compose that step from heterogeneous per-slot work:

- a slot mid-prompt contributes its next **chunked-prefill** extend (up to
  ``chunk`` prompt tokens at the slot's own position),
- a slot mid-generation contributes its **decode** token,
- a free slot contributes nothing (``n_valid = 0`` keeps it invisible to the
  attention mask and cache).

The composed :class:`TickPlan` is pure numpy — the engine turns it into one
jitted call.  The new-token axis is bucketed to ``{1, chunk}`` so the fused
step compiles exactly twice regardless of traffic (prompt lengths never leak
into compile shapes; ``n_valid`` carries the raggedness as data).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class SlotWork:
    """What one slot contributes to the tick (host-side request view)."""
    slot: int
    kind: str            # "prefill" | "decode"
    tokens: np.ndarray   # (k,) int32 — chunk of prompt, or [last_token, ...]
    completes: bool = False  # this chunk feeds the final prompt token
    # real-token count when tokens carries padding (speculative decode pads
    # short draft runs to the fixed verify width so the fused step keeps one
    # compile bucket); None = len(tokens)
    n_valid: Optional[int] = None


@dataclass
class TickPlan:
    """One fused step: tokens (B,S), per-slot pos (B,), n_valid (B,)."""
    tokens: np.ndarray
    pos: np.ndarray
    n_valid: np.ndarray
    work: List[SlotWork] = field(default_factory=list)

    @property
    def width(self) -> int:
        return int(self.tokens.shape[1])


def compose(work: List[SlotWork], pos: np.ndarray, slots: int,
            chunk: int) -> Optional[TickPlan]:
    """Bucket per-slot work into one (B,S) ragged step; None when idle.

    S is ``chunk`` whenever any slot is prefilling (decode rows ride along
    with ``n_valid = 1`` — the mixed prefill/decode batch of
    Sarathi/vLLM-style schedulers), else 1.
    """
    if not work:
        return None
    S = (chunk if any(w.kind == "prefill" for w in work)
         else max(len(w.tokens) for w in work))
    tokens = np.zeros((slots, S), np.int32)
    n_valid = np.zeros(slots, np.int32)
    for w in work:
        k = len(w.tokens)
        tokens[w.slot, :k] = w.tokens
        n_valid[w.slot] = k if w.n_valid is None else w.n_valid
    return TickPlan(tokens=tokens, pos=pos.astype(np.int32).copy(),
                    n_valid=n_valid, work=work)
