"""In-house optimizers: AdamW and Adafactor (factored second moments).

Optimizer state is described as a ParamMeta tree mirroring the params, so the
dry-run can lower ``train_step`` against abstract state (no allocation) and
the sharding plan can assign PartitionSpecs uniformly (FSDP/ZeRO: states
inherit the fully-sharded param specs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import params as pm


@dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128


def lr_schedule(oc: OptConfig, step):
    """Linear warmup + cosine decay. Warmup counts from 1 (step 0 trains)."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum((step + 1.0) / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    return oc.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), tree), gn


def _is_factored(shape, oc: OptConfig) -> bool:
    return (len(shape) >= 2 and shape[-1] >= oc.min_dim_factored
            and shape[-2] >= oc.min_dim_factored)


class Optimizer:
    def __init__(self, oc: OptConfig):
        self.oc = oc

    # --- state as ParamMeta (single source of truth) ------------------------
    def state_meta(self, param_meta):
        oc = self.oc

        def per_param(m: pm.ParamMeta):
            if oc.kind == "adamw":
                z = dataclasses.replace(m, init="zeros", dtype=oc.moment_dtype)
                return {"m": z, "v": z}
            # adafactor
            if _is_factored(m.shape, oc):
                vr = pm.ParamMeta(m.shape[:-1], m.logical[:-1], init="zeros",
                                  dtype=oc.moment_dtype)
                vc = pm.ParamMeta(m.shape[:-2] + m.shape[-1:],
                                  m.logical[:-2] + m.logical[-1:], init="zeros",
                                  dtype=oc.moment_dtype)
                return {"vr": vr, "vc": vc}
            return {"v": dataclasses.replace(m, init="zeros", dtype=oc.moment_dtype)}

        return pm.tree_map_meta(per_param, param_meta)

    def init(self, params, param_meta=None):
        oc = self.oc

        def per_param(p):
            if oc.kind == "adamw":
                # distinct buffers: m and v are donated separately
                return {"m": jnp.zeros(p.shape, jnp.dtype(oc.moment_dtype)),
                        "v": jnp.zeros(p.shape, jnp.dtype(oc.moment_dtype))}
            if _is_factored(p.shape, oc):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.dtype(oc.moment_dtype)),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.dtype(oc.moment_dtype))}
            return {"v": jnp.zeros(p.shape, jnp.dtype(oc.moment_dtype))}

        return jax.tree_util.tree_map(per_param, params)

    # --- update -------------------------------------------------------------
    def update(self, params, grads, state, step):
        oc = self.oc
        grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
        lr = lr_schedule(oc, step)
        stepf = jnp.asarray(step, jnp.float32) + 1.0

        def upd_adamw(p, g, s):
            g = g.astype(jnp.float32)
            m = s["m"].astype(jnp.float32) * oc.beta1 + (1 - oc.beta1) * g
            v = s["v"].astype(jnp.float32) * oc.beta2 + (1 - oc.beta2) * g * g
            mhat = m / (1 - oc.beta1 ** stepf)
            vhat = v / (1 - oc.beta2 ** stepf)
            upd = mhat / (jnp.sqrt(vhat) + oc.eps) + oc.weight_decay * p.astype(
                jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            mdt = jnp.dtype(oc.moment_dtype)
            return new_p, {"m": m.astype(mdt), "v": v.astype(mdt)}

        def upd_adafactor(p, g, s):
            g = g.astype(jnp.float32)
            beta2t = 1.0 - jnp.power(stepf, -oc.decay_rate)
            g2 = g * g + 1e-30
            mdt = jnp.dtype(oc.moment_dtype)
            if "vr" in s:
                vr = s["vr"].astype(jnp.float32) * beta2t + (1 - beta2t) * jnp.mean(
                    g2, axis=-1)
                vc = s["vc"].astype(jnp.float32) * beta2t + (1 - beta2t) * jnp.mean(
                    g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / (jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30))
                upd = g / (jnp.sqrt(denom) + 1e-30)
                new_s = {"vr": vr.astype(mdt), "vc": vc.astype(mdt)}
            else:
                v = s["v"].astype(jnp.float32) * beta2t + (1 - beta2t) * g2
                upd = g / (jnp.sqrt(v) + 1e-30)
                new_s = {"v": v.astype(mdt)}
            # relative step clipping (RMS-1 style)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms)
            upd = upd + oc.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
            return new_p, new_s

        upd = upd_adamw if oc.kind == "adamw" else upd_adafactor
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_state = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def make_optimizer(cfg, **overrides) -> Optimizer:
    kind = getattr(cfg, "optimizer", "adamw")
    oc = OptConfig(kind=kind, **overrides)
    return Optimizer(oc)
