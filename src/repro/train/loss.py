"""Cross-entropy LM loss with z-loss and masking (labels < 0 are padding)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lm_loss(logits, labels, z_coef: float = 1e-4):
    """logits (B,S,V) — padded vocab is fine: labels index real rows only."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_safe[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    z = jnp.square(lse) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    zloss = z_coef * jnp.sum(z) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == labels_safe) * mask) / denom
    return loss + zloss, {"nll": loss, "z_loss": zloss, "accuracy": acc,
                          "tokens": denom}
