"""train_step / eval_step builders with microbatch gradient accumulation.

``make_train_step(model, opt, n_accum)`` returns a pure function
    (params, opt_state, batch, step) -> (params, opt_state, metrics)
suitable for jit with in/out shardings from the plan. Microbatches are
scanned (sequential) so per-chip activation memory is bounded: the global
batch (B, S) is reshaped to (n_accum, B/n_accum, S).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.loss import lm_loss
from repro.train.optimizer import Optimizer


def _split_batch(batch: Dict[str, Any], n: int):
    def do(x):
        return x.reshape((n, x.shape[0] // n) + x.shape[1:])
    return {k: do(v) for k, v in batch.items()}


def make_loss_fn(model: Model):
    def loss_fn(params, mb):
        logits, aux = model.apply(params, mb)
        loss, metrics = lm_loss(logits, mb["labels"])
        cfg = model.cfg
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux["moe_aux"] \
                        + cfg.router_z_coef * aux["moe_z"]
            metrics = {**metrics, **aux}
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt: Optimizer, n_accum: int = 1,
                    hoist_gather: bool = False):
    """hoist_gather (§Perf iteration 4, default OFF): cast params to the
    compute dtype and constrain to the TP-only layout once per step instead
    of per microbatch. MEASURED REFUTED on deepseek-67b train_4k: XLA already
    hoists the loop-invariant all-gathers (LICM), so this only materialized a
    second full-precision copy (+16 GB temp, collective unchanged). Kept as
    an opt-in knob for runtimes without LICM across the accumulation loop."""
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    plan = model.plan
    hoist = (hoist_gather and n_accum > 1 and plan.mesh is not None
             and plan.fsdp and plan.dp_axes)
    if hoist:
        from repro.models import params as pm
        meta = model.param_meta()
        gathered_specs = pm.tree_map_meta(lambda m: plan.spec(m.logical), meta)
        fsdp_specs = plan.param_specs(meta)
        from jax.sharding import NamedSharding

        def gather(p, s):
            return jax.lax.with_sharding_constraint(
                p.astype(jnp.dtype(model.cfg.dtype)),
                NamedSharding(plan.mesh, s))

        def scatter_grad(g, s):
            return jax.lax.with_sharding_constraint(
                g.astype(jnp.float32), NamedSharding(plan.mesh, s))

    def train_step(params, opt_state, batch, step):
        if n_accum == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            params_c = (jax.tree_util.tree_map(gather, params, gathered_specs)
                        if hoist else params)
            mbs = _split_batch(batch, n_accum)

            def body(carry, mb):
                acc_g, acc_l = carry
                (l, m), g = grad_fn(params_c, mb)
                if hoist:
                    g = jax.tree_util.tree_map(scatter_grad, g, fsdp_specs)
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), m

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(body, (zero_g, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_accum, grads)
            loss = loss_sum / n_accum
            metrics = jax.tree_util.tree_map(lambda x: jnp.mean(x, 0), ms)

        params, opt_state, opt_metrics = opt.update(params, grads, opt_state, step)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    loss_fn = make_loss_fn(model)

    def eval_step(params, batch):
        loss, metrics = loss_fn(params, batch)
        return {**metrics, "loss": loss}

    return eval_step
