"""The paper's technique as a first-class training feature.

Trains a small model while the EnergyAwareRuntime plans per-chip rails for a
simulated 16x16 v5e pod under three policies, reproducing the paper's story
at fleet scale: power_save (Algorithm 1 — same step time, lower power),
min_energy (Algorithm 2 — stretch the step, minimize energy), and
overscale:1.2 (§III-D — error-tolerant margin violation). Also prints the
dynamic-scheme lookup table (TSD -> rails) and a straggler-mitigation event.

    PYTHONPATH=src python examples/energy_aware_training.py
"""
import jax
import numpy as np

from repro import policy as pol
from repro.configs import registry
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.data.pipeline import DataConfig, make_iterator
from repro.models.model import Model
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step


def main():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    opt = make_optimizer(cfg, lr=1e-3, warmup_steps=5, total_steps=100)
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    it = make_iterator(cfg, DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                       global_batch=8, branch=2))

    # profile from the dry-run roofline of the production workload;
    # policies are first-class repro.policy objects (see DESIGN.md)
    prof = TF.StepProfile.from_roofline(compute_s=0.7, memory_s=0.4,
                                        collective_s=0.15)
    runtimes = {name: RT.EnergyAwareRuntime(prof, policy=p)
                for name, p in (("power_save", pol.PowerSave()),
                                ("min_energy", pol.MinEnergy()),
                                ("overscale:1.2", pol.Overscale(gamma=1.2)))}

    for i in range(10):
        params, opt_state, m = step(params, opt_state, next(it), i)
        if i % 3 == 0:
            line = f"step {i}: loss={float(m['loss']):.3f}"
            for name, rt in runtimes.items():
                plan = rt.plan()
                line += f" | {name}: save={plan.saving*100:.0f}%"
            print(line)

    rt = runtimes["power_save"]
    print("\ndynamic scheme LUT (T_amb -> median rails):")
    for t, (vc, vs) in rt.dynamic_lut([15.0, 25.0, 35.0]).items():
        print(f"  {t:4.0f}C -> v_core={vc:.2f} v_sram={vs:.2f}")

    plan = rt.plan()
    rt.T = rt.T.at[42].set(88.0)  # a hot chip appears
    print("\nstraggler mitigation:", rt.straggler_mitigation(plan, 42, 1.4))


if __name__ == "__main__":
    main()
