"""Traffic-driven serving: thermal-aware admission vs throughput-only.

The DESIGN.md §8 acceptance story, end to end on real components (no
scripted load traces — a live continuous-batching ``Engine`` serves a
deterministic request workload under the full control loop):

- the day is ``scenarios.serve_day``: a hot window (rails near nominal,
  every token expensive) followed by a machine-room cool-down (low rails,
  cheap tokens);
- the workload is ``scenarios.poisson_burst``: a burst bigger than the
  slot count landing inside the hot window, plus a light Poisson tail;
- the **throughput-only** baseline admits whenever a slot is free: the
  burst is served hot;
- the **thermal-aware** run wraps the same RailField controller in an
  ``AdmissionController``: each control tick it prices the marginal
  admission off the field's per-chip nominal-power grid, defers work the
  hot window would overcharge for, and programs ``Throttle`` and
  ``SetRails`` as ONE joint decision (rails computed at the utilization
  about to be admitted);
- both runs serve the SAME tokens (greedy decode, identical outputs —
  pinned), finish inside the same SLO, and the replay fingerprints are
  deterministic; the thermal-aware day simply spends fewer joules.

``--paged`` re-runs the whole comparison through the paged KV cache
(block-table indirection, free-list page allocator) and asserts the replay
fingerprints are bitwise identical to the contiguous path — paging is a
memory-layout change, not a numerics change — before checking the same
tokens/joule win on the paged engine.

    PYTHONPATH=src python examples/traffic_serving.py [--quick] [--paged]
"""
import argparse
import time

import jax

from repro import scenarios as sc
from repro.configs import registry
from repro.models.model import Model

SLO_ENGINE_TICKS = 90.0  # completion deadline, engine ticks from submit


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="short day + small burst (the CI smoke shape)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV cache and pin its "
                         "fingerprints bitwise against the contiguous runs")
    args = ap.parse_args(argv)

    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.quick:
        day = sc.serve_day(ticks=10, hot=42.0, cool=12.0, cool_at=5)
        wl = sc.poisson_burst(burst_at=1, burst_n=6, tail_ticks=2, seed=0)
    else:
        day = sc.serve_day(ticks=14, hot=42.0, cool=12.0, cool_at=7)
        wl = sc.poisson_burst(burst_at=1, burst_n=8, tail_ticks=4, seed=0)
    print(f"[day] {day.description}  [workload] {wl.name} "
          f"({len(wl.arrivals)} requests, fp={wl.fingerprint})")

    engine_kwargs = {"paged": True} if args.paged else {}
    runs = {}
    for tag, admission in (("throughput-only", False),
                           ("thermal-aware", True)):
        t0 = time.time()
        runs[tag] = sc.serve_replay(day, wl, model, params,
                                    admission=admission, **engine_kwargs)
        r = runs[tag]
        print(f"[{tag:16s}] tokens={r.tokens:3d} energy={r.energy_j:12.0f} J"
              f"  tokens/MJ={r.tokens_per_joule * 1e6:7.1f}"
              f"  max_wait={r.max_wait:4.0f} ticks"
              f"  deferred={r.deferred:2d} fp={r.fingerprint}"
              f"  ({time.time() - t0:.1f}s)")

    thru, therm = runs["throughput-only"], runs["thermal-aware"]
    assert thru.outputs == therm.outputs, "admission changed the tokens"
    assert therm.max_wait <= SLO_ENGINE_TICKS >= thru.max_wait, "SLO miss"
    assert thru.finished == therm.finished == len(wl.arrivals)
    if args.paged:
        # block-table indirection is a memory-layout change, not a
        # numerics change: the paged day must replay the contiguous day
        # bit for bit (tokens, admission caps, energy integral)
        for tag, admission in (("throughput-only", False),
                               ("thermal-aware", True)):
            contig = sc.serve_replay(day, wl, model, params,
                                     admission=admission)
            assert runs[tag].fingerprint == contig.fingerprint, \
                f"paged {tag} diverged from the contiguous path"
        print(f"[paged] both fingerprints bitwise == contiguous path")
    win = therm.tokens_per_joule / thru.tokens_per_joule
    print(f"[win] thermal-aware serves the same tokens at {win:.2f}x "
          f"tokens/joule (deferring {therm.deferred} admissions out of the "
          f"hot window)")
    assert win > 1.0, "thermal-aware admission must beat throughput-only"


if __name__ == "__main__":
    main()
