"""Fault-tolerance walkthrough: checkpoint/restart, failure injection,
straggler detection, elastic rescale.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import tempfile

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, make_iterator
from repro.ft.elastic import choose_mesh_shape
from repro.ft.monitor import (FailureInjector, Heartbeat, StragglerDetector,
                              retry_step)
from repro.models.model import Model
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step


def main():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    opt = make_optimizer(cfg, lr=1e-3, warmup_steps=2, total_steps=100)
    step_fn = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    it = make_iterator(cfg, DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                       global_batch=8, branch=2))
    tmp = tempfile.mkdtemp()
    ckpt = CheckpointManager(tmp, keep_last=2)
    injector = FailureInjector(fail_at={4, 7})
    straggler = StragglerDetector(min_samples=4)
    hb = Heartbeat(timeout_s=30.0)

    import time
    for i in range(10):
        batch = next(it)

        def do():
            injector.maybe_fail(i)
            return step_fn(params, opt_state, batch, i)

        t0 = time.time()
        params, opt_state, m = retry_step(
            do, on_failure=lambda a, e: print(f"  [ft] {e} -> retry {a}"))
        hb.beat("worker0")
        ev = straggler.record("worker0", i, time.time() - t0)
        if ev:
            print(f"  [ft] straggler flagged: {ev.ratio:.1f}x median")
        if (i + 1) % 5 == 0:
            ckpt.save(i + 1, {"params": params, "opt": opt_state})
            print(f"step {i}: loss={float(m['loss']):.3f} (checkpointed)")
        else:
            print(f"step {i}: loss={float(m['loss']):.3f}")

    ckpt.wait()
    print(f"\ncheckpoints: {ckpt.all_steps()}; restoring latest...")
    restored, got = ckpt.restore({"params": params, "opt": opt_state})
    print(f"restored step {got}; dead workers: {hb.dead() or 'none'}")
    print("elastic: 256 devices ->", choose_mesh_shape(256, 16),
          "| after losing a host (248):", choose_mesh_shape(248, 16))


if __name__ == "__main__":
    main()
