"""Over-scaling studies: the paper's Fig-8 FPGA sweep + the §V
error-tolerant tier on the TPU substrate.

Part 1 (Fig 8): sweeps the timing-violation budget gamma, runs Algorithm 1
with the relaxed ``Overscale`` policy on the FPGA-mapped app netlists (the
whole gamma schedule is ONE batched ``repro.policy`` solve), derives the
bit-error profile from the violating-path population, and measures end
accuracy through the error-injected int8 matmul.

Part 2 (§V, repro.tolerance): the same idea live on the TPU fleet —
an accuracy-vs-rail curve for llama3.2-1b with its MLP matmuls routed
through the ABFT-checksummed over-scaled kernel, then a replayed
``sdc_storm`` day where the ``ErrorTolerant`` closed loop undercuts
PowerSave's power at a declared escaped-SDC budget, backing off when the
noise spike blows through it.

    PYTHONPATH=src python examples/overscaling_study.py [--quick]
"""
import argparse
import time

import jax
import numpy as np

from repro import scenarios as SC
from repro.configs import registry
from repro.control.lut import sweep_points
from repro.core import apps, netlist as NL, overscaling as OS, thermal
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.models.model import Model
from repro.tolerance import (AbftMatmul, FaultInjector, TimingFaultModel,
                             routed_matmuls, topk_agreement)

BUDGET = 1e-5
SWEEP, USWEEP = (20.0, 36.0, 5), (0.25, 1.0, 3)


def fig8_study(quick: bool) -> None:
    key = jax.random.PRNGKey(42)
    print("training LeNet on synthetic digits...")
    p, _ = apps.lenet_train(key, steps=200 if quick else 500)
    hd = apps.hd_train(key)
    print(f"clean accuracy: lenet={apps.lenet_accuracy(p, key):.4f} "
          f"hd={apps.hd_accuracy(hd, key):.4f}\n")

    tc = thermal.ThermalConfig(theta_ja=12.0)
    gammas = [1.0, 1.2, 1.35] if quick else [1.0, 1.1, 1.2, 1.3, 1.35, 1.4]
    print(f"{'app':8s} {'gamma':6s} {'V_core':7s} {'V_bram':7s} "
          f"{'saving':8s} {'accuracy':8s}")
    for stats, label in ((apps.LENET_STATS, "lenet"), (apps.HD_STATS, "hd")):
        nl = NL.generate(stats)
        for r in OS.sweep(nl, gammas, t_amb=40.0, tc=tc):
            g = r.gamma
            if label == "lenet":
                acc = apps.lenet_accuracy(
                    p, key, bit_probs=apps.scale_bit_probs(r.bit_probs))
            else:
                acc = apps.hd_accuracy(
                    hd, key, flip_prob=apps.hd_flip_prob(r.bit_probs))
            print(f"{label:8s} {g:<6.2f} {r.v_core:<7.2f} {r.v_bram:<7.2f} "
                  f"{r.saving*100:<7.1f}% {acc:<8.4f}")
    print("\npaper Fig 8: ~34% saving at gamma=1.0; at 1.35: LeNet 48%/-3%, "
          "HD 50%/-0.5%; errors spike past ~1.35")


def accuracy_vs_rail(quick: bool) -> None:
    """llama3.2-1b (reduced) with MLP matmuls through the ABFT kernel,
    at rails stepping below the guard band."""
    print("\n=== §V accuracy vs rail: llama3.2-1b through the ABFT "
          "matmul ===")
    # scan_layers=False: the ABFT matmul is a host-side kernel, so the
    # layer stack must unroll rather than trace under lax.scan
    cfg = registry.get("llama3.2-1b").reduced().replace(scan_layers=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = (np.arange(2 * 24, dtype=np.int32).reshape(2, 24)
              % cfg.vocab_size)
    ref_logits = np.asarray(model.apply(params, {"tokens": tokens})[0])

    fm = TimingFaultModel()
    t_chip = 65.0
    vs0 = TF.V_SRAM_NOM
    # nominal, then 5 mV steps from just above the guard band edge
    # (~0.7265 V at 65 C) down into ABFT-corrected and then overwhelmed
    # territory
    rails = [TF.V_CORE_NOM] + [0.730 - 0.005 * i
                               for i in range(3 if quick else 7)]
    print(f"{'v_core':7s} {'overshoot':10s} {'esc_rate':10s} "
          f"{'inj':>5s} {'det':>5s} {'corr':>5s} {'esc':>4s} {'top1':>6s}")
    for vc in rails:
        x = float(fm.overshoot(vc, vs0, t_chip))
        probs = fm.bit_probs(vc, vs0, t_chip)
        mm = AbftMatmul(probs, jax.random.PRNGKey(9), use_pallas=True)
        with routed_matmuls(mm):
            logits = np.asarray(model.apply(params, {"tokens": tokens})[0])
        top1 = topk_agreement(logits, ref_logits, k=1)
        c = mm.counters
        print(f"{vc:<7.3f} {x:<10.4f} "
              f"{float(np.max(fm.escaped_rate(vc, vs0, t_chip))):<10.2e} "
              f"{c.injected:>5d} {c.detected:>5d} {c.corrected:>5d} "
              f"{c.escaped:>4d} {top1:>6.3f}")
    print("at the guard band the curve is exactly flat (zero injections); "
          "below it the syndromes detect every flip, but shallow overshoot "
          "concentrates flips on the MSB whose identical deltas alias — "
          "those escapes are exactly what the ErrorTolerant budget and the "
          "controller back-off are declared against")


def sdc_storm_day(quick: bool) -> None:
    """PowerSave vs the ErrorTolerant closed loop on the sdc_storm day."""
    print(f"\n=== §V closed loop: sdc_storm at budget {BUDGET:.0e} ===")
    prof = TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)
    scn = SC.sdc_storm(ticks=16, spike_at=6) if quick else SC.sdc_storm()

    rt_ps = RT.EnergyAwareRuntime(prof, policy="power_save")
    c_ps = rt_ps.controller(
        field=rt_ps.build_field(sweep_points(*SWEEP), sweep_points(*USWEEP)),
        guard_band_c=3.0)
    r_ps = SC.replay(scn, runtime=rt_ps, controller=c_ps)

    rt_et = RT.EnergyAwareRuntime(prof, policy=f"error_tolerant:{BUDGET}")
    t0 = time.time()
    c_et = rt_et.controller(
        field=rt_et.build_field(sweep_points(*SWEEP), sweep_points(*USWEEP)),
        guard_band_c=3.0, sdc_budget=BUDGET)
    print(f"[field] ErrorTolerant RailField built in {time.time() - t0:.2f}s")
    inj = FaultInjector(TimingFaultModel(rt_et.lib), seed=7)
    r_et = SC.replay(scn, runtime=rt_et, controller=c_et, injector=inj)

    print(f"{'policy':22s} {'saving':8s} {'energy_MJ':10s} {'backoffs':9s} "
          f"{'escape_rate':12s}")
    print(f"{'power_save':22s} {r_ps.mean_saving*100:<7.1f}% "
          f"{r_ps.energy_j/1e6:<10.2f} {'-':9s} {'-':12s}")
    print(f"{'error_tolerant':22s} {r_et.mean_saving*100:<7.1f}% "
          f"{r_et.energy_j/1e6:<10.2f} {r_et.backoffs:<9d} "
          f"{r_et.escape_rate:<12.2e}")
    assert r_et.mean_saving > r_ps.mean_saving
    assert r_et.escape_rate <= BUDGET
    print(f"SDC ledger: injected={r_et.sdc_injected} "
          f"corrected={r_et.sdc_corrected} escaped={r_et.sdc_escaped} "
          f"(budget honored: {r_et.escape_rate:.2e} <= {BUDGET:.0e}; "
          f"back-off fired {r_et.backoffs}x during the spike)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-fig8", action="store_true",
                    help="only the §V error-tolerance tier")
    args = ap.parse_args()
    if not args.skip_fig8:
        fig8_study(args.quick)
    accuracy_vs_rail(args.quick)
    sdc_storm_day(args.quick)


if __name__ == "__main__":
    main()
