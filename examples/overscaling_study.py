"""Fig-8 study: voltage over-scaling on error-tolerant apps (LeNet + HD).

Sweeps the timing-violation budget gamma, runs Algorithm 1 with the relaxed
``Overscale`` policy on the FPGA-mapped app netlists (the whole gamma
schedule is ONE batched ``repro.policy`` solve), derives the bit-error
profile from the violating-path population, and measures end accuracy
through the error-injected int8 matmul.

    PYTHONPATH=src python examples/overscaling_study.py [--quick]
"""
import argparse

import jax

from repro.core import apps, netlist as NL, overscaling as OS, thermal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    key = jax.random.PRNGKey(42)
    print("training LeNet on synthetic digits...")
    p, _ = apps.lenet_train(key, steps=200 if args.quick else 500)
    hd = apps.hd_train(key)
    print(f"clean accuracy: lenet={apps.lenet_accuracy(p, key):.4f} "
          f"hd={apps.hd_accuracy(hd, key):.4f}\n")

    tc = thermal.ThermalConfig(theta_ja=12.0)
    gammas = [1.0, 1.2, 1.35] if args.quick else [1.0, 1.1, 1.2, 1.3, 1.35, 1.4]
    print(f"{'app':8s} {'gamma':6s} {'V_core':7s} {'V_bram':7s} "
          f"{'saving':8s} {'accuracy':8s}")
    for stats, label in ((apps.LENET_STATS, "lenet"), (apps.HD_STATS, "hd")):
        nl = NL.generate(stats)
        for r in OS.sweep(nl, gammas, t_amb=40.0, tc=tc):
            g = r.gamma
            if label == "lenet":
                acc = apps.lenet_accuracy(
                    p, key, bit_probs=apps.scale_bit_probs(r.bit_probs))
            else:
                acc = apps.hd_accuracy(
                    hd, key, flip_prob=apps.hd_flip_prob(r.bit_probs))
            print(f"{label:8s} {g:<6.2f} {r.v_core:<7.2f} {r.v_bram:<7.2f} "
                  f"{r.saving*100:<7.1f}% {acc:<8.4f}")
    print("\npaper Fig 8: ~34% saving at gamma=1.0; at 1.35: LeNet 48%/-3%, "
          "HD 50%/-0.5%; errors spike past ~1.35")


if __name__ == "__main__":
    main()
