"""Closed-loop serving under a diurnal ambient sweep (repro.control).

The full telemetry -> controller -> actuator loop of DESIGN.md §3 around a
live continuous-batching serve engine:

- requests trickle into the engine; every scheduler tick emits telemetry
  (queue depth, active slots, tokens, tick wall time),
- an ``AmbientSensor`` replays a diurnal sine (18-32C) with a forced +12C
  jump two thirds through the day (a cooling failure / hot-aisle event),
- the ``LutController`` answers quasi-static drift from the interpolated
  §III-B LUT (built with ONE batched solve over the ambient sweep) and
  falls back to the full Algorithm-1 fixed point on the jump,
- a ``FleetActuator`` applies the rails to the simulated 16x16 pod and
  re-solves the thermal field, closing the loop; the run report shows the
  power saved vs nominal rails with t_max bounded all day.

    PYTHONPATH=src python examples/closed_loop_serving.py
"""
import time

import jax
import numpy as np

from repro import control as ctl
from repro.configs import registry
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.models.model import Model
from repro.serve.engine import Engine, Request

TICKS = 120
CONTROL_EVERY = 4  # engine ticks per control tick
JUMP_AT = 80  # forced ambient jump (cooling failure), in engine ticks


def ambient(now: float) -> float:
    """Diurnal sine, 18-32C, plus a +12C step after JUMP_AT."""
    base = 25.0 + 7.0 * np.sin(2.0 * np.pi * now / TICKS)
    return base + (12.0 if now >= JUMP_AT else 0.0)


def main():
    # -- the serving runtime -------------------------------------------------
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=4, max_len=96)
    eng_src = ctl.EngineTelemetry()
    eng.on_tick.append(eng_src.on_tick)

    # -- the control plane ---------------------------------------------------
    prof = TF.StepProfile.from_roofline(compute_s=0.7, memory_s=0.4,
                                        collective_s=0.15)
    rt = RT.EnergyAwareRuntime(prof, policy="power_save")
    t0 = time.time()
    controller = rt.controller(sweep=(12.0, 42.0, 7), guard_band_c=3.0)
    print(f"[lut] {controller.lut} built in {time.time() - t0:.2f}s "
          f"(one solve_batch over the sweep)")
    fleet = ctl.FleetActuator.from_runtime(rt)
    loop = ctl.ControlLoop(
        ctl.TelemetryBus([ctl.AmbientSensor(ambient), eng_src, fleet]),
        controller, [fleet, ctl.EngineActuator(eng)])

    # -- one simulated day ---------------------------------------------------
    rid, t_serve = 0, 0.0
    for tick in range(TICKS):
        if tick % 6 == 0:  # request arrivals
            eng.submit(Request(rid, np.arange(4 + rid % 5) % cfg.vocab_size,
                               max_new=8))
            rid += 1
        t1 = time.time()
        eng.step()
        t_serve += time.time() - t1
        if tick % CONTROL_EVERY == 0:
            rep = loop.step(now=float(tick))
            rails = next(a for a in rep.actions
                         if isinstance(a, ctl.SetRails))
            r = rep.readout
            marker = " <- FULL REPLAN" if rails.source == "solver" else ""
            if tick % 16 == 0 or rails.source == "solver":
                print(f"tick {tick:3d}: amb={rep.snapshot.t_amb:5.1f}C "
                      f"queue={rep.snapshot.queued} "
                      f"active={rep.snapshot.active} "
                      f"rails[{rails.source}] save={r.saving*100:5.1f}% "
                      f"t_max={r.t_max:5.1f}C{marker}")
    eng.run(max_ticks=64)  # drain the tail of the queue

    # -- run report ----------------------------------------------------------
    ro = [rep.readout for rep in loop.history]
    t_max = max(r.t_max for r in ro)
    saving = float(np.mean([r.saving for r in ro]))
    st = controller.stats
    print("\n=== closed-loop day report ===")
    print(f"requests completed : {len(eng.finished)}/{rid}")
    print(f"tokens generated   : {sum(len(r.out) for r in eng.finished)} "
          f"({t_serve:.1f}s serving)")
    print(f"control ticks      : {len(loop.history)} "
          f"(lut_hits={st.lut_hits} replans={st.replans} "
          f"reasons={st.replan_reasons})")
    print(f"mean power saving  : {saving*100:.1f}% vs nominal rails")
    print(f"max junction temp  : {t_max:.1f}C "
          f"(limit {TF.T_MAX_CHIP:.0f}C)")
    assert len(eng.finished) == rid, "dropped requests"
    assert saving > 0.0, "no power saved"
    assert t_max < TF.T_MAX_CHIP, "junction limit violated"
    assert st.lut_hits > st.replans, "fast path did not dominate"
    assert st.replans >= 2, "the ambient jump should force a replan"
    print("OK: fast path dominated, jump forced a replan, margin -> power.")


if __name__ == "__main__":
    main()
