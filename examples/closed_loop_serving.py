"""Closed-loop serving on the per-chip RailField (repro.control).

The full telemetry -> controller -> actuator loop of DESIGN.md §3/§5 around
a live continuous-batching serve engine:

- requests trickle into the engine; every scheduler tick emits telemetry
  (queue depth, active slots / total slots -> the load fraction, tokens,
  tick wall time),
- an ``AmbientSensor`` replays a diurnal sine (18-32C) with a forced +12C
  jump two thirds through the day (a cooling failure / hot-aisle event),
- a mid-day request burst swings the engine load — with the old scalar LUT
  every swing past ``util_band`` forced a ``util_drift`` replan; the
  ``RailField``'s utilization axis answers it from the table,
- the ``LutController`` interpolates per-chip ``(v_core, v_sram)`` rails
  bilinearly over (ambient, per-chip utilization) — ONE early-freeze
  ``solve_batch`` built the whole 2-D grid — and falls back to the full
  Algorithm-1 fixed point on the jump,
- a ``FleetActuator`` applies the per-chip rails to the simulated 16x16
  pod and re-solves the thermal field; an ``ElasticActuator`` stands by to
  migrate work off any chip the controller condemns, closing the loop.

    PYTHONPATH=src python examples/closed_loop_serving.py
"""
import time

import jax
import numpy as np

from repro import control as ctl
from repro.configs import registry
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.ft.elastic import ElasticActuator, ElasticWorkAssignment
from repro.models.model import Model
from repro.serve.engine import Engine, Request

TICKS = 120
CONTROL_EVERY = 4  # engine ticks per control tick
JUMP_AT = 80  # forced ambient jump (cooling failure), in engine ticks
BURST_AT = range(40, 56)  # mid-day request burst (the load spike)


def ambient(now: float) -> float:
    """Diurnal sine, 18-32C, plus a +12C step after JUMP_AT."""
    base = 25.0 + 7.0 * np.sin(2.0 * np.pi * now / TICKS)
    return base + (12.0 if now >= JUMP_AT else 0.0)


def main():
    # -- the serving runtime -------------------------------------------------
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, batch_slots=4, max_len=96)
    eng_src = ctl.EngineTelemetry()
    eng.on_tick.append(eng_src.on_tick)

    # -- the control plane ---------------------------------------------------
    prof = TF.StepProfile.from_roofline(compute_s=0.7, memory_s=0.4,
                                        collective_s=0.15)
    rt = RT.EnergyAwareRuntime(prof, policy="power_save")
    t0 = time.time()
    controller = rt.controller(sweep=(12.0, 42.0, 7),
                               util_sweep=(0.25, 1.0, 4),
                               guard_band_c=3.0)
    print(f"[field] {controller.field} built in {time.time() - t0:.2f}s "
          f"(one early-freeze solve_batch over the 2-D sweep)")
    elastic = ElasticActuator(ElasticWorkAssignment(rt.substrate.n_domains))
    fleet = ctl.FleetActuator.from_runtime(rt, field=controller.field)
    loop = ctl.ControlLoop(
        ctl.TelemetryBus([ctl.AmbientSensor(ambient), eng_src, elastic,
                          fleet]),
        controller, [fleet, elastic, ctl.EngineActuator(eng)])

    # -- one simulated day ---------------------------------------------------
    rid, t_serve = 0, 0.0
    for tick in range(TICKS):
        burst = tick in BURST_AT
        if tick % 6 == 0 or burst:  # arrivals (burst: every tick)
            eng.submit(Request(rid, np.arange(4 + rid % 5) % cfg.vocab_size,
                               max_new=8))
            rid += 1
        t1 = time.time()
        eng.step()
        t_serve += time.time() - t1
        if tick % CONTROL_EVERY == 0:
            rep = loop.step(now=float(tick))
            rails = next(a for a in rep.actions
                         if isinstance(a, ctl.SetRails))
            r = rep.readout
            marker = " <- FULL REPLAN" if rails.source == "solver" else ""
            if tick % 16 == 0 or rails.source == "solver":
                vc = np.atleast_1d(np.asarray(rails.v_core))
                print(f"tick {tick:3d}: amb={rep.snapshot.t_amb:5.1f}C "
                      f"load={rep.snapshot.load or 0.0:4.2f} "
                      f"queue={rep.snapshot.queued} "
                      f"rails[{rails.source}] "
                      f"vc=[{vc.min():.3f},{vc.max():.3f}] "
                      f"save={r.saving*100:5.1f}% "
                      f"t_max={r.t_max:5.1f}C{marker}")
    eng.run(max_ticks=64)  # drain the tail of the queue

    # -- run report ----------------------------------------------------------
    ro = [rep.readout for rep in loop.history]
    t_max = max(r.t_max for r in ro)
    saving = float(np.mean([r.saving for r in ro]))
    st = controller.stats
    print("\n=== closed-loop day report ===")
    print(f"requests completed : {len(eng.finished)}/{rid}")
    print(f"tokens generated   : {sum(len(r.out) for r in eng.finished)} "
          f"({t_serve:.1f}s serving)")
    print(f"control ticks      : {len(loop.history)} "
          f"(lut_hits={st.lut_hits} replans={st.replans} "
          f"reasons={st.replan_reasons})")
    print(f"mean power saving  : {saving*100:.1f}% vs nominal rails")
    print(f"max junction temp  : {t_max:.1f}C "
          f"(limit {TF.T_MAX_CHIP:.0f}C)")
    assert len(eng.finished) == rid, "dropped requests"
    assert saving > 0.0, "no power saved"
    assert t_max < TF.T_MAX_CHIP, "junction limit violated"
    assert st.lut_hits > st.replans, "fast path did not dominate"
    assert st.replans >= 2, "the ambient jump should force a replan"
    assert not any(r.startswith("util") for r in st.replan_reasons), \
        "load swings must ride the utilization axis, not replan"
    print("OK: fast path served ambient drift AND the load burst; "
          "the jump forced a replan; margin -> power.")


if __name__ == "__main__":
    main()
