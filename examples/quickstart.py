"""Quickstart: build a model, train a few steps, serve a few tokens — on CPU.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]

Uses the reduced (smoke) configs; the same code paths scale to the production
meshes via launch/train.py + launch/dryrun.py.
"""
import argparse

import jax
import numpy as np

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_iterator
from repro.models.model import Model
from repro.serve.engine import Engine, Request
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    cfg = registry.get(args.arch).reduced()
    model = Model(cfg)
    print(f"arch={cfg.name} family={cfg.family} params={model.n_params():,}")

    # --- train ---------------------------------------------------------------
    opt = make_optimizer(cfg, lr=3e-3, warmup_steps=5, total_steps=200)
    step = jax.jit(make_train_step(model, opt))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    it = make_iterator(cfg, DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                       global_batch=8, branch=2))
    for i in range(args.steps):
        params, opt_state, m = step(params, opt_state, next(it), i)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i}: loss={float(m['loss']):.3f} "
                  f"acc={float(m['accuracy']):.3f}")

    # --- serve ---------------------------------------------------------------
    if cfg.family in ("vlm", "audio"):
        print("(serving demo skipped for stub-frontend families)")
        return
    eng = Engine(model, params, batch_slots=2, max_len=128)
    for rid in range(3):
        eng.submit(Request(rid, np.arange(5 + rid) % cfg.vocab_size,
                           max_new=8))
    for r in eng.run():
        print(f"request {r.rid}: generated {r.out}")


if __name__ == "__main__":
    main()
