"""True paged attention (repro.serve.cache.PagedKVCacheManager + engine).

The §8 acceptance pins: block-table indirection is a memory-layout change,
never a numerics change — paged replay is bitwise the contiguous replay
(dense + SWA), the speculative accepted prefix is bitwise the greedy
sequence, and the free-list allocator admits strictly more concurrent
work than contiguous slots at the same page budget (the churn workload).
"""
import jax
import numpy as np
import pytest

from repro import scenarios as sc
from repro.configs import registry
from repro.models.model import Model
from repro.serve.cache import (ExpandablePagedKVCacheManager, PageAllocator,
                               PagedKVCacheManager)
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def dense():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def swa():
    cfg = registry.get("mixtral-8x7b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    return cfg, model, params


def _prompt(cfg, rid, n=5):
    return ((np.arange(n) * 3 + rid * 7) % cfg.vocab_size).astype(np.int32)


def _outs(cfg, model, params, n_req=4, max_new=12, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("eos_id", -1)
    kw.setdefault("warmup", False)
    eng = Engine(model, params, **kw)
    for rid in range(n_req):
        eng.submit(Request(rid, _prompt(cfg, rid), max_new=max_new))
    eng.run()
    return eng, {r.rid: tuple(r.out) for r in eng.finished}


class TestPageAllocator:
    def test_alloc_free_roundtrip(self):
        al = PageAllocator(4)
        assert al.free_pages == 4 and al.used_pages == 0
        a = al.alloc(3)
        assert len(a) == 3 and len(set(a)) == 3
        assert al.free_pages == 1 and al.used_pages == 3
        al.free(a[:2])
        assert al.free_pages == 3
        b = al.alloc(3)  # reuses the freed pages
        assert al.free_pages == 0 and sorted(a[2:] + b) == list(range(4))

    def test_exhaustion_raises(self):
        al = PageAllocator(2)
        al.alloc(2)
        with pytest.raises(RuntimeError, match="exhausted"):
            al.alloc(1)

    def test_double_free_and_invalid_page_raise(self):
        al = PageAllocator(3)
        pages = al.alloc(2)
        al.free(pages)
        with pytest.raises(ValueError, match="double free"):
            al.free([pages[0]])
        with pytest.raises(ValueError, match="invalid page"):
            al.free([3])
        with pytest.raises(ValueError, match="invalid page"):
            al.free([-1])
        # the free list stayed sane: all three pages allocate exactly once
        assert sorted(al.alloc(3)) == [0, 1, 2]


class TestPagedManagerLifecycle:
    def test_non_contiguous_allocation(self, dense):
        """Pages come from the free list, not from a per-slot span: after
        interleaved alloc/free, a slot's block table holds non-adjacent
        physical pages (the whole point of the indirection)."""
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=3, max_len=64, page_size=16)
        a = mgr.allocate(5)
        b = mgr.allocate(5)
        mgr.advance([a], [20])  # a claims a second page *after* b's first
        pages_a = list(mgr.block_table[a, :2])
        assert pages_a[1] - pages_a[0] != 1  # b's page sits in between
        freed = int(mgr.block_table[b, 0])
        mgr.free(b)
        assert not mgr.allocator._owned[freed]  # b's page back in the pool
        c = mgr.allocate(5)  # new slot allocates without relocating a
        assert list(mgr.block_table[a, :2]) == pages_a
        assert mgr.block_table[c, 0] != mgr.null_page
        assert mgr.pages_in_use == mgr.recount_pages() == 3

    def test_incremental_pages_pinned_against_recount(self, dense):
        """The O(1) counter, the O(slots*width) recount, and the allocator
        ledger agree after every mutation."""
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=2, max_len=64, page_size=16)

        def pinned():
            assert (mgr.pages_in_use == mgr.recount_pages()
                    == mgr.allocator.used_pages)

        s = mgr.allocate(5)
        pinned()
        mgr.advance([s], [30])  # 30 tokens -> 2 pages
        pinned()
        assert mgr.pages_in_use == 2
        mgr.extend(s, 50)
        pinned()
        assert mgr.pages_in_use == 4 and mgr.peak_pages == 4
        mgr.trim(s, 30)
        pinned()
        assert mgr.pages_in_use == 2
        t = mgr.allocate(3)
        pinned()
        mgr.free(s)
        mgr.free(t)
        pinned()
        assert mgr.pages_in_use == 0 and mgr.peak_pages == 4

    def test_trim_is_the_spec_rollback(self, dense):
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=1, max_len=64, page_size=16)
        s = mgr.allocate(4)
        mgr.extend(s, 64)
        assert mgr.slot_pages(s) == 4
        assert mgr.trim(s, 17) == 2  # keep ceil(17/16) = 2 pages
        assert mgr.slot_pages(s) == 2 and mgr.allocator.free_pages == 2
        assert mgr.trim(s, 32) == 0  # trim never grows
        assert mgr.trim(s, 0) == 1   # but always keeps one page
        assert mgr.slot_pages(s) == 1

    def test_slot_free_guards(self, dense):
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=2, max_len=64, page_size=16)
        s = mgr.allocate(4)
        mgr.free(s)
        with pytest.raises(ValueError, match="double free"):
            mgr.free(s)
        with pytest.raises(ValueError, match="invalid slot"):
            mgr.free(2)

    def test_inverse_map_inverts_the_block_table(self, dense):
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=2, max_len=64, page_size=16)
        a = mgr.allocate(5)
        mgr.advance([a], [20])
        b = mgr.allocate(5)
        inv = mgr.inverse_map()
        B, W = mgr.block_table.shape
        for s in range(B):
            for j in range(W):
                pg = mgr.block_table[s, j]
                if pg != mgr.null_page:
                    assert inv[pg] == s * W + j
        # unallocated pages and the null page map to the fill source
        assert inv[mgr.null_page] == B * W
        unalloc = set(range(mgr.total_pages)) - {
            int(p) for p in mgr.block_table.reshape(-1)
            if p != mgr.null_page}
        assert all(inv[p] == B * W for p in unalloc)

    def test_null_page_stays_invalid_through_scatter_all(self, dense):
        """Every unallocated block-table entry aliases the null page; the
        fused-step writeback must leave it (and any unallocated page)
        invalid, or stale entries would surface under a future owner."""
        import jax.numpy as jnp
        _, model, _ = dense
        mgr = PagedKVCacheManager(model, slots=2, max_len=64, page_size=16)
        s = mgr.allocate(4)
        bt = jnp.asarray(mgr.block_table, jnp.int32)
        logical = mgr.gather_logical(mgr.pool, bt)
        # poison the logical view everywhere; only owned pages may keep it
        logical = jax.tree_util.tree_map(
            lambda x: jnp.full_like(x, 7), logical)
        pool = mgr.scatter_all(mgr.pool, logical,
                               jnp.asarray(mgr.inverse_map(), jnp.int32))
        ids = np.asarray(pool["stack"]["pos_ids"])
        owned = int(mgr.block_table[s, 0])
        assert (ids[:, owned] == 7).all()          # owned page written
        assert (ids[:, mgr.null_page] == -1).all()  # null page inert
        unowned = next(p for p in range(mgr.total_pages) if p != owned)
        assert (ids[:, unowned] == -1).all()       # unallocated page inert


class TestPagedEngineBitwise:
    def test_dense_paged_and_spec_match_contiguous(self, dense):
        cfg, model, params = dense
        _, ref = _outs(cfg, model, params)
        _, paged = _outs(cfg, model, params, paged=True)
        eng, spec = _outs(cfg, model, params, paged=True, speculate=3)
        assert ref == paged, "block-table indirection changed the tokens"
        assert ref == spec, "speculative accepted prefix != greedy"
        assert eng.spec_accepted > 0 and eng.spec_accept_rate > 0.0
        assert eng.mgr.pages_in_use == eng.mgr.recount_pages() == 0

    def test_swa_paged_matches_contiguous(self, swa):
        cfg, model, params = swa
        _, ref = _outs(cfg, model, params, n_req=3, max_new=8)
        _, paged = _outs(cfg, model, params, n_req=3, max_new=8, paged=True)
        assert ref == paged

    def test_spec_requires_greedy_and_full_window(self, dense):
        cfg, model, params = dense
        with pytest.raises(ValueError, match="greedy"):
            Engine(model, params, batch_slots=2, max_len=64,
                   temperature=0.7, speculate=2, warmup=False)
        swa_cfg = cfg.replace(sliding_window=32)
        with pytest.raises(ValueError, match="sliding_window"):
            Engine(Model(swa_cfg), params, batch_slots=2, max_len=64,
                   speculate=2, warmup=False)


class TestServeReplayPaged:
    """Fingerprint-level pins on the full closed loop (engine + admission
    + rails + energy ledger)."""

    @pytest.fixture(scope="class")
    def replays(self, dense):
        _, model, params = dense
        day = sc.serve_day(ticks=6, cool_at=3)
        wl = sc.poisson_burst(burst_at=1, burst_n=5, seed=0)
        kw = dict(engine_steps=4, drain_ticks=16)
        return {
            "contig": sc.serve_replay(day, wl, model, params, **kw),
            "paged": sc.serve_replay(day, wl, model, params, paged=True,
                                     **kw),
            "spec": sc.serve_replay(day, wl, model, params, paged=True,
                                    speculate=3, **kw),
        }

    def test_paged_fingerprint_bitwise_contiguous(self, replays):
        # outputs AND caps AND energy: the whole day replays bit for bit
        assert replays["paged"].fingerprint == replays["contig"].fingerprint

    def test_spec_outputs_match_but_day_compresses(self, replays):
        """Speculation must not change a single token — but it legitimately
        changes the *day* (fewer engine ticks -> different load trace ->
        different rail/energy fingerprint), so the pin is output equality,
        not fingerprint equality."""
        assert replays["spec"].outputs == replays["contig"].outputs
        assert replays["spec"].finished == replays["contig"].finished


class TestChurnAdmission:
    def test_paged_admits_strictly_more_at_equal_page_budget(self, dense):
        """16 pages = 4 contiguous slots (max_len=64, page_size=16). The
        paged engine runs 8 slots over the same 16 pages because short
        churn requests only ever hold 1-2 pages each — the vLLM
        fragmentation argument, live."""
        cfg, model, params = dense
        wl = sc.churn_requests()

        def run(**kw):
            eng = Engine(model, params, max_len=64, eos_id=-1,
                         warmup=False, **kw)
            for a in wl.arrivals:
                eng.submit(Request(a.rid, _prompt(cfg, a.rid, a.prompt_len),
                                   max_new=a.max_new))
            peak = 0
            while eng.step():
                peak = max(peak, sum(r is not None for r in eng.slot_req))
                assert (eng.mgr.pages_in_use == eng.mgr.recount_pages())
            assert len(eng.finished) == len(wl.arrivals)
            return eng, peak

        eng_c, peak_c = run(batch_slots=4)              # 4 slots * 4 pages
        eng_p, peak_p = run(batch_slots=8, paged=True, total_pages=16)
        assert peak_c <= 4
        assert peak_p > peak_c, (peak_p, peak_c)
        assert eng_p.mgr.peak_pages <= 16
        assert eng_p.mgr.pages_in_use == eng_p.mgr.recount_pages() == 0
        # same tokens either way — admission order changes, outputs don't
        assert ({r.rid: tuple(r.out) for r in eng_c.finished}
                == {r.rid: tuple(r.out) for r in eng_p.finished})


class TestExpandablePagedGrowth:
    def test_growth_widens_tables_without_relocating_pages(self, dense):
        _, model, _ = dense
        mgr = ExpandablePagedKVCacheManager(model, slots=2, max_len=64,
                                            initial_len=16, page_size=16)
        assert mgr.capacity == 16 and mgr.block_table.shape[1] == 1
        s = mgr.allocate(5)
        live = int(mgr.block_table[s, 0])
        mgr.ensure(40)
        assert mgr.capacity == 64 and mgr.grows >= 1
        assert mgr.block_table[s, 0] == live  # live page never relocates
        assert (mgr.block_table[:, 1:] == mgr.null_page).all()  # new: invalid
        assert mgr.pages_in_use == mgr.recount_pages() == 1
        mgr.advance([s], [40])  # claim across the grown width
        assert mgr.block_table[s, 0] == live
        assert mgr.slot_pages(s) == 3
        assert mgr.peak_pages == 3  # no undercount from the growth

    def test_engine_results_match_contiguous(self, dense):
        cfg, model, params = dense
        _, ref = _outs(cfg, model, params, n_req=3, max_new=20)
        _, exp = _outs(cfg, model, params, n_req=3, max_new=20,
                       paged=True, expandable=True)
        assert ref == exp


class TestPagedPreemption:
    def test_page_exact_eviction_and_bitwise_resume(self, dense):
        cfg, model, params = dense
        _, ref = _outs(cfg, model, params, n_req=2, max_new=16)

        eng = Engine(model, params, batch_slots=2, max_len=64, eos_id=-1,
                     warmup=False, paged=True)
        for rid in range(2):
            eng.submit(Request(rid, _prompt(cfg, rid), max_new=16))
        for _ in range(4):
            eng.step()
        pages_before = eng.mgr.pages_in_use
        assert eng.preempt_to(1) == 1
        # page-exact accounting: the parked payload counts exactly the
        # pages the victim held, and those pages actually returned to the
        # admission budget (in_use dropped by the same amount)
        victim_rid = eng.queue[0].rid
        held = eng.pool.put_pages(victim_rid)
        assert held >= 1 and eng.pool.pages_held == held
        assert eng.mgr.pages_in_use == pages_before - held
        assert eng.mgr.pages_in_use == eng.mgr.recount_pages()
        eng.run()
        assert {r.rid: tuple(r.out) for r in eng.finished} == ref
        assert eng.pool.pages_held == 0 and eng.preempts == 1
