"""Per-kernel validation: shape/dtype sweeps, allclose vs the ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as kref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.overscale_matmul import (bit_probs_to_cdf,
                                            overscale_matmul, quantize)

KEY = jax.random.PRNGKey(7)


class TestFlashAttention:
    @pytest.mark.parametrize("S,T,D", [(128, 128, 64), (256, 256, 128),
                                       (384, 384, 64), (512, 512, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, S, T, D, dtype):
        q = jax.random.normal(jax.random.fold_in(KEY, 1), (S, D), dtype)
        k = jax.random.normal(jax.random.fold_in(KEY, 2), (T, D), dtype)
        v = jax.random.normal(jax.random.fold_in(KEY, 3), (T, D), dtype)
        out = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                              interpret=True)
        ref = kref.flash_attention_ref(q, k, v, causal=True)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol)

    @pytest.mark.parametrize("bq,bk", [(64, 64), (128, 64), (64, 128)])
    def test_block_shapes(self, bq, bk):
        S, D = 256, 64
        q = jax.random.normal(jax.random.fold_in(KEY, 4), (S, D))
        k = jax.random.normal(jax.random.fold_in(KEY, 5), (S, D))
        v = jax.random.normal(jax.random.fold_in(KEY, 6), (S, D))
        out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                              interpret=True)
        ref = kref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)

    def test_non_causal(self):
        S, D = 128, 64
        q, k, v = (jax.random.normal(jax.random.fold_in(KEY, i), (S, D))
                   for i in range(3))
        out = flash_attention(q, k, v, causal=False, interpret=True)
        ref = kref.flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


class TestMambaScan:
    @pytest.mark.parametrize("S,H,P,N,chunk", [
        (128, 4, 16, 32, 32), (256, 8, 32, 64, 64), (64, 2, 8, 16, 64),
    ])
    def test_matches_model_ssd(self, S, H, P, N, chunk):
        b = 2
        xh = jax.random.normal(jax.random.fold_in(KEY, 11), (b, S, H, P)) * 0.5
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(KEY, 12), (b, S, H)))
        A = -jnp.exp(
            jax.random.normal(jax.random.fold_in(KEY, 13), (H,)) * 0.3)
        B = jax.random.normal(jax.random.fold_in(KEY, 14), (b, S, H, N)) * 0.3
        Cm = jax.random.normal(jax.random.fold_in(KEY, 15), (b, S, H, N)) * 0.3
        y_k = ops.mamba_scan_b(xh, dt, A, B, Cm, chunk=chunk)
        y_r, _ = kref.mamba_scan_ref(xh, dt, A, B, Cm, chunk)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_sequential_recurrence(self):
        """Chunked kernel == step-by-step recurrent ground truth."""
        S, H, P, N = 32, 2, 4, 8
        xh = jax.random.normal(jax.random.fold_in(KEY, 21), (S, H, P)) * 0.5
        dt = jax.nn.softplus(
            jax.random.normal(jax.random.fold_in(KEY, 22), (S, H)))
        A = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 23), (H,)) * 0.3)
        B = jax.random.normal(jax.random.fold_in(KEY, 24), (S, H, N)) * 0.3
        Cm = jax.random.normal(jax.random.fold_in(KEY, 25), (S, H, N)) * 0.3
        y_k = mamba_scan(xh, dt, A, B, Cm, chunk=8, interpret=True)
        # sequential oracle
        s = np.zeros((H, P, N), np.float32)
        ys = []
        for t in range(S):
            dA = np.exp(np.asarray(dt[t] * A))
            s = s * dA[:, None, None] + np.einsum(
                "h,hp,hn->hpn", np.asarray(dt[t]), np.asarray(xh[t]),
                np.asarray(B[t]))
            ys.append(np.einsum("hpn,hn->hp", s, np.asarray(Cm[t])))
        np.testing.assert_allclose(np.asarray(y_k), np.stack(ys),
                                   rtol=2e-4, atol=2e-4)


class TestPagedAttention:
    """Block-table decode attention: kernel vs jnp oracle vs dense _sdpa."""

    def _paged_case(self, B=3, H=4, Hkv=2, D=16, ps=8, npages=4,
                    dtype=jnp.float32):
        """A scattered layout: pages permuted across the pool, one slot
        fully disabled (pos = -1), one mid-page (pos=7), one mid-pool."""
        rng = np.random.default_rng(0)
        P = B * npages
        q = jnp.asarray(rng.standard_normal((B, H, D)), dtype)
        k = jnp.asarray(rng.standard_normal((P + 1, ps, Hkv, D)), dtype)
        v = jnp.asarray(rng.standard_normal((P + 1, ps, Hkv, D)), dtype)
        ids = np.full((P + 1, ps), -1, np.int32)
        perm = rng.permutation(P)
        bt = np.full((B, npages), P, np.int32)  # P == the null page
        pos = np.array([29, 7, -1], np.int32)
        for b in range(B):
            if pos[b] < 0:
                continue
            for j in range(pos[b] // ps + 1):
                pg = perm[b * npages + j]
                bt[b, j] = pg
                span = np.arange(j * ps, (j + 1) * ps)
                ids[pg] = np.where(span <= pos[b], span, -1)
        return (q, k, v, jnp.asarray(ids), jnp.asarray(bt),
                jnp.asarray(pos))

    @pytest.mark.parametrize("window", [0, 12])
    def test_matches_ref(self, window):
        q, k, v, ids, bt, pos = self._paged_case()
        out = ops.paged_attention_decode(q, k, v, ids, bt, pos,
                                         window=window)
        ref = kref.paged_attention_ref(q, k, v, ids, bt, pos,
                                       window=window)
        np.testing.assert_allclose(np.asarray(out[:2]), np.asarray(ref[:2]),
                                   rtol=1e-5, atol=1e-5)
        # pos = -1 disables a row: zero output, not mean(v) — exp(m - m)
        # over an all-masked page must not leak mass into l
        assert (np.asarray(out[2]) == 0.0).all()
        assert (np.asarray(ref[2]) == 0.0).all()

    def test_bf16_pools(self):
        q, k, v, ids, bt, pos = self._paged_case(dtype=jnp.bfloat16)
        out = ops.paged_attention_decode(q, k, v, ids, bt, pos)
        ref = kref.paged_attention_ref(q, k, v, ids, bt, pos)
        np.testing.assert_allclose(np.asarray(out[:2], np.float32),
                                   np.asarray(ref[:2], np.float32),
                                   rtol=5e-2, atol=5e-2)

    def test_ref_bitwise_sdpa_at_page_eq_maxlen(self):
        """With page_size == max_len and an identity block table the paged
        oracle degenerates to exactly the model's _sdpa — the anchor the
        engine's bitwise paged == contiguous pin rides on."""
        from repro.models.attention import _sdpa
        rng = np.random.default_rng(1)
        B, H, Hkv, D, T = 3, 4, 2, 16, 32
        q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, T, Hkv, D)), jnp.float32)
        pos = jnp.asarray([29, 7, 0], jnp.int32)
        span = jnp.arange(T)[None, :]
        ids = jnp.where(span <= pos[:, None], span, -1)
        bt = jnp.arange(B, dtype=jnp.int32)[:, None]
        ref = kref.paged_attention_ref(q, k, v, ids, bt, pos)
        mask = (ids >= 0) & (ids <= pos[:, None])
        dense = _sdpa(q[:, None], k, v, mask[:, None, None, None, :],
                      None)[:, 0]
        assert (np.asarray(ref) == np.asarray(dense)).all()
        out = ops.paged_attention_decode(q, k, v, ids, bt, pos)
        np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                                   rtol=1e-5, atol=1e-5)


class TestOverscaleMatmul:
    @pytest.mark.parametrize("M,K,N", [(64, 96, 80), (200, 128, 130),
                                       (128, 256, 128)])
    def test_matches_ref(self, M, K, N):
        a = jax.random.randint(jax.random.fold_in(KEY, 31), (M, K), -128, 127,
                               jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 32), (K, N), -128, 127,
                               jnp.int8)
        ug = jax.random.bits(jax.random.fold_in(KEY, 33), (M, N), jnp.uint32)
        ub = jax.random.bits(jax.random.fold_in(KEY, 34), (M, N), jnp.uint32)
        probs = np.zeros(32)
        probs[24:] = 0.02
        cdf = bit_probs_to_cdf(probs)
        out_k = overscale_matmul(a, b, ug, ub, cdf, interpret=True)
        out_r = kref.overscale_matmul_ref(a, b, ug, ub, cdf)
        assert (np.asarray(out_k) == np.asarray(out_r)).all()

    def test_zero_probs_is_exact_matmul(self):
        M = K = N = 64
        a = jax.random.randint(jax.random.fold_in(KEY, 41), (M, K), -128, 127,
                               jnp.int8)
        b = jax.random.randint(jax.random.fold_in(KEY, 42), (K, N), -128, 127,
                               jnp.int8)
        ug = jax.random.bits(jax.random.fold_in(KEY, 43), (M, N), jnp.uint32)
        ub = jax.random.bits(jax.random.fold_in(KEY, 44), (M, N), jnp.uint32)
        cdf = bit_probs_to_cdf(np.zeros(32))
        out = overscale_matmul(a, b, ug, ub, cdf, interpret=True)
        exact = a.astype(jnp.int32) @ b.astype(jnp.int32)
        assert (np.asarray(out) == np.asarray(exact)).all()

    def test_flip_rate_tracks_probability(self):
        M = K = N = 256
        a = jnp.ones((M, K), jnp.int8)
        b = jnp.ones((K, N), jnp.int8)
        ug = jax.random.bits(jax.random.fold_in(KEY, 51), (M, N), jnp.uint32)
        ub = jax.random.bits(jax.random.fold_in(KEY, 52), (M, N), jnp.uint32)
        probs = np.zeros(32)
        probs[30] = 0.05
        out = overscale_matmul(a, b, ug, ub, bit_probs_to_cdf(probs),
                               interpret=True)
        rate = float((np.asarray(out) != K).mean())
        assert rate == pytest.approx(0.05, abs=0.01)

    def test_quantize_roundtrip(self):
        x = jax.random.normal(jax.random.fold_in(KEY, 61), (64, 64))
        q, s = quantize(x)
        np.testing.assert_allclose(np.asarray(q, np.float32) * float(s),
                                   np.asarray(x), atol=float(s) * 0.51)
