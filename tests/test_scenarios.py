"""repro.scenarios — the replayable scenario library (ISSUE-4 satellite).

Determinism (same trace -> same rail decisions, replan count and energy),
the RailField replan-economy acceptance (>=2x fewer full replans than the
scalar LUT on the diurnal + load-spike day at >= equal mean power saving),
and Rebalance actions observably migrating work through ``ft/elastic``.
"""
import numpy as np
import pytest

from repro import scenarios as SC
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.control.lut import sweep_points
from repro.ft.elastic import ElasticWorkAssignment

T_KNOTS = sweep_points(10.0, 45.0, 8)
U_KNOTS = sweep_points(0.25, 1.0, 4)


@pytest.fixture(scope="module")
def runtime():
    prof = TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)
    return RT.EnergyAwareRuntime(prof, policy="power_save")


@pytest.fixture(scope="module")
def field(runtime):
    return runtime.build_field(T_KNOTS, U_KNOTS)


def _field_controller(runtime, field):
    return runtime.controller(field=field, guard_band_c=3.0)


def _scalar_controller(runtime):
    return runtime.controller(lut=runtime.build_lut(T_KNOTS),
                              guard_band_c=3.0)


class TestLibrary:
    def test_registry_builds_every_scenario(self):
        for name, mk in SC.SCENARIOS.items():
            sc = mk()
            assert sc.name == name and sc.ticks > 0
            assert np.isfinite(sc.ambient_at(0))

    def test_traces_are_pure_functions_of_time(self):
        sc = SC.diurnal_load_spike()
        assert sc.ambient_at(7) == sc.ambient_at(7)
        assert sc.load_at(12) == sc.load_at(12)
        assert sc.load_at(12) != sc.load_at(0)  # the dip is real


class TestDeterminism:
    def test_same_trace_same_decisions_replans_energy(self, runtime, field):
        sc = SC.diurnal(ticks=12, period=48)
        a = SC.replay(sc, runtime=runtime,
                      controller=_field_controller(runtime, field))
        b = SC.replay(sc, runtime=runtime,
                      controller=_field_controller(runtime, field))
        assert a.fingerprint == b.fingerprint
        assert a.replans == b.replans
        assert a.replan_reasons == b.replan_reasons
        assert a.energy_j == b.energy_j
        np.testing.assert_array_equal(a.rails, b.rails)

    def test_reused_controller_replays_identically(self, runtime, field):
        # replay() resets the controller's online state (t_prev, warm
        # fields, last plan) so one controller can serve many days and
        # each replayed day starts cold — decisions included
        sc = SC.diurnal(ticks=8, period=48)
        c = _field_controller(runtime, field)
        a = SC.replay(sc, runtime=runtime, controller=c)
        b = SC.replay(sc, runtime=runtime, controller=c)
        assert a.fingerprint == b.fingerprint
        assert a.replan_reasons == b.replan_reasons  # cold_start both days
        assert b.replan_reasons[0] == "cold_start"


class TestReplanEconomy:
    def test_field_serves_the_day_with_2x_fewer_replans(self, runtime,
                                                        field):
        # the ISSUE-4 acceptance scenario: diurnal ambient + load spikes
        sc = SC.diurnal_load_spike(ticks=48)
        fld = SC.replay(sc, runtime=runtime,
                        controller=_field_controller(runtime, field))
        base = SC.replay(sc, runtime=runtime,
                         controller=_scalar_controller(runtime))
        # every load swing forced the scalar controller to the solver;
        # the field answered them from the utilization axis
        assert any(r == "util_drift" for r in base.replan_reasons)
        assert not any(r.startswith("util") for r in fld.replan_reasons)
        assert fld.replans * 2 <= base.replans
        # ... at equal or better mean power saving, same thermal safety
        assert fld.mean_saving >= base.mean_saving - 1e-3
        assert fld.t_max < TF.T_MAX_CHIP
        assert base.t_max < TF.T_MAX_CHIP
        assert fld.lut_hits + fld.replans == sc.ticks

    def test_quiet_diurnal_rides_the_fast_path(self, runtime, field):
        r = SC.replay(SC.diurnal(ticks=12, period=48), runtime=runtime,
                      controller=_field_controller(runtime, field))
        assert r.replans == 1  # cold start only
        assert r.lut_hits == 11
        assert r.mean_saving > 0.0

    def test_ambient_jump_still_replans(self, runtime, field):
        r = SC.replay(SC.ambient_jump(ticks=12, at=6), runtime=runtime,
                      controller=_field_controller(runtime, field))
        assert any(x.startswith("ambient_jump") for x in r.replan_reasons)


class TestRebalanceMigration:
    def test_storm_condemns_and_migrates_work(self, runtime, field):
        sc = SC.straggler_storm(ticks=20, storm_at=10)
        r = SC.replay(sc, runtime=runtime,
                      controller=_field_controller(runtime, field))
        hot = sc.hotspots[0].chip
        assert r.rebalances >= 1
        assert hot in r.condemned
        # the chip's share went to zero and the survivors absorbed it
        assert r.shares[hot] == 0.0
        assert float(r.shares.sum()) == pytest.approx(len(r.shares),
                                                      rel=1e-5)
        assert np.all(r.shares[np.arange(len(r.shares)) != hot] > 1.0)
        # ... and the control loop actually planned for the migrated load:
        # the condemned chip's utilization collapses after the rebalance
        assert r.util_trace[-1, hot] == 0.0
        assert r.util_trace[0, hot] > 0.0

    def test_assignment_condemn_restore_conserves_work(self):
        a = ElasticWorkAssignment(8)
        a.condemn(3)
        assert a.shares[3] == 0.0
        assert float(a.shares.sum()) == pytest.approx(8.0, rel=1e-6)
        a.condemn(3)  # idempotent
        assert float(a.shares.sum()) == pytest.approx(8.0, rel=1e-6)
        a.restore(3)
        assert a.shares[3] > 0.0
        assert float(a.shares.sum()) == pytest.approx(8.0, rel=1e-6)
        # out-of-range chips are ignored, never crash the tick
        a.condemn(99)
        a.restore(99)
        assert float(a.shares.sum()) == pytest.approx(8.0, rel=1e-6)

    def test_cannot_condemn_the_last_chip(self):
        a = ElasticWorkAssignment(2)
        a.condemn(0)
        a.condemn(1)  # someone has to do the work
        assert a.shares[1] > 0.0
        assert a.mesh_hint() == (1, 1)
