"""RailField — the per-chip 2-axis control fast path (ISSUE-4 tentpole).

Pins the refactor's trust contracts:

- the pod-median reduction of the 2-D table reproduces the legacy scalar
  ``dynamic_lut`` EXACTLY (the DynamicLut facade is a view of the field),
- per-chip bilinear interpolation stays within one 10 mV rail step of the
  full ``Solver`` fixed point at every chip across the 2-D sweep interior,
- the early-freeze ``solve_batch`` path is bit-identical to the lockstep
  path (the satellite's parity pin),
- the controller answers (ambient, utilization) pairs from the field —
  load swings are LUT hits, not ``util_drift`` replans — while excursions
  past the solved utilization axis still replan,
- per-chip boost overrides survive field rewrites chip-wise,
- the mesh topology mapping validates worker names (ranks past the pod and
  non-numeric names land on -1, surfaced as ``unmapped``).
"""
import numpy as np
import pytest

from repro import control as ctl
from repro import policy as pol
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.launch.mesh import PodTopology

T_KNOTS = [10.0, 15.0, 20.0, 25.0, 30.0, 35.0, 40.0, 45.0]
U_KNOTS = [0.25, 0.5, 0.75, 1.0]


@pytest.fixture(scope="module")
def profile():
    return TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)


@pytest.fixture(scope="module")
def runtime(profile):
    return RT.EnergyAwareRuntime(profile, policy="power_save")


@pytest.fixture(scope="module")
def field(runtime):
    return runtime.build_field(T_KNOTS, U_KNOTS)


class TestRailFieldTable:
    def test_median_reduction_matches_legacy_lut_exactly(self, runtime,
                                                         field):
        # the golden pin: the scalar §III-B scheme is a REDUCTION of the
        # field — pod median over chips at the full-utilization slice,
        # same fixed points, zero drift allowed
        legacy = runtime.dynamic_lut(T_KNOTS)
        med = field.median_lut().as_table()
        assert set(med) == set(legacy)
        for t, (vc, vs) in legacy.items():
            assert med[t][0] == vc
            assert med[t][1] == vs

    def test_per_chip_interp_within_one_rail_step(self, runtime, field):
        # the per-chip guard-band contract, checked at 2-D interior
        # midpoints (worst case for bilinear interpolation)
        chips = runtime.substrate.n_domains
        for tm in (12.5, 27.5, 42.5):
            for um in (0.375, 0.875):
                plan, _ = runtime.planner.plan_at(
                    tm, np.full(chips, um, np.float32))
                vc, vs = field.lookup(tm, um)
                assert np.max(np.abs(vc - plan.v_core)) \
                    <= field.RAIL_STEP_V + 1e-9
                assert np.max(np.abs(vs - plan.v_sram)) \
                    <= field.RAIL_STEP_V + 1e-9

    def test_spatial_gradient_survives_the_fast_path(self, runtime, field):
        # this pod spreads heat well, so UNIFORM load converges to uniform
        # rails; the solver's spatial rail gradient appears under
        # non-uniform load — and the per-chip utilization axis reproduces
        # it chip-wise, where the scalar pod-median threw it away
        chips = field.chips
        u = np.where(np.arange(chips) < chips // 4, 0.3,
                     1.0).astype(np.float32)
        plan, _ = runtime.planner.plan_at(30.0, u)
        vc, vs = field.lookup(30.0, u)
        assert np.ptp(plan.v_core) > 0.0  # the solver is non-uniform here
        assert np.ptp(vc) > 0.0  # ... and the fast path keeps the gradient
        assert vc.shape == vs.shape == (chips,)
        assert np.max(np.abs(vc - plan.v_core)) <= field.RAIL_STEP_V + 1e-9
        assert np.max(np.abs(vs - plan.v_sram)) <= field.RAIL_STEP_V + 1e-9

    def test_lookup_clamps_both_axes(self, field):
        lo = field.lookup(field.t_min - 10.0, field.u_min - 0.5)
        lo_edge = field.lookup(field.t_min, field.u_min)
        hi = field.lookup(field.t_max + 10.0, field.u_max + 0.5)
        hi_edge = field.lookup(field.t_max, field.u_max)
        np.testing.assert_array_equal(lo[0], lo_edge[0])
        np.testing.assert_array_equal(lo[1], lo_edge[1])
        np.testing.assert_array_equal(hi[0], hi_edge[0])
        np.testing.assert_array_equal(hi[1], hi_edge[1])

    def test_per_chip_util_interpolates_per_chip(self, field):
        # chip 0 at low util, chip 1 at high: each reads its own axis row
        u = np.full(field.chips, 0.25, np.float64)
        u[1] = 1.0
        vc, _ = field.lookup(25.0, u)
        vc_lo, _ = field.lookup(25.0, 0.25)
        vc_hi, _ = field.lookup(25.0, 1.0)
        assert vc[0] == vc_lo[0]
        assert vc[1] == vc_hi[1]

    def test_covers_and_validation(self, field):
        assert field.covers(30.0) and not field.covers(55.0)
        assert field.covers(47.0, margin=2.0)
        assert field.covers_util(0.9) and field.covers_util(1.2, margin=0.25)
        assert not field.covers_util(1.3, margin=0.25)
        with pytest.raises(ValueError):
            ctl.RailField([10.0], [], np.zeros((1, 0, 4)),
                          np.zeros((1, 0, 4)))
        with pytest.raises(ValueError):
            ctl.RailField([10.0, 20.0], [1.0], np.zeros((1, 1, 4)),
                          np.zeros((1, 1, 4)))

    def test_below_axis_clamp_is_counted(self, field):
        """A lookup under ``u_min`` answers the conservative clamped slice
        but must leave an observable trace (ROADMAP item 3 / §9 ledger)."""
        base = field.clamped_below
        field.lookup(25.0, 0.5)                      # in range: no count
        assert field.clamped_below == base
        vc_lo, _ = field.lookup(25.0, 0.1)           # scalar below u_min
        assert field.clamped_below == base + 1
        us = np.full(field.chips, field.u_min)
        us[3] = 0.05                                 # one chip dips under
        field.lookup(25.0, us)
        assert field.clamped_below == base + 2
        vc_min, _ = field.lookup(25.0, field.u_min)  # exact edge: clean
        assert field.clamped_below == base + 2
        np.testing.assert_allclose(vc_lo, vc_min)    # clamped == u_min slice

    def test_nominal_fallback_below_the_axis(self, runtime, field):
        # sensed load below u_min must NOT be read against the clamped
        # u_min slice (that inflates the reported saving ~2.5x); the
        # actuator falls back to the exact nominal solve there
        fleet = ctl.FleetActuator.from_runtime(runtime, field=field)
        us = np.full(field.chips, 0.1, np.float32)
        p_clamped = float(np.sum(field.nominal_power(25.0, us)))
        p_used = fleet._nominal_power(25.0, us)
        p_exact = float(np.sum(runtime.planner.baseline_power(
            runtime.planner.env(25.0, us))))
        assert p_used == pytest.approx(p_exact)
        assert p_used < p_clamped
        # inside the axis the interpolated grid IS the reference
        us_in = np.full(field.chips, 0.8, np.float32)
        assert fleet._nominal_power(25.0, us_in) == pytest.approx(
            float(np.sum(field.nominal_power(25.0, us_in))))

    def test_baseline_prefill_hits_at_grid_knots(self, profile):
        # the 2-D build prefills the nominal-baseline cache with keys
        # matching baseline_power's float64 ambient — a replan AT a knot
        # (incl. non-representable ones like 15.833...) never re-solves
        from repro.control.lut import sweep_points
        rt2 = RT.EnergyAwareRuntime(profile, policy="power_save")
        t_knots = sweep_points(10.0, 45.0, 7)  # 15.8333..., 21.666...
        rt2.build_field(t_knots, [0.5, 1.0])
        assert rt2.planner.baseline_solves == 0
        for t in t_knots:
            rt2.planner.baseline_power(rt2.planner.env(t))
        assert rt2.planner.baseline_solves == 0  # every knot was prefilled
        rt2.planner.baseline_power(rt2.planner.env(26.2))  # off-knot
        assert rt2.planner.baseline_solves == 1

    def test_nominal_power_grid_rides_along(self, field):
        p = field.nominal_power(27.5, 0.8)
        assert p is not None and p.shape == (field.chips,)
        assert np.all(p > 0)
        # nominal power falls with utilization (dynamic part scales)
        p_lo = field.nominal_power(27.5, 0.3)
        assert float(np.sum(p_lo)) < float(np.sum(p))


class TestEarlyFreezeParity:
    # decisions must be bitwise; continuous thermal/power leaves agree to
    # f32 round-off (XLA's summation order inside the vmapped solves is
    # batch-shape-dependent, so compacted sub-batches round differently at
    # ~1e-4 degC — orders below delta_t=0.5 and the 10 mV rail grid)
    EXACT = ("idx", "n_iters", "converged", "idx_hist")
    ATOL = {"T": 2e-3, "tj_hist": 2e-3, "d_final": 1e-5}

    def test_decision_parity_with_lockstep(self, runtime):
        sub = runtime.substrate
        solver = pol.cached_solver(sub, runtime.policy_obj,
                                   runtime.planner.delta_t,
                                   runtime.planner.max_iters)
        chips = sub.n_domains
        t = np.asarray([10.0, 21.0, 32.0, 43.0, 12.5, 44.0], np.float32)
        B = t.size
        u = np.asarray([1.0, 0.5, 0.75, 1.0, 0.25, 0.6], np.float32)
        envs = {"t_amb": t,
                "util": u[:, None] * np.ones((1, chips), np.float32),
                "gamma": np.full((B,), runtime.policy_obj.gamma,
                                 np.float32)}
        lock = solver.solve_batch(envs)
        frozen = solver.solve_batch(envs, early_freeze=True)
        assert int(np.max(lock.n_iters)) > int(np.min(lock.n_iters)), \
            "test batch must have heterogeneous convergence"
        for name, a, b in zip(lock._fields, lock, frozen):
            a, b = np.asarray(a), np.asarray(b)
            if name in self.EXACT:
                np.testing.assert_array_equal(
                    a, b, err_msg=f"early-freeze changed Solution.{name}")
            else:
                np.testing.assert_allclose(
                    a, b, rtol=1e-5, atol=self.ATOL.get(name, 1e-6),
                    err_msg=f"early-freeze drifted on Solution.{name}")
        # the decoded rails — the numbers the control plane acts on — are
        # identical voltages, not merely close
        np.testing.assert_array_equal(sub.decode(lock.idx),
                                      sub.decode(frozen.idx))

    def test_segment_size_does_not_change_results(self, runtime):
        sub = runtime.substrate
        solver = pol.cached_solver(sub, runtime.policy_obj,
                                   runtime.planner.delta_t,
                                   runtime.planner.max_iters)
        chips = sub.n_domains
        envs = {"t_amb": np.asarray([15.0, 35.0], np.float32),
                "util": np.ones((2, chips), np.float32),
                "gamma": np.full((2,), runtime.policy_obj.gamma,
                                 np.float32)}
        a = solver.solve_batch(envs, early_freeze=True, segment=1)
        b = solver.solve_batch(envs, early_freeze=True, segment=3)
        np.testing.assert_array_equal(a.idx, b.idx)
        np.testing.assert_array_equal(a.T, b.T)


class TestFieldController:
    def _snap(self, t_amb, **kw):
        return ctl.Snapshot(t_amb=t_amb, **kw)

    def test_load_swing_is_a_lut_hit_not_a_replan(self, runtime, field):
        c = runtime.controller(field=field, guard_band_c=3.0)
        chips = runtime.substrate.n_domains
        c.decide(self._snap(25.0))  # cold start
        full = c.decide(self._snap(25.0),
                        util=np.ones(chips, np.float32))
        dip = c.decide(self._snap(25.0),
                       util=np.full(chips, 0.45, np.float32))
        assert c.stats.replans == 1  # only the cold start
        assert c.stats.lut_hits == 2
        vc_full = np.asarray(full[0].v_core)
        vc_dip = np.asarray(dip[0].v_core)
        assert full[0].source == dip[0].source == "lut"
        # lighter load -> cooler chips -> lower (or equal) rails
        assert np.all(vc_dip <= vc_full + 1e-9)

    def test_util_past_the_axis_replans(self, runtime, field):
        c = runtime.controller(field=field, guard_band_c=3.0,
                               util_band=0.1)
        chips = runtime.substrate.n_domains
        c.decide(self._snap(25.0))
        c.decide(self._snap(25.0), util=np.full(chips, 1.3, np.float32))
        assert any(r.startswith("util_range")
                   for r in c.stats.replan_reasons)

    def test_snapshot_load_feeds_the_second_axis(self, runtime, field):
        # engine telemetry (active/slots) reaches the field without an
        # explicit util argument
        c = runtime.controller(field=field, guard_band_c=3.0)
        c.decide(self._snap(25.0))
        a_full = c.decide(self._snap(25.0, active=64, slots=64))
        a_low = c.decide(self._snap(25.0, active=16, slots=64))
        assert c.stats.replans == 1 and c.stats.lut_hits == 2
        assert np.all(np.asarray(a_low[0].v_core)
                      <= np.asarray(a_full[0].v_core) + 1e-9)

    def test_field_rails_are_per_chip(self, runtime, field):
        c = runtime.controller(field=field)
        acts = c.decide(self._snap(27.0))  # cold start -> solver (per-chip)
        acts = c.decide(self._snap(27.2))  # fast path
        rails = acts[0]
        assert rails.source == "lut"
        assert np.asarray(rails.v_core).shape == (field.chips,)

    def test_migrated_chip_is_not_boosted(self, runtime, field):
        c = runtime.controller(field=field)
        chips = runtime.substrate.n_domains
        shares = np.ones(chips, np.float32)
        shares[5] = 0.0  # chip 5 already drained by a Rebalance
        snap = self._snap(25.0, shares=shares, stragglers=[
            ctl.StragglerSample("worker5", 0, 2.0, chip=5)])
        acts = c.decide(snap)
        assert not any(isinstance(a, (ctl.BoostRail, ctl.Rebalance))
                       for a in acts)


class TestPerChipBoostSurvival:
    def test_boosts_survive_field_rewrites_per_chip(self, runtime):
        fleet = ctl.FleetActuator.from_runtime(runtime)
        chips = runtime.substrate.n_domains
        fleet.apply(ctl.BoostRail(3, 0.73, 0.83, 1.0))
        fleet.apply(ctl.BoostRail(9, TF.V_CORE_NOM, TF.V_SRAM_NOM, 1.0))
        # a per-chip field write must preserve EACH chip's own boost rails
        vc = np.full(chips, 0.60, np.float32)
        vs = np.full(chips, 0.70, np.float32)
        fleet.apply(ctl.SetRails(vc, vs, source="lut"))
        assert fleet.v_core[3] == pytest.approx(0.73)
        assert fleet.v_sram[3] == pytest.approx(0.83)
        assert fleet.v_core[9] == pytest.approx(TF.V_CORE_NOM)
        assert fleet.v_core[4] == pytest.approx(0.60)
        fleet.apply(ctl.Rebalance(3, "too hot"))
        fleet.apply(ctl.SetRails(vc, vs, source="lut"))
        assert fleet.v_core[3] == pytest.approx(0.60)  # boost released
        assert fleet.v_core[9] == pytest.approx(TF.V_CORE_NOM)


class TestPodTopology:
    def test_valid_ranks_map_row_major(self):
        topo = PodTopology(grid=(16, 16))
        assert topo.chip_of("worker7") == 7
        assert topo.chip_of("tpu-v4-rank12") == 12  # trailing group wins
        assert topo.coords(17) == (1, 1)
        assert topo.pod_of(17) == 0

    def test_rank_past_pod_size_is_unmapped(self):
        topo = PodTopology(grid=(16, 16))
        assert topo.chip_of("worker256") == -1  # NOT chip 0
        assert topo.chip_of("worker999") == -1
        assert topo.chip_of_rank(-3) == -1

    def test_non_numeric_worker_is_unmapped(self):
        topo = PodTopology(grid=(16, 16))
        assert topo.chip_of("coordinator") == -1  # NOT chip 0

    def test_host_worker_composition(self):
        topo = PodTopology(grid=(16, 16), workers_per_host=8)
        assert topo.chip_of("host1-worker7") == 15  # 1*8 + 7
        assert topo.chip_of("worker7") == 7  # single group: plain rank
        # a stray digit group is NOT a host index: rank stays 12, not 4*8+12
        assert topo.chip_of("tpu-v4-rank12") == 12

    def test_multi_pod_foreign_ranks_are_unmapped(self):
        # the controller owns ONE pod: ranks from the other pod must not
        # silently fold onto this pod's chips
        topo = PodTopology(grid=(16, 16), n_pods=2)  # owns pod 0
        assert topo.n_chips == 512
        assert topo.chip_of("worker44") == 44
        assert topo.chip_of("worker300") == -1  # pod 1's rank: not ours
        assert topo.pod_of(300) == 1
        assert topo.chip_of("worker512") == -1

    def test_multi_pod_owned_and_fleet_views(self):
        pod1 = PodTopology(grid=(16, 16), n_pods=2, pod_index=1)
        assert pod1.chip_of("worker300") == 44  # pod 1, local 44
        assert pod1.chip_of("worker44") == -1  # pod 0's rank
        fleet = PodTopology(grid=(16, 16), n_pods=2, pod_index=None)
        assert fleet.chip_of("worker300") == 44  # fleet-wide local view
        assert fleet.chip_of("worker44") == 44

    def test_monitor_routes_through_topology(self, runtime, field):
        from repro.ft.monitor import StragglerDetector
        det = StragglerDetector(threshold=1.5, window=8, min_samples=4)
        topo = PodTopology(grid=runtime.substrate.grid)
        mon = ctl.MonitorTelemetry(det, topology=topo)
        for s in range(4):
            mon.record_step("coordinator", s, 1.0)
        mon.record_step("coordinator", 4, 2.0)  # straggler, unmappable
        samples = mon.poll(0.0)
        stragglers = [s for s in samples
                      if isinstance(s, ctl.StragglerSample)]
        assert len(stragglers) == 1 and stragglers[0].chip == -1
        c = runtime.controller(field=field)
        acts = c.decide(ctl.Snapshot(t_amb=25.0, stragglers=stragglers))
        assert c.stats.unmapped == 1
        assert not any(isinstance(a, (ctl.BoostRail, ctl.Rebalance))
                       for a in acts)
