"""Per-architecture smoke tests: reduced config, forward + one train step on
CPU, output shapes + no NaNs (assignment requirement — full configs are only
exercised by the dry-run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_iterator
from repro.models.model import Model
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step

ARCHS = sorted(registry.ARCHS)


def _batch(cfg, B=2, S=32):
    key = jax.random.PRNGKey(1)
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = registry.get(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = model.apply(params, batch)
    assert logits.shape == (2, 32, model.plan.vocab)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = registry.get(arch).reduced()
    model = Model(cfg)
    opt = make_optimizer(cfg)
    step = make_train_step(model, opt, n_accum=1)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    batch = _batch(cfg)
    params2, opt_state2, metrics = jax.jit(step)(params, opt_state, batch, 0)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, params2)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                  "mixtral-8x7b"])
def test_loss_decreases(arch):
    cfg = registry.get(arch).reduced()
    model = Model(cfg)
    opt = make_optimizer(cfg, lr=3e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(model, opt, n_accum=1))
    params = model.init(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                    branch=2)
    it = make_iterator(cfg, dc)
    losses = []
    for i in range(30):
        params, opt_state, m = step(params, opt_state, next(it), i)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]
