"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
only launch/dryrun.py (and subprocess tests) request 512 placeholders."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
