"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
only launch/dryrun.py (and subprocess tests) request 512 placeholders."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

try:  # property tests prefer real hypothesis; fall back to the local stub
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    _hypothesis_stub.install(sys.modules)

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
