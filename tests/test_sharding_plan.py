"""Sharding plan invariants (no mesh needed) + 8-device mini dry-run via
subprocess (keeps this process at 1 device, per the assignment)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.models import params as pm
from repro.models.model import Model
from repro.sharding import plan as plan_lib


class FakeMesh:
    """Just enough of Mesh for plan arithmetic without device init."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def mk_plan(cfg, pod=False):
    shape = ({"pod": 2, "data": 16, "model": 16} if pod
             else {"data": 16, "model": 16})
    return plan_lib.make_plan(cfg, FakeMesh(shape))  # type: ignore


@pytest.mark.parametrize("arch", sorted(registry.ARCHS))
def test_dims_divisible_by_tp(arch):
    cfg = registry.get(arch)
    plan = mk_plan(cfg)
    assert plan.vocab % plan.tp == 0
    assert plan.vocab >= cfg.vocab_size
    if cfg.num_heads:
        assert plan.num_heads % plan.tp == 0
        assert plan.num_kv_heads % plan.tp == 0
        assert plan.num_heads >= cfg.num_heads
    if cfg.is_moe:
        if cfg.num_experts % plan.tp == 0:
            assert plan.expert_mode == "ep"
        else:
            assert plan.expert_mode == "tp"
            assert cfg.moe_d_ff % plan.tp == 0


def test_kv_repeat_rules():
    # GQA kv=8 with tp=16 -> repeated to 16
    plan = mk_plan(registry.get("llama3.2-1b"))
    assert plan.num_kv_heads == 16 and plan.kv_repeat == 2
    # whisper 12H: pad both q and kv to 16
    plan = mk_plan(registry.get("whisper-small"))
    assert plan.num_heads == 16 and plan.num_kv_heads == 16


@pytest.mark.parametrize("arch", ["deepseek-67b", "deepseek-v2-236b",
                                  "whisper-small"])
@pytest.mark.parametrize("pod", [False, True])
def test_param_specs_shard_consistently(arch, pod):
    cfg = registry.get(arch)
    plan = mk_plan(cfg, pod)
    model = Model(cfg, plan)
    meta = model.param_meta()
    axis_sizes = {"pod": 2, "data": 16, "model": 16}

    def check(m):
        spec = plan.param_spec(m)
        for dim, ax in zip(m.shape, tuple(spec) + (None,) * 8):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = int(np.prod([axis_sizes[a] for a in axes]))
            assert dim % total == 0, (m.shape, spec)

    pm.tree_map_meta(check, meta)


def test_fsdp_shards_large_params_over_dp():
    cfg = registry.get("deepseek-67b")
    plan = mk_plan(cfg)
    meta = Model(cfg, plan).param_meta()
    # embedding: vocab on model AND d_model on data (FSDP)
    emb = meta["embed"]["embedding"]
    spec = plan.param_spec(emb)
    assert spec[0] == "model"
    assert spec[1] == ("data",) or spec[1] == "data"


MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import registry
from repro.models.model import Model
from repro.models import params as pm
from repro.sharding.plan import make_plan
from repro.train.optimizer import make_optimizer
from repro.train.step import make_train_step

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = registry.get("llama3.2-1b").reduced().replace(
    num_heads=4, num_kv_heads=2, head_dim=16, d_model=64, d_ff=128)
plan = make_plan(cfg, mesh)
model = Model(cfg, plan)
opt = make_optimizer(cfg)
meta = model.param_meta()
step = make_train_step(model, opt, n_accum=2)

with mesh:
    params = model.init(jax.random.PRNGKey(0))
    params = jax.device_put(params, plan.param_shardings(meta))
    opt_state = jax.device_put(
        opt.init(params),
        jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                               plan.param_specs(opt.state_meta(meta)),
                               is_leaf=lambda x: isinstance(x, P)))
    batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
             "labels": jnp.zeros((8, 32), jnp.int32)}
    batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    p2, o2, m = jax.jit(step, donate_argnums=(0, 1))(params, opt_state,
                                                     batch, 0)
    assert jnp.isfinite(m["loss"])
print("MINI_DRYRUN_OK", float(m["loss"]))
"""


@pytest.mark.slow
def test_mini_mesh_train_step_subprocess():
    """Real 8-device SPMD train step (subprocess keeps this process at 1)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", MINI_DRYRUN], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "MINI_DRYRUN_OK" in out.stdout, out.stderr[-2000:]

