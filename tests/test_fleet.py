"""Fleet failure domains (repro.control.fleet + scenarios.fleet_replay).

The §10 contracts, in order of importance:

- **degenerate bitwise**: a 1-pod FleetLoop replays ``diurnal_load_spike``
  and ``chaos_day`` with the exact fingerprint of the flat ControlLoop;
- **pod-count invariance**: on a clean day the physical outcome (rails,
  energy, condemned) is identical for any pod count — one shared solve,
  sliced;
- **pod_loss_day**: chaos confined to one pod walks it through
  degraded -> quarantined -> drained -> restored inside the day,
  deterministically, with zero lost serve requests and outputs bitwise
  equal to the no-failure day;
- **containment plumbing**: rail channels, telemetry fan-out, pod-seeded
  fault streams, the host-pool provenance ledger, and the per-source bus
  freshness horizon.
"""
import jax
import numpy as np
import pytest

import repro.scenarios as S
from repro import control as ctl
from repro.configs import registry
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.ft.elastic import ElasticWorkAssignment
from repro.launch.mesh import PodTopology
from repro.models.model import Model

SW = (15.0, 40.0, 4)       # coarse ambient sweep (test-speed)
US = (0.25, 1.0, 3)        # coarse utilization knots


@pytest.fixture(scope="module")
def rt():
    return RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")


@pytest.fixture(scope="module")
def dense():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# unit: pod partitioning
# ---------------------------------------------------------------------------


class TestPartition:
    def test_even_split(self):
        assert PodTopology.partition(16, 2) == ((0, 8), (8, 16))
        assert PodTopology.partition(16, 4) == ((0, 4), (4, 8), (8, 12),
                                                (12, 16))
        assert PodTopology.partition(8, 1) == ((0, 8),)

    def test_uneven_rejected(self):
        with pytest.raises(ValueError):
            PodTopology.partition(16, 3)
        with pytest.raises(ValueError):
            PodTopology.partition(16, 0)


# ---------------------------------------------------------------------------
# unit: pod-seeded fault streams (satellite 6: seed threading)
# ---------------------------------------------------------------------------


class TestForPod:
    def _model(self):
        return ctl.ControlFaultModel(rate=0.6, seed=7, nack=0.5,
                                     sensor_window=(2, 9),
                                     nack_window=(4, 6),
                                     deadline_misses=(3,),
                                     solver_faults=(5,))

    def test_pod0_is_bitwise_the_base(self):
        a, b = self._model(), self._model().for_pod(0)
        draws_a = [a.sensor_fault(t) for t in range(12)]
        draws_b = [b.sensor_fault(t) for t in range(12)]
        assert draws_a == draws_b
        assert np.array_equal(a.nack(8, 5.0, 0), b.nack(8, 5.0, 0))

    def test_sibling_pods_decorrelate(self):
        base = self._model()
        p1, p2 = base.for_pod(1), base.for_pod(2)
        d1 = [p1.sensor_fault(t) for t in range(40)]
        d2 = [p2.sensor_fault(t) for t in range(40)]
        d0 = [base.sensor_fault(t) for t in range(40)]
        assert d1 != d0 or d2 != d0
        assert d1 != d2

    def test_windows_and_scripts_preserved(self):
        p = self._model().for_pod(3)
        assert p.sensor_window == (2, 9) and p.nack_window == (4, 6)
        assert p.deadline_miss(3.0) and p.solver_fault(5.0)
        assert not p.deadline_miss(4.0)
        # scripted channels are pod-invariant; only the drawn ones differ
        assert p.nack_p == 0.5 and p.rate == 0.6


# ---------------------------------------------------------------------------
# unit: telemetry fan-out + pod views
# ---------------------------------------------------------------------------


class _StubSource:
    def __init__(self, samples):
        self.samples = samples
        self.polls = 0

    def poll(self, now):
        self.polls += 1
        return list(self.samples)


class TestPodTelemetryView:
    def test_slicing_and_primary_gating(self):
        t = np.arange(8, dtype=np.float32) + 50.0
        src = _StubSource([
            ctl.ChipTempSample(t),
            ctl.UtilSample(np.arange(8, dtype=np.float32)),
            ctl.SafeStateSample(frozenset({1, 5})),
            ctl.StragglerSample("w0", 1.0, 2.0, 6),
            ctl.SdcSample(detected=3, corrected=2, escaped=1, checked=10),
        ])
        fan = ctl.FanoutTelemetry(src)
        v0 = fan.view(0, 4, primary=True)
        v1 = fan.view(4, 8)
        s0, s1 = v0.poll(1.0), v1.poll(1.0)
        assert src.polls == 1  # shared source drained once per tick
        chip0 = next(s for s in s0 if isinstance(s, ctl.ChipTempSample))
        chip1 = next(s for s in s1 if isinstance(s, ctl.ChipTempSample))
        assert np.array_equal(chip0.t_chip, t[:4])
        assert np.array_equal(chip1.t_chip, t[4:])
        # safe-state chips arrive pod-local, and the empty slice is still
        # emitted (the pod bus's persistent set must be able to clear)
        safe0 = next(s for s in s0 if isinstance(s, ctl.SafeStateSample))
        safe1 = next(s for s in s1 if isinstance(s, ctl.SafeStateSample))
        assert safe0.chips == frozenset({1})
        assert safe1.chips == frozenset({1})  # chip 5 -> local 1
        # the straggler on chip 6 belongs to pod 1 alone, translated
        assert not any(isinstance(s, ctl.StragglerSample) for s in s0)
        strag = next(s for s in s1 if isinstance(s, ctl.StragglerSample))
        assert strag.chip == 2
        # fleet-global counters ride only the primary view
        assert any(isinstance(s, ctl.SdcSample) for s in s0)
        assert not any(isinstance(s, ctl.SdcSample) for s in s1)

    def test_full_primary_view_is_identity_valued(self):
        t = np.arange(4, dtype=np.float32) + 60.0
        src = _StubSource([ctl.ChipTempSample(t),
                           ctl.SafeStateSample(frozenset({2}))])
        out = ctl.FanoutTelemetry(src).view(0, 4, primary=True).poll(0.0)
        assert np.array_equal(out[0].t_chip, t)
        assert out[1].chips == frozenset({2})


class TestBusPerSourceFreshness:
    def test_age_tracks_the_folded_sources_own_stamp(self):
        class Amb:
            def __init__(self):
                self.until = None

            def poll(self, now):
                if self.until is not None and now > self.until:
                    return []
                return [ctl.AmbientSample(t_amb=25.0 + now)]

        a, b = Amb(), Amb()
        bus = ctl.TelemetryBus([a, b], max_age=0.75)
        bus.poll(0.0)
        b.until = 0.0  # b (the last writer at tick 0) goes silent
        snap = bus.poll(1.0)
        # a keeps writing: the folded value is a's and its age is 0 — b's
        # silence cannot age out a sibling source's fresh reading
        assert snap.t_amb == 26.0 and snap.t_amb_age == 0.0
        a.until = 1.0  # now both are silent: age grows from a's stamp
        snap = bus.poll(3.0)
        assert snap.t_amb == 26.0 and snap.t_amb_age == 2.0


# ---------------------------------------------------------------------------
# unit: pod rail channel
# ---------------------------------------------------------------------------


class TestPodRailChannel:
    def test_slice_write_leaves_siblings_alone(self, rt):
        fleet = ctl.FleetActuator.from_runtime(rt, t_amb=25.0)
        n = rt.substrate.n_domains
        before = fleet.v_core.copy()
        ch = ctl.PodRailChannel(fleet, 0, n // 2)
        ch.apply(ctl.SetRails(0.701, 0.721, source="lut"))
        assert np.allclose(fleet.v_core[:n // 2], 0.701)
        assert np.array_equal(fleet.v_core[n // 2:], before[n // 2:])

    def test_latency_double_buffer_latest_wins(self, rt):
        fleet = ctl.FleetActuator.from_runtime(rt, t_amb=25.0)
        n = rt.substrate.n_domains
        ch = ctl.PodRailChannel(fleet, 0, n, write_latency_s=1.0)
        ch.begin_tick(0.0)
        before = fleet.v_core.copy()
        ch.apply(ctl.SetRails(0.700, 0.720, source="lut"))
        ch.apply(ctl.SetRails(0.705, 0.725, source="lut"))
        assert np.array_equal(fleet.v_core, before)  # staged, not landed
        ch.begin_tick(0.5)  # latency not yet elapsed
        assert np.array_equal(fleet.v_core, before)
        ch.begin_tick(1.5)  # commits the LATEST staged write
        assert np.allclose(fleet.v_core, 0.705)
        assert ch.staged_commits == 1

    def test_freeze_safe_pins_the_slice(self, rt):
        fleet = ctl.FleetActuator.from_runtime(rt, t_amb=25.0)
        n = rt.substrate.n_domains
        ch = ctl.PodRailChannel(fleet, 0, n // 2, write_latency_s=1.0)
        ch.apply(ctl.SetRails(0.700, 0.720, source="lut"))  # staged
        ch.freeze_safe()
        assert ch._staged is None  # the in-flight write died with the pod
        assert np.allclose(fleet.v_core[:n // 2], TF.V_CORE_NOM)
        assert all(c in fleet.safe_state for c in range(n // 2))
        # pinned chips reject further writes until cleared
        ch2 = ctl.PodRailChannel(fleet, 0, n // 2)
        ch2.apply(ctl.SetRails(0.690, 0.710, source="lut"))
        assert np.allclose(fleet.v_core[:n // 2], TF.V_CORE_NOM)


# ---------------------------------------------------------------------------
# unit: elastic pod-slice views
# ---------------------------------------------------------------------------


class TestElasticPodViews:
    def test_pod_share_and_condemned_in(self):
        asg = ElasticWorkAssignment(8)
        assert asg.pod_share(0, 4) == pytest.approx(0.5)
        for c in range(4, 8):
            asg.condemn(c)
        assert asg.pod_share(4, 8) == 0.0
        assert asg.pod_share(0, 4) == pytest.approx(1.0)
        assert asg.condemned_in(4, 8) == (4, 5, 6, 7)
        assert asg.condemned_in(0, 4) == ()
        for c in range(4, 8):
            asg.restore(c)
        assert asg.pod_share(4, 8) == pytest.approx(0.5)
        assert asg.condemned_in(4, 8) == ()


# ---------------------------------------------------------------------------
# unit: host-pool provenance ledger + engine drain
# ---------------------------------------------------------------------------


class TestHostPoolLedger:
    class _Alloc:
        def __init__(self, max_len=64):
            self.max_len = max_len

    def test_foreign_resume_blocked_while_pages_unfreed(self):
        from repro.serve.cache import HostPagePool
        pool = HostPagePool()
        home, away = self._Alloc(), self._Alloc()
        pool.put("r1", np.zeros(3), pos=8, pages=1, owner=home,
                 page_ids=[4], freed=False)
        with pytest.raises(RuntimeError, match="foreign"):
            pool.take("r1", owner=away)
        rows, pos = pool.take("r1", owner=home)  # home always may resume
        assert pos == 8

    def test_freed_foreign_resume_counts_a_migration(self):
        from repro.serve.cache import HostPagePool
        pool = HostPagePool()
        home, away = self._Alloc(), self._Alloc()
        pool.put("r2", np.zeros(3), pos=8, pages=1, owner=home, freed=True)
        assert pool.migrations == 0
        pool.take("r2", owner=away)
        assert pool.migrations == 1

    def test_capacity_guard(self):
        from repro.serve.cache import HostPagePool
        pool = HostPagePool()
        small = self._Alloc(max_len=4)
        pool.put("r3", np.zeros(3), pos=8, pages=1, owner=self._Alloc())
        with pytest.raises(RuntimeError, match="max_len"):
            pool.take("r3", owner=small)


class TestEngineDrain:
    def test_drain_returns_everything_resumable(self, dense):
        from repro.serve.engine import Engine, Request
        _, model, params = dense
        eng = Engine(model, params, batch_slots=2, max_len=64, eos_id=-1,
                     warmup=False)
        reqs = [Request(i, np.arange(4, dtype=np.int32) + i, max_new=12)
                for i in range(4)]
        for r in reqs:
            eng.submit(r)
        for _ in range(3):
            eng.step()  # two active mid-decode, two queued
        out = eng.drain()
        assert sorted(r.rid for r in out) == [0, 1, 2, 3]
        assert not eng.queue
        assert all(r is None for r in eng.slot_req)
        # resubmitting the drained requests elsewhere finishes them all
        eng2 = Engine(model, params, batch_slots=2, max_len=64, eos_id=-1,
                      warmup=False)
        for r in out:
            eng2.submit(r)
        while eng2.step():
            pass
        assert sorted(r.rid for r in eng2.finished) == [0, 1, 2, 3]
        assert all(len(r.out) == 12 for r in eng2.finished)


# ---------------------------------------------------------------------------
# the §10 acceptance pins (solver in the loop)
# ---------------------------------------------------------------------------


class TestDegenerateBitwise:
    """A 1-pod fleet IS the flat loop — fingerprint-for-fingerprint."""

    def test_diurnal_load_spike(self, rt):
        sc = S.diurnal_load_spike(ticks=10)
        flat = S.replay(sc, runtime=rt, sweep=SW, util_sweep=US)
        one = S.fleet_replay(sc, n_pods=1, runtime=rt, sweep=SW,
                             util_sweep=US)
        assert one.fingerprint == flat.fingerprint
        assert one.replans == flat.replans
        assert one.replan_reasons == flat.replan_reasons

    def test_chaos_day(self, rt):
        sc = S.chaos_day(ticks=12)
        flat = S.replay(sc, runtime=rt, sweep=SW, util_sweep=US)
        one = S.fleet_replay(sc, n_pods=1, runtime=rt, sweep=SW,
                             util_sweep=US)
        assert one.fingerprint == flat.fingerprint
        assert one.write_nacks == flat.write_nacks
        assert one.frozen_ticks == flat.frozen_ticks
        assert one.safe_states == flat.safe_states


class TestPodCountInvariance:
    """Satellite 6: same chips + same workload -> same physical outcome,
    whatever the pod partitioning (clean day: the per-tick fleet util is
    assembled before any pod decides and every pod slices one memoized
    solve)."""

    def test_clean_day_invariant_across_pod_counts(self, rt):
        sc = S.diurnal_load_spike(ticks=10)
        runs = {n: S.fleet_replay(sc, n_pods=n, runtime=rt, sweep=SW,
                                  util_sweep=US) for n in (1, 2, 4)}
        fps = {n: r.fleet_fingerprint for n, r in runs.items()}
        assert fps[1] == fps[2] == fps[4], fps
        # the bookkeeping legitimately differs: every pod cold-starts
        assert runs[2].replan_reasons.count("cold_start") == 2

    def test_chaos_multi_pod_pinned_as_its_own_golden(self, rt):
        # per-pod fault streams draw in different order than the flat
        # loop: NOT invariant, but still deterministic — pin by replay
        sc = S.chaos_day(ticks=12)
        a = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                           util_sweep=US)
        b = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                           util_sweep=US)
        assert a.fingerprint == b.fingerprint


class TestChaosRateZeroMultiPod:
    """Satellite 3: a rate-0 fault model on the MULTI-pod loop is bitwise
    identity — wrappers, per-pod streams and the freshness bound change
    nothing when no fault fires."""

    def test_rate_zero_is_bitwise_identity(self, rt):
        sc = S.diurnal_load_spike(ticks=10)
        clean = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                               util_sweep=US)
        wrapped = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                                 util_sweep=US,
                                 faults=ctl.ControlFaultModel(rate=0.0))
        assert wrapped.fingerprint == clean.fingerprint
        assert wrapped.write_nacks == 0 and wrapped.quarantined == 0


class TestWatchdogOutranksStaleness:
    """Satellite 3: a NACK storm concurrent with stale telemetry in the
    same ticks — the watchdog ladder must win: rails freeze at the last
    programmed point (frozen_ticks), the stale fallback guards the fast
    path, and neither triggers a solver replan mid-storm."""

    def test_frozen_rails_while_stale_and_nacked(self, rt):
        sc = S.diurnal(ticks=12)
        sc = S.Scenario(
            name="stale_nack_storm", ticks=12, ambient=sc.ambient,
            load=lambda now: 0.9,
            chaos=lambda: ctl.ControlFaultModel(
                rate=0.0, seed=1,
                stale=0.9, dropout=0.0, spike=0.0, stuck=0.0,
                nack=0.9, sensor_window=(3, 9), nack_window=(3, 9),
                # two consecutive misses inside the same window: the
                # ladder reaches level 2 while the sensors are stale
                deadline_misses=(3, 4)))
        a = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                           util_sweep=US)
        assert a.frozen_ticks >= 1        # level 2 held rails frozen
        assert a.stale_fallbacks >= 1     # stale ticks hit the guard band
        assert a.write_nacks >= 1         # the NACK storm was live too
        # the watchdog won: no replan fired during the storm (staleness
        # has no replan reason by design; the freeze suppresses the rest)
        assert all(r == "cold_start" or r.startswith("ambient_jump")
                   for r in a.replan_reasons), a.replan_reasons
        b = S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                           util_sweep=US)
        assert a.fingerprint == b.fingerprint


class TestPodLossDay:
    """The §10 acceptance day: quarantine containment + cool-down restore,
    fingerprint-pinned."""

    @pytest.fixture(scope="class")
    def day(self, rt):
        sc = S.pod_loss_day(ticks=16)
        return S.fleet_replay(sc, n_pods=2, runtime=rt, sweep=SW,
                              util_sweep=US)

    def test_deterministic(self, rt, day):
        again = S.fleet_replay(S.pod_loss_day(ticks=16), n_pods=2,
                               runtime=rt, sweep=SW, util_sweep=US)
        assert again.fingerprint == day.fingerprint

    def test_walks_the_full_ladder_and_restores(self, day):
        assert day.quarantines == 1 and day.pod_restores == 1
        names = [e.split("@")[0] for e in day.events]
        assert names == ["pod1:degraded", "pod1:quarantined",
                         "pod1:drained", "pod1:restored"]
        # the storm is confined: pod 0 never leaves healthy
        assert all(t[0] == ctl.HEALTHY for t in day.state_trace)
        assert any(t[1] == ctl.DRAINED for t in day.state_trace)
        assert day.states == {0: ctl.HEALTHY, 1: ctl.HEALTHY}

    def test_containment_is_physical(self, day):
        # while drained, the pod's chips are at safe nominal rails and its
        # work share is zero; after restore everything is handed back
        drained = [i for i, t in enumerate(day.state_trace)
                   if t[1] == ctl.DRAINED]
        chips = day.rails.shape[2]
        lo = chips // 2
        t = drained[0]
        assert np.allclose(day.rails[t, 0, lo:], TF.V_CORE_NOM)
        assert day.condemned == ()           # restore un-condemned them
        assert day.shares.sum() == pytest.approx(chips)
        assert day.t_max < TF.T_MAX_CHIP

    def test_last_pod_is_never_quarantined(self, rt):
        # the degenerate fleet under the same chaos must keep running:
        # someone has to hold the rails
        a = S.fleet_replay(S.pod_loss_day(ticks=16, fail_pod=0), n_pods=1,
                           runtime=rt, sweep=SW, util_sweep=US)
        assert a.quarantines == 0
        assert any("quarantine_deferred" in e for e in a.events)
        assert a.states == {0: ctl.DEGRADED} or a.states == {0: ctl.HEALTHY}


class TestPodLossServeDrill:
    """Live request migration: zero lost requests, outputs bitwise equal
    to the no-failure day."""

    @pytest.fixture(scope="class")
    def drill(self, rt, dense):
        _, model, params = dense
        sc = S.pod_loss_day(ticks=16)
        wl = S.trace_requests([(t, 5, 20) for t in (1, 2, 3, 4, 4, 5)],
                              name="podloss")
        kw = dict(n_pods=2, runtime=rt, sweep=SW, util_sweep=US,
                  eos_id=-1, warmup=False, batch_slots=2, engine_steps=2)
        a = S.fleet_serve_replay(sc, wl, model, params, **kw)
        clean = S.Scenario(name=sc.name, ticks=sc.ticks,
                           ambient=sc.ambient, load=sc.load)
        b = S.fleet_serve_replay(clean, wl, model, params, **kw)
        return wl, a, b

    def test_zero_lost_and_migrated(self, drill):
        wl, a, _ = drill
        assert a.finished == len(wl.arrivals)
        assert a.rejected == 0
        assert a.migrated > 0            # requests were in flight at loss
        assert a.quarantines == 1 and a.pod_restores == 1

    def test_outputs_bitwise_equal_no_failure_day(self, drill):
        _, a, b = drill
        assert b.migrated == 0
        assert a.outputs == b.outputs    # rid-for-rid identical tokens

    def test_deterministic(self, rt, dense, drill):
        wl, a, _ = drill
        _, model, params = dense
        again = S.fleet_serve_replay(
            S.pod_loss_day(ticks=16), wl, model, params, n_pods=2,
            runtime=rt, sweep=SW, util_sweep=US, eos_id=-1, warmup=False,
            batch_slots=2, engine_steps=2)
        assert again.fingerprint == a.fingerprint
