"""KVCacheManager / ExpandableKVCacheManager (repro.serve.cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.model import Model
from repro.serve.cache import (NO_AXIS, ExpandableKVCacheManager,
                               KVCacheManager)


@pytest.fixture(scope="module")
def dense():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _leaf_shapes(cache):
    return [x.shape for x in jax.tree_util.tree_leaves(cache)]


class TestKVCacheManager:
    def test_probes_batch_axis_on_every_leaf(self, dense):
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=3, max_len=32)
        axes = jax.tree_util.tree_leaves(mgr.batch_axes)
        assert axes and all(a != NO_AXIS for a in axes)
        # probing is structural: the axis must actually carry slot count
        for ax, leaf in zip(axes, jax.tree_util.tree_leaves(mgr.cache)):
            assert leaf.shape[ax] == 3

    def test_slot_lifecycle_and_recycling(self, dense):
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=2, max_len=32)
        a = mgr.allocate(5)
        b = mgr.allocate(7)
        assert sorted([a, b]) == [0, 1] and mgr.free_slots == []
        mgr.advance([a], [5])
        assert mgr.pos[a] == 5
        mgr.free(a)
        assert a in mgr.free_slots and mgr.pos[a] == 0
        c = mgr.allocate(3)  # recycled, no new arrays
        assert c == a

    def test_free_guards_double_free_and_bad_slot(self, dense):
        """Regression (§9 satellite): a double free used to push the slot
        onto the free list twice, handing the same slot to two requests."""
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=2, max_len=32)
        s = mgr.allocate(4)
        mgr.free(s)
        with pytest.raises(ValueError, match="double free"):
            mgr.free(s)
        with pytest.raises(ValueError, match="invalid slot"):
            mgr.free(2)
        with pytest.raises(ValueError, match="invalid slot"):
            mgr.free(-1)
        # the free list stayed sane: both slots allocate exactly once
        assert sorted([mgr.allocate(1), mgr.allocate(1)]) == [0, 1]
        assert mgr.free_slots == []

    def test_free_invalidates_pos_ids_row(self, dense):
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=2, max_len=16)
        s = mgr.allocate(4)
        # mark some positions valid, then free: the row must go to -1
        mgr.cache["stack"]["pos_ids"] = (
            mgr.cache["stack"]["pos_ids"].at[:, s, :4].set(
                jnp.arange(4, dtype=jnp.int32)))
        mgr.free(s)
        assert (np.asarray(mgr.cache["stack"]["pos_ids"][:, s]) == -1).all()

    def test_page_accounting(self, dense):
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=2, max_len=32, page_size=8)
        assert mgr.total_pages == 8 and mgr.pages_in_use == 0
        s = mgr.allocate(1)
        mgr.advance([s], [9])  # 9 tokens -> 2 pages
        assert mgr.pages_in_use == 2
        assert mgr.peak_pages == 2
        mgr.free(s)
        assert mgr.pages_in_use == 0 and mgr.peak_pages == 2

    def test_incremental_pages_pinned_against_recount(self, dense):
        """pages_in_use is maintained incrementally (O(1) per advance);
        pin it against the from-scratch recount through a full slot
        lifecycle including preemption restore."""
        _, model, _ = dense
        mgr = KVCacheManager(model, slots=3, max_len=32, page_size=8)
        assert mgr.pages_in_use == mgr.recount_pages() == 0
        a = mgr.allocate(3)
        b = mgr.allocate(9)
        assert mgr.pages_in_use == mgr.recount_pages() == 2
        mgr.advance([a, b], [3, 9])       # b crosses into page 2
        assert mgr.pages_in_use == mgr.recount_pages() == 3
        mgr.advance([b], [8])             # page 3
        assert mgr.pages_in_use == mgr.recount_pages() == 4
        rows, pos = mgr.read_rows([b]), int(mgr.pos[b])
        mgr.free(b)
        assert mgr.pages_in_use == mgr.recount_pages() == 1
        c = mgr.allocate(1)
        mgr.restore(c, rows, pos)         # resume rewinds the page count
        assert mgr.pages_in_use == mgr.recount_pages() == 4
        mgr.free(a)
        mgr.free(c)
        assert mgr.pages_in_use == mgr.recount_pages() == 0
        assert mgr.peak_pages == 4

    def test_write_rows_scatters_one_request(self, dense):
        _, model, params = dense
        mgr = KVCacheManager(model, slots=3, max_len=16)
        toks = jnp.arange(4, dtype=jnp.int32)[None]
        _, rows = model.prefill(params, {"tokens": toks}, max_len=16)
        mgr.write_rows([2], rows)
        got = np.asarray(mgr.cache["stack"]["k"], np.float32)
        ref = np.asarray(rows["stack"]["k"], np.float32)[:, 0]
        np.testing.assert_allclose(got[:, 2], ref, rtol=1e-6)
        assert (got[:, 0] == 0).all()  # other slots untouched


class TestExpandableKVCacheManager:
    def test_grows_by_doubling_to_max_len(self, dense):
        _, model, _ = dense
        mgr = ExpandableKVCacheManager(model, slots=2, max_len=64,
                                       initial_len=8)
        assert mgr.capacity == 8
        shapes0 = _leaf_shapes(mgr.cache)
        mgr.ensure(8)
        assert mgr.capacity == 8 and _leaf_shapes(mgr.cache) == shapes0
        mgr.ensure(9)
        assert mgr.capacity == 16 and mgr.grows == 1
        mgr.ensure(50)  # doubles twice in one call
        assert mgr.capacity == 64 and mgr.grows == 2
        with pytest.raises(ValueError):
            mgr.ensure(65)

    def test_growth_pads_pos_ids_invalid(self, dense):
        _, model, _ = dense
        mgr = ExpandableKVCacheManager(model, slots=2, max_len=32,
                                       initial_len=8)
        mgr.cache["stack"]["pos_ids"] = (
            mgr.cache["stack"]["pos_ids"].at[..., :2].set(0))
        mgr.ensure(16)
        ids = np.asarray(mgr.cache["stack"]["pos_ids"])
        assert ids.shape[-1] == 16
        assert (ids[..., :2] == 0).all()   # old contents preserved
        assert (ids[..., 8:] == -1).all()  # new space invalid, not pos 0

    def test_engine_results_match_fixed_cache(self, dense):
        from repro.serve.engine import Engine, Request
        cfg, model, params = dense
        prompt = np.arange(5) % cfg.vocab_size

        def gen(expandable):
            eng = Engine(model, params, batch_slots=2, max_len=64,
                         eos_id=-1, expandable=expandable, warmup=False)
            eng.submit(Request(0, prompt, max_new=6))
            return eng.run()[0].out

        assert gen(False) == gen(True)
