"""Minimal deterministic stand-in for `hypothesis` (see conftest.py).

The container image may lack the real library; installing packages is not an
option, and the property tests only use a small surface: ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``, and the
``integers`` / ``floats`` / ``sampled_from`` strategies.  This stub replays
each property over a deterministic sample set (bounds first, then seeded
uniforms) so the assertions still exercise a meaningful input range.

If the real `hypothesis` is importable it is always preferred — conftest
only installs this module into ``sys.modules`` on ImportError.
"""
from __future__ import annotations

import random
from types import ModuleType, SimpleNamespace


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_at(self, rng, i):
        return self._draw(rng, i)


def integers(min_value, max_value):
    bounds = [min_value, max_value]

    def draw(rng, i):
        if i < len(bounds):
            return bounds[i]
        return rng.randint(min_value, max_value)

    return _Strategy(draw)


def floats(min_value, max_value, **_kw):
    bounds = [min_value, max_value]

    def draw(rng, i):
        if i < len(bounds):
            return bounds[i]
        return rng.uniform(min_value, max_value)

    return _Strategy(draw)


def sampled_from(choices):
    seq = list(choices)

    def draw(rng, i):
        if i < len(seq):
            return seq[i]
        return rng.choice(seq)

    return _Strategy(draw)


def lists(elem, min_size=0, max_size=10):
    def draw(rng, i):
        n = rng.randint(min_size, max_size)
        return [elem.example_at(rng, rng.randint(0, 10**6)) for _ in range(n)]

    return _Strategy(draw)


def settings(max_examples=20, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        def runner(*args, **kwargs):
            n = getattr(runner, "_stub_max_examples", None) or getattr(
                fn, "_stub_max_examples", 20)
            rng = random.Random(0)
            for i in range(n):
                drawn = {k: s.example_at(rng, i)
                         for k, s in strategies.items()}
                fn(*args, **drawn, **kwargs)

        # keep the wrapper signature opaque (no __wrapped__): pytest must
        # not mistake the strategy kwargs for fixtures
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner._stub_given = True
        return runner

    return deco


def install(sys_modules) -> None:
    """Register this stub as the `hypothesis` package."""
    mod = ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = SimpleNamespace(all=lambda: [])
    st = ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.lists = lists
    mod.strategies = st
    sys_modules["hypothesis"] = mod
    sys_modules["hypothesis.strategies"] = st
