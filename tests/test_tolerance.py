"""repro.tolerance — the §V error-tolerant over-scaling tier (ISSUE-6).

Covers the four layers end to end: the live timing-fault model and seeded
injector (zero at the guard band, deterministic streams), the ABFT
row/column-checksummed matmul (Pallas-vs-ref parity under forced
injections, single-flip repair, aliasing escapes), the ``ErrorTolerant``
policy (budget -> 0 collapses to PowerSave bitwise; nonzero budgets buy
power below the guard band while the *predicted* escaped-SDC rate honors
the budget), and the closed loop (controller back-off hysteresis, the
``sdc_storm`` acceptance day, cooled-chip restore).  Plus the
``core/overscaling.error_profile`` edge cases the static tier never pinned.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import policy as pol
from repro import scenarios as SC
from repro.core import netlist as NL
from repro.core import overscaling as OS
from repro.core import runtime as RT
from repro.core import thermal
from repro.core import tpu_fleet as TF
from repro.core import vtr_benchmarks as vb
from repro.control import RailBackoff, Restore, SetRails, Snapshot
from repro.control.lut import sweep_points
from repro.kernels.abft_matmul import abft_matmul, checksum_refs
from repro.kernels.overscale_matmul import bit_probs_to_cdf
from repro.kernels.ref import abft_matmul_ref
from repro.tolerance import (AbftMatmul, FaultInjector, TimingFaultModel,
                             detect_and_correct, routed_matmuls,
                             topk_agreement)

TC12 = thermal.ThermalConfig(theta_ja=12.0)
T_KNOTS = sweep_points(20.0, 36.0, 5)
U_KNOTS = sweep_points(0.25, 1.0, 3)
BUDGET = 1e-5


@pytest.fixture(scope="module")
def profile():
    return TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)


@pytest.fixture(scope="module")
def rt_ps(profile):
    return RT.EnergyAwareRuntime(profile, policy="power_save")


@pytest.fixture(scope="module")
def rt_et(profile):
    return RT.EnergyAwareRuntime(profile, policy=f"error_tolerant:{BUDGET}")


@pytest.fixture(scope="module")
def field_ps(rt_ps):
    return rt_ps.build_field(T_KNOTS, U_KNOTS)


@pytest.fixture(scope="module")
def field_et(rt_et):
    return rt_et.build_field(T_KNOTS, U_KNOTS)


# ===========================================================================
# core/overscaling.error_profile edge cases (the static FPGA tier)
# ===========================================================================


class TestErrorProfileEdges:
    @pytest.fixture(scope="class")
    def sweep(self):
        nl = NL.generate(vb.BY_NAME["raygentop"])
        return OS.sweep(nl, [1.0, 1.15, 1.3], t_amb=40.0, tc=TC12)

    def test_gamma_one_is_exactly_error_free(self, sweep):
        # the guard-band contract: no relaxation, no violating path, no
        # flipped bit — the probabilities are hard zeros, not small floats
        r = sweep[0]
        assert r.frac_violating == 0.0
        assert r.mean_overshoot == 0.0
        assert np.all(r.bit_probs == 0.0)

    def test_bit_probs_monotone_in_gamma(self, sweep):
        totals = [float(r.bit_probs.sum()) for r in sweep]
        assert totals[0] <= totals[1] <= totals[2]
        assert totals[2] > 0.0

    def test_bit_probs_monotone_in_temperature_at_fixed_rails(self):
        # hotter silicon = slower paths = deeper violations — at FIXED
        # rails (the solved operating point re-optimizes rails per
        # temperature, so only the fixed-rail profile is monotone)
        import repro.core.characterization as C
        nl = NL.generate(vb.BY_NAME["raygentop"])
        lib, nlj = C.default_library(), nl.as_jax()
        d_worst = float(NL.crit_delay(
            lib, nlj, jnp.full((nl.n_tiles,), 60.0),
            C.V_CORE_NOM, C.V_BRAM_NOM))
        out = []
        for t in (40.0, 60.0, 80.0):
            frac, overshoot, bp = OS.error_profile(
                lib, nlj, nl, jnp.full((nl.n_tiles,), t),
                0.70, 0.75, d_worst, 1.0)
            out.append((frac, overshoot, float(bp.sum())))
        fracs, overs, totals = zip(*out)
        assert fracs[0] <= fracs[1] <= fracs[2]
        assert totals[0] <= totals[1] <= totals[2]
        assert totals[2] > 0.0

    def test_cdf_round_trip(self, sweep):
        probs = sweep[2].bit_probs
        cdf = np.asarray(bit_probs_to_cdf(probs))
        assert cdf.shape == (33,)
        assert cdf[0] == 0.0
        np.testing.assert_allclose(np.diff(cdf), probs, atol=1e-7)
        assert cdf[-1] == pytest.approx(float(probs.sum()), abs=1e-6)
        assert np.all(np.diff(cdf) >= -1e-9)  # monotone


# ===========================================================================
# faults: the live model + seeded injector
# ===========================================================================


class TestTimingFaultModel:
    def test_zero_at_guard_band_rails(self):
        m = TimingFaultModel()
        assert float(m.overshoot(TF.V_CORE_NOM, TF.V_SRAM_NOM, 60.0)) == 0.0
        assert float(m.sdc_rate(TF.V_CORE_NOM, TF.V_SRAM_NOM, 60.0)) == 0.0
        assert np.all(m.bit_probs(TF.V_CORE_NOM, TF.V_SRAM_NOM, 60.0) == 0.0)

    def test_rate_monotone_in_undervolt_and_temperature(self):
        m = TimingFaultModel()
        r = [float(m.sdc_rate(vc, 0.80, 60.0))
             for vc in (0.66, 0.64, 0.62)]
        assert r[0] < r[1] < r[2]
        rt = [float(m.sdc_rate(0.64, 0.80, t)) for t in (40.0, 60.0, 80.0)]
        assert rt[0] < rt[1] < rt[2]

    def test_bit_profile_is_carry_tail_weighted(self):
        m = TimingFaultModel()
        bp = m.bit_probs(0.64, 0.80, 60.0)
        assert bp[:20].sum() == 0.0  # only the carry/MSB tail flips
        assert bp[31] > 0.0

    def test_shared_constants_close_the_prediction_loop(self):
        # the policy's inverse rate model and the injector's forward model
        # are the same curve: escaped_rate(overshoot_budget(b)) == b
        for b in (1e-6, 1e-5, 1e-4):
            x = float(pol.overshoot_budget(b))
            assert float(pol.escaped_sdc_rate(x)) == pytest.approx(b,
                                                                   rel=1e-5)
        m = TimingFaultModel()
        raw = m.sdc_rate(0.66, 0.80, 70.0)
        np.testing.assert_allclose(m.escaped_rate(0.66, 0.80, 70.0),
                                   pol.ABFT_ESCAPE * raw, rtol=1e-7)


class TestFaultInjector:
    def test_zero_injections_at_nominal(self):
        inj = FaultInjector(seed=3)
        c = inj.tick(0.0, TF.V_CORE_NOM, TF.V_SRAM_NOM, 60.0)
        assert c.injected == 0 and c.escaped == 0
        assert c.checked > 0  # traffic is still checksummed

    def test_deterministic_given_seed(self):
        a, b = FaultInjector(seed=11), FaultInjector(seed=11)
        seq = []
        for t in range(4):
            ca = a.tick(float(t), 0.64, 0.80, 70.0)
            cb = b.tick(float(t), 0.64, 0.80, 70.0)
            assert (ca.injected, ca.detected, ca.escaped, ca.checked) == \
                   (cb.injected, cb.detected, cb.escaped, cb.checked)
            seq.append(ca.injected)
        assert a.totals.injected == b.totals.injected
        a.reset()  # reset restarts the exact same stream
        assert [a.tick(float(t), 0.64, 0.80, 70.0).injected
                for t in range(4)] == seq

    def test_ledger_is_conserved(self):
        inj = FaultInjector(seed=5)
        c = inj.tick(0.0, 0.62, 0.78, 75.0)
        assert c.injected > 0
        assert c.detected + c.escaped == c.injected
        assert c.corrected == c.detected  # what ABFT catches, it repairs
        assert inj.totals.escape_rate == pytest.approx(
            c.escaped / c.checked)

    def test_noise_trace_scales_the_rate(self):
        quiet = FaultInjector(seed=9)
        noisy = FaultInjector(seed=9, noise=lambda now: 8.0)
        cq = quiet.tick(0.0, 0.64, 0.80, 70.0)
        cn = noisy.tick(0.0, 0.64, 0.80, 70.0)
        assert cn.injected > cq.injected


# ===========================================================================
# ABFT: kernel parity + detect/correct
# ===========================================================================


def _inputs(m, k, n, p_tail=0.02, seed=0):
    rng = np.random.default_rng(seed)
    a = rng.integers(-30, 30, (m, k)).astype(np.int8)
    b = rng.integers(-30, 30, (k, n)).astype(np.int8)
    key = jax.random.PRNGKey(seed)
    u_gate = jax.random.bits(key, (m, n), jnp.uint32)
    u_bit = jax.random.bits(jax.random.fold_in(key, 1), (m, n), jnp.uint32)
    probs = np.zeros(32)
    probs[24:] = p_tail / 8.0
    return a, b, u_gate, u_bit, bit_probs_to_cdf(probs)


class TestAbftKernel:
    @pytest.mark.parametrize("shape", [(64, 96, 80), (200, 128, 130),
                                       (96, 72, 60)])
    def test_pallas_matches_ref_under_forced_injections(self, shape):
        a, b, ug, ub, cdf = _inputs(*shape, p_tail=0.05)
        c_k, rs_k, cs_k = jax.tree_util.tree_map(
            np.asarray, abft_matmul(a, b, ug, ub, cdf, interpret=True))
        c_r, rs_r, cs_r = jax.tree_util.tree_map(
            np.asarray, abft_matmul_ref(a, b, ug, ub, cdf))
        # forced flips actually happened, and both paths agree bit-exactly
        clean = a.astype(np.int64) @ b.astype(np.int64)
        assert np.count_nonzero(c_r.astype(np.int64) != clean) > 0
        np.testing.assert_array_equal(c_k, c_r)
        np.testing.assert_array_equal(rs_k, rs_r)
        np.testing.assert_array_equal(cs_k, cs_r)

    def test_fused_checksums_sum_the_corrupted_product(self):
        # the kernel checksums C' (post-injection), so syndromes against
        # the protected references see exactly the injected deltas
        a, b, ug, ub, cdf = _inputs(64, 96, 80, p_tail=0.05)
        c, rs, cs = jax.tree_util.tree_map(
            np.asarray, abft_matmul(a, b, ug, ub, cdf, interpret=True))
        np.testing.assert_array_equal(
            rs, c.sum(axis=1, dtype=np.int64).astype(np.int32))
        np.testing.assert_array_equal(
            cs, c.sum(axis=0, dtype=np.int64).astype(np.int32))

    def test_clean_checksums_equal_protected_references(self):
        a, b, ug, ub, _ = _inputs(64, 96, 80)
        cdf0 = bit_probs_to_cdf(np.zeros(32))
        c, rs, cs = abft_matmul(a, b, ug, ub, cdf0, interpret=True)
        row_ref, col_ref = checksum_refs(a, b)
        np.testing.assert_array_equal(np.asarray(rs), np.asarray(row_ref))
        np.testing.assert_array_equal(np.asarray(cs), np.asarray(col_ref))
        clean = a.astype(np.int64) @ b.astype(np.int64)
        np.testing.assert_array_equal(np.asarray(c), clean.astype(np.int32))


class TestDetectAndCorrect:
    def _clean(self, m=16, k=12, n=20, seed=2):
        rng = np.random.default_rng(seed)
        a = rng.integers(-4, 4, (m, k)).astype(np.int8)
        b = rng.integers(-4, 4, (k, n)).astype(np.int8)
        c = (a.astype(np.int32) @ b.astype(np.int32))
        return a, b, c

    @staticmethod
    def _sums(c):
        return (c.sum(axis=1, dtype=np.int64).astype(np.int32),
                c.sum(axis=0, dtype=np.int64).astype(np.int32))

    def test_single_flip_is_repaired_exactly(self):
        a, b, clean = self._clean()
        bad = clean.copy()
        bad[3, 5] += np.int32(1 << 20)  # one carry-tail flip
        rs, cs = self._sums(bad)
        row_ref, col_ref = checksum_refs(a, b)
        fixed, detected, corrected = detect_and_correct(
            bad, rs, cs, row_ref, col_ref)
        assert detected == 1 and corrected == 1
        np.testing.assert_array_equal(fixed, clean)

    def test_distinct_double_flips_both_repaired(self):
        a, b, clean = self._clean()
        bad = clean.copy()
        bad[1, 2] += np.int32(1 << 18)
        bad[7, 9] -= np.int32(1 << 22)  # distinct rows, cols AND deltas
        rs, cs = self._sums(bad)
        row_ref, col_ref = checksum_refs(a, b)
        fixed, detected, corrected = detect_and_correct(
            bad, rs, cs, row_ref, col_ref)
        assert detected == 2 and corrected == 2
        np.testing.assert_array_equal(fixed, clean)

    def test_aliased_flips_detected_but_escape(self):
        # two flips in one row: the row syndrome is their sum, neither
        # column syndrome matches it — detected, not uniquely localizable
        a, b, clean = self._clean()
        bad = clean.copy()
        bad[3, 5] += np.int32(1 << 20)
        bad[3, 9] += np.int32(1 << 20)
        rs, cs = self._sums(bad)
        row_ref, col_ref = checksum_refs(a, b)
        fixed, detected, corrected = detect_and_correct(
            bad, rs, cs, row_ref, col_ref)
        assert detected == 2
        assert corrected == 0  # no healthy cell was "repaired"
        assert np.count_nonzero(fixed != clean) == 2  # the escapes

    def test_ambiguous_syndrome_never_corrupts_a_healthy_cell(self):
        # same delta at (2,4) and (6,8): the syndrome match matrix pairs
        # rows {2,6} x cols {4,8} four ways — repair must decline
        a, b, clean = self._clean()
        bad = clean.copy()
        bad[2, 4] += np.int32(1 << 19)
        bad[6, 8] += np.int32(1 << 19)
        rs, cs = self._sums(bad)
        row_ref, col_ref = checksum_refs(a, b)
        fixed, detected, corrected = detect_and_correct(
            bad, rs, cs, row_ref, col_ref)
        assert detected == 2 and corrected == 0
        np.testing.assert_array_equal(fixed != clean, bad != clean)


class TestAbftMatmulWrapper:
    def test_sparse_flips_fully_repaired(self):
        # at realistic per-call flip counts (a couple of cells) the
        # syndromes localize every one — output error is quantization only
        probs = np.zeros(32)
        probs[20:] = 0.0008 / 12.0
        mm = AbftMatmul(probs, jax.random.PRNGKey(7), use_pallas=True)
        rng = np.random.default_rng(1)
        a = rng.standard_normal((48, 64)).astype(np.float32)
        b = rng.standard_normal((64, 40)).astype(np.float32)
        out = np.asarray(mm(a, b))
        c = mm.counters
        assert c.checked == 48 * 40
        assert c.injected >= 1
        assert c.corrected == c.injected
        assert c.escaped == 0
        rel = np.linalg.norm(out - a @ b) / np.linalg.norm(a @ b)
        assert rel < 0.05

    def test_heavy_flips_ledger_invariants(self):
        # pile on flips until rows/columns collide: repairs decline, the
        # residue is counted as escapes, and the ledger stays consistent
        probs = np.zeros(32)
        probs[26:] = 0.02 / 6.0
        mm = AbftMatmul(probs, jax.random.PRNGKey(7), use_pallas=True)
        rng = np.random.default_rng(1)
        a = rng.standard_normal((48, 64)).astype(np.float32)
        b = rng.standard_normal((64, 40)).astype(np.float32)
        mm(a, b)
        c = mm.counters
        assert c.injected > 5
        assert 0 < c.corrected < c.injected  # aliasing declined some
        assert c.detected <= c.injected  # cancellation can hide syndromes
        # corrections never touch healthy cells, so what remains wrong is
        # exactly the uncorrected injections
        assert c.escaped == c.injected - c.corrected
        assert 0.0 < c.escape_rate < c.injected / c.checked

    def test_zero_probs_is_plain_quantized_matmul(self):
        mm = AbftMatmul(np.zeros(32), jax.random.PRNGKey(0))
        rng = np.random.default_rng(4)
        a = rng.standard_normal((32, 48)).astype(np.float32)
        b = rng.standard_normal((48, 24)).astype(np.float32)
        out = np.asarray(mm(a, b))
        assert mm.counters.injected == 0
        assert mm.counters.escaped == 0
        rel = np.linalg.norm(out - a @ b) / np.linalg.norm(a @ b)
        assert rel < 0.02  # int8 quantization error only

    def test_routed_matmuls_installs_and_restores_the_hook(self):
        from repro.models import layers
        calls = []

        def spy(a, b):
            calls.append((a.shape, b.shape))
            return a @ b

        assert layers.MATMUL is None
        x = jnp.ones((2, 3, 4), jnp.bfloat16)
        w = jnp.ones((4, 5), jnp.bfloat16)
        with routed_matmuls(spy):
            y = layers.matmul(x, w)
        assert layers.MATMUL is None  # restored
        assert calls == [((6, 4), (4, 5))]  # 2D-flattened f32 routing
        assert y.shape == (2, 3, 5) and y.dtype == jnp.bfloat16

    def test_topk_agreement_bounds(self):
        logits = np.asarray(np.random.default_rng(0)
                            .standard_normal((4, 7, 50)), np.float32)
        assert topk_agreement(logits, logits, k=1) == 1.0
        assert topk_agreement(logits, logits, k=4) == 1.0
        shuffled = logits[..., ::-1].copy()
        assert topk_agreement(logits, shuffled, k=1) < 1.0


# ===========================================================================
# the ErrorTolerant policy
# ===========================================================================


class TestErrorTolerantPolicy:
    def test_from_spec(self):
        p = pol.from_spec("error_tolerant:1e-5")
        assert isinstance(p, pol.ErrorTolerant)
        assert p.budget == pytest.approx(1e-5)
        assert pol.from_spec("error_tolerant").budget == 0.0
        with pytest.raises(ValueError):
            pol.from_spec("error_tolerant:lots")

    def test_budget_zero_is_powersave_bitwise(self, rt_ps, profile):
        rt0 = RT.EnergyAwareRuntime(profile, policy="error_tolerant")
        ps, _ = rt_ps.planner.plan_at(28.0, None)
        et, _ = rt0.planner.plan_at(28.0, None)
        np.testing.assert_array_equal(et.v_core, ps.v_core)
        np.testing.assert_array_equal(et.v_sram, ps.v_sram)
        assert et.pod_power_w == pytest.approx(ps.pod_power_w)

    def test_budget_buys_power_below_the_guard_band(self, rt_ps, rt_et):
        ps, _ = rt_ps.planner.plan_at(28.0, None)
        et, T = rt_et.planner.plan_at(28.0, None)
        assert et.saving > ps.saving + 0.02  # strictly beyond PowerSave
        assert float(np.median(et.v_core)) < float(np.median(ps.v_core))
        # ... and the model the injector runs agrees the rails fit the
        # budget: predicted escaped rate at the solved (rails, T) field
        m = TimingFaultModel(rt_et.lib)
        pred = m.escaped_rate(et.v_core, et.v_sram,
                              np.asarray(T).reshape(-1))
        assert float(np.max(pred)) <= BUDGET * 1.05

    def test_runtime_spec_round_trip(self, rt_et):
        assert isinstance(rt_et.policy_obj, pol.ErrorTolerant)
        assert rt_et.policy_obj.budget == pytest.approx(BUDGET)
        assert rt_et.policy == "error_tolerant"  # the reported spec name


# ===========================================================================
# the closed loop: back-off hysteresis, sdc_storm, restore
# ===========================================================================


def _sdc_snap(t_amb=28.0, escaped=0, checked=10**9, **kw):
    return Snapshot(t_amb=t_amb, sdc_escaped=escaped,
                    sdc_detected=escaped, sdc_corrected=0,
                    sdc_checked=checked, **kw)


def _rails(actions):
    (s,) = [a for a in actions if isinstance(a, SetRails)]
    return np.asarray(s.v_core, np.float32)


class TestBackoffHysteresis:
    def test_retreat_and_redescend(self, rt_et, field_et):
        c = rt_et.controller(field=field_et, sdc_budget=BUDGET,
                             sdc_hysteresis=2)
        c.reset()
        vc0 = _rails(c.decide(_sdc_snap()))  # clean cold start
        hot = c.decide(_sdc_snap(escaped=30_000))  # 3e-5 > budget
        assert any(isinstance(a, RailBackoff) for a in hot)
        vc1 = _rails(hot)
        np.testing.assert_allclose(
            vc1, np.minimum(vc0 + 0.010, TF.V_CORE_NOM), atol=1e-6)
        # a second over-budget tick deepens the retreat
        vc2 = _rails(c.decide(_sdc_snap(escaped=30_000)))
        np.testing.assert_allclose(
            vc2, np.minimum(vc0 + 0.020, TF.V_CORE_NOM), atol=1e-6)
        # clean ticks: hold, hold ... then one step back down per window
        vc3 = _rails(c.decide(_sdc_snap()))
        np.testing.assert_allclose(vc3, vc2, atol=1e-6)
        vc4 = _rails(c.decide(_sdc_snap()))  # 2nd clean: backoff 2 -> 1
        np.testing.assert_allclose(vc4, vc1, atol=1e-6)
        c.decide(_sdc_snap())
        vc6 = _rails(c.decide(_sdc_snap()))  # 4th clean: backoff 1 -> 0
        np.testing.assert_allclose(vc6, vc0, atol=1e-6)
        assert c.stats.backoffs == 2

    def test_disabled_by_default(self, rt_et, field_et):
        c = rt_et.controller(field=field_et)
        c.reset()
        vc0 = _rails(c.decide(_sdc_snap()))
        acts = c.decide(_sdc_snap(escaped=10**6))
        assert not any(isinstance(a, RailBackoff) for a in acts)
        np.testing.assert_allclose(_rails(acts), vc0, atol=1e-6)

    def test_reset_clears_the_retreat(self, rt_et, field_et):
        c = rt_et.controller(field=field_et, sdc_budget=BUDGET)
        c.reset()
        c.decide(_sdc_snap())
        c.decide(_sdc_snap(escaped=10**5))
        assert c._backoff == 1
        c.reset()
        assert c._backoff == 0 and c._sdc_clean == 0


class TestSdcStorm:
    @pytest.fixture(scope="class")
    def storm(self, rt_ps, rt_et, field_ps, field_et):
        scn = SC.sdc_storm()
        r_ps = SC.replay(scn, runtime=rt_ps,
                         controller=rt_ps.controller(field=field_ps,
                                                     guard_band_c=3.0))
        inj = FaultInjector(TimingFaultModel(rt_et.lib), seed=7)
        c_et = rt_et.controller(field=field_et, guard_band_c=3.0,
                                sdc_budget=BUDGET)
        r_et = SC.replay(scn, runtime=rt_et, controller=c_et, injector=inj)
        return r_ps, r_et

    def test_saves_beyond_powersave_at_declared_budget(self, storm):
        r_ps, r_et = storm
        assert r_et.mean_saving > r_ps.mean_saving  # strictly greater
        assert r_et.energy_j < r_ps.energy_j
        assert r_et.t_max < TF.T_MAX_CHIP

    def test_escape_rate_lands_inside_the_budget(self, storm):
        _, r_et = storm
        assert r_et.sdc_checked > 0
        assert r_et.sdc_injected > 0  # the storm was real
        assert r_et.escape_rate <= BUDGET
        assert r_et.sdc_detected == r_et.sdc_corrected
        assert (r_et.sdc_detected + r_et.sdc_escaped == r_et.sdc_injected)

    def test_spike_forces_observable_backoff(self, storm):
        _, r_et = storm
        assert r_et.backoffs >= 1
        # the retreat shows in the rail trace: spike-era rails sit above
        # the quiet-era rails on at least one tick
        quiet = r_et.rails[10, 0]
        spike = r_et.rails[22, 0]
        assert float(np.min(spike - quiet)) >= 0.0
        assert float(np.max(spike - quiet)) > 0.005

    def test_powersave_day_stays_error_free(self, rt_ps, field_ps):
        # at-or-above guard band rails inject nothing, storm or not
        inj = FaultInjector(TimingFaultModel(rt_ps.lib), seed=7)
        r = SC.replay(SC.sdc_storm(ticks=8), runtime=rt_ps,
                      controller=rt_ps.controller(field=field_ps,
                                                  guard_band_c=3.0),
                      injector=inj)
        assert r.sdc_injected == 0
        assert r.escape_rate == 0.0

    def test_deterministic_replay(self, rt_et, field_et, storm):
        _, r_et = storm
        inj = FaultInjector(TimingFaultModel(rt_et.lib), seed=7)
        c = rt_et.controller(field=field_et, guard_band_c=3.0,
                             sdc_budget=BUDGET)
        again = SC.replay(SC.sdc_storm(), runtime=rt_et, controller=c,
                          injector=inj)
        assert again.fingerprint == r_et.fingerprint
        assert again.sdc_escaped == r_et.sdc_escaped
        assert again.backoffs == r_et.backoffs


class TestRestore:
    def test_cool_down_hysteresis_then_restore(self, rt_ps, field_ps):
        chips = rt_ps.substrate.n_domains
        c = rt_ps.controller(field=field_ps, restore_after=2,
                             restore_below_c=70.0)
        c.reset()
        shares = np.ones(chips, np.float32)
        shares[0] = 0.0
        cool = np.full(chips, 55.0, np.float32)
        hot = cool.copy()
        hot[0] = 80.0
        s = dict(t_amb=28.0, shares=shares)
        assert not any(isinstance(a, Restore)
                       for a in c.decide(Snapshot(t_chip=cool, **s)))
        # a hot tick resets the cool-down counter
        assert not any(isinstance(a, Restore)
                       for a in c.decide(Snapshot(t_chip=hot, **s)))
        assert not any(isinstance(a, Restore)
                       for a in c.decide(Snapshot(t_chip=cool, **s)))
        acts = c.decide(Snapshot(t_chip=cool, **s))
        assert any(isinstance(a, Restore) and a.chip == 0 for a in acts)
        assert c.stats.restores == 1

    def test_disabled_by_default(self, rt_ps, field_ps):
        chips = rt_ps.substrate.n_domains
        c = rt_ps.controller(field=field_ps)
        c.reset()
        shares = np.ones(chips, np.float32)
        shares[0] = 0.0
        cool = np.full(chips, 50.0, np.float32)
        for _ in range(5):
            acts = c.decide(Snapshot(t_amb=28.0, shares=shares,
                                     t_chip=cool))
            assert not any(isinstance(a, Restore) for a in acts)

    def test_storm_restore_migrates_work_back(self, rt_ps, field_ps):
        # the straggler storm condemns the hot chip; with restore enabled
        # the loop re-admits it once the TSD reads it cool again
        scn = SC.straggler_storm(ticks=24, storm_at=8)
        c = rt_ps.controller(field=field_ps, guard_band_c=3.0,
                             restore_after=3, restore_below_c=70.0)
        r = SC.replay(scn, runtime=rt_ps, controller=c)
        assert r.rebalances >= 1
        assert r.restores >= 1
        # after the restore the chip carries work again (it may be
        # re-condemned by the still-running storm; either way the restore
        # actually moved shares through the elastic assignment)
        assert r.restores <= r.rebalances


class TestUnrolledStack:
    """scan_layers=False unrolls the block stack into a python loop (the
    host-side ABFT routing can't execute under a lax.scan trace) — the two
    paths must agree bitwise for every stacked family."""

    @pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-780m",
                                      "mixtral-8x7b"])
    def test_loop_matches_scan(self, arch):
        from repro.configs import registry
        from repro.models.model import Model

        cfg = registry.get(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        tokens = (np.arange(2 * 16, dtype=np.int32).reshape(2, 16)
                  % cfg.vocab_size)
        logits_scan, aux_scan = Model(cfg.replace(scan_layers=True)).apply(
            params, {"tokens": tokens})
        logits_loop, aux_loop = Model(cfg.replace(scan_layers=False)).apply(
            params, {"tokens": tokens})
        # same function, different reduction order: only a few ulps of
        # bf16 output rounding are allowed between the two paths — except
        # moe, where near-tied router probs make top-k expert selection
        # chaotically sensitive to that rounding
        if not cfg.is_moe:
            np.testing.assert_allclose(np.asarray(logits_scan, np.float32),
                                       np.asarray(logits_loop, np.float32),
                                       rtol=0.0, atol=0.06)
            assert topk_agreement(np.asarray(logits_loop, np.float32),
                                  np.asarray(logits_scan, np.float32),
                                  k=1) > 0.95
        assert np.asarray(logits_loop).shape == np.asarray(logits_scan).shape
        assert np.all(np.isfinite(np.asarray(logits_loop, np.float32)))
        for k in aux_scan:
            # moe aux is routing-sensitive at random init; same order of
            # magnitude is the strongest portable claim
            assert np.isfinite(float(aux_loop[k]))
            if not cfg.is_moe:
                np.testing.assert_allclose(float(aux_scan[k]),
                                           float(aux_loop[k]),
                                           rtol=0.05, atol=1e-4)

    def test_routed_abft_under_unrolled_stack(self):
        # the motivating composition: clean-profile ABFT matmuls routed
        # through the unrolled model reproduce the plain forward logits
        from repro.configs import registry
        from repro.models.model import Model

        cfg = registry.get("llama3.2-1b").reduced().replace(
            scan_layers=False)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1))
        tokens = (np.arange(2 * 12, dtype=np.int32).reshape(2, 12)
                  % cfg.vocab_size)
        ref = np.asarray(model.apply(params, {"tokens": tokens})[0])
        mm = AbftMatmul(np.zeros(32), jax.random.PRNGKey(3),
                        use_pallas=False)
        with routed_matmuls(mm):
            out = np.asarray(model.apply(params, {"tokens": tokens})[0])
        assert mm.counters.checked > 0
        assert mm.counters.injected == 0
        assert mm.counters.escaped == 0
        assert topk_agreement(out, ref, k=1) > 0.9
