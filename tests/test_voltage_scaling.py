"""Algorithm 1 + Algorithm 2 + over-scaling against the paper's claims."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (characterization as C, energy_opt as EO,
                        netlist as NL, overscaling as OS, thermal,
                        voltage_scaling as VS, vtr_benchmarks as vb)

TC12 = thermal.ThermalConfig(theta_ja=12.0)
TC2 = thermal.ThermalConfig(theta_ja=2.0)


@pytest.fixture(scope="module")
def mkdelay():
    return vb.load("mkDelayWorker32B")


@pytest.fixture(scope="module")
def case_study(mkdelay):
    return VS.run(mkdelay, 60.0, 1.0, TC12)


class TestTableII:
    """mkDelayWorker @ Tamb=60C, theta=12: paper's exact iteration trace."""

    def test_frequency_calibration(self, case_study):
        assert 1000.0 / case_study.d_worst_ns == pytest.approx(71.6, rel=0.01)

    def test_converges_within_6_iters(self, case_study):
        assert case_study.converged
        assert len(case_study.trace) <= 6  # paper: <6 for all benchmarks

    def test_iteration_trace(self, case_study):
        t1, tN = case_study.trace[0], case_study.trace[-1]
        # paper iter 1: (0.74, 0.92) 485 mW, Tj 65.82
        assert t1.v_core == pytest.approx(0.74, abs=0.015)
        assert t1.power_mw == pytest.approx(485, rel=0.10)
        assert t1.t_junct == pytest.approx(65.82, abs=1.0)
        # paper converged: (0.75, 0.91) 564 mW, Tj 66.77
        assert tN.v_core == pytest.approx(0.75, abs=0.015)
        assert tN.power_mw == pytest.approx(564, rel=0.10)
        assert tN.t_junct == pytest.approx(66.77, abs=1.0)
        # V_bram is the one soft spot of the repro (0.83 vs paper 0.91:
        # our analytic BRAM delay fit is slightly shallower than HSPICE)
        assert tN.v_bram == pytest.approx(0.91, abs=0.10)

    def test_power_rises_with_thermal_feedback(self, case_study):
        # heating tightens the margin: converged power > first-iteration power
        assert case_study.trace[-1].power_mw > case_study.trace[0].power_mw

    def test_timing_met_at_convergence(self, mkdelay, case_study):
        lib = C.default_library()
        nlj = mkdelay.as_jax()
        T = jnp.full((mkdelay.n_tiles,), case_study.t_junct_mean)
        d = float(NL.crit_delay(lib, nlj, T, case_study.v_core,
                                case_study.v_bram))
        assert d <= case_study.d_worst_ns * (1 + 1e-4)


class TestFig6:
    """Average power savings inside the paper's reported bands."""

    @pytest.mark.slow
    def test_average_savings(self):
        fast = ["mkPktMerge", "or1200", "boundtop", "raygentop",
                "blob_merge"]
        s40 = [VS.run(vb.load(n), 40.0, 1.0, TC12).saving for n in fast]
        s65 = [VS.run(vb.load(n), 65.0, 1.0, TC2).saving for n in fast]
        assert 0.24 <= float(np.mean(s40)) <= 0.42  # paper 28.3-36.0 full set
        assert 0.16 <= float(np.mean(s65)) <= 0.33  # paper 20.0-25.0 full set
        # lower temperature => more margin => more saving, benchmark-wise
        assert float(np.mean(s40)) > float(np.mean(s65))

    def test_bram_floor_for_short_memory_paths(self):
        # LU8PEEng: CP is 21x the BRAM path -> V_bram dives to the 0.55 floor
        r = VS.run(vb.load("LU8PEEng"), 65.0, 1.0, TC2)
        assert r.v_bram == pytest.approx(0.55, abs=0.011)


class TestDynamicScheme:
    def test_lut_voltages_rise_with_ambient(self):
        nl = vb.load("mkPktMerge")
        lut = VS.dynamic_lut(nl, [10.0, 40.0, 70.0], tc=TC2)
        vcs = [lut[t][0] for t in (10.0, 40.0, 70.0)]
        assert vcs == sorted(vcs)
        assert vcs[-1] <= C.V_CORE_NOM + 1e-6


class TestAlgorithm2:
    @pytest.fixture(scope="class")
    def result(self):
        return EO.run(vb.load("mkPktMerge"), 65.0, 1.0, TC2)

    def test_energy_saving_band(self, result):
        assert 0.40 <= result.saving <= 0.75  # paper: 44-66%

    def test_delay_stretched(self, result):
        # energy optimum trades delay (paper: ~2.7x mean stretch)
        assert result.d_opt_ns > 1.3 * result.d_worst_ns

    def test_search_sound(self):
        """The batched solver subsumes the paper's pruning: use_pruning is
        a no-op, and the chosen pair must be energy-optimal over the WHOLE
        grid evaluated at the converged temperature field."""
        nl = vb.load("or1200")
        full = EO.run(nl, 65.0, 1.0, TC2, use_pruning=False)
        fast = EO.run(nl, 65.0, 1.0, TC2, use_pruning=True)
        assert fast.energy == full.energy  # identical path by construction
        assert fast.n_refined <= 8  # fixed-point iterations, not pairs

        from repro import policy as pol
        sub = pol.fpga_substrate(nl, tc=TC2)
        sol = pol.cached_solver(sub, pol.MinEnergy(), 0.1, 8).solve(
            {"t_amb": 65.0, "act": 1.0})
        env = {"t_amb": jnp.float32(65.0), "act": jnp.float32(1.0)}
        me = pol.MinEnergy()
        T = jnp.asarray(sol.T)
        d = sub.cand_delay(T, env)
        f = me.frequency(sub, d, env)
        e = sub.cand_power(T, f, env) * sub.exec_time(f)
        e_chosen = float(e[0, int(sol.idx[0])])
        assert e_chosen <= float(jnp.min(e)) * (1 + 1e-3)

    def test_beats_power_flow_on_energy(self, result):
        r1 = VS.run(vb.load("mkPktMerge"), 65.0, 1.0, TC2)
        e1 = r1.power_mw * r1.d_worst_ns
        assert result.energy < e1


class TestOverscaling:
    @pytest.fixture(scope="class")
    def sweep(self):
        nl = NL.generate(vb.BY_NAME["raygentop"])
        return OS.sweep(nl, [1.0, 1.2, 1.4], t_amb=40.0, tc=TC12)

    def test_no_violations_at_gamma_1(self, sweep):
        assert sweep[0].frac_violating == 0.0
        assert sweep[0].bit_probs.sum() == 0.0

    def test_saving_monotone_in_gamma(self, sweep):
        savs = [r.saving for r in sweep]
        assert savs == sorted(savs)

    def test_errors_grow_with_gamma(self, sweep):
        bps = [r.bit_probs.sum() for r in sweep]
        assert bps[0] <= bps[1] <= bps[2]
        assert bps[2] > 0

    def test_msb_weighted(self, sweep):
        bp = sweep[2].bit_probs
        assert bp[:16].sum() == 0.0  # only the carry tail is corrupted
        assert bp[31] >= bp[20]
