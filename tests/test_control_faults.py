"""§9 fault containment (repro.control.faults + the hardened loop).

The design contract pinned here: the fault model is seeded/deterministic
and rate-0 is bitwise identity; the bus quarantines implausible/stale
samples and carries last-good forward with growing age; the controller
answers stale ticks at last-good + guard band, survives solver divergence
and missed deadlines through the watchdog ladder (fast-path-only ->
frozen rails -> hysteresis recovery); the rail-write channel retries with
backoff and pins exhausted chips to nominal safe-state rails which the
planner then rebalances around; and ``scenarios.chaos_day`` replays the
whole escalation fingerprint-pinned without ever exceeding the junction
limit."""
import dataclasses

import numpy as np
import pytest

from repro import scenarios as sc
from repro import control as ctl
from repro.control import LutController, Rebalance, SetRails
from repro.control.telemetry import (AmbientSample, SafeStateSample,
                                     Snapshot, TelemetryBus)
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.control.lut import sweep_points

T_KNOTS = sweep_points(10.0, 45.0, 4)
U_KNOTS = sweep_points(0.25, 1.0, 4)


@pytest.fixture(scope="module")
def runtime():
    prof = TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)
    return RT.EnergyAwareRuntime(prof, policy="power_save")


@pytest.fixture(scope="module")
def field(runtime):
    return runtime.build_field(T_KNOTS, U_KNOTS)


def _ctl(runtime, field, **kw):
    kw.setdefault("guard_band_c", 3.0)
    return LutController(runtime.planner, field=field, **kw)


def _rails(actions):
    rails = [a for a in actions if isinstance(a, SetRails)]
    assert len(rails) == 1
    return rails[0]


# ---------------------------------------------------------------------------
# the fault model itself
# ---------------------------------------------------------------------------


class TestFaultModel:
    def test_rate_zero_is_identity(self):
        fm = ctl.ControlFaultModel(rate=0.0)
        for t in range(24):
            assert fm.sensor_fault(float(t)) is None
            assert not fm.nack(8, float(t), 0).any()
            assert not fm.deadline_miss(float(t))
            assert not fm.solver_fault(float(t))

    def test_seeded_and_reset_replays_identically(self):
        fm = ctl.ControlFaultModel(rate=0.8, seed=3)
        sensors = [fm.sensor_fault(float(t)) for t in range(64)]
        nacks = [fm.nack(16, float(t), 0).tolist() for t in range(16)]
        fm.reset()
        assert [fm.sensor_fault(float(t)) for t in range(64)] == sensors
        assert [fm.nack(16, float(t), 0).tolist()
                for t in range(16)] == nacks
        assert any(s is not None for s in sensors)  # faults actually drawn
        assert any(any(m) for m in nacks)
        other = ctl.ControlFaultModel(rate=0.8, seed=4)
        assert [other.sensor_fault(float(t)) for t in range(64)] != sensors

    def test_window_gates_without_shifting_the_stream(self):
        """Draws happen every call (stream alignment), but outside the
        window the channel is clean — so a windowed model agrees with the
        unwindowed one *inside* the window, draw for draw."""
        win = ctl.ControlFaultModel(rate=1.0, seed=0, sensor_window=(5, 10))
        full = ctl.ControlFaultModel(rate=1.0, seed=0)
        got = [win.sensor_fault(float(t)) for t in range(15)]
        ref = [full.sensor_fault(float(t)) for t in range(15)]
        assert [g is not None for g in got] == [5 <= t < 10
                                               for t in range(15)]
        assert got[5:10] == ref[5:10]

    def test_scripted_watchdog_ticks(self):
        fm = ctl.ControlFaultModel(deadline_misses=(3,), solver_faults=(7,))
        assert fm.deadline_miss(3.0) and fm.deadline_miss(3.4)
        assert not fm.deadline_miss(4.0)
        assert fm.solver_fault(7.0) and not fm.solver_fault(3.0)


# ---------------------------------------------------------------------------
# sensor-side corruption + bus quarantine
# ---------------------------------------------------------------------------


def _one_class(cls, **kw):
    """A model where every in-window draw lands on exactly one class."""
    p = {c: 0.0 for c in ("dropout", "spike", "stale", "stuck")}
    p[cls] = 1.0
    return ctl.ControlFaultModel(seed=0, **p, **kw)


class TestChaosTelemetry:
    def _src(self):
        return ctl.AmbientSensor(lambda now: 20.0 + now)

    def test_dropout_loses_the_sample(self):
        wrap = ctl.ChaosTelemetry(self._src(), _one_class("dropout"))
        assert wrap.poll(0.0) == []

    def test_spike_offsets_by_spike_c(self):
        wrap = ctl.ChaosTelemetry(self._src(), _one_class("spike"))
        (smp,) = wrap.poll(0.0)
        assert smp.t_amb == pytest.approx(20.0 + 500.0)

    def test_stale_replays_the_old_sample_with_its_old_stamp(self):
        wrap = ctl.ChaosTelemetry(self._src(), _one_class("stale"))
        (first,) = wrap.poll(0.0)  # nothing to repeat yet: passes clean
        assert first.t_amb == 20.0 and first.stamp is None
        (rep,) = wrap.poll(1.0)
        assert rep.t_amb == 20.0  # yesterday's value...
        assert rep.stamp == 0.0   # ...with yesterday's stamp (age catches it)

    def test_stuck_freezes_the_value_with_fresh_stamps(self):
        fm = _one_class("stuck", sensor_window=(0, 1), stuck_ticks=3)
        wrap = ctl.ChaosTelemetry(self._src(), fm)
        vals = [wrap.poll(float(t))[0] for t in range(4)]
        # frozen at the tick-0 reading for stuck_ticks polls, fresh stamps
        assert [s.t_amb for s in vals] == [20.0, 20.0, 20.0, 23.0]
        assert all(s.stamp is None for s in vals)  # undetectable by the bus

    def test_rate_zero_wrapper_is_bitwise_identity(self):
        src = self._src()
        wrap = ctl.ChaosTelemetry(src, ctl.ControlFaultModel(rate=0.0))
        for t in range(8):
            assert wrap.poll(float(t)) == src.poll(float(t))


class _Script:
    """A source replaying a fixed per-tick sample script."""

    def __init__(self, rows):
        self.rows = rows

    def poll(self, now):
        return self.rows[int(now)]


class TestBusQuarantine:
    def test_implausible_sample_is_quarantined_last_good_carries(self):
        bus = TelemetryBus([_Script([
            [AmbientSample(25.0)],
            [AmbientSample(525.0)],   # spike: outside T_AMB_VALID
            [],                       # dropout
            [AmbientSample(24.0)],
        ])], max_age=0.75)
        s0 = bus.poll(0.0)
        assert (s0.t_amb, s0.t_amb_age, s0.quarantined) == (25.0, 0.0, 0)
        s1 = bus.poll(1.0)
        assert s1.quarantined == 1
        assert s1.t_amb == 25.0 and s1.t_amb_age == 1.0  # last-good ages
        s2 = bus.poll(2.0)
        assert s2.quarantined == 0 and s2.t_amb_age == 2.0
        s3 = bus.poll(3.0)
        assert (s3.t_amb, s3.t_amb_age) == (24.0, 0.0)
        assert bus.quarantined_total == 1

    def test_stale_stamp_is_quarantined_by_age(self):
        bus = TelemetryBus([_Script([
            [AmbientSample(25.0, stamp=0.0)],
            [AmbientSample(25.0, stamp=0.0)],  # replayed: 1 tick old
        ])], max_age=0.75)
        assert bus.poll(0.0).t_amb == 25.0
        s1 = bus.poll(1.0)
        assert s1.quarantined == 1 and s1.t_amb_age == 1.0

    def test_age_is_infinite_before_any_accepted_reading(self):
        bus = TelemetryBus([_Script([[AmbientSample(525.0)]])],
                           max_age=0.75)
        s = bus.poll(0.0)
        assert s.t_amb is None and np.isinf(s.t_amb_age)

    def test_safe_state_sample_folds(self):
        bus = TelemetryBus([_Script([
            [AmbientSample(25.0), SafeStateSample(frozenset({3, 7}))],
        ])])
        assert bus.poll(0.0).safe_state == frozenset({3, 7})


# ---------------------------------------------------------------------------
# controller: stale fallback + watchdog ladder
# ---------------------------------------------------------------------------


class TestStaleFallback:
    def test_stale_ambient_answers_at_guard_banded_last_good(self, runtime,
                                                             field):
        c = _ctl(runtime, field, stale_after=2.0)
        acts = c.decide(Snapshot(now=0.0, t_amb=25.0, t_amb_age=5.0))
        vc, vs = field.lookup(25.0 + c.guard_band_c)  # conservatively hot
        assert np.allclose(_rails(acts).v_core, vc)
        assert np.allclose(_rails(acts).v_sram, vs)
        assert c.stats.stale_fallbacks == 1
        assert c.stats.replans == 0  # a stale value never reaches the solver
        fresh_vc, _ = field.lookup(25.0)
        assert np.median(_rails(acts).v_core) >= np.median(fresh_vc)

    def test_thermal_emergency_outranks_staleness(self, runtime, field):
        c = _ctl(runtime, field, stale_after=2.0)
        hot = np.full(field.chips, TF.T_MAX_CHIP - 1.0)
        c.decide(Snapshot(now=0.0, t_amb=25.0, t_amb_age=5.0, t_chip=hot))
        assert c.stats.replans == 1
        assert c.stats.replan_reasons[-1].startswith("thermal_emergency")


class TestWatchdogLadder:
    def test_trip_degrade_freeze_and_hysteresis_recovery(self, runtime,
                                                         field):
        c = _ctl(runtime, field, watchdog_hysteresis=2)
        r0 = _rails(c.decide(Snapshot(now=0.0, t_amb=25.0)))
        assert r0.source == "solver"  # cold start replans

        c.note_deadline_miss()
        r1 = _rails(c.decide(Snapshot(now=1.0, t_amb=25.0)))
        assert r1.source == "lut"  # level 1: fast path only
        assert c._degrade == 1 and c.stats.degraded_ticks == 1

        c.note_deadline_miss()
        r2 = _rails(c.decide(Snapshot(now=2.0, t_amb=31.0)))
        assert r2.source == "frozen"  # level 2: ambient moved, rails do not
        assert np.array_equal(r2.v_core, r1.v_core)
        assert np.array_equal(r2.v_sram, r1.v_sram)
        assert c.stats.frozen_ticks == 1

        # two clean ticks per de-escalation step; full recovery at tick 6
        for t in (3, 4, 5):
            c.decide(Snapshot(now=float(t), t_amb=25.0))
        assert c._degrade == 1
        r6 = _rails(c.decide(Snapshot(now=6.0, t_amb=25.0)))
        assert c._degrade == 0 and r6.source in ("lut", "solver")
        assert c.stats.recover_ticks == [5.0]  # tripped at 1, clean at 6
        assert c.stats.watchdog_events == ["deadline_miss@1",
                                           "deadline_miss@2"]

    def test_scripted_solver_divergence_answers_from_the_fast_path(
            self, runtime, field):
        fm = ctl.ControlFaultModel(solver_faults=(0,))
        c = _ctl(runtime, field, faults=fm)
        acts = c.decide(Snapshot(now=0.0, t_amb=25.0))  # cold start replan…
        assert _rails(acts).source == "lut"  # …diverges -> fast path
        assert c.stats.replans == 0
        assert c.stats.watchdog_events == ["solver_divergence@0"]
        assert c._degrade == 1

    def test_loop_deadline_miss_feeds_the_watchdog(self, runtime, field):
        c = _ctl(runtime, field)
        fleet = ctl.FleetActuator.from_runtime(runtime, t_amb=25.0,
                                               field=field)
        bus = TelemetryBus([ctl.AmbientSensor(lambda now: 25.0), fleet])
        loop = ctl.ControlLoop(bus, c, [fleet], tick_deadline_s=0.0)
        loop.step()
        loop.step()  # the miss noted on tick 0 trips on tick 1
        assert loop.deadline_misses == 2
        assert c._degrade >= 1
        assert any(e.startswith("deadline_miss")
                   for e in c.stats.watchdog_events)

    def test_safe_state_chips_are_rebalanced_once(self, runtime, field):
        c = _ctl(runtime, field)
        snap = Snapshot(now=0.0, t_amb=25.0, safe_state=frozenset({2, 5}))
        acts = c.decide(snap)
        reb = [a for a in acts if isinstance(a, Rebalance)
               and a.reason == "safe_state_rails"]
        assert sorted(r.chip for r in reb) == [2, 5]
        assert c.stats.safe_states == 2
        again = c.decide(Snapshot(now=1.0, t_amb=25.0,
                                  safe_state=frozenset({2, 5})))
        assert not any(isinstance(a, Rebalance) for a in again)


# ---------------------------------------------------------------------------
# actuator: verify-after-write retry -> safe state
# ---------------------------------------------------------------------------


class TestRailWriteChannel:
    def _fleet(self, runtime, field, fm):
        fleet = ctl.FleetActuator.from_runtime(runtime, t_amb=25.0,
                                               field=field)
        fleet.write_faults = fm
        return fleet

    def _set(self, field):
        vc, vs = field.lookup(25.0)
        return SetRails(np.asarray(vc, np.float32),
                        np.asarray(vs, np.float32), source="lut")

    def test_total_nack_exhausts_retries_and_pins_safe_state(self, runtime,
                                                             field):
        fleet = self._fleet(runtime, field, ctl.ControlFaultModel(nack=1.0))
        fleet.begin_tick(0.0)
        fleet.apply(self._set(field))
        chips = fleet.v_core.shape[0]
        assert fleet.safe_state == set(range(chips))
        assert np.all(fleet.v_core == np.float32(TF.V_CORE_NOM))
        assert np.all(fleet.v_sram == np.float32(TF.V_SRAM_NOM))
        assert fleet.write_retries == chips * fleet.max_retries
        assert fleet.backoff_wait_us > 0
        assert len(fleet.safe_log) == chips
        smp = [s for s in fleet.poll(0.0)
               if isinstance(s, SafeStateSample)]
        assert len(smp) == 1 and smp[0].chips == frozenset(range(chips))

    def test_partial_nack_retries_then_lands_the_write(self, runtime,
                                                       field):
        fm = ctl.ControlFaultModel(nack=0.4, seed=1)
        fleet = self._fleet(runtime, field, fm)
        fleet.begin_tick(0.0)
        act = self._set(field)
        fleet.apply(act)
        # p^4 ~ 2.6% per chip: with 256 chips some retries happen, and at
        # most a handful of chips exhaust into safe state
        assert fleet.write_retries > 0
        ok = [c for c in range(fleet.v_core.shape[0])
              if c not in fleet.safe_state]
        assert len(ok) > fleet.v_core.shape[0] * 0.9
        assert np.allclose(fleet.v_core[ok], np.asarray(act.v_core)[ok])

    def test_safe_state_ignores_writes_until_cleared(self, runtime, field):
        fleet = self._fleet(
            runtime, field,
            ctl.ControlFaultModel(nack=1.0, nack_window=(0, 1)))
        fleet.begin_tick(0.0)
        act = self._set(field)
        fleet.apply(act)  # in-window: everything pins
        fleet.clear_safe_state(0)
        fleet.begin_tick(5.0)  # outside the window: writes succeed
        fleet.apply(act)
        assert fleet.v_core[0] == np.float32(np.asarray(act.v_core)[0])
        assert np.all(fleet.v_core[1:] == np.float32(TF.V_CORE_NOM))

    def test_rate_zero_write_channel_is_identity(self, runtime, field):
        clean = ctl.FleetActuator.from_runtime(runtime, t_amb=25.0,
                                               field=field)
        fleet = self._fleet(runtime, field, ctl.ControlFaultModel(rate=0.0))
        fleet.begin_tick(0.0)
        act = self._set(field)
        clean.apply(act)
        fleet.apply(act)
        assert np.array_equal(fleet.v_core, clean.v_core)
        assert np.array_equal(fleet.v_sram, clean.v_sram)
        assert fleet.write_nacks == 0 and not fleet.safe_state


# ---------------------------------------------------------------------------
# the §9 acceptance day
# ---------------------------------------------------------------------------


class TestChaosDay:
    @pytest.fixture(scope="class")
    def day(self):
        return sc.chaos_day()  # the tuned 48-tick acceptance day

    @pytest.fixture(scope="class")
    def rep(self, runtime, field, day):
        return sc.replay(day, runtime=runtime,
                         controller=_ctl(runtime, field))

    def test_fingerprint_pinned_and_never_over_limit(self, runtime, field,
                                                     day, rep):
        again = sc.replay(day, runtime=runtime,
                          controller=_ctl(runtime, field))
        assert again.fingerprint == rep.fingerprint
        assert rep.t_max < TF.T_MAX_CHIP  # contained, faults and all

    def test_every_containment_layer_actually_fired(self, rep):
        assert rep.quarantined > 0        # bus validity/freshness
        assert rep.stale_fallbacks > 0    # guard-banded last-good
        assert rep.frozen_ticks > 0       # watchdog level 2 reached
        assert rep.degraded_ticks > rep.frozen_ticks
        assert rep.safe_states > 0        # NACK burst pinned chips
        assert rep.write_nacks > 0 and rep.write_retries > 0
        assert rep.below_axis_clamps > 0  # the load dip under u_min
        assert rep.recover_ticks          # ladder climbed back down
        assert rep.mean_ticks_to_recover > 0

    def test_rate_zero_model_changes_nothing(self, runtime, field, day):
        quiet = dataclasses.replace(day, chaos=None)
        c = _ctl(runtime, field)
        clean = sc.replay(quiet, runtime=runtime, controller=c)
        zeroed = sc.replay(quiet, runtime=runtime, controller=c,
                           faults=ctl.ControlFaultModel(rate=0.0))
        assert zeroed.fingerprint == clean.fingerprint
        assert zeroed.energy_j == clean.energy_j
        assert zeroed.quarantined == 0 and zeroed.safe_states == 0
        assert zeroed.frozen_ticks == 0 and not zeroed.watchdog_events
