"""TPU energy runtime policies + serving engine + error-tolerant apps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.models.model import Model
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def profile():
    return TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)


class TestEnergyRuntime:
    def test_power_save_holds_contract(self, profile):
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        p = rt.plan()
        assert p.step_s == pytest.approx(profile.step_s)
        assert 0.0 < p.saving < 0.5
        assert p.t_max < TF.T_MAX_CHIP
        assert (p.v_core <= TF.V_CORE_NOM + 1e-6).all()

    def test_min_energy_beats_power_save(self, profile):
        ps = RT.EnergyAwareRuntime(profile, policy="power_save").plan()
        me = RT.EnergyAwareRuntime(profile, policy="min_energy").plan()
        # energy metric: P x t
        e_ps = ps.pod_power_w * profile.step_s
        e_me = me.pod_power_w * me.step_s
        assert e_me < e_ps
        assert me.step_s > profile.step_s  # delay traded for energy

    def test_overscale_saves_more_power(self, profile):
        ps = RT.EnergyAwareRuntime(profile, policy="power_save").plan()
        os_ = RT.EnergyAwareRuntime(profile, policy="overscale:1.2").plan()
        assert os_.saving > ps.saving
        assert os_.step_s == pytest.approx(profile.step_s)  # clock held

    def test_dynamic_lut_monotone(self, profile):
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        lut = rt.dynamic_lut([15.0, 30.0, 45.0])
        vcs = [lut[t][0] for t in (15.0, 30.0, 45.0)]
        assert all(b >= a - 1e-6 for a, b in zip(vcs, vcs[1:]))
        assert vcs[-1] <= 0.75 + 1e-6

    def test_straggler_boost_costs_power(self, profile):
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        p = rt.plan()
        rt.T = rt.T.at[37].set(88.0)
        out = rt.straggler_mitigation(p, 37, 1.3)
        assert out["action"] == "boost_rail"
        assert out["extra_power_w"] > 0

    def test_cold_pod_saves_more(self, profile):
        hot = RT.EnergyAwareRuntime(profile, policy="power_save",
                                    t_amb=40.0).plan()
        cold = RT.EnergyAwareRuntime(profile, policy="power_save",
                                     t_amb=10.0).plan()
        assert cold.saving > hot.saving


class TestServingEngine:
    @pytest.fixture(scope="class")
    def setup(self):
        cfg = registry.get("llama3.2-1b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def test_generates_requested_tokens(self, setup):
        cfg, model, params = setup
        eng = Engine(model, params, batch_slots=2, max_len=64)
        for rid in range(3):
            eng.submit(Request(rid, np.arange(4 + rid) % cfg.vocab_size,
                               max_new=6))
        done = eng.run()
        assert len(done) == 3
        for r in done:
            assert 1 <= len(r.out) <= 6

    def test_batched_equals_single(self, setup):
        """Slot batching must not change greedy outputs."""
        cfg, model, params = setup
        prompt = np.arange(5) % cfg.vocab_size

        def gen(slots):
            eng = Engine(model, params, batch_slots=slots, max_len=64,
                         eos_id=-1)
            eng.submit(Request(0, prompt, max_new=5))
            return eng.run()[0].out

        assert gen(1) == gen(4)


class TestApps:
    def test_lenet_trains(self):
        from repro.core import apps
        p, info = apps.lenet_train(jax.random.PRNGKey(42), steps=300)
        assert apps.lenet_accuracy(p, jax.random.PRNGKey(42), n=512) > 0.95

    def test_hd_trains_and_degrades_gracefully(self):
        from repro.core import apps
        key = jax.random.PRNGKey(42)
        hd = apps.hd_train(key)
        clean = apps.hd_accuracy(hd, key)
        noisy = apps.hd_accuracy(hd, key, flip_prob=0.30)
        assert clean > 0.98
        # paper [44]: ~4% drop at 30% bit flips
        assert 0.003 < clean - noisy < 0.12
