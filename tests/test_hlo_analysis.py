"""Trip-count-aware HLO analyzer: validated against known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.hlo_analysis import analyze, shape_bytes


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_shape_bytes():
    assert shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert shape_bytes("bf16[2,3]{1,0}") == 12
    assert shape_bytes("(f32[4]{0}, s32[2]{0})") == 16 + 8
    assert shape_bytes("pred[10]{0}") == 10


def test_scan_trip_count_multiplies_flops():
    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    n = 10
    c = _compile(scanned, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((n, 64, 64), jnp.float32))
    cost = analyze(c.as_text())
    expect = n * 2 * 64 ** 3
    assert cost.flops == pytest.approx(expect, rel=0.01)
    # and cost_analysis() itself counts the body once (the bug we correct);
    # newer jax returns a single dict, older a one-element list of dicts
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca
    assert ca["flops"] == pytest.approx(expect / n, rel=0.01)


def test_single_dot_flops():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((32, 128), jnp.float32),
                 jax.ShapeDtypeStruct((128, 16), jnp.float32))
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(2 * 32 * 128 * 16, rel=0.01)


def test_batched_dot_flops():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_nested_scan_multiplies():
    def nested(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return ci @ w, None
            c, _ = jax.lax.scan(inner, c, ws)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    c = _compile(nested, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((5, 32, 32), jnp.float32))
    cost = analyze(c.as_text())
    assert cost.flops == pytest.approx(3 * 5 * 2 * 32 ** 3, rel=0.01)
