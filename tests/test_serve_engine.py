"""Continuous-batching engine edge cases + telemetry (repro.serve.engine)."""
import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.control.telemetry import TickSample
from repro.models.model import Model
from repro.serve.engine import Engine, Request


@pytest.fixture(scope="module")
def dense():
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _eng(model, params, **kw):
    kw.setdefault("batch_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("eos_id", -1)
    kw.setdefault("warmup", False)
    return Engine(model, params, **kw)


class TestMidFlightAdmission:
    def test_admission_mid_decode_matches_solo(self, dense):
        """A request admitted while another is mid-decode must produce the
        same greedy tokens as running alone (the ragged pos/n_valid
        contract: no global position barrier)."""
        cfg, model, params = dense
        pa = np.arange(5) % cfg.vocab_size
        pb = (np.arange(7) * 2 + 1) % cfg.vocab_size

        solo = _eng(model, params)
        solo.submit(Request(0, pb, max_new=6))
        ref = solo.run()[0].out

        eng = _eng(model, params)
        eng.submit(Request(0, pa, max_new=12))
        for _ in range(4):  # A is now several tokens into decode
            eng.step()
        assert any(r is not None for r in eng.slot_req)
        eng.submit(Request(1, pb, max_new=6))
        done = {r.rid: r for r in eng.run()}
        assert done[1].out == ref

    def test_staggered_prompts_all_match_solo(self, dense):
        cfg, model, params = dense
        prompts = [(np.arange(3 + 4 * i) * (i + 1)) % cfg.vocab_size
                   for i in range(3)]
        refs = []
        for i, p in enumerate(prompts):
            e = _eng(model, params)
            e.submit(Request(i, p, max_new=5))
            refs.append(e.run()[0].out)

        eng = _eng(model, params, batch_slots=2)  # 3 reqs through 2 slots
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new=5))
        done = {r.rid: r.out for r in eng.run()}
        assert [done[i] for i in range(3)] == refs


class TestSlotRecycling:
    def test_many_requests_reuse_slots_without_growth(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params, batch_slots=2)
        shapes0 = [x.shape for x in jax.tree_util.tree_leaves(eng.cache)]
        for rid in range(6):
            eng.submit(Request(rid, np.arange(4 + rid % 3) % cfg.vocab_size,
                               max_new=4))
        done = eng.run()
        assert len(done) == 6
        shapes1 = [x.shape for x in jax.tree_util.tree_leaves(eng.cache)]
        assert shapes0 == shapes1  # recycling, not reallocation
        assert eng.mgr.pages_in_use == 0
        assert 0 < eng.mgr.peak_pages <= eng.mgr.total_pages


class TestEdgeCases:
    def test_admit_cap_zero_starves_then_recovers(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params, admit_cap=0)
        eng.submit(Request(0, np.arange(4) % cfg.vocab_size, max_new=3))
        samples = []
        eng.on_tick.append(samples.append)
        for _ in range(3):
            assert eng.step()  # work exists, none admitted
        assert not eng.finished and len(eng.queue) == 1
        # starvation is VISIBLE: every throttled step emitted telemetry
        assert len(samples) == 3
        assert all(s.queued == 1 and s.admitted == 0 and s.tokens == 0
                   for s in samples)
        assert samples[-1].oldest_wait == 2.0
        eng.admit_cap = None  # Throttle(None) lifts the cap
        done = eng.run()
        assert len(done) == 1 and len(done[0].out) == 3

    def test_prompt_at_or_over_max_len_rejected(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params, max_len=16)
        eng.submit(Request(0, np.arange(16) % cfg.vocab_size, max_new=2))
        eng.submit(Request(1, np.arange(40) % cfg.vocab_size, max_new=2))
        eng.submit(Request(2, np.arange(4) % cfg.vocab_size, max_new=2))
        done = {r.rid: r for r in eng.run()}
        assert done[0].error == "prompt_too_long" and done[0].out == []
        assert done[1].error == "prompt_too_long"
        assert done[2].error is None and len(done[2].out) == 2
        assert len(eng.mgr.free_slots) == eng.B  # nothing leaked

    def test_eos_on_first_decode_tick(self, dense):
        cfg, model, params = dense
        prompt = np.arange(5) % cfg.vocab_size
        probe = _eng(model, params)
        probe.submit(Request(0, prompt, max_new=4))
        first = probe.run()[0].out[0]

        eng = _eng(model, params, eos_id=first)
        eng.submit(Request(0, prompt, max_new=4))
        done = eng.run()
        assert done[0].out == [first] and done[0].done
        assert len(eng.mgr.free_slots) == eng.B  # slot freed immediately

    def test_run_on_empty_queue_is_a_noop(self, dense):
        _, model, params = dense
        eng = _eng(model, params)
        samples = []
        eng.on_tick.append(samples.append)
        assert eng.run() == []
        assert eng.step() is False
        assert samples == [] and eng.ticks == 0


class TestPreemption:
    """§9 thermal-emergency preemption: evict -> host page pool -> resume
    bitwise-identical."""

    def _refs(self, model, params, prompts, max_new=8):
        refs = []
        for i, p in enumerate(prompts):
            e = _eng(model, params)
            e.submit(Request(i, p, max_new=max_new))
            refs.append(e.run()[0].out)
        return refs

    def test_preempt_resume_is_bitwise_identical(self, dense):
        cfg, model, params = dense
        prompts = [np.arange(5) % cfg.vocab_size,
                   (np.arange(7) * 2 + 1) % cfg.vocab_size]
        refs = self._refs(model, params, prompts)

        eng = _eng(model, params)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new=8))
        for _ in range(3):  # both mid-decode
            eng.step()
        assert eng.preempt_to(0) == 2  # full eviction to the page pool
        assert len(eng.pool) == 2 and sorted(eng.mgr.free_slots) == [0, 1]
        assert all(r is None for r in eng.slot_req)
        done = {r.rid: r for r in eng.run()}
        assert [done[i].out for i in range(2)] == refs
        assert all(done[i].preempts == 1 for i in range(2))
        assert eng.preempts == 2 and len(eng.pool) == 0

    def test_low_priority_newest_evicted_first(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params)
        eng.submit(Request(0, np.arange(4) % cfg.vocab_size, max_new=8,
                           priority=1))  # premium
        eng.step()
        eng.submit(Request(1, np.arange(6) % cfg.vocab_size, max_new=8))
        eng.step()
        assert eng.preempt_to(1) == 1
        kept = [r for r in eng.slot_req if r is not None]
        assert len(kept) == 1 and kept[0].rid == 0  # premium survives
        assert eng.queue[0].rid == 1 and 1 in eng.pool

    def test_preempt_mid_prefill_resumes_the_stream(self, dense):
        cfg, model, params = dense
        prompt = np.arange(11) % cfg.vocab_size
        ref = self._refs(model, params, [prompt], max_new=6)[0]
        eng = _eng(model, params, prefill_chunk=4)
        eng.submit(Request(0, prompt, max_new=6))
        eng.step()  # one 4-token chunk fed: mid-prefill
        req = next(r for r in eng.slot_req if r is not None)
        assert 0 < req.fed < len(prompt)
        eng.preempt_to(0)
        assert eng.run()[0].out == ref

    def test_resume_across_expandable_growth(self, dense):
        cfg, model, params = dense
        prompt = np.arange(5) % cfg.vocab_size
        solo = _eng(model, params, expandable=True)
        solo.submit(Request(0, prompt, max_new=12))
        ref = solo.run()[0].out

        eng = _eng(model, params, expandable=True)
        eng.submit(Request(0, prompt, max_new=12))
        for _ in range(2):
            eng.step()
        eng.preempt_to(0)
        # the cache regrows while the rows sit in the host pool: restore
        # must pad the stashed rows out to the new leaf shapes
        eng.submit(Request(1, (np.arange(9) * 3 + 2) % cfg.vocab_size,
                           max_new=12))
        done = {r.rid: r for r in eng.run()}
        assert done[0].out == ref
        assert done[0].preempts == 1

    def test_preempt_to_is_a_noop_when_under_cap(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params)
        eng.submit(Request(0, np.arange(4) % cfg.vocab_size, max_new=4))
        eng.step()
        assert eng.preempt_to(2) == 0
        assert eng.preempts == 0 and len(eng.pool) == 0


class TestTelemetry:
    def test_every_step_emits_one_sample(self, dense):
        cfg, model, params = dense
        eng = _eng(model, params)
        samples = []
        eng.on_tick.append(samples.append)
        eng.submit(Request(0, np.arange(4) % cfg.vocab_size, max_new=3))
        eng.submit(Request(1, np.arange(6) % cfg.vocab_size, max_new=3))
        steps = 0
        while True:  # count CALLS: the final productive step returns False
            steps += 1
            if not eng.step():
                break
        assert len(samples) == steps
        assert all(isinstance(s, TickSample) for s in samples)
        assert samples[0].admitted == 2  # both fit the 2 slots at once
        assert sum(s.tokens for s in samples) == 6
        assert samples[-1].finished == 2 and samples[-1].active == 0
        assert [s.tick for s in samples] == list(range(steps))
        assert all(s.slots == eng.B for s in samples)
