"""Pipeline parallelism: GPipe schedule == sequential execution (subprocess
with 4 host devices so this process stays at 1 device)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.sharding.pipeline import pipeline_apply

mesh = jax.make_mesh((4,), ("pp",))
P_STAGES, D = 4, 16
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (P_STAGES, D, D)) * (0.5 / np.sqrt(D))
bs = jax.random.normal(jax.random.fold_in(key, 1), (P_STAGES, D)) * 0.1
params = {"w": ws, "b": bs}

def stage(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jax.random.normal(jax.random.fold_in(key, 2), (8, D))

# sequential reference
ref = x
for i in range(P_STAGES):
    ref = stage({"w": ws[i], "b": bs[i]}, ref)

for M in (2, 4, 8):
    out = pipeline_apply(stage, params, x, mesh, "pp", n_microbatches=M)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, (M, err)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_gpipe_schedule_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, (out.stdout[-500:], out.stderr[-2000:])
