"""Model correctness: decode==forward, blockwise attention, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models import attention as A
from repro.models import moe as moe_lib
from repro.models.model import Model
from repro.sharding.plan import make_plan

DECODE_ARCHS = ["llama3.2-1b", "qwen3-1.7b", "mixtral-8x7b",
                "deepseek-v2-236b", "mamba2-780m", "zamba2-1.2b",
                "llama-3.2-vision-11b", "whisper-small"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = registry.get(arch).reduced().replace(
        dtype="float32", param_dtype="float32", moe_capacity_factor=16.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S, P = 2, 24, 16
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.1 * jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model))
    if cfg.family == "audio":
        batch["audio_frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder_frames, cfg.d_model))
    full, _ = model.apply(params, batch)
    pf = dict(batch)
    pf["tokens"] = batch["tokens"][:, :P]
    logits, cache = model.prefill(params, pf, max_len=S)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, P - 1]), atol=2e-4)
    for t in range(P, S):
        logits, cache = model.decode(params, batch["tokens"][:, t:t + 1],
                                     cache, t)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-4,
                                   err_msg=f"step {t}")


def test_blockwise_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    B, S, H, Hkv, D = 2, 256, 8, 4, 32
    q = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, S, Hkv, D))
    plan = make_plan(registry.get("llama3.2-1b").reduced())
    for window in (0, 64):
        ref = A._sdpa(q, k, v, A.causal_mask(S, S, 0, window), plan)
        out = A.blockwise_sdpa(q, k, v, causal=True, window=window,
                               q_block=64, kv_block=32)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-6)


def test_sliding_window_ring_cache():
    """SWA decode cache is a ring buffer shorter than the sequence."""
    cfg = registry.get("mixtral-8x7b").reduced().replace(
        dtype="float32", param_dtype="float32", sliding_window=8,
        moe_capacity_factor=16.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = model.apply(params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :4]}, max_len=S)
    assert cache["stack"]["k"].shape[2] == 8  # (L, B, T=window, hkv, dh)
    for t in range(4, S):
        logits, cache = model.decode(params, toks[:, t:t + 1], cache, t)
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full[:, t]), atol=2e-4,
                                   err_msg=f"step {t}")


def test_sliding_window_chunked_prefill_past_wrap():
    """SWA ragged chunked prefill (§9 satellite): multi-token chunks
    (S > 1) streamed through the ring cache must match the full forward
    while crossing the window-wrap boundary — including a ragged chunk
    whose padded tail must not clobber live ring entries."""
    cfg = registry.get("mixtral-8x7b").reduced().replace(
        dtype="float32", param_dtype="float32", sliding_window=8,
        moe_capacity_factor=16.0)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 26
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    full, _ = model.apply(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :4]}, max_len=S)
    assert cache["stack"]["k"].shape[2] == 8  # ring shorter than sequence
    t = 4
    # (chunk_len, n_valid): the 2-valid chunk writes its padded third slot
    # across the ring boundary; later chunks straddle the wrap themselves
    for k, nv in [(3, 3), (3, 2), (4, 4), (4, 4), (4, 4), (4, 4), (1, 1)]:
        chunk = toks[:, t:t + k]
        if chunk.shape[1] < k:  # pad the scripted length at the tail
            chunk = jnp.pad(chunk, ((0, 0), (0, k - chunk.shape[1])))
        logits, cache = model.decode(
            params, chunk, cache, t,
            n_valid=None if nv == k else jnp.asarray([nv]))
        np.testing.assert_allclose(
            np.asarray(logits[:, :nv]), np.asarray(full[:, t:t + nv]),
            atol=2e-4, err_msg=f"chunk at {t} (+{nv})")
        t += nv
    assert t == S  # the schedule covered the whole sequence


class TestMoE:
    def test_router_topk_weights_normalized(self):
        key = jax.random.PRNGKey(0)
        logits = jax.random.normal(key, (32, 8))
        w, idx, aux, z = moe_lib.router_topk(logits, 2)
        np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
        assert float(aux) >= 1.0 - 1e-5  # balance loss lower bound E*sum>=1

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_no_drop_moe_is_permutation_invariant(self, seed):
        """With ample capacity, MoE output is per-token (permuting the batch
        permutes the output)."""
        cfg = registry.get("mixtral-8x7b").reduced().replace(
            dtype="float32", param_dtype="float32", moe_capacity_factor=16.0)
        plan = make_plan(cfg)
        key = jax.random.PRNGKey(seed)
        p = __import__("repro.models.params", fromlist=["materialize"]) \
            .materialize(moe_lib.moe_params(cfg, plan), key, "float32")
        x = jax.random.normal(jax.random.fold_in(key, 1), (1, 16, cfg.d_model))
        out, _ = moe_lib.moe_apply(p, x, cfg, plan)
        perm = jax.random.permutation(jax.random.fold_in(key, 2), 16)
        out_p, _ = moe_lib.moe_apply(p, x[:, perm], cfg, plan)
        np.testing.assert_allclose(np.asarray(out[:, perm]),
                                   np.asarray(out_p), atol=1e-4)


def test_rope_preserves_norm():
    from repro.models.layers import apply_rope
    cfg = registry.get("llama3.2-1b").reduced()
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 16, 4, 16))
    pos = jnp.arange(16)[None]
    y = apply_rope(x, pos, cfg)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
