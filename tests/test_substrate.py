"""Substrate tests: checkpoint, fault tolerance, data pipeline, optimizer."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint.manager import CheckpointManager
from repro.configs import registry
from repro.data.pipeline import DataConfig, SyntheticLM, make_iterator
from repro.ft.elastic import choose_mesh_shape
from repro.ft.monitor import (FailureInjector, Heartbeat, StragglerDetector,
                              TransientError, retry_step)
from repro.train.optimizer import OptConfig, Optimizer, lr_schedule


class TestCheckpoint:
    def _tree(self, k=0):
        return {"a": jnp.arange(12.0).reshape(3, 4) + k,
                "b": {"c": jnp.ones((5,)) * k, "d": jnp.zeros((2, 2))}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = self._tree(3)
        mgr.save(7, tree, metadata={"arch": "x"})
        restored, step = mgr.restore(self._tree(0))
        assert step == 7
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            tree, restored)

    def test_async_save_and_fence(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        mgr.save(1, self._tree(1))
        mgr.wait()
        assert mgr.latest_step() == 1

    def test_keep_last_prunes(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
        for s in range(5):
            mgr.save(s, self._tree(s))
        assert mgr.all_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._tree(1))
        npz = os.path.join(str(tmp_path), "step_00000001", "arrays.npz")
        with open(npz, "r+b") as f:
            f.seek(30)
            f.write(b"\x00\x01\x02")
        with pytest.raises(IOError, match="corrupt"):
            mgr.restore(self._tree(0))

    def test_restore_specific_step(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=5, async_save=False)
        for s in (2, 4, 6):
            mgr.save(s, self._tree(s))
        restored, step = mgr.restore(self._tree(0), step=4)
        assert step == 4
        assert float(restored["a"][0, 0]) == 4.0


class TestFT:
    def test_heartbeat_dead_set(self):
        hb = Heartbeat(timeout_s=10.0)
        hb.beat("w0", t=100.0)
        hb.beat("w1", t=105.0)
        assert hb.dead(now=112.0) == {"w0"}
        assert hb.alive(now=112.0) == {"w1"}

    def test_straggler_detection(self):
        det = StragglerDetector(threshold=1.5, min_samples=8)
        for i in range(10):
            det.record("w0", i, 1.0)
        ev = det.record("w0", 10, 2.0)
        assert ev is not None and ev.ratio == pytest.approx(2.0)
        assert det.record("w0", 11, 1.1) is None

    def test_retry_then_succeed(self):
        inj = FailureInjector(fail_at={0})
        calls = []

        def step():
            inj.maybe_fail(0)
            calls.append(1)
            return "ok"

        assert retry_step(step) == "ok"
        assert len(calls) == 1

    def test_retry_exhausted_raises(self):
        def always_fail():
            raise TransientError("boom")

        with pytest.raises(TransientError):
            retry_step(always_fail, max_retries=2)

    @settings(max_examples=30, deadline=None)
    @given(n=st.integers(1, 4096), model=st.sampled_from([1, 2, 4, 8, 16]))
    def test_choose_mesh_shape_valid(self, n, model):
        data, m = choose_mesh_shape(n, model)
        assert data * m <= n
        assert data >= 1 and m >= 1


class TestData:
    def test_deterministic(self):
        dc = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=3)
        a = SyntheticLM(dc).batch(5)
        b = SyntheticLM(dc).batch(5)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    def test_labels_shifted(self):
        dc = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
        b = SyntheticLM(dc).batch(0)
        # labels[t] is the successor of tokens[t]
        np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                      np.asarray(b["labels"][:, :-1]))

    def test_bigram_structure_learnable(self):
        dc = DataConfig(vocab_size=64, seq_len=32, global_batch=4, branch=2)
        src = SyntheticLM(dc)
        b = src.batch(0)
        toks, labels = np.asarray(b["tokens"]), np.asarray(b["labels"])
        for t in range(dc.seq_len):
            assert all(labels[i, t] in src.successors[toks[i, t]]
                       for i in range(4))

    def test_shards_distinct_and_deterministic(self):
        dc = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
        src = SyntheticLM(dc)
        s0 = src.batch(1, shard=0, n_shards=2)
        s1 = src.batch(1, shard=1, n_shards=2)
        assert s0["tokens"].shape == (4, 16)
        assert not np.array_equal(np.asarray(s0["tokens"]),
                                  np.asarray(s1["tokens"]))


class TestOptimizer:
    def _quad_loss(self, p):
        return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(
            jnp.square(p["b"] + 1.0))

    @pytest.mark.parametrize("kind", ["adamw", "adafactor"])
    def test_converges_on_quadratic(self, kind):
        oc = OptConfig(kind=kind, lr=0.1, warmup_steps=0, total_steps=10_000,
                       weight_decay=0.0, grad_clip=100.0)
        opt = Optimizer(oc)
        params = {"w": jnp.zeros((4, 130)), "b": jnp.zeros((200, 140))}
        state = opt.init(params)
        for i in range(200):
            grads = jax.grad(self._quad_loss)(params)
            params, state, _ = opt.update(params, grads, state, i)
        assert self._quad_loss(params) < 0.3

    def test_grad_clip(self):
        oc = OptConfig(grad_clip=1.0)
        opt = Optimizer(oc)
        params = {"w": jnp.zeros((4,))}
        grads = {"w": jnp.full((4,), 100.0)}
        state = opt.init(params)
        _, _, metrics = opt.update(params, grads, state, 0)
        assert float(metrics["grad_norm"]) == pytest.approx(200.0)

    def test_lr_schedule_shape(self):
        oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        # warmup counts from 1 so step 0 trains (lr = lr/warmup)
        assert float(lr_schedule(oc, jnp.int32(0))) == pytest.approx(0.1)
        assert float(lr_schedule(oc, jnp.int32(9))) == pytest.approx(1.0)
        assert float(lr_schedule(oc, jnp.int32(100))) == pytest.approx(0.0,
                                                                       abs=1e-6)

    def test_adafactor_memory_factored(self):
        cfg = registry.get("deepseek-v2-236b")
        oc = OptConfig(kind="adafactor")
        opt = Optimizer(oc)
        meta = {"w": __import__("repro.models.params",
                                fromlist=["ParamMeta"]).ParamMeta(
            (1024, 2048), (None, None))}
        sm = opt.state_meta(meta)
        assert sm["w"]["vr"].shape == (1024,)
        assert sm["w"]["vc"].shape == (2048,)
