"""repro.policy — the unified Substrate/Policy/Solver stack.

Equivalence contract: the legacy entry points (voltage_scaling.run,
energy_opt.run, overscaling.run, EnergyAwareRuntime.plan) are thin wrappers
over the shared Solver and must reproduce their pre-refactor results.  The
GOLDEN_* constants below were captured from the seed implementation (Python
fixed-point loops) before the migration; everything is pinned to 1e-3.
"""
import numpy as np
import pytest

from repro import policy as pol
from repro.core import (energy_opt as EO, netlist as NL, overscaling as OS,
                        runtime as RT, thermal, tpu_fleet as TF,
                        voltage_scaling as VS, vtr_benchmarks as vb)

TC12 = thermal.ThermalConfig(theta_ja=12.0)
TC2 = thermal.ThermalConfig(theta_ja=2.0)

# pre-refactor (seed) results, captured on the legacy Python loops
GOLDEN_VS = {"v_core": 0.74, "v_bram": 0.79, "power_mw": 8.458870,
             "iters": 2}  # VS.run(mkPktMerge, 60C, act 1.0, theta 12)
GOLDEN_EO = {"v_core": 0.55, "v_bram": 0.55, "d_opt_ns": 17.019848,
             "energy": 27.992240, "saving": 0.640888,
             "freq_ratio": 0.367218}  # EO.run(mkPktMerge, 65C, theta 2)
GOLDEN_OS = {"v_core": 0.66, "v_bram": 0.70, "power_mw": 39.173454,
             "saving": 0.454091,
             "frac_violating": 0.542969}  # OS.run(raygentop, g=1.2, 40C)
GOLDEN_TPU = {  # EnergyAwareRuntime(profile).plan() @ 25C, 16x16 pod
    "power_save": {"pod_power_w": 50196.734, "saving": 0.114950,
                   "step_s": 0.86, "t_max": 64.216},
    "min_energy": {"pod_power_w": 12895.854, "saving": 0.534707,
                   "step_s": 1.759880, "t_max": 35.075},
    "overscale:1.2": {"pod_power_w": 33512.879, "saving": 0.409113,
                      "step_s": 0.86, "t_max": 51.182},
}


@pytest.fixture(scope="module")
def mkpkt():
    return vb.load("mkPktMerge")


@pytest.fixture(scope="module")
def profile():
    return TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)


class TestPolicyEquivalence:
    def test_power_save_matches_legacy(self, mkpkt):
        r = VS.run(mkpkt, 60.0, 1.0, TC12)
        assert r.v_core == pytest.approx(GOLDEN_VS["v_core"], abs=1e-3)
        assert r.v_bram == pytest.approx(GOLDEN_VS["v_bram"], abs=1e-3)
        assert r.power_mw == pytest.approx(GOLDEN_VS["power_mw"], rel=1e-3)
        assert len(r.trace) == GOLDEN_VS["iters"]
        # the raw policy API lands on the same operating point
        sub = pol.fpga_substrate(mkpkt, tc=TC12)
        solver = pol.cached_solver(sub, pol.PowerSave(), 0.1, 10,
                                   refine_window=VS.REFINE_WINDOW_V)
        sol = solver.solve({"t_amb": 60.0, "act": 1.0})
        vc, vbr = sub.decode(sol.idx)
        assert float(vc[0]) == pytest.approx(r.v_core, abs=1e-6)
        assert float(vbr[0]) == pytest.approx(r.v_bram, abs=1e-6)
        assert float(sol.power[0]) == pytest.approx(r.power_mw, rel=1e-6)

    def test_min_energy_matches_legacy(self, mkpkt):
        r = EO.run(mkpkt, 65.0, 1.0, TC2)
        assert r.v_core == pytest.approx(GOLDEN_EO["v_core"], abs=1e-3)
        assert r.v_bram == pytest.approx(GOLDEN_EO["v_bram"], abs=1e-3)
        assert r.d_opt_ns == pytest.approx(GOLDEN_EO["d_opt_ns"], rel=1e-3)
        assert r.energy == pytest.approx(GOLDEN_EO["energy"], rel=1e-3)
        assert r.saving == pytest.approx(GOLDEN_EO["saving"], abs=1e-3)
        assert r.freq_ratio == pytest.approx(GOLDEN_EO["freq_ratio"],
                                             rel=1e-3)

    def test_overscale_matches_legacy(self):
        nl = NL.generate(vb.BY_NAME["raygentop"])
        r = OS.run(nl, 1.2, t_amb=40.0, tc=TC12)
        assert r.v_core == pytest.approx(GOLDEN_OS["v_core"], abs=1e-3)
        assert r.v_bram == pytest.approx(GOLDEN_OS["v_bram"], abs=1e-3)
        assert r.power_mw == pytest.approx(GOLDEN_OS["power_mw"], rel=1e-3)
        assert r.saving == pytest.approx(GOLDEN_OS["saving"], abs=1e-3)
        assert r.frac_violating == pytest.approx(
            GOLDEN_OS["frac_violating"], abs=1e-3)

    @pytest.mark.parametrize("spec", list(GOLDEN_TPU))
    def test_tpu_policies_match_legacy(self, profile, spec):
        g = GOLDEN_TPU[spec]
        p = RT.EnergyAwareRuntime(profile, policy=spec).plan()
        assert p.pod_power_w == pytest.approx(g["pod_power_w"], rel=1e-3)
        assert p.saving == pytest.approx(g["saving"], abs=1e-3)
        assert p.step_s == pytest.approx(g["step_s"], rel=1e-3)
        assert p.t_max == pytest.approx(g["t_max"], abs=0.1)

    def test_policy_object_equals_spec_string(self, profile):
        a = RT.EnergyAwareRuntime(profile, policy="overscale:1.2").plan()
        b = RT.EnergyAwareRuntime(profile,
                                  policy=pol.Overscale(gamma=1.2)).plan()
        assert a.pod_power_w == pytest.approx(b.pod_power_w, rel=1e-6)
        np.testing.assert_array_equal(a.v_core, b.v_core)


class TestSolveBatch:
    def test_fpga_lut_batch_equals_sequential(self, mkpkt):
        t_ambs = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0]
        lut = VS.dynamic_lut(mkpkt, t_ambs, tc=TC2)  # one batched call
        sub = pol.fpga_substrate(mkpkt, tc=TC2)
        solver = pol.cached_solver(sub, pol.PowerSave(), 0.1, 10,
                                   refine_window=VS.REFINE_WINDOW_V)
        for t in t_ambs:  # sequential fixed points, same solver
            sol = solver.solve({"t_amb": t, "act": 1.0})
            vc, vbr = sub.decode(sol.idx)
            assert lut[t] == (pytest.approx(float(vc[0]), abs=1e-6),
                              pytest.approx(float(vbr[0]), abs=1e-6))

    def test_tpu_lut_batch_equals_sequential(self, profile):
        t_ambs = [15.0, 25.0, 35.0, 45.0]
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        lut = rt.dynamic_lut(t_ambs)  # one batched call
        for t in t_ambs:  # a fresh runtime per ambient = the legacy sweep
            p = RT.EnergyAwareRuntime(profile, policy="power_save",
                                      t_amb=t).plan()
            assert lut[t][0] == pytest.approx(float(np.median(p.v_core)),
                                              abs=1e-6)
            assert lut[t][1] == pytest.approx(float(np.median(p.v_sram)),
                                              abs=1e-6)

    def test_gamma_sweep_batch_equals_sequential(self):
        nl = NL.generate(vb.BY_NAME["raygentop"])
        gammas = [1.0, 1.2, 1.4]
        batched = OS.sweep(nl, gammas, t_amb=40.0, tc=TC12)
        for g, r in zip(gammas, batched):
            single = OS.run(nl, g, t_amb=40.0, tc=TC12)
            assert r.v_core == pytest.approx(single.v_core, abs=1e-6)
            assert r.v_bram == pytest.approx(single.v_bram, abs=1e-6)
            assert r.power_mw == pytest.approx(single.power_mw, rel=1e-6)

    def test_dynamic_lut_does_not_corrupt_state(self, profile):
        """Regression: the legacy sweep left self.T at the last ambient's
        estimate, corrupting subsequent plan() calls."""
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        control = RT.EnergyAwareRuntime(profile, policy="power_save")
        rt.plan()
        control.plan()
        T_after_plan = np.asarray(rt.T).copy()
        rt.dynamic_lut([15.0, 30.0, 45.0, 60.0])
        np.testing.assert_array_equal(np.asarray(rt.T), T_after_plan)
        assert rt.t_amb == 25.0
        # a plan after the sweep must equal one on an untouched runtime
        after, ref = rt.plan(), control.plan()
        assert after.pod_power_w == pytest.approx(ref.pod_power_w, rel=1e-6)
        np.testing.assert_array_equal(after.v_core, ref.v_core)


class TestGuards:
    def test_vs_zero_iters_no_crash(self, mkpkt):
        # legacy: IndexError on trace[-1] / UnboundLocalError on vc_prev
        r = VS.run(mkpkt, 60.0, 1.0, TC12, max_iters=0)
        assert len(r.trace) == 1  # clamped to one iteration
        assert r.power_mw > 0

    def test_eo_zero_iters_no_crash(self, mkpkt):
        # legacy: ZeroDivisionError on best.d_opt_ns == 0
        r = EO.run(mkpkt, 65.0, 1.0, TC2, max_iters=0)
        assert r.d_opt_ns > 0
        assert np.isfinite(r.freq_ratio)

    def test_safe_div_guards_degenerate(self):
        assert EO._safe_div(1.0, 0.0) == 0.0
        assert EO._safe_div(1.0, 0.0, default=1.0) == 1.0
        assert EO._safe_div(6.0, 3.0) == 2.0

    def test_from_spec(self):
        assert isinstance(pol.from_spec("power_save"), pol.PowerSave)
        assert isinstance(pol.from_spec("min_energy"), pol.MinEnergy)
        ov = pol.from_spec("overscale:1.35")
        assert isinstance(ov, pol.Overscale)
        assert ov.gamma == pytest.approx(1.35)
        assert pol.from_spec(ov) is ov
        with pytest.raises(ValueError):
            pol.from_spec("warp_speed")

    def test_solver_clamps_max_iters(self, mkpkt):
        sub = pol.fpga_substrate(mkpkt, tc=TC12)
        s = pol.Solver(sub, pol.PowerSave(), max_iters=0)
        assert s.max_iters == 1


class TestSubstrateProtocol:
    def test_both_implementations_satisfy_protocol(self, mkpkt, profile):
        fpga = pol.fpga_substrate(mkpkt, tc=TC12)
        tpu = pol.tpu_substrate(profile)
        for sub in (fpga, tpu):
            assert isinstance(sub, pol.Substrate)
            assert sub.n_candidates > 0
            assert 0 <= sub.nominal_idx < sub.n_candidates
            assert sub.d_worst > 0

    def test_fpga_d_worst_cached_and_shared(self, mkpkt):
        sub = pol.fpga_substrate(mkpkt, tc=TC12)
        assert sub.nominal_only().d_worst == sub.d_worst
        assert pol.fpga_substrate(mkpkt, tc=TC12) is sub  # memoized
