"""Characterized library: the paper's Fig 2 / Fig 3 anchors hold exactly."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import characterization as C

lib = C.default_library()


class TestFig2:
    def test_sb_temp_margin(self):
        # Fig 2(a): SB delay at 40C is 0.85x of its 100C value
        r = np.int32(C.SB)
        ratio = float(lib.delay(r, 0.8, 40.0) / lib.delay(r, 0.8, 100.0))
        assert ratio == pytest.approx(0.85, abs=0.01)

    def test_sb_068_consumes_margin(self):
        # Fig 2(b): V=0.68 raises 40C delay back to the worst case
        r = np.int32(C.SB)
        ratio = float(lib.delay(r, 0.68, 40.0) / lib.delay(r, 0.8, 100.0))
        assert ratio == pytest.approx(1.0, abs=0.02)

    def test_lut_steeper_than_sb(self):
        # LUT delay "severely increases at lower voltages" (pass gates)
        lut = float(lib.delay(np.int32(C.LUT), 0.68, 40.0)
                    / lib.delay(np.int32(C.LUT), 0.8, 40.0))
        sb = float(lib.delay(np.int32(C.SB), 0.68, 40.0)
                   / lib.delay(np.int32(C.SB), 0.8, 40.0))
        assert lut > sb
        assert lut == pytest.approx(1.42, abs=0.03)

    def test_sb_power_reduction_32pct(self):
        # Fig 2(c): 120 mV cut shrinks SB power by ~32% (char point)
        r = np.int32(C.SB)
        f, act = 0.6, 0.5
        p0 = float(lib.dynamic(r, 0.80, f, act) + lib.leakage(r, 0.80, 100.0))
        p1 = float(lib.dynamic(r, 0.68, f, act) + lib.leakage(r, 0.68, 100.0))
        assert 1 - p1 / p0 == pytest.approx(0.32, abs=0.05)

    def test_bram_power_falls_faster(self):
        # BRAM enjoys more power saving per mV than soft logic
        sb = float(lib.dynamic(np.int32(C.SB), 0.68, 0.6, 0.5)
                   / lib.dynamic(np.int32(C.SB), 0.80, 0.6, 0.5))
        br = float(lib.dynamic(np.int32(C.BRAM), 0.83, 0.6, 0.5)
                   / lib.dynamic(np.int32(C.BRAM), 0.95, 0.6, 0.5))
        assert br < sb

    def test_leakage_exponent(self):
        # paper: leakage ~ e^{0.015 T}
        r = np.int32(C.LUT)
        ratio = float(lib.leakage(r, 0.8, 85.0) / lib.leakage(r, 0.8, 25.0))
        assert ratio == pytest.approx(np.exp(0.015 * 60), rel=0.01)


class TestFig3:
    def test_internal_activity_anchors(self):
        # alpha_in 0.1 -> ~0.05 ; alpha_in 1.0 -> ~0.27
        assert float(C.internal_activity(0.1)) == pytest.approx(0.05, abs=0.01)
        assert float(C.internal_activity(1.0)) == pytest.approx(0.27, abs=0.01)

    def test_dsp_power_saturates(self):
        # +37% from 0.1->0.3, flat to 0.7, slight decline after
        f = C.dsp_activity_factor
        rise = float(f(0.3) / f(0.1))
        assert rise == pytest.approx(1.37 / 1.123, abs=0.05)
        assert float(f(0.5)) == pytest.approx(float(f(0.69)), abs=0.01)
        assert float(f(1.0)) < float(f(0.5))


class TestMonotonicity:
    @settings(max_examples=50, deadline=None)
    @given(res=st.integers(0, C.N_RESOURCES - 1),
           v=st.floats(0.60, 0.78), t=st.floats(0.0, 99.0))
    def test_delay_monotone(self, res, v, t):
        r = np.int32(res)
        vn = 0.95 if res == C.BRAM else 0.80
        # delay increases as V drops and as T rises (super-threshold regime)
        assert float(lib.delay(r, v, t)) >= float(lib.delay(r, vn, t)) - 1e-6
        assert float(lib.delay(r, vn, t)) <= float(
            lib.delay(r, vn, min(t + 20, 100.0))) + 1e-6

    @settings(max_examples=50, deadline=None)
    @given(res=st.integers(0, C.N_RESOURCES - 1),
           v=st.floats(0.56, 0.94), t=st.floats(0.0, 100.0))
    def test_power_monotone_in_v(self, res, v, t):
        r = np.int32(res)
        dv = 0.01
        p_lo = float(lib.dynamic(r, v, 0.5, 0.5) + lib.leakage(r, v, t))
        p_hi = float(lib.dynamic(r, v + dv, 0.5, 0.5)
                     + lib.leakage(r, v + dv, t))
        assert p_lo <= p_hi + 1e-9
