"""Multigrid thermal solver: tier parity, warm starts, batch T0, RB kernel.

The multigrid tier must land on the same steady state as the (seed) Jacobi
relaxation — the fixed point is solver-independent at the configured
tolerance — from any warm start, at any grid shape the repo uses (1x1
degenerate, odd dims, the paper's 92x92 Table-II die, 256x256 stress), for
both package classes (theta_ja 2 / 12) and adversarial power maps.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import policy as pol
from repro.core import thermal, tpu_fleet as TF
from repro.core.thermal import ThermalConfig
from repro.kernels import ops, ref as kref

# |mg - jacobi|_inf bound: the seed Jacobi stop (per-sweep |dT| < tol)
# leaves a true error of ~tol * rho/(1-rho) = tol * 4*spreading; multigrid
# stops at the f32 residual floor, far tighter. 2e-2 covers theta=12's
# 5e-3 degC Jacobi slack plus f32 noise with margin, and would still catch
# any real operator/transfer bug (those show up at whole degrees).
PARITY_ATOL = 2e-2

SEED_JACOBI = dict(solver="jacobi", check_every=1)


def _power_maps(cells: int):
    rng = np.random.default_rng(3)
    hot = np.zeros(cells)
    hot[cells // 2] = 500.0  # one 500 mW hot spot
    return {"zero": np.zeros(cells), "hotspot": hot,
            "uniform": rng.uniform(0.0, 5.0, cells)}


class TestTierParity:
    @pytest.mark.parametrize("m,n", [(1, 1), (3, 5), (23, 17), (92, 92)])
    @pytest.mark.parametrize("theta", [2.0, 12.0])
    def test_multigrid_matches_jacobi(self, m, n, theta):
        tc_mg = ThermalConfig(theta_ja=theta)
        tc_ja = ThermalConfig(theta_ja=theta, **SEED_JACOBI)
        for name, P in _power_maps(m * n).items():
            Pj = jnp.asarray(P, jnp.float32)
            T_mg = np.asarray(thermal.solve(Pj, m, n, 25.0, tc_mg))
            T_ja = np.asarray(thermal.solve(Pj, m, n, 25.0, tc_ja))
            np.testing.assert_allclose(T_mg, T_ja, atol=PARITY_ATOL,
                                       err_msg=f"{m}x{n} {name}")

    def test_256x256_energy_balance(self):
        """Full-scale grid: the chunked-Jacobi reference is too slow here,
        so pin the exact conservation law instead — all heat exits through
        G_v, so the mean rise must equal theta_JA * P_total."""
        m = 256
        tc = ThermalConfig(theta_ja=2.0)
        rng = np.random.default_rng(5)
        P = jnp.asarray(rng.uniform(0.0, 1.0, (m * m,)), jnp.float32)
        T = np.asarray(thermal.solve(P, m, m, 25.0, tc))
        rise = float(T.mean() - 25.0)
        expect = 2.0 * float(np.asarray(P).sum()) * 1e-3
        assert rise == pytest.approx(expect, rel=1e-3)

    def test_chunked_jacobi_matches_seed_loop(self):
        """check_every=K stops within K sweeps of the seed criterion."""
        m = 23
        tc1 = ThermalConfig(theta_ja=12.0, **SEED_JACOBI)
        tcK = ThermalConfig(theta_ja=12.0, solver="jacobi", check_every=32)
        P = jnp.asarray(_power_maps(m * m)["uniform"], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(thermal.solve(P, m, m, 25.0, tc1)),
            np.asarray(thermal.solve(P, m, m, 25.0, tcK)), atol=1e-3)

    def test_pod_config_parity(self):
        """The TPU-fleet thermal config (spreading=2, tol=1e-4) converges
        to the same field through both tiers."""
        tcp = TF.pod_thermal_config(0.20, 256)
        assert tcp.solver == "multigrid"
        tcj = ThermalConfig(theta_ja=tcp.theta_ja, spreading=tcp.spreading,
                            tol=tcp.tol, max_iters=tcp.max_iters,
                            **SEED_JACOBI)
        rng = np.random.default_rng(7)
        P = jnp.asarray(rng.uniform(0, 300, (256,)) * 1e3, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(thermal.solve(P, 16, 16, 25.0, tcp)),
            np.asarray(thermal.solve(P, 16, 16, 25.0, tcj)),
            atol=PARITY_ATOL)

    def test_unknown_solver_raises(self):
        with pytest.raises(ValueError):
            thermal.solve(jnp.zeros((4,)), 2, 2, 25.0,
                          ThermalConfig(solver="warp"))


class TestWarmStart:
    @settings(max_examples=8, deadline=None)
    @given(offset=st.floats(-30.0, 60.0))
    def test_converged_field_invariant_to_T0(self, offset):
        """Property: the steady state does not depend on the warm start."""
        m = 23
        tc = ThermalConfig(theta_ja=12.0)
        P = jnp.asarray(_power_maps(m * m)["uniform"], jnp.float32)
        T_default = np.asarray(thermal.solve(P, m, m, 25.0, tc))
        T0 = jnp.full((m * m,), 25.0 + offset, jnp.float32)
        T_warm = np.asarray(thermal.solve(P, m, m, 25.0, tc, T0))
        np.testing.assert_allclose(T_warm, T_default, atol=5e-3)

    def test_warm_start_from_converged_is_noop_fast_path(self):
        """Restarting from the converged field returns it unchanged (the
        0-cycle path: the initial residual is already under tol)."""
        m = 16
        tc = ThermalConfig(theta_ja=2.0)
        P = jnp.asarray(_power_maps(m * m)["hotspot"], jnp.float32)
        T1 = thermal.solve(P, m, m, 25.0, tc)
        T2 = thermal.solve(P, m, m, 25.0, tc, T1)
        np.testing.assert_array_equal(np.asarray(T1), np.asarray(T2))

    def test_accepts_2d_T0(self):
        m, n = 5, 7
        tc = ThermalConfig(theta_ja=2.0)
        P = jnp.zeros((m * n,))
        T0 = jnp.full((m, n), 40.0)
        T = thermal.solve(P, m, n, 25.0, tc, T0)
        np.testing.assert_allclose(np.asarray(T), 25.0, atol=1e-3)


class TestBackendDispatch:
    @pytest.mark.parametrize("m,n", [(8, 8), (23, 17)])
    def test_pallas_smoother_matches_jnp(self, m, n):
        """backend="pallas" routes the RB smoother through the fused
        Pallas kernel (interpreter off-TPU) — same steady state."""
        tc_j = ThermalConfig(theta_ja=12.0, backend="jnp")
        tc_p = ThermalConfig(theta_ja=12.0, backend="pallas")
        P = jnp.asarray(_power_maps(m * n)["uniform"], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(thermal.solve(P, m, n, 25.0, tc_j)),
            np.asarray(thermal.solve(P, m, n, 25.0, tc_p)), atol=1e-3)

    def test_auto_backend_is_jnp_off_tpu(self):
        assert thermal._use_pallas(ThermalConfig()) == (
            jax.default_backend() == "tpu")
        assert not thermal._use_pallas(ThermalConfig(backend="jnp"))
        assert thermal._use_pallas(ThermalConfig(backend="pallas"))


class TestRedBlackKernel:
    @pytest.mark.parametrize("m,n", [(8, 8), (16, 32), (92, 92)])
    @pytest.mark.parametrize("phase", [0, 1])
    def test_rb_kernel_matches_ref(self, m, n, phase):
        tc = ThermalConfig(theta_ja=12.0)
        g_v, g_lat = thermal.conductances(m, n, tc)
        rng = np.random.default_rng(11)
        T0 = jnp.asarray(rng.uniform(25, 40, (m, n)), jnp.float32)
        P = jnp.asarray(rng.uniform(0, 5e-3, (m, n)), jnp.float32)
        diag = jnp.asarray(thermal._diag_np(np.full((m, n), g_v), g_lat))
        out_k = ops.thermal_sweep(T0, P, diag, g_lat=g_lat,
                                  g_v_tamb=g_v * 25.0, iters=5, phase=phase)
        out_r = kref.thermal_stencil_ref(T0, P, diag, g_lat, g_v * 25.0, 5,
                                         phase=phase)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   rtol=1e-6, atol=1e-6)

    def test_rb_sweep_differs_from_jacobi(self):
        """The phases are genuinely sequential (black sees fresh red)."""
        m = 8
        tc = ThermalConfig(theta_ja=12.0)
        g_v, g_lat = thermal.conductances(m, m, tc)
        rng = np.random.default_rng(13)
        T0 = jnp.asarray(rng.uniform(25, 40, (m, m)), jnp.float32)
        P = jnp.asarray(rng.uniform(0, 5e-3, (m, m)), jnp.float32)
        diag = jnp.asarray(thermal._diag_np(np.full((m, m), g_v), g_lat))
        rb = ops.thermal_sweep(T0, P, diag, g_lat=g_lat, g_v_tamb=g_v * 25.0,
                               iters=1, phase=0)
        ja = ops.thermal_sweep(T0, P, diag, g_lat=g_lat, g_v_tamb=g_v * 25.0,
                               iters=1, phase=None)
        assert float(jnp.max(jnp.abs(rb - ja))) > 0


class TestSolveBatchT0:
    def test_vmapped_T0_equals_looped(self):
        """The satellite pin: one vmapped T0 call == the per-element loop."""
        prof = TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                            collective_s=0.2)
        sub = pol.tpu_substrate(prof)
        t = np.asarray([15.0, 25.0, 40.0], np.float32)
        B, chips = len(t), sub.n_domains
        envs = {"t_amb": jnp.asarray(t),
                "util": jnp.ones((B, chips), jnp.float32),
                "gamma": jnp.ones((B,), jnp.float32)}
        batched = jax.vmap(sub.T0)(envs)
        looped = jnp.stack([
            sub.T0(jax.tree_util.tree_map(lambda x: x[b], envs))
            for b in range(B)])
        np.testing.assert_array_equal(np.asarray(batched),
                                      np.asarray(looped))
