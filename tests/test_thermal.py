"""Thermal solver: theta_JA calibration, physics, kernel-vs-ref equality."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import thermal
from repro.core.thermal import ThermalConfig, conductances
from repro.kernels import ops, ref as kref


@pytest.mark.parametrize("theta", [2.0, 12.0])
def test_theta_ja_calibration(theta):
    """Paper setup: 1 W total -> mean junction rise == theta_JA."""
    tc = ThermalConfig(theta_ja=theta)
    m = n = 24
    P = jnp.full((m * n,), 1000.0 / (m * n))
    T = thermal.solve(P, m, n, 25.0, tc)
    assert float(T.mean() - 25.0) == pytest.approx(theta, rel=0.02)


def test_hotspot_peaks_above_mean():
    tc = ThermalConfig(theta_ja=12.0)
    P = jnp.zeros((32 * 32,)).at[32 * 16 + 16].set(1000.0)
    T = thermal.solve(P, 32, 32, 25.0, tc)
    assert float(T.max()) > float(T.mean()) + 50
    # energy balance: mean rise still == theta (all heat exits vertically)
    assert float(T.mean() - 25.0) == pytest.approx(12.0, rel=0.02)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.2, 5.0))
def test_linearity(scale):
    """Steady state is linear in power: T(c*P) - Tamb == c*(T(P) - Tamb)."""
    tc = ThermalConfig(theta_ja=2.0)
    rng = np.random.default_rng(0)
    P = jnp.asarray(rng.uniform(0, 10, (16 * 16,)), jnp.float32)
    T1 = thermal.solve(P, 16, 16, 25.0, tc)
    T2 = thermal.solve(P * scale, 16, 16, 25.0, tc)
    np.testing.assert_allclose(np.asarray(T2 - 25.0),
                               np.asarray(T1 - 25.0) * scale,
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("m,n", [(8, 8), (16, 32), (92, 92)])
@pytest.mark.parametrize("iters", [1, 17, 64])
def test_stencil_kernel_matches_ref(m, n, iters):
    tc = ThermalConfig(theta_ja=12.0)
    g_v, g_lat = conductances(m, n, tc)
    rng = np.random.default_rng(1)
    T0 = jnp.asarray(rng.uniform(25, 40, (m, n)), jnp.float32)
    P = jnp.asarray(rng.uniform(0, 5e-3, (m, n)), jnp.float32)
    nbrc = jnp.full((m, n), 4.0).at[0, :].add(-1).at[-1, :].add(-1) \
        .at[:, 0].add(-1).at[:, -1].add(-1)
    diag = g_v + g_lat * nbrc
    out_k = ops.thermal_sweep(T0, P, diag, g_lat=g_lat, g_v_tamb=g_v * 25.0,
                              iters=iters)
    out_r = kref.thermal_stencil_ref(T0, P, diag, g_lat, g_v * 25.0, iters)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-6, atol=1e-6)
