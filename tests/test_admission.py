"""Thermal-aware admission co-scheduling (repro.control.admission) +
the §8 serving acceptance day (scenarios.serve_replay)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro import scenarios as sc
from repro.configs import registry
from repro.control import (AdmissionController, LutController, SetRails,
                           Snapshot, Throttle)
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.models.model import Model


@pytest.fixture(scope="module")
def rt():
    return RT.EnergyAwareRuntime(
        TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                     collective_s=0.2),
        policy="power_save")


@pytest.fixture(scope="module")
def field(rt):
    from repro.control.lut import sweep_points
    return rt.build_field(sweep_points(10.0, 45.0, 4),
                          sweep_points(0.25, 1.0, 4))


def _adm(rt, field, **kw):
    kw.setdefault("defer_premium", 1.05)
    kw.setdefault("max_wait", 64.0)
    return AdmissionController(
        LutController(rt.planner, field=field, guard_band_c=3.0), **kw)


def _snap(t_amb, queued=3, active=0, slots=4, wait=0.0, t_chip=None):
    return Snapshot(t_amb=t_amb, queued=queued, active=active, slots=slots,
                    oldest_wait=wait, t_chip=t_chip)


def _cap(actions):
    thr = [a for a in actions if isinstance(a, Throttle)]
    assert len(thr) == 1  # exactly one joint Throttle per decision
    return thr[0].admit_cap


class TestAdmissionPricing:
    def test_cold_admits_hot_defers(self, rt, field):
        adm = _adm(rt, field)
        assert _cap(adm.decide(_snap(10.0))) == 3  # day's best price
        assert _cap(adm.decide(_snap(44.0))) == 0  # hot: defer everything
        assert adm.stats.deferred >= 3

    def test_rails_ride_with_the_throttle(self, rt, field):
        """SetRails and Throttle land as ONE decision, and the rails are
        computed at the planned (post-admission) utilization: admitting 3
        of 4 slots at a cold tick must program higher rails than the
        deferred (still ~idle) hot pod's sensed load would."""
        adm = _adm(rt, field)
        acts = adm.decide(_snap(10.0))
        rails = [a for a in acts if isinstance(a, SetRails)]
        assert len(rails) == 1 and _cap(acts) == 3
        vc_planned = float(np.median(np.asarray(rails[0].v_core)))
        vc_idle, _ = field.lookup(10.0, 0.25)
        assert vc_planned > float(np.median(vc_idle))  # rails for u=0.75

    def test_slo_forcing_admits_backlog(self, rt, field):
        adm = _adm(rt, field, max_wait=8.0)
        assert _cap(adm.decide(_snap(44.0, wait=7.9))) == 0
        assert _cap(adm.decide(_snap(44.0, wait=8.0))) == 3
        assert adm.stats.forced == 1

    def test_min_active_floor(self, rt, field):
        adm = _adm(rt, field, min_active=1)
        assert _cap(adm.decide(_snap(44.0, active=0))) == 1
        assert _cap(adm.decide(_snap(44.0, active=1))) == 0

    def test_free_slots_bound_the_budget(self, rt, field):
        adm = _adm(rt, field)
        assert _cap(adm.decide(_snap(10.0, queued=9, active=3))) == 1
        assert _cap(adm.decide(_snap(10.0, queued=9, active=4))) == 0

    def test_thermal_emergency_floors_the_budget(self, rt, field):
        """The inner controller's emergency throttle (junction temperature
        crowding the limit) caps admission even at the day's best price."""
        adm = _adm(rt, field)
        hot_chips = np.full(field.chips, TF.T_MAX_CHIP - 1.0)
        assert _cap(adm.decide(_snap(10.0, t_chip=hot_chips))) <= 1
        # the emergency cap stays armed across ticks (hysteresis) even
        # though the inner controller only emits Throttle on transitions
        assert _cap(adm.decide(_snap(10.0, t_chip=hot_chips))) <= 1
        cool_chips = np.full(field.chips, 60.0)
        assert _cap(adm.decide(_snap(10.0, t_chip=cool_chips))) == 3

    def test_passthrough_without_pricing_signal(self, rt, field):
        adm = _adm(rt, field)
        acts = adm.decide(_snap(25.0, slots=0))  # legacy ambient-only tick
        assert not any(isinstance(a, Throttle) for a in acts)
        assert adm.stats.passthrough == 1


class TestWorkloads:
    def test_poisson_fingerprint_pins_the_seed(self):
        a = sc.poisson_requests(ticks=8, rate=1.5, seed=0)
        b = sc.poisson_requests(ticks=8, rate=1.5, seed=0)
        c = sc.poisson_requests(ticks=8, rate=1.5, seed=1)
        assert a.fingerprint == b.fingerprint
        assert a.fingerprint != c.fingerprint

    def test_trace_requests_round_trip(self):
        wl = sc.trace_requests([(0, 4, 2), (3, 8, 5)])
        assert [a.tick for a in wl.arrivals] == [0, 3]
        assert wl.arrivals[1].prompt_len == 8
        assert wl.by_tick()[3][0].rid == 1

    def test_burst_rides_hot_window(self):
        wl = sc.poisson_burst(burst_at=2, burst_n=5, tail_ticks=3, seed=7)
        assert sum(a.tick == 2 for a in wl.arrivals) == 5
        assert all(a.tick > 2 for a in wl.arrivals[5:])


class TestServeReplayAcceptance:
    SLO = 60.0  # engine ticks, submit -> finish

    @pytest.fixture(scope="class")
    def dense(self):
        cfg = registry.get("llama3.2-1b").reduced()
        model = Model(cfg)
        return model, model.init(jax.random.PRNGKey(0))

    @pytest.fixture(scope="class")
    def runs(self, rt, field, dense):
        model, params = dense
        day = sc.serve_day(ticks=10, hot=42.0, cool=12.0, cool_at=5)
        wl = sc.poisson_burst(burst_at=1, burst_n=6, tail_ticks=2, seed=0)
        mk = lambda: LutController(rt.planner, field=field, guard_band_c=3.0)
        thru = sc.serve_replay(day, wl, model, params, controller=mk(),
                               runtime=rt)
        therm = sc.serve_replay(
            day, wl, model, params, runtime=rt,
            controller=AdmissionController(mk(), defer_premium=1.05,
                                           max_wait=240.0))
        return wl, thru, therm

    def test_thermal_beats_throughput_at_equal_slo(self, runs):
        wl, thru, therm = runs
        # same requests, same greedy tokens — the energy is the difference
        assert thru.outputs == therm.outputs
        assert thru.finished == therm.finished == len(wl.arrivals)
        assert thru.rejected == therm.rejected == 0
        assert thru.max_wait <= self.SLO and therm.max_wait <= self.SLO
        assert therm.deferred > 0  # the hot window was actually deferred
        assert therm.tokens_per_joule > thru.tokens_per_joule

    def test_thermal_emergency_preempts_and_resumes_identically(
            self, rt, field, dense):
        """§9 escalation tail: a junction-temperature runaway while slots
        are busy must Preempt active low-priority requests (KV to the host
        page pool) and the requeued requests must finish with the very
        same greedy tokens as the undisturbed baseline run."""
        model, params = dense
        day = sc.serve_day(ticks=10, hot=42.0, cool=12.0, cool_at=5)
        # runaway lands AFTER the cool-down, when the backlog has been
        # bulk-admitted and the slots are actually busy
        day = dataclasses.replace(
            day, hotspots=tuple(sc.Hotspot(t, 0, TF.T_MAX_CHIP - 1.0)
                                for t in (6, 7)))
        wl = sc.poisson_burst(burst_at=1, burst_n=6, tail_ticks=2, seed=0)
        mk = lambda: LutController(rt.planner, field=field, guard_band_c=3.0)
        thru = sc.serve_replay(day, wl, model, params, controller=mk(),
                               runtime=rt)
        therm = sc.serve_replay(
            day, wl, model, params, runtime=rt,
            controller=AdmissionController(mk(), defer_premium=1.05,
                                           max_wait=240.0, preempt=True))
        assert therm.preempts > 0 and therm.preempted_reqs > 0
        assert therm.outputs == thru.outputs  # bitwise-identical resumption
        assert therm.finished == thru.finished == len(wl.arrivals)

    def test_replay_is_fingerprint_pinned(self, rt, field, dense, runs):
        wl, _, therm = runs
        model, params = dense
        day = sc.serve_day(ticks=10, hot=42.0, cool=12.0, cool_at=5)
        again = sc.serve_replay(
            day, wl, model, params, runtime=rt,
            controller=AdmissionController(
                LutController(rt.planner, field=field, guard_band_c=3.0),
                defer_premium=1.05, max_wait=240.0))
        assert again.fingerprint == therm.fingerprint
        assert again.caps.tolist() == therm.caps.tolist()
