"""repro.control — the telemetry -> controller -> actuator control plane.

Covers the ISSUE-2 closed-loop contract: the DynamicLut fast path stays
within a rail guard band of the full solver, a diurnal ambient sweep keeps
t_max under the rated junction limit while saving power vs nominal, an
injected straggler triggers rail-boost-then-rebalance, a throttled serve
engine still completes every request, the rolling straggler median matches
the legacy sort-everything statistic, and the nominal-baseline solve is
cached per environment.
"""
import numpy as np
import pytest

import jax

from repro import control as ctl
from repro.core import runtime as RT
from repro.core import tpu_fleet as TF
from repro.ft.monitor import StragglerDetector, _RollingMedian


@pytest.fixture(scope="module")
def profile():
    return TF.StepProfile.from_roofline(compute_s=0.8, memory_s=0.45,
                                        collective_s=0.2)


@pytest.fixture(scope="module")
def runtime(profile):
    return RT.EnergyAwareRuntime(profile, policy="power_save")


@pytest.fixture(scope="module")
def lut(runtime):
    return runtime.build_lut([10.0, 20.0, 30.0, 40.0, 50.0])


class TestDynamicLut:
    # one 10 mV rail step: the interpolant over 10C knots must stay within
    # a grid step of the full fixed point (the controller's trust contract)
    RAIL_GUARD_V = 0.010

    def test_interp_error_under_guard_band(self, runtime, lut):
        for t in (15.0, 25.0, 35.0, 45.0):
            vc_full, vs_full = runtime.planner.lut([t])[t]
            vc_i, vs_i = lut.lookup(t)
            assert abs(vc_i - vc_full) <= self.RAIL_GUARD_V + 1e-9
            assert abs(vs_i - vs_full) <= self.RAIL_GUARD_V + 1e-9

    def test_clamps_at_sweep_edges(self, lut):
        assert lut.lookup(-5.0) == lut.lookup(lut.t_min)
        assert lut.lookup(90.0) == lut.lookup(lut.t_max)
        assert lut.covers(30.0) and not lut.covers(55.0)
        assert lut.covers(52.0, margin=2.0)

    def test_wraps_raw_dynamic_lut_table(self, runtime):
        raw = runtime.dynamic_lut([15.0, 30.0, 45.0])
        assert isinstance(raw, dict)  # legacy contract: raw knot dict
        wrapped = ctl.DynamicLut(raw)
        for t, (vc, vs) in raw.items():
            got = wrapped.lookup(t)
            assert got[0] == pytest.approx(vc, abs=1e-6)
            assert got[1] == pytest.approx(vs, abs=1e-6)
        assert wrapped.as_table().keys() == raw.keys()

    def test_array_lookup(self, lut):
        vc, vs = lut.lookup(np.asarray([15.0, 25.0]))
        assert vc.shape == (2,) and vs.shape == (2,)

    def test_rejects_empty(self):
        with pytest.raises(ValueError):
            ctl.DynamicLut({})


class TestClosedLoop:
    def _loop(self, runtime, lut, trace, **ctrl_kw):
        controller = runtime.controller(lut=lut, **ctrl_kw)
        fleet = ctl.FleetActuator.from_runtime(runtime)
        bus = ctl.TelemetryBus([ctl.AmbientSensor(trace), fleet])
        return ctl.ControlLoop(bus, controller, [fleet]), controller, fleet

    def test_diurnal_sweep_saves_power_bounded_tmax(self, runtime, lut):
        trace = lambda now: 25.0 + 10.0 * np.sin(2 * np.pi * now / 24.0)
        loop, controller, fleet = self._loop(runtime, lut, trace,
                                             guard_band_c=3.0)
        reports = [loop.step(now=float(h)) for h in range(24)]
        t_maxes = [r.readout.t_max for r in reports]
        savings = [r.readout.saving for r in reports]
        assert max(t_maxes) < TF.T_MAX_CHIP  # junction limit held all day
        assert np.mean(savings) > 0.0  # margin converted to power
        # steady state rides the LUT; the solver runs only on the cold start
        assert controller.stats.lut_hits > controller.stats.replans
        assert controller.stats.replans >= 1

    def test_ambient_jump_triggers_full_replan(self, runtime, lut):
        trace = lambda now: 22.0 if now < 3 else 34.0  # forced step change
        loop, controller, _ = self._loop(runtime, lut, trace,
                                         guard_band_c=2.0)
        for k in range(6):
            loop.step(now=float(k))
        assert controller.stats.replans == 2  # cold start + the jump
        assert any(r.startswith("ambient_jump")
                   for r in controller.stats.replan_reasons)
        assert controller.stats.lut_hits == 4

    def test_out_of_range_ambient_replans(self, runtime, lut):
        loop, controller, _ = self._loop(runtime, lut, 52.0,
                                         guard_band_c=1.0)
        loop.step(now=0.0)  # cold start
        loop.step(now=1.0)  # 52C is outside the [10, 50] sweep + guard
        assert any(r.startswith("lut_range")
                   for r in controller.stats.replan_reasons[1:])

    def test_straggler_boost_then_rebalance(self, runtime, lut):
        det = StragglerDetector(threshold=1.5, window=8, min_samples=4)
        mon = ctl.MonitorTelemetry(det)
        controller = runtime.controller(lut=lut, guard_band_c=2.0)
        fleet = ctl.FleetActuator.from_runtime(runtime)
        bus = ctl.TelemetryBus([ctl.AmbientSensor(25.0), mon, fleet])
        loop = ctl.ControlLoop(bus, controller, [fleet])
        loop.step(now=0.0)  # settle: chip temps ~warm, far from the limit

        for s in range(4):  # healthy fleet baseline
            mon.record_step("worker7", s, 1.0)
        mon.record_step("worker7", 4, 1.9)  # slow step -> straggler event
        rep = loop.step(now=1.0)
        boosts = [a for a in rep.actions if isinstance(a, ctl.BoostRail)]
        assert len(boosts) == 1 and boosts[0].chip == 7
        assert boosts[0].extra_power_w > 0  # perf-preserving costs power
        assert fleet.v_core[7] == pytest.approx(TF.V_CORE_NOM)
        assert fleet.v_sram[7] == pytest.approx(TF.V_SRAM_NOM)
        assert 7 in fleet.boosted

        # chip so hot even nominal rails can't hold the clock -> rebalance
        fleet.T = fleet.T.copy()
        fleet.T[7] = 94.5
        mon.record_step("worker7", 5, 2.2)
        rep = loop.step(now=2.0)
        rebs = [a for a in rep.actions if isinstance(a, ctl.Rebalance)]
        assert len(rebs) == 1 and rebs[0].chip == 7
        assert 7 not in fleet.boosted  # work moved off; boost released
        assert controller.stats.boosts == 1
        assert controller.stats.rebalances == 1

    def test_thermal_pressure_throttles_then_lifts(self, runtime, lut):
        class FakeEngine:
            admit_cap = None

        eng = FakeEngine()
        controller = runtime.controller(lut=lut, guard_band_c=50.0,
                                        t_headroom_c=5.0)
        fleet = ctl.FleetActuator.from_runtime(runtime)
        bus = ctl.TelemetryBus([ctl.AmbientSensor(25.0), fleet])
        loop = ctl.ControlLoop(bus, controller,
                               [fleet, ctl.EngineActuator(eng)])
        loop.step(now=0.0)
        fleet.T = fleet.T.copy()
        fleet.T[:] = TF.T_MAX_CHIP - 1.0  # emergency band
        rep = loop.step(now=1.0)
        assert eng.admit_cap == controller.throttle_cap
        assert any(isinstance(a, ctl.Throttle) for a in rep.actions)
        # a thermal emergency also forces a replan regardless of drift
        assert any(r.startswith("thermal_emergency")
                   for r in controller.stats.replan_reasons)
        # cooled back down -> throttle lifts
        fleet.T = np.asarray(runtime.substrate.T0({"t_amb": 25.0})).copy()
        loop.step(now=2.0)
        loop.step(now=3.0)
        assert eng.admit_cap is None


class TestWorkerChipMapping:
    def test_trailing_digits_only(self):
        from repro.control.telemetry import _default_chip_of
        assert _default_chip_of("worker7") == 7
        assert _default_chip_of("host1-worker7") == 7  # not 17
        assert _default_chip_of("tpu-v4-rank12") == 12
        assert _default_chip_of("coordinator") == 0

    def test_unmapped_chip_does_not_crash_the_tick(self, runtime, lut):
        controller = runtime.controller(lut=lut)
        snap = ctl.Snapshot(t_amb=25.0, stragglers=[
            ctl.StragglerSample("w", 0, 2.0, chip=999)])  # out of range
        actions = controller.decide(snap)
        assert not any(isinstance(a, (ctl.BoostRail, ctl.Rebalance))
                       for a in actions)
        assert controller.stats.unmapped == 1


class TestTelemetryBus:
    def test_scalar_state_persists_events_drain(self):
        class OneShot:
            def __init__(self):
                self.fired = False

            def poll(self, now):
                if self.fired:
                    return []
                self.fired = True
                return [ctl.AmbientSample(30.0),
                        ctl.StragglerSample("w1", 3, 2.0, 1)]

        bus = ctl.TelemetryBus([OneShot()])
        s1 = bus.poll(0.0)
        assert s1.t_amb == 30.0 and len(s1.stragglers) == 1
        s2 = bus.poll(1.0)
        assert s2.t_amb == 30.0  # latest value carries forward
        assert s2.stragglers == []  # events deliver exactly once


class TestRollingMedian:
    def test_matches_legacy_sorted_median(self):
        rng = np.random.default_rng(0)
        det = StragglerDetector(threshold=1e9, window=5, min_samples=1)
        from collections import deque
        shadow = {}
        for i in range(400):
            w = f"worker{int(rng.integers(0, 6))}"
            v = float(rng.uniform(0.5, 3.0))
            det.record(w, i, v)
            dq = shadow.setdefault(w, deque(maxlen=5))
            dq.append(v)
            allt = sorted(t for d in shadow.values() for t in d)
            assert det._median.median == allt[len(allt) // 2]

    def test_boundary_duplicate_removal(self):
        # regression: duplicates straddling the lo/hi boundary must not
        # desync the heap sizes when one instance is removed
        m = _RollingMedian()
        for v in [1.0, 2.0, 2.0, 3.0]:
            m.add(v)
        m.remove(2.0)
        assert m.median == 2.0  # {1,2,3} -> sorted[1]
        assert len(m) == 3

    def test_fuzz_quantized_times_vs_sorted(self):
        # step times that quantize to equal values exercise the boundary-
        # straddling duplicate path on every window eviction
        rng = np.random.default_rng(7)
        det = StragglerDetector(threshold=1e9, window=3, min_samples=1)
        from collections import deque
        shadow = {}
        for i in range(300):
            w = f"worker{int(rng.integers(0, 4))}"
            v = float(rng.choice([1.0, 1.5, 2.0]))
            det.record(w, i, v)
            dq = shadow.setdefault(w, deque(maxlen=3))
            dq.append(v)
            allt = sorted(t for d in shadow.values() for t in d)
            assert det._median.median == allt[len(allt) // 2]

    def test_duplicates_and_removals(self):
        m = _RollingMedian()
        for v in [1.0, 1.0, 1.0, 2.0, 2.0]:
            m.add(v)
        assert m.median == 1.0  # sorted[2]
        m.remove(1.0)
        assert m.median == 2.0  # [1,1,2,2] -> sorted[2]
        m.remove(2.0)
        assert m.median == 1.0  # [1,1,2]
        assert len(m) == 3

    def test_detector_still_flags_stragglers(self):
        det = StragglerDetector(threshold=1.5, window=16, min_samples=4)
        for s in range(6):
            assert det.record("w0", s, 1.0) is None
        ev = det.record("w1", 6, 1.8)
        assert ev is not None and ev.ratio == pytest.approx(1.8)


class TestBaselineCache:
    def test_baseline_solved_once_per_environment(self, profile):
        rt = RT.EnergyAwareRuntime(profile, policy="power_save")
        rt.plan()
        rt.plan()
        assert rt.planner.baseline_solves == 1  # same env -> cache hit
        rt.t_amb = 31.0  # new environment -> one more solve
        rt.plan()
        rt.plan()
        assert rt.planner.baseline_solves == 2
        util = np.ones(rt.m * rt.n, np.float32)
        util[:8] = 0.5  # new utilization -> new environment
        rt.plan(util_scale=util)
        assert rt.planner.baseline_solves == 3

    def test_cached_baseline_matches_policy_switch(self, profile):
        # the cached baseline is policy-independent: two policies on the
        # same environment report the same nominal reference power
        a = RT.EnergyAwareRuntime(profile, policy="power_save").plan()
        b = RT.EnergyAwareRuntime(profile, policy="overscale:1.2").plan()
        assert a.baseline_power_w == pytest.approx(b.baseline_power_w,
                                                   rel=1e-6)


class TestEngineControlPlane:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import registry
        from repro.models.model import Model
        cfg = registry.get("llama3.2-1b").reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        return cfg, model, params

    def test_throttled_engine_completes_all_requests(self, setup):
        from repro.serve.engine import Engine, Request
        cfg, model, params = setup
        eng = Engine(model, params, batch_slots=4, max_len=64,
                     admit_cap=1)  # throttled actuation
        for rid in range(5):
            eng.submit(Request(rid, np.arange(3 + rid) % cfg.vocab_size,
                               max_new=4))
        done = eng.run()
        assert len(done) == 5
        for r in done:
            assert 1 <= len(r.out) <= 4

    def test_tick_telemetry_reaches_snapshot(self, setup):
        from repro.serve.engine import Engine, Request
        cfg, model, params = setup
        eng = Engine(model, params, batch_slots=2, max_len=64)
        src = ctl.EngineTelemetry()
        eng.on_tick.append(src.on_tick)
        for rid in range(3):
            eng.submit(Request(rid, np.arange(4) % cfg.vocab_size,
                               max_new=3))
        bus = ctl.TelemetryBus([src])
        eng.run()
        snap = bus.poll(0.0)
        assert snap.tokens > 0  # decode ticks reported their tokens
        assert snap.tick_s is not None and snap.tick_s > 0
        assert snap.queued == 0 and snap.active == 0  # drained at the end

    def test_throttle_action_programs_engine(self, setup):
        from repro.serve.engine import Engine
        cfg, model, params = setup
        eng = Engine(model, params, batch_slots=2, max_len=32)
        act = ctl.EngineActuator(eng)
        assert act.apply(ctl.Throttle(1)) and eng.admit_cap == 1
        assert act.apply(ctl.Throttle(None)) and eng.admit_cap is None
        assert not act.apply(ctl.SetRails(0.7, 0.8, "lut"))  # not ours
