"""Re-lower + re-analyze one cell (the §Perf inner loop)."""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
import argparse, json, sys, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("cell", help="arch:shape")
    ap.add_argument("--tag", default="opt")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    from repro.launch.dryrun import run_cell
    hlo = f"artifacts/hlo_perf/{arch}_{shape}_{args.tag}.hlo"
    os.makedirs("artifacts/hlo_perf", exist_ok=True)
    rec = run_cell(arch, shape, "pod", save_hlo=hlo)
    if not rec["ok"]:
        print(rec["error"]); sys.exit(1)
    from benchmarks.roofline import analyze_cell
    r = analyze_cell(arch, shape, args.tag, hlo_dir="artifacts/hlo_perf")
    print(json.dumps({k: r[k] for k in
                      ("compute_s", "memory_s", "collective_s", "dominant",
                       "useful_ratio", "roofline_fraction")}, indent=1))
    print("mem/dev GB: args=%.2f temp=%.2f" % (
        rec["argument_bytes_per_device"]/1e9, rec["temp_bytes_per_device"]/1e9))


if __name__ == "__main__":
    main()
