"""Kernel microbenchmarks (interpret-mode wall times are STRUCTURAL only —
the CPU interpreter executes the kernel body; TPU perf comes from the
roofline, not these numbers). Also times each kernel's jnp reference, which
IS meaningful on CPU.

The ``thermal_solve_*_us`` family times the full steady-state solve at the
paper's 92x92 / theta_ja=12 reference point through each solver tier
(multigrid cold + warm restart, chunked Jacobi, seed Jacobi) — the number
every fixed point in the repo bottoms out in.

``--smoke`` additionally runs the closed-loop serving tick benchmark
(repro.control): engine tokens/s, LUT-fast-path control tick latency, and
full-solver replan latency. ``--json PATH`` dumps every number for the CI
artifact. ``--check BASELINE.json`` compares against a committed baseline
(BENCH_kernels.json) and fails on >2x regression of any jnp-path ``*_us``
entry (interpret-mode entries are structural and excluded)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def run(quick: bool = False) -> Dict:
    from repro.kernels import ops, ref as kref
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(0)
    out = {}

    S, D = (256, 64) if quick else (1024, 128)
    q = jax.random.normal(jax.random.fold_in(key, 1), (S, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (S, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (S, D))
    out["flash_attention_ref_us"] = _time(
        lambda a, b, c: kref.flash_attention_ref(a, b, c), q, k, v)
    out["flash_attention_interpret_us"] = _time(
        lambda a, b, c: flash_attention(a, b, c, interpret=True), q, k, v)

    # paged-attention decode: block-table K/V gather through scalar
    # prefetch (B slots, non-contiguous pages, one query token per slot)
    Bp, Hp, Hkv, Dp = (4, 8, 2, 64)
    ps, npages = (16, 4) if quick else (16, 16)
    Pp = Bp * npages
    qp = jax.random.normal(jax.random.fold_in(key, 20), (Bp, Hp, Dp))
    kp = jax.random.normal(jax.random.fold_in(key, 21), (Pp + 1, ps, Hkv, Dp))
    vp = jax.random.normal(jax.random.fold_in(key, 22), (Pp + 1, ps, Hkv, Dp))
    posp = jnp.full((Bp,), npages * ps - 1, jnp.int32)
    # page slot*npages + j carries logical positions [j*ps, (j+1)*ps); the
    # trailing pool index Pp is the invalid null page (ids = -1)
    idsp = (jnp.arange(ps, dtype=jnp.int32)[None]
            + (jnp.arange(Pp + 1, dtype=jnp.int32)[:, None] % npages) * ps
            ).at[Pp].set(-1)
    btp = (jnp.arange(npages, dtype=jnp.int32)[None]
           + jnp.arange(Bp, dtype=jnp.int32)[:, None] * npages)
    out["paged_attention_ref_us"] = _time(
        lambda *a: kref.paged_attention_ref(*a), qp, kp, vp, idsp, btp, posp)
    out["paged_attention_interpret_us"] = _time(
        lambda *a: ops.paged_attention_decode(*a), qp, kp, vp, idsp, btp,
        posp)

    b, S2, H, P, N = 1, (128 if quick else 512), 8, 32, 64
    xh = jax.random.normal(jax.random.fold_in(key, 4), (b, S2, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5),
                                           (b, S2, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (H,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 7), (b, S2, H, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 8), (b, S2, H, N)) * 0.3
    out["mamba_scan_ref_us"] = _time(
        lambda *a: kref.mamba_scan_ref(*a, 64)[0], xh, dt, A, B, Cm)
    out["mamba_scan_interpret_us"] = _time(
        lambda *a: ops.mamba_scan_b(*a, chunk=64), xh, dt, A, B, Cm)

    m = 92
    from repro.core import thermal
    from repro.core.thermal import ThermalConfig, conductances
    tc = ThermalConfig(theta_ja=12.0)
    g_v, g_lat = conductances(m, m, tc)
    T = jnp.full((m, m), 30.0)
    Pw = jax.random.uniform(jax.random.fold_in(key, 9), (m, m)) * 5e-3
    nbrc = jnp.full((m, m), 4.0).at[0, :].add(-1).at[-1, :].add(-1) \
        .at[:, 0].add(-1).at[:, -1].add(-1)
    diag = g_v + g_lat * nbrc
    out["thermal_stencil_ref_us"] = _time(
        lambda *a: kref.thermal_stencil_ref(*a, 64), T, Pw, diag, g_lat,
        g_v * 25.0)
    out["thermal_stencil_interpret_us"] = _time(
        lambda t, p, d: ops.thermal_sweep(t, p, d, g_lat=g_lat,
                                          g_v_tamb=g_v * 25.0, iters=64),
        T, Pw, diag)

    # full steady-state solve, 92x92 theta_ja=12 (the paper's Table-II die):
    # multigrid tier (cold + warm restart) vs the chunked and seed (one
    # reduce per sweep) Jacobi relaxations — all pure-jnp on CPU
    P_mw = Pw.reshape(-1) * 1e3
    tc_seed = ThermalConfig(theta_ja=12.0, solver="jacobi", check_every=1)
    tc_chunk = ThermalConfig(theta_ja=12.0, solver="jacobi")
    out["thermal_solve_multigrid_us"] = _time(
        lambda p: thermal.solve(p, m, m, 25.0, tc), P_mw)
    T_conv = thermal.solve(P_mw, m, m, 25.0, tc)
    out["thermal_solve_multigrid_warm_us"] = _time(
        lambda p, t0: thermal.solve(p, m, m, 25.0, tc, t0), P_mw, T_conv)
    out["thermal_solve_jacobi_chunked_us"] = _time(
        lambda p: thermal.solve(p, m, m, 25.0, tc_chunk), P_mw)
    out["thermal_solve_jacobi_seed_us"] = _time(
        lambda p: thermal.solve(p, m, m, 25.0, tc_seed), P_mw)
    out["thermal_solve_speedup"] = (out["thermal_solve_jacobi_seed_us"]
                                    / out["thermal_solve_multigrid_us"])

    M = 128 if quick else 256
    a8 = jax.random.randint(jax.random.fold_in(key, 10), (M, M), -128, 127,
                            jnp.int8)
    b8 = jax.random.randint(jax.random.fold_in(key, 11), (M, M), -128, 127,
                            jnp.int8)
    ug = jax.random.bits(jax.random.fold_in(key, 12), (M, M), jnp.uint32)
    ub = jax.random.bits(jax.random.fold_in(key, 13), (M, M), jnp.uint32)
    from repro.kernels.overscale_matmul import bit_probs_to_cdf
    probs = np.zeros(32)
    probs[28:] = 0.01
    cdf = bit_probs_to_cdf(probs)
    out["overscale_matmul_ref_us"] = _time(
        kref.overscale_matmul_ref, a8, b8, ug, ub, cdf)
    out["overscale_matmul_interpret_us"] = _time(
        lambda *a: ops.overscale_mm(*a), a8, b8, ug, ub, cdf)

    # ABFT-checksummed variant (repro.tolerance): the jnp oracle is the
    # gated timing; the fused Pallas kernel is structural on CPU.  The
    # detect rate is data (deterministic given the key), not a gate.
    out["abft_matmul_us"] = _time(
        kref.abft_matmul_ref, a8, b8, ug, ub, cdf)
    out["abft_matmul_interpret_us"] = _time(
        lambda *a: ops.abft_mm(*a), a8, b8, ug, ub, cdf)
    from repro.tolerance import AbftMatmul
    sparse = np.zeros(32)
    sparse[20:] = 0.002 / 12  # distinct deltas: syndromes localize
    af = jax.random.normal(jax.random.fold_in(key, 14), (M, M))
    bf = jax.random.normal(jax.random.fold_in(key, 15), (M, M))
    mm = AbftMatmul(sparse, jax.random.fold_in(key, 16))
    mm(af, bf)
    assert mm.counters.injected > 0
    out["sdc_detect_rate"] = mm.counters.detect_rate
    return out


def closed_loop(quick: bool = True) -> Dict:
    """Closed-loop serving tick benchmark (DESIGN.md §3).

    Measures the latencies that matter for the control plane under load:
    serve-engine token throughput, the LutController fast-path tick
    (interpolated lookup + actuation + thermal settle), a full-solver
    replan (warm jit), the thermal-aware admission decision, and the
    tokens/joule the §8 acceptance day serves at."""
    import jax
    import numpy as np

    from repro import control as ctl
    from repro.configs import registry
    from repro.core import runtime as RT
    from repro.core import tpu_fleet as TF
    from repro.models.model import Model
    from repro.serve.engine import Engine, Request

    out = {}

    # -- serving throughput under continuous batching ------------------------
    # the headline number runs the PAGED path with speculative decode (the
    # production configuration); the contiguous engine rides along as the
    # decode-tax comparator.  Best-of-3 days: the tokens are deterministic,
    # only the wall clock varies.
    cfg = registry.get("llama3.2-1b").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_req = 6 if quick else 16

    def _serve_day(eng):
        best = 0.0
        for _ in range(3):
            for rid in range(n_req):
                eng.submit(Request(rid, np.arange(4 + rid % 3)
                                   % cfg.vocab_size, max_new=8))
            eng.step()  # prefill/decode compiles land on day one only
            t0 = time.time()
            eng.run()
            toks = sum(len(r.out) for r in eng.finished)
            eng.finished.clear()
            best = max(best, toks / (time.time() - t0))
        return best

    eng = Engine(model, params, batch_slots=4, max_len=64, paged=True,
                 speculate=3)
    out["serve_tokens_per_s"] = _serve_day(eng)
    out["spec_decode_accept_rate"] = eng.spec_accept_rate
    assert out["spec_decode_accept_rate"] > 0.0
    eng_c = Engine(model, params, batch_slots=4, max_len=64)
    out["serve_tokens_per_s_contiguous"] = _serve_day(eng_c)

    # paged decode tax: one fused decode tick, block-table gather/scatter
    # vs the contiguous cache, interleaved best-of-reps so machine drift
    # hits both paths equally.  The 1.2x bound is the PR's acceptance gate.
    def _steady(paged):
        e = Engine(model, params, batch_slots=4, max_len=64, paged=paged)
        for rid in range(4):
            e.submit(Request(rid, np.arange(6) % cfg.vocab_size,
                             max_new=60))
        for _ in range(4):
            e.step()  # feed prompts; all slots now mid-decode
        plan, _ = e._compose()
        key = jax.random.PRNGKey(0)
        e._run_fused(e._fused, plan, key)
        return e, plan, key

    pair = {False: _steady(False), True: _steady(True)}
    best = {False: float("inf"), True: float("inf")}
    iters = 20
    for _ in range(9):
        for paged, (e, plan, key) in pair.items():
            t0 = time.perf_counter()
            for _ in range(iters):
                e._run_fused(e._fused, plan, key)
            best[paged] = min(best[paged],
                              (time.perf_counter() - t0) / iters)
    out["contig_decode_us"] = best[False] * 1e6
    out["paged_decode_us"] = best[True] * 1e6
    tax = out["paged_decode_us"] / out["contig_decode_us"]
    assert tax <= 1.2, (
        f"paged decode tax {tax:.3f}x exceeds the 1.2x budget "
        f"({out['paged_decode_us']:.0f}us vs "
        f"{out['contig_decode_us']:.0f}us)")

    # -- control-plane latencies --------------------------------------------
    from repro.control.lut import sweep_points
    prof = TF.StepProfile.from_roofline(compute_s=0.7, memory_s=0.4,
                                        collective_s=0.15)
    rt = RT.EnergyAwareRuntime(prof, policy="power_save")
    t_knots, u_knots = sweep_points(15.0, 40.0, 6), sweep_points(0.25, 1.0, 4)
    t0 = time.time()
    controller = rt.controller(sweep=(15.0, 40.0, 6),
                               util_sweep=(0.25, 1.0, 4), guard_band_c=3.0)
    out["lut_build_s"] = time.time() - t0  # cold 2-D field incl. compiles

    # warm 2-D RailField rebuild (the steady-state refresh cost): the whole
    # ambient x utilization grid through the early-freeze batched solver,
    # vs the lockstep path.  Best-of-3 so one GC pause / device-sync
    # hiccup can't trip the 2x gate; the speedup ratio is REPORTED data,
    # not a gated claim — at this 6x4 grid on CPU the compaction win and
    # the segment-dispatch overhead roughly cancel (the win grows with
    # batch size and convergence spread; the build stays ONE logical
    # sweep either way)
    def _best_of(fn, n=3):
        ts = []
        for _ in range(n):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return min(ts) * 1e3

    field = rt.planner.rail_field(t_knots, u_knots)  # warm the jits
    out["railfield_build_ms"] = _best_of(
        lambda: rt.planner.rail_field(t_knots, u_knots))
    rt.planner.rail_field(t_knots, u_knots, early_freeze=False)  # compile
    out["railfield_build_lockstep_ms"] = _best_of(
        lambda: rt.planner.rail_field(t_knots, u_knots,
                                      early_freeze=False))
    out["railfield_build_speedup"] = (out["railfield_build_lockstep_ms"]
                                      / out["railfield_build_ms"])
    iters = 2000  # per-chip bilinear fast-path lookup
    t0 = time.perf_counter()
    for k in range(iters):
        field.lookup(27.3 + 1e-4 * k, 0.77)
    out["railfield_lookup_us"] = (time.perf_counter() - t0) / iters * 1e6

    amb = ctl.AmbientSensor(25.0)
    fleet = ctl.FleetActuator.from_runtime(rt)
    loop = ctl.ControlLoop(ctl.TelemetryBus([amb, fleet]), controller,
                           [fleet])
    loop.step(now=0.0)  # cold start: solver replan + jit compile

    amb.trace = 35.0  # beyond the guard band -> warm full-solver replan
    t0 = time.perf_counter()
    loop.step(now=1.0)
    out["replan_latency_ms"] = (time.perf_counter() - t0) * 1e3

    iters = 5
    t0 = time.perf_counter()
    for k in range(iters):  # quasi-static drift stays on the LUT fast path
        amb.trace = 35.0 + 0.1 * (k + 1)
        loop.step(now=2.0 + k)
    out["ctl_tick_ms"] = (time.perf_counter() - t0) / iters * 1e3
    assert controller.stats.replans == 2 and controller.stats.lut_hits == iters

    # the replan core in isolation (warm jit, warm-started fixed point,
    # averaged — replan_latency_ms above is one tick incl. settle/telemetry
    # and is noise-dominated): Algorithm 1 rails -> thermal solve -> repeat
    rt.plan()
    t0 = time.perf_counter()
    for _ in range(5):
        rt.plan()
    out["fleet_plan_ms"] = (time.perf_counter() - t0) / 5 * 1e3

    # -- thermal-aware admission (DESIGN.md §8) ------------------------------
    # decision latency: one AdmissionController tick = marginal-power
    # pricing off the p_nom grid + the inner RailField lookup (the path a
    # production scheduler runs per control tick, gated like the lookup)
    from repro import scenarios as sc
    from repro.control.admission import AdmissionController
    adm = AdmissionController(controller, defer_premium=1.05)
    adm.decide(ctl.Snapshot(t_amb=25.0, queued=3, active=1, slots=4))
    iters = 1000
    t0 = time.perf_counter()
    for k in range(iters):
        adm.decide(ctl.Snapshot(t_amb=25.0 + 1e-4 * k, queued=3, active=1,
                                slots=4))
    out["admission_latency_us"] = (time.perf_counter() - t0) / iters * 1e6

    # served efficiency on the §8 acceptance day (hot window -> cool-down,
    # burst during the hot window): tokens per joule with thermal-aware
    # admission.  Deterministic inputs, but wall-clock-free only in the
    # token ledger — the energy integral is simulated, so the number is
    # stable; it is still reported (not gated) because it shifts whenever
    # the power model or the day is retuned.
    day = sc.serve_day(ticks=8, hot=38.0, cool=16.0, cool_at=4)
    wl = sc.poisson_burst(burst_at=1, burst_n=6, seed=0)
    rep = sc.serve_replay(day, wl, model, params, controller=adm,
                          runtime=rt, engine_steps=6, batch_slots=4,
                          max_len=64)
    out["serve_tokens_per_joule"] = rep.tokens_per_joule

    # -- fault containment (DESIGN.md §9) ------------------------------------
    # thermal-emergency preemption latency on the PAGED path: one Preempt
    # actuation = gather the victim's allocated block-table pages (page-
    # exact, not the slot's full span), device->host into the page pool,
    # free the pages, requeue (the resume tick afterwards is untimed)
    eng2 = Engine(model, params, batch_slots=4, max_len=64, paged=True)
    for rid in range(10):
        eng2.submit(Request(rid, np.arange(6) % cfg.vocab_size, max_new=48))
    eng2.step()  # fill slots, pay prefill/decode + gather compiles
    eng2.preempt_to(eng2.B - 1)  # compile the row gather outside the timing
    lat = []
    for _ in range(3 if quick else 8):
        while sum(r is not None for r in eng2.slot_req) < 2 and eng2.step():
            pass
        t0 = time.perf_counter()
        eng2.preempt_to(1)
        lat.append(time.perf_counter() - t0)
        eng2.step()  # untimed: re-admit (bitwise resume) for the next round
    out["preempt_latency_us"] = float(np.mean(lat)) * 1e6

    # watchdog recovery on the §9 chaos day: ticks from a trip (missed
    # deadline / diverged solver) back to the normal solver-eligible path.
    # Deterministic (seeded fault streams), so the --check gate pins it.
    crep = sc.replay(sc.chaos_day(ticks=20), runtime=rt,
                     controller=controller)
    assert crep.recover_ticks, "chaos day completed no watchdog episode"
    out["mean_ticks_to_recover"] = crep.mean_ticks_to_recover

    # -- fleet failure domains (DESIGN.md §10) -------------------------------
    # the multi-pod control tick on the LUT fast path: fan-out poll, two
    # pod decides off slices of one shared RailField, one global settle.
    # Pure numpy + one thermal solve per tick -> gated like the flat tick.
    from repro.ft.elastic import ElasticActuator, ElasticWorkAssignment
    from repro.launch.mesh import PodTopology

    n = rt.substrate.n_domains
    fleet2 = ctl.FleetActuator.from_runtime(rt, field=field)
    elastic = ElasticActuator(ElasticWorkAssignment(n))
    fan = ctl.FanoutTelemetry(fleet2)
    efan = ctl.FanoutTelemetry(elastic)
    amb2 = ctl.AmbientSensor(25.0)
    ctx = ctl.TickContext()
    pods = []
    for i, (lo, hi) in enumerate(PodTopology.partition(n, 2)):
        bus = ctl.TelemetryBus([amb2, fan.view(lo, hi, primary=(i == 0)),
                                efan.view(lo, hi)])
        pc = ctl.LutController(ctl.PodPlanner(rt.planner, lo, hi, ctx=ctx),
                               field=field.slice_chips(lo, hi))
        pods.append(ctl.PodDomain(i, lo, hi, bus, pc,
                                  ctl.PodRailChannel(fleet2, lo, hi)))
    floop = ctl.FleetLoop(pods, fleet2, elastic=elastic, ctx=ctx)
    floop.step(now=0.0)  # cold start: both pods share one memoized solve
    iters = 5
    t0 = time.perf_counter()
    for k in range(iters):
        amb2.trace = 25.0 + 0.1 * (k + 1)
        floop.step(now=1.0 + k)
    out["fleet_tick_us"] = (time.perf_counter() - t0) / iters * 1e6

    # pod failover: the quarantine actuation end to end — drop staged rail
    # writes and pin the slice to safe state, condemn the pod's chips onto
    # the survivors, drain the pod engine's active slots + queue to the
    # shared host page pool and resubmit round-robin.  Deterministic work
    # (page-exact gathers dominate), so the --check gate pins it.
    from repro.serve.cache import HostPagePool
    pool = HostPagePool()
    for pod in pods[:2]:
        pod.engine = Engine(model, params, batch_slots=2, max_len=64,
                            eos_id=-1, warmup=False, pool=pool)
    for rid in range(4):
        pods[1].engine.submit(
            Request(100 + rid, np.arange(6) % cfg.vocab_size, max_new=48))
    pods[1].engine.step()  # two active mid-decode, two queued
    lat = []
    for k in range(-1, 3 if quick else 8):  # round -1: untimed compile
        t0 = time.perf_counter()
        floop._quarantine(pods[1], now=11.0 + k, events=[])
        if k >= 0:
            lat.append(time.perf_counter() - t0)
        # untimed: undo for the next round (restore shares + rail pins,
        # hand the migrated requests back to the victim pod)
        floop._restore(pods[1], now=11.5 + k, events=[])
        back = pods[0].engine.drain()
        for req in back:
            pods[1].engine.submit(req)
        pods[1].engine.step()
    out["pod_failover_ms"] = float(np.mean(lat)) * 1e3
    return out


REGRESSION_FACTOR = 2.0  # --check fails past this ratio (CI machine slack)

# throughput/rate entries gate in the OPPOSITE direction: current must not
# fall below baseline / REGRESSION_FACTOR (the serving acceptance floor —
# e.g. a paged-path tokens/s collapse or a dead speculative accept rate)
LOWER_BOUND_KEYS = ("serve_tokens_per_s", "spec_decode_accept_rate")


def _gated(k: str) -> bool:
    """jnp-path ``*_us`` entries plus the warm RailField build are gated;
    interpret-mode and load-dependent latency entries are not."""
    if k == "railfield_build_ms":  # warm device-call-bound: stable
        return True
    if k == "pod_failover_ms":  # deterministic containment actuation
        return True
    if k == "mean_ticks_to_recover":  # deterministic chaos-day replay:
        return True                   # a drift here is a logic change
    if k in LOWER_BOUND_KEYS:
        return True
    return k.endswith("_us") and "interpret" not in k


def check_regressions(baseline: Dict, current: Dict,
                      factor: float = REGRESSION_FACTOR):
    """Compare gated entries against the committed baseline.

    Interpret-mode entries are structural (the CPU interpreter's wall time
    says nothing about TPU perf) and throughput/latency entries of the
    closed-loop benchmark are load-dependent; the stable regression signal
    is the jnp-reference kernel + solver timings, plus the warm RailField
    build and fast-path lookup (``railfield_build_ms`` /
    ``railfield_lookup_us``).  ``LOWER_BOUND_KEYS`` (paged-path serving
    throughput, speculative accept rate) gate downward instead: they fail
    when the current value drops below ``baseline / factor``. Returns
    offending
    ``(key, baseline, current)`` rows and the baseline keys absent from
    the current results (a missing key would otherwise silently disable
    its gate — the caller must treat it as a failure)."""
    bad, missing = [], []
    for k in sorted(baseline):
        if not _gated(k):
            continue
        if k not in current:
            missing.append(k)
        elif k in LOWER_BOUND_KEYS:
            if current[k] < baseline[k] / factor:
                bad.append((k, baseline[k], current[k]))
        elif current[k] > baseline[k] * factor:
            bad.append((k, baseline[k], current[k]))
    return bad, missing


def main(argv=None) -> None:
    """CI smoke entry: ``python benchmarks/kernels_bench.py --smoke``."""
    import argparse
    import json
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes; assert every kernel runs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump results as JSON (the CI artifact); with "
                         "--check (and no --smoke), an existing file here "
                         "is reused as the current numbers")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="fail on >2x regression of any jnp-path *_us "
                         "entry vs this baseline JSON (BENCH_kernels.json)")
    args = ap.parse_args(argv)

    if (args.check and not args.smoke and args.json
            and os.path.exists(args.json)):
        with open(args.json) as f:  # reuse the artifact just benchmarked
            res = json.load(f)
    else:
        # the committed baseline is produced by --smoke, so a --check run
        # must measure smoke shapes too (full shapes are 4-5x slower and
        # would trip the gate spuriously)
        smoke = args.smoke or bool(args.check)
        res = run(quick=smoke)
        if smoke:
            res.update(closed_loop(quick=True))
        for k, v in res.items():
            print(f"{k},{v:.4g}" if v < 100 else f"{k},{v:.0f}")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(res, f, indent=2, sort_keys=True)
            print(f"[json] wrote {args.json}")
        assert all(v > 0 for v in res.values())

    if args.check:
        with open(args.check) as f:
            baseline = json.load(f)
        bad, missing = check_regressions(baseline, res)
        for k, b, c in bad:
            print(f"[check] REGRESSION {k}: {b:.1f} -> {c:.1f} us "
                  f"({c / b:.2f}x)")
        for k in missing:
            print(f"[check] MISSING {k}: in {args.check} but not in the "
                  f"current results (rename it in both, or refresh the "
                  f"baseline)")
        if bad or missing:
            sys.exit(1)
        n = sum(1 for k in baseline if _gated(k))
        print(f"[check] OK: {n} gated entries within "
              f"{REGRESSION_FACTOR}x of {args.check}")


if __name__ == "__main__":
    main()
