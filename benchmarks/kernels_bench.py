"""Kernel microbenchmarks (interpret-mode wall times are STRUCTURAL only —
the CPU interpreter executes the kernel body; TPU perf comes from the
roofline, not these numbers). Also times each kernel's jnp reference, which
IS meaningful on CPU."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, iters=3) -> float:
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def run(quick: bool = False) -> Dict:
    from repro.kernels import ops, ref as kref
    from repro.kernels.flash_attention import flash_attention
    key = jax.random.PRNGKey(0)
    out = {}

    S, D = (256, 64) if quick else (1024, 128)
    q = jax.random.normal(jax.random.fold_in(key, 1), (S, D))
    k = jax.random.normal(jax.random.fold_in(key, 2), (S, D))
    v = jax.random.normal(jax.random.fold_in(key, 3), (S, D))
    out["flash_attention_ref_us"] = _time(
        lambda a, b, c: kref.flash_attention_ref(a, b, c), q, k, v)
    out["flash_attention_interpret_us"] = _time(
        lambda a, b, c: flash_attention(a, b, c, interpret=True), q, k, v)

    b, S2, H, P, N = 1, (128 if quick else 512), 8, 32, 64
    xh = jax.random.normal(jax.random.fold_in(key, 4), (b, S2, H, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 5),
                                           (b, S2, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 6), (H,)) * 0.3)
    B = jax.random.normal(jax.random.fold_in(key, 7), (b, S2, H, N)) * 0.3
    Cm = jax.random.normal(jax.random.fold_in(key, 8), (b, S2, H, N)) * 0.3
    out["mamba_scan_ref_us"] = _time(
        lambda *a: kref.mamba_scan_ref(*a, 64)[0], xh, dt, A, B, Cm)
    out["mamba_scan_interpret_us"] = _time(
        lambda *a: ops.mamba_scan_b(*a, chunk=64), xh, dt, A, B, Cm)

    m = 92
    from repro.core.thermal import ThermalConfig, conductances
    tc = ThermalConfig(theta_ja=12.0)
    g_v, g_lat = conductances(m, m, tc)
    T = jnp.full((m, m), 30.0)
    Pw = jax.random.uniform(jax.random.fold_in(key, 9), (m, m)) * 5e-3
    nbrc = jnp.full((m, m), 4.0).at[0, :].add(-1).at[-1, :].add(-1) \
        .at[:, 0].add(-1).at[:, -1].add(-1)
    diag = g_v + g_lat * nbrc
    out["thermal_stencil_ref_us"] = _time(
        lambda *a: kref.thermal_stencil_ref(*a, 64), T, Pw, diag, g_lat,
        g_v * 25.0)
    out["thermal_stencil_interpret_us"] = _time(
        lambda t, p, d: ops.thermal_sweep(t, p, d, g_lat=g_lat,
                                          g_v_tamb=g_v * 25.0, iters=64),
        T, Pw, diag)

    M = 128 if quick else 256
    a8 = jax.random.randint(jax.random.fold_in(key, 10), (M, M), -128, 127,
                            jnp.int8)
    b8 = jax.random.randint(jax.random.fold_in(key, 11), (M, M), -128, 127,
                            jnp.int8)
    ug = jax.random.bits(jax.random.fold_in(key, 12), (M, M), jnp.uint32)
    ub = jax.random.bits(jax.random.fold_in(key, 13), (M, M), jnp.uint32)
    from repro.kernels.overscale_matmul import bit_probs_to_cdf
    probs = np.zeros(32)
    probs[28:] = 0.01
    cdf = bit_probs_to_cdf(probs)
    out["overscale_matmul_ref_us"] = _time(
        kref.overscale_matmul_ref, a8, b8, ug, ub, cdf)
    out["overscale_matmul_interpret_us"] = _time(
        lambda *a: ops.overscale_mm(*a), a8, b8, ug, ub, cdf)
    return out


def main(argv=None) -> None:
    """CI smoke entry: ``python benchmarks/kernels_bench.py --smoke``."""
    import argparse
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes; assert every kernel runs")
    args = ap.parse_args(argv)
    res = run(quick=args.smoke)
    for k, v in res.items():
        print(f"{k},{v:.0f}")
    assert all(v > 0 for v in res.values())


if __name__ == "__main__":
    main()
