"""Benchmark harness master: one entry per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV per the repo convention and writes
the full structured results to artifacts/bench_results.json.

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import kernels_bench, paper_figs, roofline  # noqa: E402

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args, _ = ap.parse_known_args()
    quick = args.quick

    benches = [
        ("fig2_characterization", paper_figs.fig2_characterization),
        ("fig3_activity", paper_figs.fig3_activity),
        ("table2_casestudy", paper_figs.table2_casestudy),
        ("fig6_power", paper_figs.fig6_power),
        ("fig7_energy", paper_figs.fig7_energy),
        ("fig8_overscaling", paper_figs.fig8_overscaling),
        ("tpu_runtime", paper_figs.tpu_runtime_bench),
        ("dynamic_lut", paper_figs.dynamic_lut_bench),
        ("kernels", kernels_bench.run),
        ("roofline", roofline.run),
    ]
    os.makedirs(ART, exist_ok=True)
    results = {}
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            res = fn(quick=quick)
            ok = True
        except Exception as e:  # noqa
            res = {"error": f"{type(e).__name__}: {e}"}
            ok = False
        us = (time.time() - t0) * 1e6
        results[name] = res
        derived = _headline(name, res) if ok else res["error"]
        print(f"{name},{us:.0f},{derived}")

    with open(os.path.join(ART, "bench_results.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    print(f"# wrote {os.path.join(ART, 'bench_results.json')}")


def _headline(name: str, res) -> str:
    try:
        if name == "fig2_characterization":
            return (f"sb40C={res['sb_delay_40C_over_100C']:.3f}(0.85) "
                    f"sbP={res['sb_power_ratio_0.68V']:.2f}(0.68)")
        if name == "fig3_activity":
            return f"a_int(1.0)={res['alpha_internal'][-1]}(0.27)"
        if name == "table2_casestudy":
            f_ = res["iters"][-1]
            return (f"final=({f_['v_core']:.2f},{f_['v_bram']:.2f})"
                    f"{f_['power_mw']}mW(paper (0.75,0.91)564mW)")
        if name == "fig6_power":
            return (f"avg40C={res['avg_saving_40C_alpha1']*100:.1f}%"
                    f"(28.3-36.0) avg65C={res['avg_saving_65C_alpha1']*100:.1f}%"
                    f"(20.0-25.0)")
        if name == "fig7_energy":
            return (f"avg={res['avg_saving']*100:.1f}%(44-66) "
                    f"freq_ratio={res['avg_freq_ratio']:.2f}(0.37)")
        if name == "fig8_overscaling":
            l135 = [r for r in res["lenet"] if r["gamma"] == 1.35]
            h135 = [r for r in res["hd"] if r["gamma"] == 1.35]
            if l135 and h135:
                return (f"g1.35: lenet {l135[0]['saving']*100:.0f}%/"
                        f"acc{l135[0]['acc']:.3f} hd {h135[0]['saving']*100:.0f}%/"
                        f"acc{h135[0]['acc']:.3f} (paper 48%/-3% 50%/-0.5%)")
            return "ok"
        if name == "tpu_runtime":
            t = res["train_compute_bound"]
            return (f"train: save={t['power_save']['saving']*100:.1f}% "
                    f"minE={t['min_energy']['saving']*100:.1f}%")
        if name == "dynamic_lut":
            return (f"match={res['match']} batch={res['wall_batch_s']}s "
                    f"seq-run={res['wall_sequential_run_s']}s "
                    f"(seed impl {res['seed_implementation_s']}s)")
        if name == "kernels":
            return f"{len(res)} timings"
        if name == "roofline":
            n = len(res["cells"])
            doms = [c["dominant"] for c in res["cells"]]
            return (f"{n} cells: {doms.count('compute')}comp/"
                    f"{doms.count('memory')}mem/{doms.count('collective')}coll")
    except Exception as e:  # noqa
        return f"headline-error {e}"
    return "ok"


if __name__ == "__main__":
    main()
