"""Roofline analysis per (arch × shape) from the compiled dry-run artifacts.

Three terms per cell (TPU v5e constants; per-chip quantities from the
post-SPMD partitioned HLO via the trip-count-aware analyzer):

    compute    = HLO_FLOPs / 197 TFLOP/s
    memory     = HLO_bytes / 819 GB/s
    collective = collective_bytes / 50 GB/s (ICI link)

plus MODEL_FLOPS (6·N_active·D train, 2·N_active·D inference), the useful-
compute ratio MODEL_FLOPS/HLO_FLOPs, the dominant bottleneck, and the
roofline fraction = ideal-compute-time / max(term) that §Perf hillclimbs.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.configs import registry
from repro.configs.base import SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
CHIPS = 256

HLO_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "hlo")
DRYRUN_JSON = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                           "dryrun.json")


def active_params(arch: str) -> float:
    """Params touched per token (MoE: shared + top-k routed only)."""
    cfg = registry.get(arch)
    from repro.models.model import Model
    total = Model(cfg).n_params()
    if not cfg.is_moe:
        return float(total)
    n_moe_layers = cfg.num_layers - cfg.first_k_dense
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed = n_moe_layers * cfg.num_experts * per_expert
    active_frac = cfg.num_experts_per_tok / cfg.num_experts
    return float(total - routed * (1.0 - active_frac))


def model_flops_per_chip(arch: str, shape_name: str) -> float:
    cfg = registry.get(arch)
    shape = SHAPES[shape_name]
    n_act = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens / CHIPS
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens / CHIPS
    # decode: one token per sequence per step
    return 2.0 * n_act * shape.global_batch / CHIPS


def analyze_cell(arch: str, shape: str, mesh: str = "pod",
                 hlo_dir: str = HLO_DIR) -> Optional[Dict]:
    path = os.path.join(hlo_dir, f"{arch}_{shape}_{mesh}.hlo")
    if not os.path.exists(path):
        return None
    from benchmarks.hlo_analysis import analyze_file
    c = analyze_file(path)
    t_comp = c.flops / PEAK_FLOPS
    t_mem = c.bytes / HBM_BW
    t_coll = c.collective_bytes / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mflops = model_flops_per_chip(arch, shape)
    ideal = mflops / PEAK_FLOPS
    frac = ideal / max(max(terms.values()), 1e-12)
    hints = {
        "compute": "cut non-model FLOPs (remat recompute, masked attention "
                   "blocks, padded heads) or raise MXU utilization",
        "memory": "fuse/convert fp32 intermediates, shrink KV/cache traffic, "
                  "better layouts (this term is a CPU-HLO upper bound)",
        "collective": "reshard to cut all-gathers (FSDP prefetch), overlap "
                      "collectives with compute, or change expert dispatch",
    }
    return {
        "arch": arch, "shape": shape, "mesh": mesh,
        "flops_hlo": c.flops, "bytes_hlo": c.bytes,
        "collective_bytes": c.collective_bytes,
        "collectives_by_type": dict(c.coll),
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mflops,
        "useful_ratio": mflops / max(c.flops, 1.0),
        "roofline_fraction": frac,
        "hint": hints[dominant],
    }


def run(quick: bool = False, hlo_dir: str = HLO_DIR,
        out_json: Optional[str] = None) -> Dict:
    cells = list(registry.all_cells())
    if quick:
        cells = cells[:4]
    rows = []
    for arch, shape in cells:
        r = analyze_cell(arch, shape, "pod", hlo_dir)
        if r:
            rows.append(r)
    out = {"cells": rows, "constants": {
        "peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW, "ici_bw": ICI_BW,
        "chips": CHIPS}}
    if out_json:
        with open(out_json, "w") as f:
            json.dump(out, f, indent=1)
    return out


def markdown_table(result: Dict) -> str:
    lines = [
        "| arch | shape | compute s | memory s | coll s | dominant | "
        "useful | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in result["cells"]:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    res = run(out_json=os.path.join(os.path.dirname(DRYRUN_JSON),
                                    "roofline.json"))
    print(markdown_table(res))
