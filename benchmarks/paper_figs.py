"""One benchmark per paper table/figure. Each returns a dict of derived
numbers and asserts the paper's headline claims (tolerance bands documented
in EXPERIMENTS.md)."""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (apps, characterization as C, energy_opt as EO,
                        netlist as NLmod, overscaling as OS, thermal,
                        voltage_scaling as VS, vtr_benchmarks as vb)

TC12 = thermal.ThermalConfig(theta_ja=12.0)
TC2 = thermal.ThermalConfig(theta_ja=2.0)


def fig2_characterization(quick=False) -> Dict:
    """Fig 2: delay/power vs (T, V) per resource — calibration anchors."""
    lib = C.default_library()
    sb, lut, bram = np.int32(C.SB), np.int32(C.LUT), np.int32(C.BRAM)
    out = {
        "sb_delay_40C_over_100C": float(lib.delay(sb, 0.8, 40.0)
                                        / lib.delay(sb, 0.8, 100.0)),
        "sb_delay_0.68V40C_over_nom100C": float(lib.delay(sb, 0.68, 40.0)
                                                / lib.delay(sb, 0.8, 100.0)),
        "lut_delay_ratio_0.68V": float(lib.delay(lut, 0.68, 40.0)
                                       / lib.delay(lut, 0.8, 40.0)),
        "sb_power_ratio_0.68V": float(
            (lib.dynamic(sb, 0.68, 0.6, 0.5) + lib.leakage(sb, 0.68, 100.0))
            / (lib.dynamic(sb, 0.80, 0.6, 0.5) + lib.leakage(sb, 0.80, 100.0))),
        "leakage_T_exponent": float(np.log(
            lib.leakage(lut, 0.8, 85.0) / lib.leakage(lut, 0.8, 25.0)) / 60.0),
        "paper": {"sb_delay_40C": 0.85, "sb_power_ratio": 0.68,
                  "leakage_exp": 0.015},
    }
    return out


def fig3_activity(quick=False) -> Dict:
    a = np.array([0.1, 0.3, 0.5, 0.7, 1.0])
    return {
        "alpha_in": a.tolist(),
        "alpha_internal": np.asarray(C.internal_activity(a)).round(4).tolist(),
        "dsp_factor": np.asarray(C.dsp_activity_factor(a)).round(4).tolist(),
        "paper": {"internal_at_0.1": 0.05, "internal_at_1.0": 0.27,
                  "dsp_rise_to_0.3": 1.37},
    }


def table2_casestudy(quick=False) -> Dict:
    """mkDelayWorker @ 60C / theta=12: the paper's iteration trace."""
    nl = vb.load("mkDelayWorker32B")
    r = VS.run(nl, 60.0, 1.0, TC12)
    lib = C.default_library()
    nlj = nl.as_jax()
    lkg25, _ = NLmod.tile_power(lib, nlj, jnp.full((nl.n_tiles,), 25.0),
                                C.V_CORE_NOM, C.V_BRAM_NOM,
                                1.0 / r.d_worst_ns, 1.0)
    return {
        "f_mhz": 1000.0 / r.d_worst_ns,
        "leakage_25C_W": float(jnp.sum(lkg25)) / 1000.0,
        "iters": [
            {"it": t.it, "v_core": t.v_core, "v_bram": t.v_bram,
             "power_mw": round(t.power_mw), "t_junct": round(t.t_junct, 2),
             "wall_s": round(t.wall_s, 2)} for t in r.trace
        ],
        "paper": {"f_mhz": 71.6, "leakage_25C_W": 0.367,
                  "iter1": (0.74, 0.92, 485, 65.82),
                  "final": (0.75, 0.91, 564, 66.77)},
    }


def fig6_power(quick=False) -> Dict:
    """Power savings @ (40C, theta12) and (65C, theta2), activity range."""
    names = (["mkPktMerge", "or1200", "boundtop"] if quick
             else [b.name for b in vb.BENCHES])
    out: Dict = {"benchmarks": {}}
    for tamb, tc in ((40.0, TC12), (65.0, TC2)):
        savings_hi, savings_lo = [], []
        for n in names:
            nl = vb.load(n)
            r = VS.run(nl, tamb, 1.0, tc)
            # low-activity end of the band: same voltages, alpha=0.1 power
            lib = C.default_library()
            nlj = nl.as_jax()
            T = jnp.full((nl.n_tiles,), r.t_junct_mean)
            f = 1.0 / r.d_worst_ns
            lk, dy = NLmod.tile_power(lib, nlj, T, r.v_core, r.v_bram, f, 0.1)
            lkb, dyb = NLmod.tile_power(lib, nlj, T, C.V_CORE_NOM,
                                        C.V_BRAM_NOM, f, 0.1)
            s_lo = 1.0 - float(jnp.sum(lk + dy)) / float(jnp.sum(lkb + dyb))
            savings_hi.append(r.saving)
            savings_lo.append(s_lo)
            out["benchmarks"].setdefault(n, {})[f"{tamb:.0f}C"] = {
                "v_core": r.v_core, "v_bram": r.v_bram,
                "saving_alpha1": round(r.saving, 4),
                "saving_alpha0.1": round(s_lo, 4),
                "iters": len(r.trace),
            }
        out[f"avg_saving_{tamb:.0f}C_alpha1"] = float(np.mean(savings_hi))
        out[f"avg_saving_{tamb:.0f}C_alpha0.1"] = float(np.mean(savings_lo))
    out["paper"] = {"40C": (0.283, 0.360), "65C": (0.200, 0.250)}
    return out


def fig7_energy(quick=False) -> Dict:
    """Energy-optimization flow @ 65C: savings, voltages, frequency ratio."""
    names = (["mkPktMerge", "or1200"] if quick
             else [b.name for b in vb.BENCHES])
    res = {}
    savs, fratios = [], []
    for n in names:
        r = EO.run(vb.load(n), 65.0, 1.0, TC2)
        res[n] = {"v_core": r.v_core, "v_bram": r.v_bram,
                  "saving": round(r.saving, 4),
                  "freq_ratio": round(r.freq_ratio, 3),
                  "refined": r.n_refined,
                  "wall_s": round(r.wall_s, 1),
                  "wall_full_est_s": round(r.wall_full_est_s, 1)}
        savs.append(r.saving)
        fratios.append(r.freq_ratio)
    return {"benchmarks": res, "avg_saving": float(np.mean(savs)),
            "avg_freq_ratio": float(np.mean(fratios)),
            "paper": {"saving_range": (0.44, 0.66), "avg_freq_ratio": 0.37,
                      "speedup_narrative": "72min -> 49s via pruning"}}


def fig8_overscaling(quick=False) -> Dict:
    """Voltage over-scaling: power saving + accuracy for LeNet & HD."""
    key = jax.random.PRNGKey(42)
    p, _ = apps.lenet_train(key, steps=200 if quick else 500)
    hd = apps.hd_train(key)
    gammas = [1.0, 1.2, 1.35] if quick else [1.0, 1.1, 1.2, 1.3, 1.35, 1.4]
    out: Dict = {"lenet": [], "hd": [],
                 "clean": {"lenet": apps.lenet_accuracy(p, key),
                           "hd": apps.hd_accuracy(hd, key)}}
    for stats, label in ((apps.LENET_STATS, "lenet"), (apps.HD_STATS, "hd")):
        nl = NLmod.generate(stats)
        # the whole gamma schedule is one batched policy solve
        for r in OS.sweep(nl, gammas, t_amb=40.0, tc=TC12):
            g = float(r.gamma)
            bp = apps.scale_bit_probs(r.bit_probs)
            acc = (apps.lenet_accuracy(p, key, bit_probs=bp)
                   if label == "lenet"
                   else apps.hd_accuracy(hd, key,
                                         flip_prob=apps.hd_flip_prob(
                                             r.bit_probs)))
            out[label].append({"gamma": g, "saving": round(r.saving, 4),
                               "v_core": r.v_core, "v_bram": r.v_bram,
                               "acc": round(acc, 4)})
    out["paper"] = {"gamma1_saving": 0.34, "gamma135": {
        "lenet": (0.48, -0.03), "hd": (0.50, -0.005)}}
    return out


def tpu_runtime_bench(quick=False) -> Dict:
    """TPU-fleet adaptation: per-policy pod savings for three workload mixes."""
    from repro import policy as pol
    from repro.core import runtime as RT, tpu_fleet as TF
    mixes = {
        "train_compute_bound": (0.8, 0.35, 0.15),
        "decode_memory_bound": (0.15, 0.7, 0.1),
        "moe_collective_bound": (0.45, 0.3, 0.5),
    }
    policies = {"power_save": pol.PowerSave(), "min_energy": pol.MinEnergy(),
                "overscale:1.2": pol.Overscale(gamma=1.2)}
    out: Dict = {}
    for name, (c, m, i) in mixes.items():
        prof = TF.StepProfile.from_roofline(c, m, i)
        row = {}
        for label, p in policies.items():
            plan = RT.EnergyAwareRuntime(prof, policy=p).plan()
            row[label] = {"saving": round(plan.saving, 4),
                          "t_max": round(plan.t_max, 1),
                          "step_s": round(plan.step_s, 4)}
        out[name] = row
    return out


def dynamic_lut_bench(quick=False) -> Dict:
    """§III-B dynamic scheme: batched LUT build vs sequential run() calls.

    The acceptance check of the repro.policy refactor: solve_batch over the
    ambient sweep must reproduce the sequential table exactly, in one
    compiled device call.  Both paths are timed warm; on a single CPU core
    they are work-bound and land near parity (the batch's win there is
    compile/dispatch amortization and accelerator vectorization) — the
    end-to-end speedup vs the seed implementation (eager Python loop per
    ambient, 5.35 s for this table) is ~10x either way."""
    import time as _t

    from repro.core import voltage_scaling as VS

    nl = vb.load("mkPktMerge")
    t_ambs = [10.0, 20.0, 30.0, 40.0, 50.0, 60.0, 70.0, 80.0]

    t0 = _t.time()
    lut_batch = VS.dynamic_lut(nl, t_ambs, tc=TC2)
    wall_batch_cold = _t.time() - t0
    t0 = _t.time()
    lut_batch = VS.dynamic_lut(nl, t_ambs, tc=TC2)
    wall_batch = _t.time() - t0

    VS.run(nl, t_ambs[0], 1.0, TC2)  # warm the sequential path too:
    t0 = _t.time()                   # compare execution, not tracing
    seq = [VS.run(nl, t, 1.0, TC2) for t in t_ambs]
    wall_seq = _t.time() - t0
    lut_seq = {t: (r.v_core, r.v_bram) for t, r in zip(t_ambs, seq)}

    return {
        "n_ambients": len(t_ambs),
        "lut": {f"{k:.0f}": v for k, v in lut_batch.items()},
        "match": all(lut_batch[t] == lut_seq[t] for t in t_ambs),
        "wall_batch_cold_s": round(wall_batch_cold, 3),
        "wall_batch_s": round(wall_batch, 3),
        "wall_sequential_run_s": round(wall_seq, 3),
        "speedup_vs_sequential": round(wall_seq / max(wall_batch, 1e-9), 2),
        "seed_implementation_s": 5.35,  # measured pre-refactor, same table
    }
